//! End-to-end validation driver (DESIGN.md §6, paper Fig. 1):
//!
//!   train a transformer LM from scratch through the AOT train step →
//!   log the loss curve → SWSC-compress Q&K at 3 and 2 avg-bits →
//!   RTN-quantize at the same budgets → evaluate perplexity for every
//!   variant → print the Table-I-shaped report.
//!
//! Uses the `small` preset (≈4.8 M params). Control the training length
//! with SWSC_E2E_STEPS (default 200; the recorded EXPERIMENTS.md run used
//! the 400-step checkpoint from `swsc train`). Requires `make artifacts`.

use std::path::Path;
use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::compress_model;
use swsc::eval::Evaluator;
use swsc::model::{init_params, ModelConfig};
use swsc::quant::{rtn_quantize, RtnConfig};
use swsc::report::{render_table1, Table1Row};
use swsc::runtime::{ArtifactManifest, Engine};
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus};
use swsc::train::{LrSchedule, Trainer};

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.txt").exists(), "run `make artifacts` first");
    let steps: usize =
        std::env::var("SWSC_E2E_STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(200);

    let cfg = ModelConfig::small();
    let man = ArtifactManifest::load(dir, "small")?;
    let engine = Engine::new(man)?;
    println!("== SWSC end-to-end pipeline ==");
    println!("model: {} ({} params)", cfg.fingerprint(), cfg.param_count());

    // --- data -----------------------------------------------------------
    let corpus = SyntheticCorpus::generate(&CorpusConfig { seed: 42, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, cfg.vocab);
    let train_data = Dataset::from_text(&corpus.train_text, &tok, cfg.batch, cfg.seq);
    let eval_data = Dataset::from_text(&corpus.eval_text, &tok, cfg.batch, cfg.seq);
    println!("corpus: {} train / {} eval tokens", train_data.tokens(), eval_data.tokens());

    // --- train (or reuse the CLI run's checkpoint) -----------------------
    let ck = if Path::new("runs/default/model.swck").exists() {
        println!("\n[1/3] reusing trained checkpoint runs/default/model.swck");
        swsc::io::Checkpoint::load(Path::new("runs/default/model.swck"))?
    } else {
        println!("\n[1/3] training {steps} steps (set SWSC_E2E_STEPS to change)");
        let mut trainer = Trainer::new(engine.clone(), cfg.clone(), &init_params(&cfg, 42))?;
        let sched = LrSchedule::new(3e-4, steps / 20 + 1, steps);
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let loss = trainer.step(&train_data.batch(step), sched.at(step))?;
            if step % 25 == 0 || step + 1 == steps {
                println!("  step {step:>4}  loss {loss:.4}  ({:.1}s)", t0.elapsed().as_secs_f64());
            }
        }
        trainer.to_checkpoint()?
    };

    // --- evaluate variants ------------------------------------------------
    println!("\n[2/3] compressing and evaluating variants");
    let evaluator = Evaluator::new(engine, cfg.clone())?;
    let fp32 = evaluator.perplexity_of(&ck, &eval_data)?.perplexity;
    println!("  fp32 baseline ppl: {fp32:.3}");

    let mut rows = Vec::new();
    for proj in [ProjectorSet::Q, ProjectorSet::K, ProjectorSet::QAndK] {
        for bits in [3.0f64, 2.0] {
            let mut qck = ck.clone();
            let rtn_cfg = RtnConfig { bits: bits as u32, ..Default::default() };
            for (name, _) in ck.shapes() {
                if proj.matches(&name) {
                    let q = rtn_quantize(qck.get(&name).unwrap(), &rtn_cfg);
                    qck.insert(&name, q);
                }
            }
            let rtn_ppl = evaluator.perplexity_of(&qck, &eval_data)?.perplexity;

            let plan = CompressionPlan::for_target_bits(&ck.shapes(), proj, bits, 0.5, 42);
            let out = compress_model(&ck, &plan, 8, None)?;
            let mut sck = ck.clone();
            for (name, t) in out.file.restore_all() {
                sck.insert(&name, t);
            }
            let swsc_ppl = evaluator.perplexity_of(&sck, &eval_data)?.perplexity;
            println!(
                "  {:<5} @ {bits} bits:  RTN {rtn_ppl:>12.3}   SWSC {swsc_ppl:>10.3}   (compressed {} matrices in {:.2}s)",
                proj.label(), plan.len(), out.wall_seconds
            );
            for (method, ppl) in [("RTN", rtn_ppl), ("SWSC", swsc_ppl)] {
                rows.push(Table1Row {
                    projector: proj.label().into(),
                    method: method.into(),
                    avg_bits: bits,
                    perplexity: ppl,
                });
            }
        }
    }

    println!("\n[3/3] report\n");
    println!("{}", render_table1("e2e pipeline (synthetic tiny-wiki)", fp32, &rows));
    Ok(())
}
