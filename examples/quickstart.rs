//! Quickstart: compress one weight matrix with SWSC and inspect the
//! storage/quality trade — no artifacts or training required.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use swsc::compress::{compress_matrix, matrix_stats, SwscConfig};
use swsc::quant::bits::swsc_params_for_bits;
use swsc::quant::{rtn_quantize, RtnConfig};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn main() {
    // A 256x256 "attention projector" whose channels cluster into 20
    // groups — the structure trained LLM projectors exhibit and SWSC
    // exploits.
    let m = 256;
    let mut rng = Rng::new(2024);
    let groups = 20;
    let centers: Vec<Vec<f32>> =
        (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let mut w = Tensor::zeros(&[m, m]);
    for j in 0..m {
        let col: Vec<f32> =
            centers[j % groups].iter().map(|&v| v + rng.normal_f32(0.0, 0.2)).collect();
        w.set_col(j, &col);
    }

    println!("SWSC quickstart — one {m}x{m} matrix\n");
    println!("step 1: pick (k, r) for a 2-bit storage budget");
    let (k, r) = swsc_params_for_bits(m, 2.0, 0.5);
    println!("  -> k = {k} clusters, rank r = {r}\n");

    println!("step 2: cluster channels, share representatives, compensate error");
    let compressed = compress_matrix(&w, &SwscConfig::new(k, r));
    let stats = matrix_stats("demo.wq", &w, &compressed);
    println!("  {stats}\n");

    println!("step 3: storage accounting (paper Table II math)");
    let bits = compressed.bits();
    println!("  centroids: {} bits", bits.centroid_bits);
    println!("  labels:    {} bits", bits.label_bits);
    println!("  factors:   {} bits", bits.factor_bits);
    println!("  avg bits/weight: {:.4}  (compression {:.1}x vs fp16)\n",
        bits.avg_bits, compressed.compression_ratio());

    println!("step 4: compare against RTN at the same budget");
    let rtn = rtn_quantize(&w, &RtnConfig { bits: 2, ..Default::default() });
    println!("  SWSC mse: {:.4e}", compressed.reconstruct().mse(&w));
    println!("  RTN  mse: {:.4e}", w.mse(&rtn));
    println!("\nrestored weight W_new = W' + A·B is ready for inference.");
}
