//! §IV-B ablation: the paper compresses Q and K but *not* V, arguing the
//! value projector "stores the specific features of the model and has a
//! higher requirement for accuracy". This example tests that claim
//! directly: compress each projector alone at the same 2-bit budget and
//! compare perplexity damage. Requires `make artifacts` (tiny preset; uses
//! a short training run so the weights carry real signal).

use std::path::Path;
use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::compress_model;
use swsc::eval::Evaluator;
use swsc::model::{init_params, ModelConfig};
use swsc::runtime::{ArtifactManifest, Engine};
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus};
use swsc::train::{LrSchedule, Trainer};

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.txt").exists(), "run `make artifacts` first");
    let cfg = ModelConfig::tiny();
    let man = ArtifactManifest::load(dir, "tiny")?;
    let engine = Engine::new(man)?;

    let corpus = SyntheticCorpus::generate(&CorpusConfig { seed: 5, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, cfg.vocab);
    let train_data = Dataset::from_text(&corpus.train_text, &tok, cfg.batch, cfg.seq);
    let eval_data = Dataset::from_text(&corpus.eval_text, &tok, cfg.batch, cfg.seq);

    let steps = 150;
    println!("training tiny model {steps} steps for the ablation...");
    let mut trainer = Trainer::new(engine.clone(), cfg.clone(), &init_params(&cfg, 5))?;
    let sched = LrSchedule::new(3e-3, 10, steps);
    for step in 0..steps {
        trainer.step(&train_data.batch(step), sched.at(step))?;
    }
    let ck = trainer.to_checkpoint()?;

    let evaluator = Evaluator::new(engine, cfg.clone())?;
    let fp32 = evaluator.perplexity_of(&ck, &eval_data)?.perplexity;
    println!("fp32 baseline ppl: {fp32:.3}\n");

    println!("| projector | ppl @2bits | damage (x fp32) |");
    println!("|-----------|------------|-----------------|");
    let mut damages = Vec::new();
    for proj in [ProjectorSet::Q, ProjectorSet::K, ProjectorSet::V] {
        let plan = CompressionPlan::for_target_bits(&ck.shapes(), proj, 2.0, 0.5, 5);
        let out = compress_model(&ck, &plan, 4, None)?;
        let mut sck = ck.clone();
        for (name, t) in out.file.restore_all() {
            sck.insert(&name, t);
        }
        let ppl = evaluator.perplexity_of(&sck, &eval_data)?.perplexity;
        let damage = ppl / fp32;
        println!("| {:<9} | {ppl:<10.3} | {damage:<15.3} |", proj.label());
        damages.push((proj.label(), damage));
    }

    let v_damage = damages.iter().find(|(l, _)| *l == "V").unwrap().1;
    let qk_max =
        damages.iter().filter(|(l, _)| *l != "V").map(|(_, d)| *d).fold(0.0f64, f64::max);
    println!();
    if v_damage > qk_max {
        println!(
            "paper's §IV-B claim holds here: V compression hurts {v_damage:.2}x vs worst of Q/K {qk_max:.2}x"
        );
    } else {
        println!(
            "note: at this scale V damage ({v_damage:.2}x) did not exceed Q/K ({qk_max:.2}x) — \
             the paper's claim is about 7B-scale models; see EXPERIMENTS.md discussion"
        );
    }
    Ok(())
}
