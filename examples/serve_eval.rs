//! Batched evaluation service demo (the L3 serving path).
//!
//! Spins up the [`EvalService`] over the tiny preset, fires concurrent
//! requests from several client threads, and reports latency/throughput +
//! batcher metrics — showing the dynamic batching and backpressure the
//! coordinator provides. Requires `make artifacts`.

use std::path::Path;
use std::sync::Arc;
use swsc::coordinator::{EvalRequest, EvalService, ServiceConfig};
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::runtime::ArtifactManifest;
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus};
use swsc::util::timer::Stats;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    anyhow::ensure!(dir.join("manifest.txt").exists(), "run `make artifacts` first");

    let cfg = ModelConfig::tiny();
    let man = ArtifactManifest::load(dir, "tiny")?;

    // Model: fresh init (the demo is about the serving machinery).
    let ck = init_params(&cfg, 9);
    let host_params: Vec<swsc::tensor::Tensor> =
        param_specs(&cfg).iter().map(|s| ck.get(&s.name).unwrap().clone()).collect();

    // Token windows from the synthetic corpus.
    let corpus = SyntheticCorpus::generate(&CorpusConfig { articles: 20, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, cfg.vocab);
    let data = Dataset::from_text(&corpus.eval_text, &tok, 1, cfg.seq);

    println!("starting eval service (batch={}, seq={})...", cfg.batch, cfg.seq);
    let service = Arc::new(EvalService::start(
        man,
        cfg.clone(),
        host_params,
        ServiceConfig { queue_capacity: 64, ..Default::default() },
    )?);

    let clients = 4;
    let per_client = 24;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let service = service.clone();
        let data = data.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Stats> {
            let mut lat = Stats::new();
            for i in 0..per_client {
                let b = data.batch(c * per_client + i);
                let mut window = b.inputs.clone();
                window.push(b.targets[cfg.seq - 1]);
                let t = std::time::Instant::now();
                let resp = service.eval_blocking(EvalRequest { tokens: window })?;
                lat.push(t.elapsed().as_secs_f64());
                anyhow::ensure!(resp.tokens == cfg.seq);
            }
            Ok(lat)
        }));
    }

    let mut all = Stats::new();
    for h in handles {
        let lat = h.join().unwrap()?;
        for _ in 0..lat.count() {} // merged below via summary prints
        println!(
            "client done: mean {:.2} ms  p50 {:.2} ms  max {:.2} ms",
            lat.mean() * 1e3,
            lat.percentile(50.0) * 1e3,
            lat.max() * 1e3
        );
        all.push(lat.mean());
    }
    let wall = t0.elapsed().as_secs_f64();
    let total = clients * per_client;
    println!("\n{total} requests in {wall:.2}s -> {:.1} req/s", total as f64 / wall);
    println!("\nbatcher metrics:\n{}", service.metrics.render());

    let batches = service.metrics.counter("service.batches");
    println!(
        "batching efficiency: {total} requests in {batches} executions ({:.1} req/batch of max {})",
        total as f64 / batches.max(1) as f64,
        cfg.batch
    );
    Arc::try_unwrap(service).ok().map(|s| s.shutdown());
    Ok(())
}
