"""L2 model tests: shapes, causality, loss sanity, Adam step behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile.configs import PRESETS, param_specs
from compile.model import (
    example_params,
    forward,
    make_fwd_eval,
    make_train_step,
    split_params,
)

CFG = PRESETS["tiny"]


def toks(seed=0):
    r = np.random.default_rng(seed)
    t = jnp.asarray(r.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq)), jnp.int32)
    u = jnp.asarray(r.integers(0, CFG.vocab, size=(CFG.batch, CFG.seq)), jnp.int32)
    return t, u


class TestForward:
    def test_logit_shape(self):
        flat = example_params(CFG)
        params = split_params(CFG, flat)
        t, _ = toks()
        logits = forward(CFG, params, t)
        assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_causality(self):
        """Changing a future token must not change past logits."""
        flat = example_params(CFG)
        params = split_params(CFG, flat)
        t, _ = toks()
        logits_a = forward(CFG, params, t)
        t2 = t.at[:, -1].set((t[:, -1] + 1) % CFG.vocab)
        logits_b = forward(CFG, params, t2)
        assert_allclose(
            np.asarray(logits_a[:, :-1]), np.asarray(logits_b[:, :-1]), rtol=1e-5, atol=1e-5
        )
        assert not np.allclose(np.asarray(logits_a[:, -1]), np.asarray(logits_b[:, -1]))


class TestFwdEval:
    def test_output_shapes_and_uniform_baseline(self):
        fwd_eval = make_fwd_eval(CFG)
        flat = example_params(CFG)
        t, u = toks()
        nll, cnt = fwd_eval(*flat, t, u)
        assert nll.shape == (CFG.batch,)
        assert cnt.shape == (CFG.batch,)
        assert_allclose(np.asarray(cnt), float(CFG.seq))
        # Near-random init ⇒ per-token NLL ≈ log(vocab).
        per_tok = float(jnp.sum(nll) / jnp.sum(cnt))
        assert abs(per_tok - np.log(CFG.vocab)) < 0.5, per_tok


class TestTrainStep:
    def test_loss_decreases_on_repeated_batch(self):
        step_fn = jax.jit(make_train_step(CFG))
        n = len(param_specs(CFG))
        flat = example_params(CFG)
        m = [jnp.zeros_like(p) for p in flat]
        v = [jnp.zeros_like(p) for p in flat]
        t, u = toks()
        losses = []
        for s in range(8):
            out = step_fn(*flat, *m, *v, jnp.float32(s), jnp.float32(1e-2), t, u)
            flat, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
            losses.append(float(out[-1]))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_arity(self):
        step_fn = make_train_step(CFG)
        n = len(param_specs(CFG))
        flat = example_params(CFG)
        zeros = [jnp.zeros_like(p) for p in flat]
        t, u = toks()
        out = step_fn(*flat, *zeros, *zeros, jnp.float32(0), jnp.float32(1e-3), t, u)
        assert len(out) == 3 * n + 1

    def test_zero_lr_keeps_params(self):
        step_fn = make_train_step(CFG)
        n = len(param_specs(CFG))
        flat = example_params(CFG)
        zeros = [jnp.zeros_like(p) for p in flat]
        t, u = toks()
        out = step_fn(*flat, *zeros, *zeros, jnp.float32(0), jnp.float32(0.0), t, u)
        for p_new, p_old in zip(out[:n], flat):
            assert_allclose(np.asarray(p_new), np.asarray(p_old), rtol=1e-6, atol=1e-7)


class TestParamSpecs:
    def test_counts(self):
        for name, cfg in PRESETS.items():
            specs = param_specs(cfg)
            assert len(specs) == 2 + cfg.n_layers * 12 + 2, name

    def test_fingerprints_unique(self):
        fps = {cfg.fingerprint() for cfg in PRESETS.values()}
        assert len(fps) == len(PRESETS)
