"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Every kernel is compared against its ref.py oracle with assert_allclose,
plus hypothesis sweeps over shapes / cluster counts / ranks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.kmeans import centroid_update, kmeans_assign, kmeans_step
from compile.kernels.matmul import decode_matmul
from compile.kernels.reconstruct import swsc_reconstruct
from compile.kernels.rtn import rtn_quantize

RNG = np.random.default_rng(0)


def rand(*shape):
    return jnp.asarray(RNG.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------- kmeans


class TestKmeansAssign:
    def test_matches_ref_basic(self):
        pts, cen = rand(64, 32), rand(8, 32)
        lab, d2 = kmeans_assign(pts, cen)
        rlab, rd2 = ref.kmeans_assign_ref(pts, cen)
        assert_allclose(np.asarray(lab), np.asarray(rlab))
        assert_allclose(np.asarray(d2), np.asarray(rd2), rtol=1e-4, atol=1e-4)

    def test_obvious_nearest(self):
        pts = jnp.array([[0.0, 0.0], [10.0, 10.0]], jnp.float32)
        cen = jnp.array([[0.1, 0.1], [9.9, 9.9]], jnp.float32)
        lab, _ = kmeans_assign(pts, cen)
        assert lab.tolist() == [0, 1]

    def test_labels_in_range(self):
        pts, cen = rand(128, 16), rand(5, 16)
        lab, _ = kmeans_assign(pts, cen)
        assert int(lab.min()) >= 0 and int(lab.max()) < 5

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([32, 64, 96, 128]),
        m=st.sampled_from([8, 16, 64, 256]),
        k=st.integers(2, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, n, m, k, seed):
        r = np.random.default_rng(seed)
        pts = jnp.asarray(r.normal(size=(n, m)), jnp.float32)
        cen = jnp.asarray(r.normal(size=(k, m)), jnp.float32)
        lab, d2 = kmeans_assign(pts, cen)
        rlab, rd2 = ref.kmeans_assign_ref(pts, cen)
        # Ties can resolve differently; compare via distances.
        assert_allclose(np.asarray(d2), np.asarray(rd2), rtol=1e-3, atol=1e-3)
        assert (np.asarray(lab) == np.asarray(rlab)).mean() > 0.99


class TestCentroidUpdate:
    def test_matches_ref(self):
        pts = rand(96, 24)
        lab = jnp.asarray(RNG.integers(0, 6, size=96), jnp.int32)
        sums, counts = centroid_update(pts, lab, 6)
        rsums, rcounts = ref.centroid_update_ref(pts, lab, 6)
        assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-4, atol=1e-4)
        assert_allclose(np.asarray(counts), np.asarray(rcounts))

    def test_counts_sum_to_n(self):
        pts = rand(64, 8)
        lab = jnp.asarray(RNG.integers(0, 4, size=64), jnp.int32)
        _, counts = centroid_update(pts, lab, 4)
        assert float(counts.sum()) == 64.0

    def test_empty_cluster_zero(self):
        pts = rand(32, 4)
        lab = jnp.zeros(32, jnp.int32)  # everything in cluster 0
        sums, counts = centroid_update(pts, lab, 3)
        assert float(counts[1]) == 0.0 and float(counts[2]) == 0.0
        assert_allclose(np.asarray(sums[1]), 0.0)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.sampled_from([32, 64, 128]),
        m=st.sampled_from([4, 16, 32]),
        k=st.integers(1, 12),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, n, m, k, seed):
        r = np.random.default_rng(seed)
        pts = jnp.asarray(r.normal(size=(n, m)), jnp.float32)
        lab = jnp.asarray(r.integers(0, k, size=n), jnp.int32)
        sums, counts = centroid_update(pts, lab, k)
        rsums, rcounts = ref.centroid_update_ref(pts, lab, k)
        assert_allclose(np.asarray(sums), np.asarray(rsums), rtol=1e-3, atol=1e-3)
        assert_allclose(np.asarray(counts), np.asarray(rcounts))


class TestKmeansStep:
    def test_one_step_reduces_inertia(self):
        r = np.random.default_rng(7)
        blobs = np.concatenate(
            [r.normal(loc=0.0, size=(32, 16)), r.normal(loc=8.0, size=(32, 16))]
        )
        pts = jnp.asarray(blobs, jnp.float32)
        cen0 = pts[:2]
        lab, inertia0, cen1 = kmeans_step(pts, cen0)
        _, inertia1, _ = kmeans_step(pts, cen1)
        assert float(inertia1) <= float(inertia0) + 1e-4

    def test_fixed_point_on_perfect_centroids(self):
        pts = jnp.asarray([[0.0, 0.0], [0.0, 0.0], [4.0, 4.0], [4.0, 4.0]], jnp.float32)
        cen = jnp.asarray([[0.0, 0.0], [4.0, 4.0]], jnp.float32)
        lab, inertia, new_c = kmeans_step(pts, cen)
        assert float(inertia) < 1e-9
        assert_allclose(np.asarray(new_c), np.asarray(cen))


# ---------------------------------------------------------- reconstruct


class TestReconstruct:
    def test_matches_ref(self):
        m, n, k, r = 32, 64, 6, 4
        lab = jnp.asarray(RNG.integers(0, k, size=n), jnp.int32)
        cen, fa, fb = rand(m, k), rand(m, r), rand(r, n)
        out = swsc_reconstruct(lab, cen, fa, fb)
        want = ref.swsc_reconstruct_ref(lab, cen, fa, fb)
        assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_pure_gather_when_factors_zero(self):
        m, n, k, r = 16, 32, 4, 2
        lab = jnp.asarray(RNG.integers(0, k, size=n), jnp.int32)
        cen = rand(m, k)
        out = swsc_reconstruct(lab, cen, jnp.zeros((m, r)), jnp.zeros((r, n)))
        want = cen[:, lab]
        assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.sampled_from([8, 32, 256]),
        n=st.sampled_from([32, 64, 256]),
        k=st.integers(1, 24),
        r=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, m, n, k, r, seed):
        rg = np.random.default_rng(seed)
        lab = jnp.asarray(rg.integers(0, k, size=n), jnp.int32)
        cen = jnp.asarray(rg.normal(size=(m, k)), jnp.float32)
        fa = jnp.asarray(rg.normal(size=(m, r)), jnp.float32)
        fb = jnp.asarray(rg.normal(size=(r, n)), jnp.float32)
        out = swsc_reconstruct(lab, cen, fa, fb)
        want = ref.swsc_reconstruct_ref(lab, cen, fa, fb)
        assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ rtn


class TestRtn:
    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_matches_ref(self, bits):
        w = rand(48, 32)
        out = rtn_quantize(w, bits)
        want = ref.rtn_ref(w, bits)
        assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_levels_bounded(self):
        w = rand(64, 16)
        out = np.asarray(rtn_quantize(w, 2))
        for j in range(16):
            assert len(np.unique(np.round(out[:, j], 5))) <= 4

    def test_error_shrinks_with_bits(self):
        w = rand(128, 8)
        errs = [float(jnp.mean((rtn_quantize(w, b) - w) ** 2)) for b in (2, 4, 8)]
        assert errs[0] > errs[1] > errs[2]

    def test_constant_channel_exact(self):
        w = jnp.full((16, 4), 2.5, jnp.float32)
        assert_allclose(np.asarray(rtn_quantize(w, 2)), 2.5)


# -------------------------------------------------------- decode matmul


class TestDecodeMatmul:
    def test_matches_ref_and_dense(self):
        b, m, n, k, r = 8, 32, 64, 6, 4
        x = rand(b, m)
        lab = jnp.asarray(RNG.integers(0, k, size=n), jnp.int32)
        cen, fa, fb = rand(m, k), rand(m, r), rand(r, n)
        y = decode_matmul(x, lab, cen, fa, fb)
        want = ref.decode_matmul_ref(x, lab, cen, fa, fb)
        assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-4, atol=1e-4)
        # And against the dense path through the reconstructed matrix.
        w_new = ref.swsc_reconstruct_ref(lab, cen, fa, fb)
        assert_allclose(np.asarray(y), np.asarray(x @ w_new), rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(
        b=st.sampled_from([1, 4, 16]),
        m=st.sampled_from([16, 64]),
        n=st.sampled_from([32, 128]),
        k=st.integers(1, 12),
        r=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_dense(self, b, m, n, k, r, seed):
        rg = np.random.default_rng(seed)
        x = jnp.asarray(rg.normal(size=(b, m)), jnp.float32)
        lab = jnp.asarray(rg.integers(0, k, size=n), jnp.int32)
        cen = jnp.asarray(rg.normal(size=(m, k)), jnp.float32)
        fa = jnp.asarray(rg.normal(size=(m, r)), jnp.float32)
        fb = jnp.asarray(rg.normal(size=(r, n)), jnp.float32)
        y = decode_matmul(x, lab, cen, fa, fb)
        w_new = ref.swsc_reconstruct_ref(lab, cen, fa, fb)
        assert_allclose(np.asarray(y), np.asarray(x @ w_new), rtol=2e-3, atol=2e-3)
