"""AOT lowering tests: HLO text emission, manifest shape, budget math."""

import os

import jax
import jax.numpy as jnp

from compile.aot import budgets_for, lower_entry, spec, to_hlo_text
from compile.configs import PRESETS, swsc_params_for_bits


class TestBudgets:
    def test_paper_scale_m4096(self):
        assert swsc_params_for_bits(4096, 2.0) == (256, 128)
        assert swsc_params_for_bits(4096, 1.0) == (128, 64)

    def test_small_preset_scale(self):
        d = PRESETS["small"].d_model  # 256
        assert swsc_params_for_bits(d, 2.0) == (16, 8)
        assert swsc_params_for_bits(d, 3.0) == (24, 12)

    def test_budgets_for_dedups(self):
        pairs = budgets_for(256)
        assert pairs == [(24, 12), (16, 8)]


class TestHloEmission:
    def test_simple_fn_round_trips_text(self):
        def fn(x, y):
            return (x @ y + 1.0,)

        lowered = jax.jit(fn).lower(spec((4, 4)), spec((4, 4)))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "f32[4,4]" in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        """interpret=True Pallas must not leave custom-calls that the
        CPU PJRT in rust cannot execute."""
        from compile.kernels.rtn import rtn_quantize

        lowered = jax.jit(lambda w: (rtn_quantize(w, 3),)).lower(spec((32, 32)))
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        for bad in ("mosaic", "tpu_custom_call"):
            assert bad not in text.lower(), f"found {bad} in lowered HLO"

    def test_lower_entry_writes_file(self, tmp_path):
        def fn(x):
            return (x * 2.0,)

        n = lower_entry(fn, [spec((8,))], str(tmp_path), "t.hlo.txt")
        assert n > 0
        assert os.path.getsize(tmp_path / "t.hlo.txt") == n
