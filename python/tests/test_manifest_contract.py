"""Cross-layer contract: the generated manifest must agree with
configs.param_specs (which rust's model::params mirrors verbatim —
rust asserts its own side via ArtifactManifest::verify_config)."""

import os

import pytest

from compile.configs import PRESETS, param_specs

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.txt")


def parse_manifest(text, preset):
    current, fingerprint, params, exes = None, None, [], {}
    for line in text.splitlines():
        parts = line.split()
        if not parts or parts[0].startswith("#"):
            continue
        if parts[0] == "preset":
            current = parts[1]
        elif current == preset and parts[0] == "fingerprint":
            fingerprint = parts[1]
        elif current == preset and parts[0] == "param":
            params.append((parts[1], tuple(int(x) for x in parts[2].split(","))))
        elif current == preset and parts[0] == "executable":
            exes[parts[1]] = (parts[2], int(parts[3]))
    return fingerprint, params, exes


@pytest.mark.skipif(not os.path.exists(ART), reason="run `make artifacts` first")
@pytest.mark.parametrize("preset", ["tiny", "small"])
class TestManifestContract:
    def test_fingerprint_and_param_order(self, preset):
        cfg = PRESETS[preset]
        fingerprint, params, _ = parse_manifest(open(ART).read(), preset)
        assert fingerprint == cfg.fingerprint()
        assert params == [(n, tuple(s)) for n, s in param_specs(cfg)]

    def test_all_executables_present_with_files(self, preset):
        cfg = PRESETS[preset]
        _, _, exes = parse_manifest(open(ART).read(), preset)
        n = len(param_specs(cfg))
        assert exes["fwd_eval"][1] == 2
        assert exes["train_step"][1] == 3 * n + 1
        art_dir = os.path.dirname(ART)
        for fname, _n_out in exes.values():
            path = os.path.join(art_dir, fname)
            assert os.path.exists(path), fname
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, f"{fname} is not HLO text"

    def test_kernel_artifacts_cover_table1_budgets(self, preset):
        from compile.aot import budgets_for

        cfg = PRESETS[preset]
        _, _, exes = parse_manifest(open(ART).read(), preset)
        for k, r in budgets_for(cfg.d_model):
            assert f"kmeans_step_k{k}" in exes
            assert f"reconstruct_k{k}_r{r}" in exes
            assert f"decode_matmul_k{k}_r{r}" in exes
