"""Layer 2: the GPT-style decoder, its loss, and the Adam train step.

Everything here is traced once by aot.py and lowered to HLO text; at
runtime rust feeds parameters positionally. The parameter order is the
canonical order from configs.param_specs (== rust model::params). The LM
head is weight-tied to the token embedding.

Design rule (DESIGN.md §9): only portable HLO ops — no custom-calls — so
the lowered text round-trips through xla_extension 0.5.1. That means
jnp/lax only (no jnp.linalg.*), and the SVD used by error compensation
lives in rust.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, param_specs

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def split_params(cfg: ModelConfig, flat):
    """Flat positional list -> name->array dict (traced-safe)."""
    specs = param_specs(cfg)
    assert len(flat) == len(specs), (len(flat), len(specs))
    return {name: arr for (name, _), arr in zip(specs, flat)}


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def attention(x, wq, wk, wv, wo, n_heads):
    """Causal multi-head self-attention. x: [b, s, d]."""
    b, s, d = x.shape
    hd = d // n_heads

    def heads(w):
        return (x @ w).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)  # [b,h,s,hd]

    q, k, v = heads(wq), heads(wk), heads(wv)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)  # [b,h,s,hd]
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ wo


def forward(cfg: ModelConfig, params: dict, tokens):
    """tokens [b, s] int32 -> logits [b, s, vocab]."""
    x = params["embed.tok"][tokens] + params["embed.pos"][None, :, :]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        h = layer_norm(x, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        x = x + attention(
            h,
            params[f"{p}.attn.wq"],
            params[f"{p}.attn.wk"],
            params[f"{p}.attn.wv"],
            params[f"{p}.attn.wo"],
            cfg.n_heads,
        )
        h = layer_norm(x, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        h = jax.nn.gelu(h @ params[f"{p}.mlp.w1"] + params[f"{p}.mlp.b1"])
        x = x + h @ params[f"{p}.mlp.w2"] + params[f"{p}.mlp.b2"]
    x = layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    return x @ params["embed.tok"].T  # tied head


def nll_rows(cfg: ModelConfig, params: dict, tokens, targets):
    """Per-row (per-batch-element) NLL sums and token counts."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt_logp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]  # [b,s]
    nll = -jnp.sum(tgt_logp, axis=1)  # [b]
    count = jnp.full((cfg.batch,), float(cfg.seq), dtype=jnp.float32)
    return nll.astype(jnp.float32), count


def make_fwd_eval(cfg: ModelConfig):
    """(params..., tokens, targets) -> (nll_rows [b], tok_rows [b])."""

    def fwd_eval(*args):
        flat, tokens, targets = args[:-2], args[-2], args[-1]
        params = split_params(cfg, flat)
        return nll_rows(cfg, params, tokens, targets)

    return fwd_eval


def make_train_step(cfg: ModelConfig):
    """(params..., m..., v..., step, lr, tokens, targets)
    -> (params'..., m'..., v'..., loss). Plain Adam, mean-token loss."""
    n = len(param_specs(cfg))

    def loss_fn(flat, tokens, targets):
        params = split_params(cfg, flat)
        nll, count = nll_rows(cfg, params, tokens, targets)
        return jnp.sum(nll) / jnp.sum(count)

    def train_step(*args):
        flat = list(args[0:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        step, lr, tokens, targets = args[3 * n :]

        loss, grads = jax.value_and_grad(loss_fn)(flat, tokens, targets)
        t = step + 1.0
        bc1 = 1.0 - ADAM_B1**t
        bc2 = 1.0 - ADAM_B2**t
        new_flat, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(flat, grads, m, v):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
            update = (mi / bc1) / (jnp.sqrt(vi / bc2) + ADAM_EPS)
            new_flat.append(p - lr * update)
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_flat) + tuple(new_m) + tuple(new_v) + (loss,)

    return train_step


def example_params(cfg: ModelConfig, seed: int = 0):
    """Random parameters with the canonical shapes (tests / AOT specs)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".b", ".b1", ".b2")):
            out.append(jnp.zeros(shape, jnp.float32))
        else:
            out.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
    return out
