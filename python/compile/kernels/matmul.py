"""Fused decompressed matmul — the inference-efficiency form of SWSC.

``y = x @ W_new`` computed *without materializing* ``W_new``:

    y = (x @ C) @ onehot(labels)  +  (x @ A) @ B

FLOPs drop from ``b*m*n`` to ``b*m*(k+r) + b*(k+r)*n`` — proportional to
the avg-bits compression ratio. On TPU this is the HBM-traffic story too:
C, A, B together are 16(k+2r)/m x smaller than W, and all three stay
resident in VMEM across channel tiles while x streams through the MXU.

  VMEM per step = b*m (x) + m*k (C) + m*r (A) + r*bn (B tile) + b*bn (out)
  small preset 2-bit (b=8, m=256, k=16, r=8, bn=128): ~45 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kmeans import _pick_block


def _decode_matmul_kernel(k, x_ref, lab_ref, c_ref, a_ref, b_ref, out_ref):
    x = x_ref[...]  # [b, m]
    lab = lab_ref[...]  # [bn]
    cen = c_ref[...]  # [m, k]
    fa = a_ref[...]  # [m, r]
    fb = b_ref[...]  # [r, bn]
    xc = jnp.dot(x, cen, preferred_element_type=jnp.float32)  # [b, k]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0) == lab[None, :]).astype(
        x.dtype
    )  # [k, bn]
    gathered = jnp.dot(xc, onehot, preferred_element_type=jnp.float32)  # [b, bn]
    xa = jnp.dot(x, fa, preferred_element_type=jnp.float32)  # [b, r]
    out_ref[...] = gathered + jnp.dot(xa, fb, preferred_element_type=jnp.float32)


def decode_matmul(x, labels, centroids, factor_a, factor_b, block_n: int | None = None):
    """x [b, m] @ compressed(m, n) -> y [b, n]."""
    b, m = x.shape
    (n,) = labels.shape
    m2, k = centroids.shape
    _, r = factor_a.shape
    assert m == m2 and factor_b.shape == (r, n)
    bn = block_n or _pick_block(n)
    assert n % bn == 0
    return pl.pallas_call(
        functools.partial(_decode_matmul_kernel, k),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((b, m), lambda i: (0, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((r, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.float32),
        interpret=True,
    )(x, labels, centroids, factor_a, factor_b)
