"""Pallas kernel for SWSC weight restoration (paper Fig. 3, load path).

``W_new[:, j] = centroids[:, labels[j]] + (A @ B)[:, j]``

The gather is phrased as a one-hot matmul ``centroids @ onehot(labels)`` so
*both* terms are MXU matmuls — on TPU the whole restoration is systolic
work with no scatter/gather unit involvement. Channel tiles keep VMEM
bounded:

  VMEM per step = m*k (centroids) + m*r (A) + r*bn (B tile) + m*bn (out)
  small preset 2-bit (m=256, k=16, r=8, bn=128): ~176 KiB.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kmeans import _pick_block


def _reconstruct_kernel(k, lab_ref, cen_ref, a_ref, b_ref, out_ref):
    lab = lab_ref[...]  # [bn]
    cen = cen_ref[...]  # [m, k]
    a = a_ref[...]  # [m, r]
    b = b_ref[...]  # [r, bn]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0) == lab[None, :]).astype(
        cen.dtype
    )  # [k, bn]
    w_prime = jnp.dot(cen, onehot, preferred_element_type=jnp.float32)  # [m, bn]
    comp = jnp.dot(a, b, preferred_element_type=jnp.float32)  # [m, bn]
    out_ref[...] = w_prime + comp


def swsc_reconstruct(labels, centroids, factor_a, factor_b, block_n: int | None = None):
    """labels [n] i32, centroids [m,k], A [m,r], B [r,n] -> W_new [m,n]."""
    (n,) = labels.shape
    m, k = centroids.shape
    m2, r = factor_a.shape
    r2, n2 = factor_b.shape
    assert m == m2 and r == r2 and n == n2, (centroids.shape, factor_a.shape, factor_b.shape)
    bn = block_n or _pick_block(n)
    assert n % bn == 0
    import functools

    return pl.pallas_call(
        functools.partial(_reconstruct_kernel, k),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((m, k), lambda i: (0, 0)),
            pl.BlockSpec((m, r), lambda i: (0, 0)),
            pl.BlockSpec((r, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(labels, centroids, factor_a, factor_b)
