"""Pallas kernels for the K-Means hot path (assignment + centroid update).

TPU-first design (DESIGN.md §Hardware-Adaptation): the distance computation
is phrased as a matmul ``points @ centroids.T`` so it lands on the MXU
(128x128 systolic array), with the norm terms as cheap VPU adds. Channels
are tiled along the grid so each block's VMEM footprint is bounded:

  VMEM per step  =  bn*m (points) + k*m (centroids) + bn*k (cross) floats
  default small preset (bn=128, m=256, k<=24):  ~161 KiB — fits easily.

Kernels MUST run with interpret=True on CPU PJRT: real TPU lowering emits a
Mosaic custom-call the CPU plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, want: int = 128) -> int:
    """Largest divisor of n that is <= want (grid must tile n exactly)."""
    b = min(n, want)
    while n % b != 0:
        b -= 1
    return b


def _assign_kernel(pts_ref, cen_ref, lab_ref, d2_ref):
    pts = pts_ref[...]  # [bn, m]
    cen = cen_ref[...]  # [k, m]
    # ||x-c||^2 = ||x||^2 - 2 x.c + ||c||^2 ; cross term on the MXU.
    cross = jnp.dot(pts, cen.T, preferred_element_type=jnp.float32)  # [bn, k]
    pnorm = jnp.sum(pts * pts, axis=1, keepdims=True)  # [bn, 1]
    cnorm = jnp.sum(cen * cen, axis=1)[None, :]  # [1, k]
    d2 = pnorm - 2.0 * cross + cnorm
    lab_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    d2_ref[...] = jnp.min(d2, axis=1)


def kmeans_assign(points, centroids, block_n: int | None = None):
    """points [n, m], centroids [k, m] -> (labels [n] i32, min_d2 [n] f32)."""
    n, m = points.shape
    k, m2 = centroids.shape
    assert m == m2, (m, m2)
    bn = block_n or _pick_block(n)
    assert n % bn == 0, f"n={n} not tileable by {bn}"
    return pl.pallas_call(
        _assign_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(points, centroids)


def _update_kernel(k, pts_ref, lab_ref, sum_ref, cnt_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    pts = pts_ref[...]  # [bn, m]
    lab = lab_ref[...]  # [bn]
    # One-hot segment-sum as a matmul: onehot.T @ points on the MXU.
    onehot = (lab[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)).astype(
        pts.dtype
    )  # [bn, k]
    sum_ref[...] += jnp.dot(onehot.T, pts, preferred_element_type=jnp.float32)
    cnt_ref[...] += jnp.sum(onehot, axis=0)


def centroid_update(points, labels, k: int, block_n: int | None = None):
    """points [n, m], labels [n] -> (sums [k, m], counts [k]).

    Grid accumulates over channel tiles into the same output block
    (revisiting pattern); the mean division happens in the caller so empty
    clusters stay detectable.
    """
    n, m = points.shape
    bn = block_n or _pick_block(n)
    assert n % bn == 0
    return pl.pallas_call(
        functools.partial(_update_kernel, k),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, m), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k, m), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, m), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(points, labels)


def kmeans_step(points, centroids):
    """One full Lloyd step built from the two kernels:
    (labels, inertia, new_centroids). Empty clusters keep their position.
    This is the graph AOT-exported for the rust accelerated path."""
    k = centroids.shape[0]
    labels, d2 = kmeans_assign(points, centroids)
    sums, counts = centroid_update(points, labels, k)
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), centroids)
    return labels, jnp.sum(d2), new_c
