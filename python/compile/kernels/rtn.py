"""Pallas kernel for the RTN baseline (per-channel asymmetric fake-quant).

Pure VPU work (elementwise + column reductions); tiled over channels so a
block is a [m, bn] panel. Matches quant::rtn::rtn_quantize (asymmetric) in
rust and rtn_ref in ref.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kmeans import _pick_block


def _rtn_kernel(bits, w_ref, out_ref):
    w = w_ref[...]  # [m, bn]
    levels = float(2**bits)
    lo = jnp.min(w, axis=0, keepdims=True)
    hi = jnp.max(w, axis=0, keepdims=True)
    flat = hi <= lo
    scale = jnp.where(flat, 1.0, (hi - lo) / (levels - 1.0))
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(w / scale + zero), 0.0, levels - 1.0)
    deq = (q - zero) * scale
    out_ref[...] = jnp.where(flat, w, deq)


def rtn_quantize(w, bits: int, block_n: int | None = None):
    """w [m, n] -> fake-quantized w at `bits` per weight (per-column grid)."""
    m, n = w.shape
    bn = block_n or _pick_block(n)
    assert n % bn == 0
    return pl.pallas_call(
        functools.partial(_rtn_kernel, bits),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((m, bn), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(w)
