"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts each Pallas kernel
(interpret=True) matches its oracle to float tolerance, and hypothesis
sweeps shapes/k/r. The oracles are also what the rust-side CPU
implementations are tested against (same math, different language).
"""

import jax
import jax.numpy as jnp


def kmeans_assign_ref(points, centroids):
    """points [n, m], centroids [k, m] -> (labels [n] i32, min_d2 [n] f32).

    Distances via the expansion ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2 —
    the same matmul-centric form the Pallas kernel uses for the MXU.
    """
    pnorm = jnp.sum(points * points, axis=1, keepdims=True)  # [n,1]
    cnorm = jnp.sum(centroids * centroids, axis=1)[None, :]  # [1,k]
    cross = points @ centroids.T  # [n,k]
    d2 = pnorm - 2.0 * cross + cnorm
    labels = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return labels, jnp.min(d2, axis=1)


def centroid_update_ref(points, labels, k):
    """points [n, m], labels [n] -> (sums [k, m], counts [k])."""
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(points.dtype)  # [n,k]
    sums = onehot.T @ points  # [k,m]
    counts = jnp.sum(onehot, axis=0)  # [k]
    return sums, counts


def swsc_reconstruct_ref(labels, centroids, factor_a, factor_b):
    """labels [n], centroids [m, k], A [m, r], B [r, n] -> W_new [m, n].

    The paper's load-time restoration: W' (gather representative columns)
    plus the SVD compensation A.B.
    """
    w_prime = centroids[:, labels]  # [m, n]
    return w_prime + factor_a @ factor_b


def rtn_ref(w, bits):
    """Per-channel (column) asymmetric RTN fake-quant — mirrors quant::rtn."""
    levels = float(2**bits)
    lo = jnp.min(w, axis=0, keepdims=True)
    hi = jnp.max(w, axis=0, keepdims=True)
    flat = hi <= lo
    scale = jnp.where(flat, 1.0, (hi - lo) / (levels - 1.0))
    zero = jnp.round(-lo / scale)
    q = jnp.clip(jnp.round(w / scale + zero), 0.0, levels - 1.0)
    deq = (q - zero) * scale
    return jnp.where(flat, w, deq)


def decode_matmul_ref(x, labels, centroids, factor_a, factor_b):
    """Fused decompressed matmul: y = x @ W_new without materializing W_new.

    y = (x @ C) gathered by labels + (x @ A) @ B — FLOPs scale with k and r
    instead of n, which is the inference-side payoff of the paper's storage
    layout (DESIGN.md §3, hardware adaptation).
    """
    xc = x @ centroids  # [b, k]
    gathered = xc[:, labels]  # [b, n]
    return gathered + (x @ factor_a) @ factor_b


def kmeans_lloyd_ref(points, centroids, iters):
    """Full Lloyd loop (assign+update, no empty-cluster repair) used by the
    accelerated-path agreement tests."""
    k = centroids.shape[0]

    def body(c, _):
        labels, _d = kmeans_assign_ref(points, c)
        sums, counts = centroid_update_ref(points, labels, k)
        new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), c)
        return new_c, None

    final, _ = jax.lax.scan(body, centroids, None, length=iters)
    return final
