"""Model presets — must mirror rust/src/model/config.rs exactly.

The fingerprint string is the cross-layer contract: rust refuses to load
artifacts whose fingerprint does not match its own ModelConfig.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def fingerprint(self) -> str:
        return (
            f"v{self.vocab}_d{self.d_model}_l{self.n_layers}_h{self.n_heads}"
            f"_f{self.d_ff}_s{self.seq}_b{self.batch}"
        )


PRESETS = {
    "tiny": ModelConfig(vocab=256, d_model=64, n_layers=2, n_heads=2, d_ff=128, seq=32, batch=4),
    "small": ModelConfig(vocab=512, d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq=128, batch=8),
    "big": ModelConfig(vocab=8192, d_model=768, n_layers=12, n_heads=12, d_ff=3072, seq=256, batch=8),
}


def swsc_params_for_bits(m: int, target_bits: float, rank_share: float = 0.5):
    """(k, r) for a target avg-bits budget — mirrors quant::bits in rust."""
    share = min(max(rank_share, 0.0), 1.0)
    k = max(1, round(target_bits * (1.0 - share) * m / 16.0))
    r = max(0, round(target_bits * share * m / 32.0))
    return k, r


def param_specs(cfg: ModelConfig):
    """Canonical (name, shape) list — must match rust model::params order."""
    d = cfg.d_model
    specs = [("embed.tok", (cfg.vocab, d)), ("embed.pos", (cfg.seq, d))]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        specs += [
            (f"{p}.ln1.g", (d,)),
            (f"{p}.ln1.b", (d,)),
            (f"{p}.attn.wq", (d, d)),
            (f"{p}.attn.wk", (d, d)),
            (f"{p}.attn.wv", (d, d)),
            (f"{p}.attn.wo", (d, d)),
            (f"{p}.ln2.g", (d,)),
            (f"{p}.ln2.b", (d,)),
            (f"{p}.mlp.w1", (d, cfg.d_ff)),
            (f"{p}.mlp.b1", (cfg.d_ff,)),
            (f"{p}.mlp.w2", (cfg.d_ff, d)),
            (f"{p}.mlp.b2", (d,)),
        ]
    specs += [("final_ln.g", (d,)), ("final_ln.b", (d,))]
    return specs
