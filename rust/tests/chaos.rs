//! Chaos tests for the fault-tolerant serving layer (PR 8).
//!
//! Every test here drives the *public* serving surface under injected or
//! provoked failures and pins the fault-tolerance contract:
//!
//! - a panic poisons exactly the fated request — cohort-mates in the same
//!   continuous batch stay **bitwise equal to solo**, and the coalescer
//!   thread survives to serve the next request;
//! - deadlines answer [`ServeError::DeadlineExceeded`] (at admission or
//!   mid-flight) without moving any survivor's bits;
//! - quota shed and bounded retry degrade gracefully and observably
//!   (`serve.retries`, `serve.quota_rejected`);
//! - model hot-swap under load serves old bits or new bits, never a blend;
//! - the injected fault schedule is a pure function of (seed, request-id):
//!   the CI chaos job replays these tests at `SWSC_THREADS` ∈ {1, 4} with a
//!   fixed `SWSC_CHAOS_SEED` and must see identical classifications.
//!
//! Injection rates make fixed seeds statistically fragile, so tests
//! seed-scan at runtime against an oracle [`FaultInjector`] until the
//! schedule mixes the outcomes they need — deterministic, and independent
//! of thread count or wall clock.

use std::sync::Arc;
use std::time::Duration;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::infer::{CompressedForward, CompressedModel, InferMode};
use swsc::io::SwscFile;
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::serve::{
    AdmissionError, BatchConfig, BatchServer, FaultConfig, FaultInjector, ForwardRequest,
    ForwardScheduling, LinearRequest, ModelRegistry, QuotaConfig, RetryPolicy, ServeError,
    ServerOptions, DEFAULT_MODEL,
};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

/// A tiny-config `.swsc` container covering every model parameter.
fn demo_file(cfg: &ModelConfig, seed: u64) -> SwscFile {
    let ck = init_params(cfg, seed);
    let mut file = SwscFile::new();
    for spec in param_specs(cfg) {
        let t = ck.get(&spec.name).unwrap().clone();
        if spec.shape.len() == 2 && spec.shape[1] >= 16 {
            file.compressed.insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
        } else {
            file.dense.insert(spec.name.clone(), t);
        }
    }
    file
}

fn forward_from(file: &SwscFile, cfg: &ModelConfig) -> Arc<CompressedForward> {
    let model = Arc::new(CompressedModel::from_file(file, InferMode::Compressed));
    Arc::new(CompressedForward::new(model, cfg.clone()).expect("forward build failed"))
}

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

fn token_windows(cfg: &ModelConfig, seed: u64, n: usize) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let t = 1 + rng.below(cfg.seq);
            (0..t).map(|_| rng.below(cfg.vocab) as u32).collect()
        })
        .collect()
}

/// The PR 8 acceptance scenario: several forward requests overlap in the
/// continuous scheduler; a seeded fault panics exactly one of them. The
/// fated request answers [`ServeError::Panicked`], every cohort-mate's
/// logits stay bitwise equal to solo execution, and the server keeps
/// accepting (and serving, bitwise) afterwards.
#[test]
fn injected_panic_poisons_one_request_cohort_mates_stay_bitwise() {
    let cfg = ModelConfig::tiny();
    let file = demo_file(&cfg, 31);
    let fwd = forward_from(&file, &cfg);
    let warm: Vec<u32> = (0..cfg.seq).map(|i| (i % cfg.vocab) as u32).collect();
    fwd.forward(&warm).expect("panel warmup forward failed");

    let n = 6usize;
    let wins = token_windows(&cfg, 0xC0C0, n);
    let solo: Vec<Vec<u32>> = wins.iter().map(|w| bits(&fwd.forward(w).unwrap())).collect();

    // Seed-scan: exactly one of the n cohort ids is fated to panic, and
    // the post-recovery probe (id n) is clean. Request ids are assigned
    // in admission order, so submission order fixes the mapping.
    let mut faults = FaultConfig { panic_rate: 0.2, ..Default::default() };
    faults.seed = (0..10_000u64)
        .find(|&s| {
            let o = FaultInjector::new(FaultConfig { seed: s, ..faults.clone() });
            (0..n as u64).filter(|&id| o.injects_panic(id)).count() == 1
                && !o.injects_panic(n as u64)
        })
        .expect("no seed in 0..10000 poisons exactly one of the first ids");
    let oracle = FaultInjector::new(faults.clone());

    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default().with_forward_scheduling(ForwardScheduling::Continuous),
        ServerOptions { faults: Some(faults), ..Default::default() },
    );

    // Submit the whole cohort before reading any response, so requests
    // overlap in the continuous scheduler's in-flight set.
    let receivers: Vec<_> = wins
        .iter()
        .map(|w| server.submit_forward(DEFAULT_MODEL, ForwardRequest::new(w.clone())).unwrap())
        .collect();
    let mut panicked = 0;
    for (id, rx) in receivers.into_iter().enumerate() {
        let got = rx.recv().expect("coalescer must answer every responder");
        if oracle.injects_panic(id as u64) {
            match got.expect_err("fated request must fail") {
                ServeError::Panicked { message } => {
                    assert!(message.contains("injected fault"), "unexpected payload: {message}");
                }
                other => panic!("fated request got {other:?}, not Panicked"),
            }
            panicked += 1;
        } else {
            let resp = got.expect("cohort-mate must be served");
            assert_eq!(bits(&resp.logits), solo[id], "cohort-mate bits moved (request {id})");
        }
    }
    assert_eq!(panicked, 1);

    // The coalescer thread survived containment: the server keeps
    // accepting and serving, still bitwise equal to solo.
    assert!(!server.queue().is_shutting_down());
    let probe = server
        .submit_forward(DEFAULT_MODEL, ForwardRequest::new(wins[0].clone()))
        .unwrap()
        .recv()
        .unwrap()
        .expect("server must keep serving after a contained panic");
    assert_eq!(bits(&probe.logits), solo[0]);
    assert_eq!(server.metrics().counter("serve.panics"), 1);
    assert_eq!(server.metrics().counter("serve.errors"), 1);
    server.shutdown();
}

/// Deadlines end to end: already-expired requests answer
/// `DeadlineExceeded` at admission (never occupying a queue slot), while
/// a request with a comfortable deadline is served bitwise equal to solo.
#[test]
fn deadlines_are_enforced_end_to_end() {
    let cfg = ModelConfig::tiny();
    let file = demo_file(&cfg, 32);
    let fwd = forward_from(&file, &cfg);
    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start(Arc::new(reg), BatchConfig::default());
    let metrics = server.metrics().clone();

    // Expired requests are answered before any model or weight lookup —
    // the bogus weight name below never resolves.
    let stale = ForwardRequest::new(vec![1, 2, 3]).with_timeout(Duration::ZERO);
    let rx = server.submit_forward(DEFAULT_MODEL, stale).unwrap();
    assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
    let stale =
        LinearRequest::new("never.resolved", Tensor::zeros(&[1, 4])).with_timeout(Duration::ZERO);
    let rx = server.submit(DEFAULT_MODEL, stale).unwrap();
    assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
    assert_eq!(metrics.counter("serve.deadline_miss"), 2);
    assert_eq!(server.queue().depth(), 0, "expired requests must not occupy queue slots");

    // A generous deadline changes scheduling eligibility, never bits.
    let tokens: Vec<u32> = (0..cfg.seq).map(|i| (i * 3 % cfg.vocab) as u32).collect();
    let want = bits(&fwd.forward(&tokens).unwrap());
    let live = ForwardRequest::new(tokens).with_timeout(Duration::from_secs(300));
    let resp = server.submit_forward(DEFAULT_MODEL, live).unwrap().recv().unwrap().unwrap();
    assert_eq!(bits(&resp.logits), want, "deadline-carrying request must stay bitwise");
    server.shutdown();
}

/// Graceful degradation: a zero quota sheds the hot model immediately,
/// the bounded retry policy spends exactly its budget (observably, via
/// `serve.retries` / `serve.quota_rejected`), cold aliases are untouched,
/// and an expired request short-circuits the retry loop.
#[test]
fn quota_shed_is_immediate_and_retry_budget_is_bounded() {
    let d = 16usize;
    let mut rng = Rng::new(33);
    let mut file = SwscFile::new();
    file.compressed
        .insert("w".into(), compress_matrix(&Tensor::randn(&[d, d], &mut rng), &SwscConfig::new(4, 2)));
    let reg = ModelRegistry::new();
    let model = reg.insert_file("hot", &file, InferMode::Compressed);
    reg.insert("cold", model.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        ServerOptions {
            quotas: QuotaConfig::new().with_limit("hot", 0),
            faults: None,
            ..Default::default()
        },
    );
    let metrics = server.metrics().clone();

    let policy = RetryPolicy {
        attempts: 3,
        backoff: Duration::from_micros(200),
        max_backoff: Duration::from_millis(1),
    };
    let err = server
        .submit_with_retry("hot", LinearRequest::new("w", Tensor::zeros(&[1, d])), policy)
        .unwrap_err();
    assert_eq!(err, AdmissionError::QuotaExceeded);
    // 3 attempts = 2 retries; every attempt was a quota rejection.
    assert_eq!(metrics.counter("serve.retries"), 2);
    assert_eq!(metrics.counter("serve.quota_rejected"), 3);

    // The cold alias of the same Arc'd model admits freely — and stays
    // bitwise equal to direct apply.
    let x = Tensor::randn(&[2, d], &mut rng);
    let got = server
        .submit_with_retry("cold", LinearRequest::new("w", x.clone()), RetryPolicy::none())
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    assert_eq!(bits(&got.y), bits(&model.apply("w", &x).unwrap()));

    // An already-expired request is answered at admission instead of
    // burning the retry budget against the quota.
    let stale = LinearRequest::new("w", Tensor::zeros(&[1, d])).with_timeout(Duration::ZERO);
    let rx = server
        .submit_with_retry("hot", stale, policy)
        .expect("expired requests are answered, not retried");
    assert_eq!(rx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
    assert_eq!(metrics.counter("serve.retries"), 2, "no retries spent on the expired request");
    server.shutdown();
}

/// Hot-swap under load (satellite S3's race case): a swapper thread flips
/// the live name between two containers while requests stream in. Every
/// response must be bitwise equal to one container or the other — the
/// atomic `Arc` flip admits no blended state — and after the dust
/// settles the server serves exactly the last-installed container.
#[test]
fn hot_swap_under_load_serves_old_or_new_bits_never_a_blend() {
    let cfg = ModelConfig::tiny();
    let file_a = demo_file(&cfg, 41);
    let file_b = demo_file(&cfg, 42);
    let oracle_a = forward_from(&file_a, &cfg);
    let oracle_b = forward_from(&file_b, &cfg);
    let tokens: Vec<u32> = (0..cfg.seq / 2).map(|i| (i * 5 % cfg.vocab) as u32).collect();
    let want_a = bits(&oracle_a.forward(&tokens).unwrap());
    let want_b = bits(&oracle_b.forward(&tokens).unwrap());
    assert_ne!(want_a, want_b, "the two containers must actually differ");

    let reg = ModelRegistry::new();
    reg.insert_forward("live", forward_from(&file_a, &cfg));
    let server = Arc::new(BatchServer::start(Arc::new(reg), BatchConfig::default()));

    let swaps = 8u64;
    let swapper = {
        let server = server.clone();
        let (file_a, file_b, cfg) = (file_a.clone(), file_b.clone(), cfg.clone());
        std::thread::spawn(move || {
            for i in 0..swaps {
                let file = if i % 2 == 0 { &file_b } else { &file_a };
                server
                    .replace_forward_file("live", file, cfg.clone(), InferMode::Compressed)
                    .expect("hot swap of a valid container must succeed");
            }
        })
    };
    for i in 0..24 {
        let got = server
            .submit_forward_blocking("live", ForwardRequest::new(tokens.clone()))
            .expect("requests racing a hot swap must still be served");
        let b = bits(&got.logits);
        assert!(b == want_a || b == want_b, "response {i} is neither container's bits");
    }
    swapper.join().unwrap();
    assert_eq!(server.metrics().counter("serve.swaps"), swaps);

    // Settle on A: the very next response is exactly A's bits.
    server.replace_forward_file("live", &file_a, cfg.clone(), InferMode::Compressed).unwrap();
    let got = server.submit_forward_blocking("live", ForwardRequest::new(tokens.clone())).unwrap();
    assert_eq!(bits(&got.logits), want_a);

    // Unregistering the live name is a typed error for new requests, not
    // a crash — and the server stays up to serve other names.
    server.registry().remove("live").expect("live model must be registered");
    let gone = server
        .submit_forward("live", ForwardRequest::new(tokens.clone()))
        .unwrap()
        .recv()
        .unwrap();
    assert_eq!(gone.unwrap_err(), ServeError::UnknownModel("live".into()));
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
}

/// The whole fault schedule — rejections, panics, delays — is a pure
/// function of (seed, request-id). Two full server lifecycles over the
/// same request stream classify identically, match the oracle exactly,
/// and every *served* response stays bitwise equal to solo even while its
/// neighbours panic or dawdle. The CI chaos job replays this at
/// `SWSC_THREADS` ∈ {1, 4} with a pinned `SWSC_CHAOS_SEED`; thread count
/// must not change a single classification.
#[test]
fn chaos_schedule_is_deterministic_across_runs() {
    let d = 16usize;
    let mut rng = Rng::new(55);
    let mut file = SwscFile::new();
    file.compressed
        .insert("w".into(), compress_matrix(&Tensor::randn(&[d, d], &mut rng), &SwscConfig::new(4, 2)));
    let solo = CompressedModel::from_file(&file, InferMode::Compressed);
    let n = 48u64;
    let xs: Vec<Tensor> = (0..n).map(|_| Tensor::randn(&[2, d], &mut rng)).collect();
    let want: Vec<Vec<u32>> = xs.iter().map(|x| bits(&solo.apply("w", x).unwrap())).collect();

    let base = FaultConfig {
        seed: 0,
        panic_rate: 0.25,
        delay_rate: 0.1,
        delay: Duration::from_micros(50),
        reject_rate: 0.15,
    };
    // CI pins the seed; locally, scan for one that mixes all three
    // outcomes so the test always exercises every classification.
    let seed = match std::env::var("SWSC_CHAOS_SEED").ok().and_then(|v| v.trim().parse().ok()) {
        Some(s) => s,
        None => (0..10_000u64)
            .find(|&s| {
                let o = FaultInjector::new(FaultConfig { seed: s, ..base.clone() });
                let rejected = (0..n).filter(|&id| o.injects_rejection(id)).count();
                let panicked = (0..n)
                    .filter(|&id| !o.injects_rejection(id) && o.injects_panic(id))
                    .count();
                rejected >= 2 && panicked >= 2 && rejected + panicked + 2 <= n as usize
            })
            .expect("no seed in 0..10000 mixes all three outcomes"),
    };
    let faults = FaultConfig { seed, ..base };
    let oracle = FaultInjector::new(faults.clone());

    // 0 = served, 1 = panicked, 2 = rejected at admission.
    let run = || -> Vec<u8> {
        let reg = ModelRegistry::new();
        reg.insert_file(DEFAULT_MODEL, &file, InferMode::Compressed);
        let server = BatchServer::start_with_opts(
            Arc::new(reg),
            BatchConfig::default(),
            ServerOptions { faults: Some(faults.clone()), ..Default::default() },
        );
        let mut outcomes = Vec::with_capacity(xs.len());
        for (i, x) in xs.iter().enumerate() {
            match server.try_submit(DEFAULT_MODEL, LinearRequest::new("w", x.clone())) {
                Ok(rx) => match rx.recv().unwrap() {
                    Ok(resp) => {
                        assert_eq!(bits(&resp.y), want[i], "served response {i} drifted from solo");
                        outcomes.push(0);
                    }
                    Err(ServeError::Panicked { .. }) => outcomes.push(1),
                    Err(e) => panic!("unexpected serve error for request {i}: {e}"),
                },
                Err(AdmissionError::Overloaded) => outcomes.push(2),
                Err(e) => panic!("unexpected admission error for request {i}: {e}"),
            }
        }
        server.shutdown();
        outcomes
    };

    let first = run();
    // Exact oracle agreement: sequential submission maps request i to id i.
    for (i, &got) in first.iter().enumerate() {
        let id = i as u64;
        let expect = if oracle.injects_rejection(id) {
            2
        } else if oracle.injects_panic(id) {
            1
        } else {
            0
        };
        assert_eq!(got, expect, "request {i} classified {got}, oracle says {expect}");
    }
    // And a fresh server over the same stream replays it identically.
    assert_eq!(first, run(), "two runs over one seed must classify identically");
}
