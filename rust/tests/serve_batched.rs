//! Batched-serving invariants (ISSUE 5).
//!
//! The load-bearing contract: **coalescing is invisible in the results.**
//! `apply` is row-independent (each output row is a single-register
//! increasing-k dot over that row's own activations — the crate-wide
//! kernel policy), so stacking requests into a micro-batch and splitting
//! the result is bitwise identical to serving each request alone, at any
//! `SWSC_THREADS` (the CI tier-1 matrix runs this file under
//! `SWSC_THREADS ∈ {1, 4}`; the property test additionally sweeps
//! explicit thread configs). Pinned here:
//!
//! 1. the row-independence property itself, at the `CompressedLinear`
//!    level (arbitrary stacking splits × thread counts, bitwise);
//! 2. `EvalService` end to end: `batching: Enabled` responses bitwise
//!    equal `batching: Disabled` responses and the direct
//!    `CompressedModel::apply` oracle, over a ragged multi-weight stream
//!    (compressed + dense entries);
//! 3. multi-model interleaving through one `BatchServer` — grouping by
//!    (model, weight) never crosses streams;
//! 4. admission control: explicit `Overloaded` / `ShuttingDown`, and
//!    drain-on-shutdown answering rather than dropping.

use std::sync::Arc;
use std::time::Duration;
use swsc::compress::{compress_matrix, CompressedMatrix, SwscConfig};
use swsc::coordinator::{EvalService, LinearRequest, ServiceConfig};
use swsc::exec::ExecConfig;
use swsc::infer::{CompressedLinear, CompressedModel, InferMode};
use swsc::io::SwscFile;
use swsc::model::ModelConfig;
use swsc::serve::{
    AdmissionError, BatchConfig, BatchServer, Batching, ModelRegistry, DEFAULT_MODEL,
};
use swsc::tensor::Tensor;
use swsc::util::prop::check;
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

fn synthetic(m: usize, n: usize, k: usize, r: usize, rng: &mut Rng) -> CompressedMatrix {
    CompressedMatrix {
        shape: (m, n),
        labels: (0..n).map(|_| rng.below(k) as u32).collect(),
        centroids: Tensor::randn(&[m, k], rng),
        factor_a: Tensor::randn(&[m, r], rng),
        factor_b: Tensor::randn(&[r, n], rng),
    }
}

/// The foundation the coalescer stands on: `apply` on a stacked batch
/// equals the row-wise concatenation of `apply` on any split of it —
/// bitwise, at any thread count, including lazily packed panels whose
/// first touch happens under either path.
#[test]
fn prop_apply_is_row_independent_bitwise() {
    check(
        "apply(stack(x1..xg)) == concat(apply(x1)..apply(xg)), bitwise",
        701,
        12,
        |r| {
            let m = 8 + r.below(56);
            let n = 8 + r.below(56);
            let k = 2 + r.below(6);
            let rank = if r.below(3) == 0 { 0 } else { 1 + r.below(6) };
            let c = synthetic(m, n, k, rank, r);
            let rows = 1 + r.below(20);
            let x = Tensor::randn(&[rows, m], r);
            // Random contiguous split of the batch into request slabs.
            let mut splits = vec![0];
            let mut at = 0;
            loop {
                at += 1 + r.below(4);
                if at >= rows {
                    break;
                }
                splits.push(at);
            }
            splits.push(rows);
            (c, x, splits)
        },
        |(c, x, splits)| {
            let lin = CompressedLinear::from_matrix(c);
            let full = lin.apply_with(x, ExecConfig::serial());
            let n = full.cols();
            for t in [1usize, 2, 4] {
                let cfg = ExecConfig::with_threads(t);
                if bits(&lin.apply_with(x, cfg)) != bits(&full) {
                    return Err(format!("stacked apply differs at {t} threads"));
                }
                // A fresh operator whose panels first pack under this
                // thread config must agree too (packing is
                // value-deterministic).
                let fresh = CompressedLinear::from_matrix(c);
                for w in splits.windows(2) {
                    let (lo, hi) = (w[0], w[1]);
                    let rows = hi - lo;
                    let m = x.cols();
                    let slab = Tensor::from_vec(
                        &[rows, m],
                        x.data()[lo * m..hi * m].to_vec(),
                    );
                    let solo = fresh.apply_with(&slab, cfg);
                    let want: Vec<u32> =
                        full.data()[lo * n..hi * n].iter().map(|v| v.to_bits()).collect();
                    if bits(&solo) != want {
                        return Err(format!(
                            "rows {lo}..{hi} not bitwise equal between solo and stacked \
                             apply at {t} threads"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Weights with clustered channel structure (the paper's regime), so the
/// end-to-end tests run on real compression output.
fn structured_weights(m: usize, n: usize, groups: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> =
        (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let mut w = Tensor::zeros(&[m, n]);
    for j in 0..n {
        let col: Vec<f32> =
            centers[j % groups].iter().map(|&v| v + rng.normal_f32(0.0, 0.1)).collect();
        w.set_col(j, &col);
    }
    w
}

fn service_file(seed: u64, d: usize) -> SwscFile {
    let mut file = SwscFile::new();
    for (i, name) in ["attn.wq", "attn.wk", "mlp.w1"].iter().enumerate() {
        let w = structured_weights(d, d, 4, seed + i as u64);
        file.compressed.insert((*name).into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
    }
    file.dense.insert("attn.wv".into(), Tensor::randn(&[d, d], &mut Rng::new(seed + 9)));
    file
}

/// Seeded ragged request stream over every servable entry (compressed
/// and dense).
fn request_stream(d: usize, count: usize, seed: u64) -> Vec<LinearRequest> {
    let names = ["attn.wq", "attn.wk", "mlp.w1", "attn.wv"];
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            LinearRequest::new(names[i % names.len()], Tensor::randn(&[1 + rng.below(7), d], &mut rng))
        })
        .collect()
}

/// ISSUE 5 satellite: batched responses are bitwise equal to
/// `batching: Disabled` solo responses (and to the direct oracle) over a
/// ragged, multi-weight stream — and the serve metrics expose the
/// latency/batch-size histograms.
#[test]
fn batched_service_bitwise_equals_disabled_solo() {
    let d = 32;
    let cfg = ModelConfig::tiny();
    let file = service_file(800, d);
    let stream = request_stream(d, 40, 801);
    let oracle = CompressedModel::from_file(&file, InferMode::Compressed);

    // Batched service: submit everything first (a wide fill window +
    // generous row bound lets the stream coalesce), then collect.
    let batched_svc = EvalService::start_with_swsc(
        None,
        cfg.clone(),
        &file,
        ServiceConfig {
            batching: Batching::Enabled(BatchConfig {
                max_batch_rows: 128,
                max_wait: Duration::from_millis(200),
                ..Default::default()
            }),
            ..Default::default()
        },
    )
    .unwrap();
    let rxs: Vec<_> =
        stream.iter().map(|r| batched_svc.submit_linear(r.clone()).unwrap()).collect();
    let batched: Vec<Tensor> =
        rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().y).collect();
    assert_eq!(batched_svc.metrics.counter("serve.requests"), stream.len() as u64);
    assert_eq!(
        batched_svc.metrics.counter("service.linear_requests"),
        stream.len() as u64
    );
    // Coalescing actually happened: fewer batches than requests. (The
    // whole stream is queued within the 200 ms fill window — zero
    // coalescing would need every window to expire between two
    // back-to-back submits.)
    let batches = batched_svc.metrics.counter("serve.batches");
    assert!(batches < stream.len() as u64, "no coalescing observed ({batches} batches)");
    // Histogram surface: latency percentiles recorded and rendered.
    assert!(batched_svc.metrics.timing_percentile("serve.latency_seconds", 95.0) > 0.0);
    assert!(batched_svc.metrics.render().contains("p95="));
    batched_svc.shutdown();

    // Solo oracle service: the inline pre-batching path.
    let solo_svc = EvalService::start_with_swsc(
        None,
        cfg,
        &file,
        ServiceConfig { batching: Batching::Disabled, ..Default::default() },
    )
    .unwrap();
    for (req, got) in stream.iter().zip(&batched) {
        let solo = solo_svc.linear_blocking(req.clone()).unwrap();
        assert_eq!(
            bits(got),
            bits(&solo.y),
            "batched and solo responses differ for `{}`",
            req.name
        );
        let want = oracle.apply(&req.name, &req.x).unwrap();
        assert_eq!(bits(got), bits(&want), "batched response differs from oracle `{}`", req.name);
    }
    solo_svc.shutdown();
}

/// Multi-model interleaving: two models with identical weight *names*
/// but different values behind one server — every response must match
/// its own model's oracle bitwise (a grouping mixup would cross them).
#[test]
fn multi_model_interleaving_routes_correctly() {
    let d = 24;
    let reg = ModelRegistry::new();
    let file_a = service_file(820, d);
    let file_b = service_file(830, d);
    let model_a = reg.insert_file("a", &file_a, InferMode::Compressed);
    let model_b = reg.insert_file("b", &file_b, InferMode::Compressed);
    let server = BatchServer::start(
        Arc::new(reg),
        BatchConfig {
            max_batch_rows: 256,
            max_wait: Duration::from_millis(200),
            ..Default::default()
        },
    );

    let mut rng = Rng::new(840);
    let reqs: Vec<(String, LinearRequest)> = (0..24)
        .map(|i| {
            let model = if i % 2 == 0 { "a" } else { "b" };
            let weight = ["attn.wq", "attn.wk", "mlp.w1"][i % 3];
            (
                model.to_string(),
                LinearRequest::new(weight, Tensor::randn(&[1 + (i % 4), d], &mut rng)),
            )
        })
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(model, req)| server.submit(model, req.clone()).unwrap())
        .collect();
    for ((model, req), rx) in reqs.iter().zip(rxs) {
        let got = rx.recv().unwrap().unwrap();
        let oracle = if model == "a" { &model_a } else { &model_b };
        let want = oracle.apply(&req.name, &req.x).unwrap();
        assert_eq!(
            bits(&got.y),
            bits(&want),
            "response crossed streams: model {model}, weight {}",
            req.name
        );
    }
    server.shutdown();
}

/// Admission control end to end: a tiny queue rejects with explicit
/// `Overloaded` while the coalescer is busy, everything admitted is
/// served, and `begin_shutdown` deterministically rejects new work.
#[test]
fn admission_overload_and_shutdown() {
    let mut rng = Rng::new(850);
    let mut file = SwscFile::new();
    file.compressed.insert("w".into(), synthetic(512, 512, 16, 8, &mut rng));
    let reg = ModelRegistry::new();
    reg.insert_file(DEFAULT_MODEL, &file, InferMode::Compressed);
    let server = BatchServer::start_with(
        Arc::new(reg),
        BatchConfig::solo(),
        2,
        Arc::new(swsc::coordinator::Metrics::new()),
    );
    assert_eq!(server.queue().capacity(), 2);

    // A deliberately heavy request occupies the coalescer...
    let slow = server
        .submit(DEFAULT_MODEL, LinearRequest::new("w", Tensor::randn(&[8192, 512], &mut rng)))
        .unwrap();
    // ...while a burst overfills the depth-2 queue. Whatever the exact
    // interleaving, the 4th try_submit cannot fit (at most the slow
    // request has left the queue, leaving capacity for two).
    let mut accepted = Vec::new();
    let mut overloaded = 0;
    for _ in 0..4 {
        match server.try_submit(DEFAULT_MODEL, LinearRequest::new("w", Tensor::zeros(&[1, 512]))) {
            Ok(rx) => accepted.push(rx),
            Err(AdmissionError::Overloaded) => overloaded += 1,
            Err(e) => panic!("unexpected admission error: {e}"),
        }
    }
    assert!(overloaded >= 1, "depth-2 queue admitted a 4-deep burst");
    assert!(accepted.len() <= 3);
    assert!(slow.recv().unwrap().is_ok());
    for rx in accepted {
        assert!(rx.recv().unwrap().is_ok(), "admitted request must be served");
    }
    assert!(server.metrics().counter("serve.rejected_overloaded") >= 1);

    // Shutdown is deterministic: the flag flips before the marker lands.
    server.begin_shutdown();
    let refused =
        server.try_submit(DEFAULT_MODEL, LinearRequest::new("w", Tensor::zeros(&[1, 512])));
    assert_eq!(refused.err(), Some(AdmissionError::ShuttingDown));
    server.shutdown();
}

/// `EvalService::begin_shutdown` + the batched path: new submissions are
/// rejected, previously admitted ones are answered (served, or an
/// explicit shutdown error — never a silent drop).
#[test]
fn eval_service_begin_shutdown_answers_everything() {
    let d = 32;
    let file = service_file(860, d);
    let service = EvalService::start_with_swsc(
        None,
        ModelConfig::tiny(),
        &file,
        ServiceConfig::default(),
    )
    .unwrap();
    let mut rng = Rng::new(861);
    let rxs: Vec<_> = (0..6)
        .map(|_| {
            service
                .submit_linear(LinearRequest::new("attn.wq", Tensor::randn(&[2, d], &mut rng)))
                .unwrap()
        })
        .collect();
    service.begin_shutdown();
    match service.try_submit_linear(LinearRequest::new("attn.wq", Tensor::zeros(&[1, d]))) {
        Err(AdmissionError::ShuttingDown) => {}
        Err(e) => panic!("unexpected admission error: {e}"),
        Ok(_) => panic!("admission after begin_shutdown must be rejected"),
    }
    for rx in rxs {
        // Admitted before the marker ⇒ a real response (these were ahead
        // of the shutdown marker, so they are served).
        let resp = rx.recv().expect("responder dropped silently");
        assert!(resp.is_ok(), "pre-shutdown request failed: {resp:?}");
    }
    service.shutdown();
}
