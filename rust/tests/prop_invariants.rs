//! Property-based tests over coordinator + pipeline invariants
//! (DESIGN.md §7). Uses the in-crate mini-prop harness (no proptest in the
//! vendored set); every failure reports seed + case for exact replay.

use swsc::compress::{compress_matrix, CompressionPlan, ProjectorSet, SvdBackend, SwscConfig};
use swsc::coordinator::compress_model;
use swsc::exec::ExecConfig;
use swsc::io::{pack_u32, unpack_u32, Checkpoint};
use swsc::kmeans::{
    assign_blocked_with, assign_gemm_with, cluster_channels, init_kmeans_pp, minibatch_kmeans_with,
    update_with, KMeansConfig,
};
use swsc::linalg::{svd_jacobi, truncate};
use swsc::quant::bits::{swsc_avg_bits, swsc_params_for_bits};
use swsc::quant::{rtn_quantize, RtnConfig, RtnMode};
use swsc::tensor::Tensor;
use swsc::util::prop::{check, default_cases};
use swsc::util::rng::Rng;

#[test]
fn prop_kmeans_labels_in_range_and_count_preserved() {
    check(
        "labels ∈ [0,k), one per channel",
        301,
        default_cases(),
        |r| {
            let m = 4 + r.below(24);
            let n = 4 + r.below(40);
            let k = 1 + r.below(10);
            (Tensor::randn(&[m, n], r), k)
        },
        |(w, k)| {
            let res = cluster_channels(w, &KMeansConfig { k: *k, ..Default::default() });
            if res.labels.len() != w.cols() {
                return Err(format!("{} labels for {} channels", res.labels.len(), w.cols()));
            }
            let kk = res.centroids.cols();
            if res.labels.iter().any(|&l| l as usize >= kk) {
                return Err("label out of range".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_compensation_never_hurts_mse() {
    check(
        "SVD compensation monotone",
        302,
        24,
        |r| {
            let m = 8 + r.below(32);
            let n = 8 + r.below(32);
            let k = 2 + r.below(6);
            let rank = 1 + r.below(8);
            (Tensor::randn(&[m, n], r), k, rank)
        },
        |(w, k, rank)| {
            let c = compress_matrix(w, &SwscConfig::new(*k, *rank));
            let with = c.reconstruct().mse(w);
            let without = c.reconstruct_uncompensated().mse(w);
            if with <= without + 1e-9 {
                Ok(())
            } else {
                Err(format!("compensated {with} > uncompensated {without}"))
            }
        },
    );
}

#[test]
fn prop_avg_bits_monotone_in_k_and_r() {
    check(
        "avg_bits strictly increasing",
        303,
        default_cases(),
        |r| {
            let m = 32 + r.below(512);
            let n = 32 + r.below(512);
            let k = 1 + r.below(64);
            let rank = r.below(32);
            (m, n, k, rank)
        },
        |&(m, n, k, rank)| {
            let base = swsc_avg_bits(m, n, k, rank).avg_bits;
            let more_k = swsc_avg_bits(m, n, k + 1, rank).avg_bits;
            let more_r = swsc_avg_bits(m, n, k, rank + 1).avg_bits;
            if more_k > base && more_r > base {
                Ok(())
            } else {
                Err(format!("not monotone: {base} vs k+1 {more_k}, r+1 {more_r}"))
            }
        },
    );
}

#[test]
fn prop_svd_energy_monotone_and_bounded() {
    check(
        "singular energy monotone in rank",
        304,
        16,
        |r| {
            let m = 6 + r.below(20);
            let n = 6 + r.below(20);
            Tensor::randn(&[m, n], r)
        },
        |w| {
            let full = svd_jacobi(w);
            let total = w.fro_norm().powi(2);
            let mut last = 0.0;
            for rank in 1..=full.rank() {
                let e = truncate(&full, rank).energy_fraction(total);
                if e < last - 1e-9 || e > 1.0 + 1e-9 {
                    return Err(format!("energy {e} at rank {rank} (last {last})"));
                }
                last = e;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rtn_idempotent() {
    check(
        "RTN(RTN(w)) == RTN(w)",
        305,
        default_cases(),
        |r| {
            let m = 4 + r.below(40);
            let n = 1 + r.below(10);
            let bits = 2 + r.below(5) as u32;
            (Tensor::randn(&[m, n], r), bits)
        },
        |(w, bits)| {
            let cfg = RtnConfig { bits: *bits, mode: RtnMode::Asymmetric };
            let once = rtn_quantize(w, &cfg);
            let twice = rtn_quantize(&once, &cfg);
            // Quantizing a quantized matrix keeps grid points (same min/max).
            if once.mse(&twice) < 1e-10 {
                Ok(())
            } else {
                Err(format!("not idempotent: mse {}", once.mse(&twice)))
            }
        },
    );
}

#[test]
fn prop_bitpack_round_trip_arbitrary() {
    check(
        "bitpack/unpack identity",
        306,
        default_cases(),
        |r| {
            let bits = 1 + r.below(20) as u32;
            let n = r.below(500);
            let mask = if bits == 32 { u64::MAX } else { (1u64 << bits) - 1 };
            let vals: Vec<u32> = (0..n).map(|_| (r.next_u64() & mask) as u32).collect();
            (vals, bits)
        },
        |(vals, bits)| {
            let got = unpack_u32(&pack_u32(vals, *bits), vals.len(), *bits);
            if &got == vals { Ok(()) } else { Err("mismatch".into()) }
        },
    );
}

#[test]
fn prop_scheduler_compresses_each_matrix_exactly_once() {
    check(
        "scheduler completeness",
        307,
        12,
        |r| {
            // Random mini-model: random number of layers of random width.
            let layers = 1 + r.below(4);
            let d = 8 * (1 + r.below(4));
            let mut ck = Checkpoint::new();
            for i in 0..layers {
                ck.insert(&format!("layers.{i}.attn.wq"), Tensor::randn(&[d, d], r));
                ck.insert(&format!("layers.{i}.attn.wk"), Tensor::randn(&[d, d], r));
                ck.insert(&format!("layers.{i}.attn.wv"), Tensor::randn(&[d, d], r));
            }
            ck.insert("embed.tok", Tensor::randn(&[32, d], r));
            let workers = 1 + r.below(8);
            (ck, workers)
        },
        |(ck, workers)| {
            let plan =
                CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 1);
            let out = compress_model(ck, &plan, *workers, None).map_err(|e| e.to_string())?;
            if out.file.compressed.len() != plan.len() {
                return Err(format!(
                    "{} compressed vs {} planned",
                    out.file.compressed.len(),
                    plan.len()
                ));
            }
            if out.file.compressed.len() + out.file.dense.len() != ck.len() {
                return Err("entries lost or duplicated".into());
            }
            for name in out.file.compressed.keys() {
                if out.file.dense.contains_key(name) {
                    return Err(format!("{name} both compressed and dense"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_planner_budget_within_tolerance() {
    check(
        "planned (k,r) lands near target bits",
        308,
        default_cases(),
        |r| {
            let m = 64 * (1 + r.below(64)); // 64..4096
            let target = 0.5 + r.uniform() * 3.5;
            let share = 0.2 + r.uniform() * 0.6;
            (m, target, share)
        },
        |&(m, target, share)| {
            let (k, rank) = swsc_params_for_bits(m, target, share);
            let got = 16.0 * (k as f64 + 2.0 * rank as f64) / m as f64;
            // Rounding granularity: 16/m per cluster, 32/m per rank.
            let tol = (16.0 / m as f64 + 32.0 / m as f64).max(0.02);
            if (got - target).abs() <= tol + 0.26 {
                Ok(())
            } else {
                Err(format!("m={m} target={target:.2} share={share:.2} -> {got:.3}"))
            }
        },
    );
}

/// ISSUE 1 tentpole invariant: the deterministic executor makes every
/// compression-time result bit-identical across thread counts. Checks
/// matmul, k-means labels/inertia/centroids, and the full
/// `CompressedMatrix` against the `threads = 1` reference for threads ∈
/// {2, 4, 8} on random shapes.
#[test]
fn prop_serial_parallel_parity_bitwise() {
    const THREADS: [usize; 3] = [2, 4, 8];
    // True bitwise comparison: derived f32 PartialEq would equate 0.0 with
    // -0.0 and mismatch identical NaNs.
    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }
    check(
        "threads ∈ {1,2,4,8} are bit-identical",
        310,
        6,
        |r| {
            // ≥ 128 per side so the matmul leg clears the serial-fallback
            // work threshold and the parallel kernel actually runs; sizes
            // and case count stay modest so debug-mode tier-1 runs fast.
            let m = 128 + r.below(64);
            let n = 128 + r.below(64);
            let p = 128 + r.below(32);
            let k = 2 + r.below(8);
            let rank = 1 + r.below(6);
            (Tensor::randn(&[m, n], r), Tensor::randn(&[n, p], r), k, rank)
        },
        |(a, b, k, rank)| {
            // 1. Blocked matmul: row bands are independent.
            let mm_base = bits(&a.matmul_with(b, ExecConfig::serial()));
            for t in THREADS {
                if bits(&a.matmul_with(b, ExecConfig::with_threads(t))) != mm_base {
                    return Err(format!("matmul differs at {t} threads"));
                }
            }

            // 2. K-means labels/inertia/centroids: fixed point chunks,
            // partials reduced in chunk order.
            let cluster = |exec: ExecConfig| {
                let mut cfg = KMeansConfig { k: *k, seed: 11, max_iters: 8, ..Default::default() };
                cfg.exec = exec;
                cluster_channels(a, &cfg)
            };
            let km_base = cluster(ExecConfig::serial());
            for t in THREADS {
                let km = cluster(ExecConfig::with_threads(t));
                if km.labels != km_base.labels {
                    return Err(format!("kmeans labels differ at {t} threads"));
                }
                if km.inertia.to_bits() != km_base.inertia.to_bits() {
                    return Err(format!(
                        "kmeans inertia differs at {t} threads: {} vs {}",
                        km.inertia, km_base.inertia
                    ));
                }
                if bits(&km.centroids) != bits(&km_base.centroids) {
                    return Err(format!("kmeans centroids differ at {t} threads"));
                }
            }

            // 3. Full SWSC output, forcing the randomized backend so the
            // parallel subspace-iteration GEMMs are actually on the path.
            let compress = |exec: ExecConfig| {
                let mut cfg = SwscConfig::new(*k, *rank);
                cfg.seed = 5;
                cfg.svd = SvdBackend::Randomized;
                cfg.kmeans.max_iters = 8;
                cfg.exec = exec;
                compress_matrix(a, &cfg)
            };
            let c_base = compress(ExecConfig::serial());
            for t in THREADS {
                let c = compress(ExecConfig::with_threads(t));
                if c.labels != c_base.labels
                    || bits(&c.centroids) != bits(&c_base.centroids)
                    || bits(&c.factor_a) != bits(&c_base.factor_a)
                    || bits(&c.factor_b) != bits(&c_base.factor_b)
                {
                    return Err(format!("CompressedMatrix differs at {t} threads"));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE 2 tentpole invariant, part 1: mini-batch k-means joins the
/// bit-parity contract. The sampler draws every step's indices from a
/// stream derived from (plan seed, step), and assignment runs on the
/// deterministic executor, so centroids, labels, and inertia must be
/// bit-identical at threads ∈ {1, 2, 4, 8}.
#[test]
fn prop_minibatch_parity_bitwise() {
    const THREADS: [usize; 3] = [2, 4, 8];
    fn bits(t: &swsc::tensor::Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }
    check(
        "minibatch threads ∈ {1,2,4,8} are bit-identical",
        311,
        6,
        |r| {
            // Several POINT_CHUNK chunks of points so the executor actually
            // fans out; batch/steps sized to move centroids around.
            let n = 300 + r.below(300);
            let m = 4 + r.below(12);
            let k = 2 + r.below(6);
            let batch = 16 + r.below(64);
            let steps = 5 + r.below(20);
            let seed = r.next_u64();
            (Tensor::randn(&[n, m], r), k, batch, steps, seed)
        },
        |(pts, k, batch, steps, seed)| {
            let init = init_kmeans_pp(pts, *k, &mut Rng::new(seed ^ 1));
            let run = |threads: usize| {
                let mut rng = Rng::new(*seed);
                minibatch_kmeans_with(
                    pts,
                    init.clone(),
                    *batch,
                    *steps,
                    &mut rng,
                    ExecConfig::with_threads(threads),
                )
            };
            let (c_base, l_base, i_base) = run(1);
            for t in THREADS {
                let (c, l, i) = run(t);
                if l != l_base {
                    return Err(format!("minibatch labels differ at {t} threads"));
                }
                if i.to_bits() != i_base.to_bits() {
                    return Err(format!("minibatch inertia differs at {t} threads: {i} vs {i_base}"));
                }
                if bits(&c) != bits(&c_base) {
                    return Err(format!("minibatch centroids differ at {t} threads"));
                }
            }
            Ok(())
        },
    );
}

/// ISSUE 2 tentpole invariant, part 2: the blocked cross-term assign is
/// exactly the naive (un-blocked full-GEMM) assign — equal labels, equal
/// inertia bits, and bit-equal centroids after the update step — at every
/// thread count.
#[test]
fn prop_blocked_assign_equals_naive_exactly() {
    fn bits(t: &swsc::tensor::Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }
    check(
        "blocked == naive Lloyd assign",
        312,
        8,
        |r| {
            // Ragged sizes on purpose: partial point chunks, k not a tile
            // multiple, dims crossing the microkernel block edge.
            let n = 64 + r.below(700);
            let m = 3 + r.below(90);
            let k = 1 + r.below(40);
            (Tensor::randn(&[n, m], r), Tensor::randn(&[k, m], r))
        },
        |(pts, cen)| {
            for t in [1usize, 2, 4, 8] {
                let cfg = ExecConfig::with_threads(t);
                let (bl, bi) = assign_blocked_with(pts, cen, cfg);
                let (nl, ni) = assign_gemm_with(pts, cen, cfg);
                if bl != nl {
                    return Err(format!("labels differ at {t} threads"));
                }
                if bi.to_bits() != ni.to_bits() {
                    return Err(format!("inertia differs at {t} threads: {bi} vs {ni}"));
                }
                let mut cen_b = cen.clone();
                let mut cen_n = cen.clone();
                update_with(pts, &bl, &mut cen_b, cfg);
                update_with(pts, &nl, &mut cen_n, cfg);
                if bits(&cen_b) != bits(&cen_n) {
                    return Err(format!("updated centroids differ at {t} threads"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_reconstruction_error_bounded_by_clustering_error() {
    // W_new = W' + truncSVD(W - W') ⇒ ‖W - W_new‖ ≤ ‖W - W'‖ for any rank.
    check(
        "‖W−W_new‖ ≤ ‖W−W'‖",
        309,
        16,
        |r| {
            let m = 8 + r.below(24);
            let k = 2 + r.below(5);
            let rank = r.below(6);
            (Tensor::randn(&[m, m], r), k, rank)
        },
        |(w, k, rank)| {
            let c = compress_matrix(w, &SwscConfig::new(*k, *rank));
            let e_new = w.sub(&c.reconstruct()).fro_norm();
            let e_prime = w.sub(&c.reconstruct_uncompensated()).fro_norm();
            if e_new <= e_prime + 1e-4 {
                Ok(())
            } else {
                Err(format!("{e_new} > {e_prime}"))
            }
        },
    );
}
