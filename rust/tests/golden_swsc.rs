//! Golden-file regression test for `.swsc` artifact bytes.
//!
//! The determinism contract (ISSUE 2) says a compressed checkpoint is a
//! pure function of (weights, plan): worker counts, exec backends, and
//! scheduling must never change a byte, and refactors of the pool or the
//! blocked Lloyd path must never *silently* change the artifact. This test
//! pins both:
//!
//! 1. In-run invariants (always checked): the same seeded model compressed
//!    at workers ∈ {1, 2, 4, 8} and under both exec backends produces
//!    byte-identical `.swsc` containers.
//! 2. A checked-in fixture: the bytes must match
//!    `tests/fixtures/golden_tiny.swsc`. If the fixture is missing it is
//!    bootstrapped (written and reported) so fresh clones stay green; an
//!    *existing* fixture that mismatches is a hard failure. Intentional
//!    format/pipeline changes regenerate with `SWSC_REGEN_GOLDEN=1` and
//!    commit the new fixture.
//!
//! Cross-platform note: the golden model uses `Tensor::rand` (uniform)
//! weights and 64² matrices, which keeps the whole pipeline — SplitMix64
//! draws, k-means++ picks, Lloyd, the Jacobi SVD the planner selects at
//! this size, fp16 encode, bit-packing, CRC — on IEEE add/mul/sqrt only.
//! No libm transcendentals (`ln`, `sin`, `cos` from Box–Muller sampling)
//! touch the artifact, so the bytes are reproducible on any IEEE-754 host,
//! not just one libc version.

use std::path::PathBuf;

use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::compress_model;
use swsc::exec::{self, ExecBackend};
use swsc::io::Checkpoint;
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn golden_checkpoint() -> Checkpoint {
    let mut rng = Rng::new(0xC0FFEE);
    let mut ck = Checkpoint::new();
    for i in 0..2 {
        for p in ["wq", "wk", "wv"] {
            ck.insert(&format!("layers.{i}.attn.{p}"), Tensor::rand(&[64, 64], -1.0, 1.0, &mut rng));
        }
    }
    ck.insert("embed.tok", Tensor::rand(&[32, 64], -1.0, 1.0, &mut rng));
    ck
}

fn compress_bytes(workers: usize) -> Vec<u8> {
    let ck = golden_checkpoint();
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 9);
    assert!(!plan.is_empty(), "golden plan selected no matrices");
    compress_model(&ck, &plan, workers, None).expect("golden compression failed").file.to_bytes()
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden_tiny.swsc")
}

#[test]
fn golden_swsc_bytes_are_scheduling_invariant_and_match_fixture() {
    let bytes = compress_bytes(4);

    // 1a. Worker count must never change a byte.
    for workers in [1, 2, 8] {
        assert_eq!(
            compress_bytes(workers),
            bytes,
            "worker count {workers} changed the .swsc bytes"
        );
    }

    // 1b. Neither must the exec backend (pool vs spawn-per-call).
    exec::set_backend(ExecBackend::SpawnPerCall);
    let spawn_bytes = compress_bytes(4);
    exec::set_backend(ExecBackend::Pool);
    assert_eq!(spawn_bytes, bytes, "exec backend changed the .swsc bytes");

    // 2. Checked-in fixture.
    let path = fixture_path();
    if std::env::var("SWSC_REGEN_GOLDEN").is_ok() || !path.exists() {
        // Bootstrap keeps fresh clones green, but it makes the cross-run
        // guard vacuous until the fixture is committed. Strict mode
        // (SWSC_REQUIRE_GOLDEN=1) refuses to bootstrap — flip it on in CI
        // once tests/fixtures/golden_tiny.swsc is in the tree.
        assert!(
            std::env::var("SWSC_REQUIRE_GOLDEN").is_err() || std::env::var("SWSC_REGEN_GOLDEN").is_ok(),
            "SWSC_REQUIRE_GOLDEN is set but {} is missing — generate it locally \
             (cargo test --test golden_swsc) and commit it",
            path.display()
        );
        std::fs::create_dir_all(path.parent().unwrap()).expect("create fixtures dir");
        std::fs::write(&path, &bytes).expect("write golden fixture");
        eprintln!(
            "golden fixture written to {} ({} bytes) — commit it so future runs compare against it",
            path.display(),
            bytes.len()
        );
        return;
    }
    let want = std::fs::read(&path).expect("read golden fixture");
    if want != bytes {
        let first_diff = want
            .iter()
            .zip(&bytes)
            .position(|(a, b)| a != b)
            .unwrap_or_else(|| want.len().min(bytes.len()));
        panic!(
            "compressed .swsc bytes diverged from the checked-in fixture: fixture {} B, \
             produced {} B, first mismatch at byte {}. If this pipeline change is intentional, \
             regenerate with `SWSC_REGEN_GOLDEN=1 cargo test --test golden_swsc` and commit \
             tests/fixtures/golden_tiny.swsc.",
            want.len(),
            bytes.len(),
            first_diff
        );
    }
}
