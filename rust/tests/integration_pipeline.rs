//! End-to-end pipeline integration (tiny preset, artifact-gated): train a
//! few steps through the AOT train step, compress Q/K, evaluate all the
//! variants, and exercise the batched eval service.

use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::{compress_model, EvalRequest, EvalService, ServiceConfig};
use swsc::eval::Evaluator;
use swsc::io::Checkpoint;
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::runtime::{ArtifactManifest, Engine};
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus};
use swsc::train::{LrSchedule, Trainer};
use std::path::Path;

fn manifest() -> Option<ArtifactManifest> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(ArtifactManifest::load(dir, "tiny").expect("manifest"))
}

fn tiny_data(cfg: &ModelConfig) -> (Dataset, Dataset) {
    let corpus = SyntheticCorpus::generate(&CorpusConfig { articles: 30, seed: 7, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, cfg.vocab);
    (
        Dataset::from_text(&corpus.train_text, &tok, cfg.batch, cfg.seq),
        Dataset::from_text(&corpus.eval_text, &tok, cfg.batch, cfg.seq),
    )
}

#[test]
fn train_compress_eval_end_to_end() {
    let Some(man) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let engine = Engine::new(man).unwrap();
    let (train_data, eval_data) = tiny_data(&cfg);

    // 1. Train a handful of steps — loss must drop.
    let init = init_params(&cfg, 3);
    let mut trainer = Trainer::new(engine.clone(), cfg.clone(), &init).unwrap();
    let sched = LrSchedule::new(3e-3, 2, 40);
    for step in 0..40 {
        trainer.step(&train_data.batch(step), sched.at(step)).unwrap();
    }
    let first = trainer.losses[0];
    let last = *trainer.losses.last().unwrap();
    assert!(last < first - 0.3, "loss did not drop: {first} -> {last}");

    // 2. Evaluate the trained model.
    let ck = trainer.to_checkpoint().unwrap();
    let evaluator = Evaluator::new(engine.clone(), cfg.clone()).unwrap();
    let fp32 = evaluator.perplexity_of(&ck, &eval_data).unwrap();
    assert!(fp32.perplexity < cfg.vocab as f64, "trained ppl must beat uniform");

    // 3. Compress Q&K at 2 bits and re-evaluate: damage should be finite
    //    and bounded (SWSC keeps the model usable).
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 0);
    let out = compress_model(&ck, &plan, 4, None).unwrap();
    let mut sck = ck.clone();
    for (name, t) in out.file.restore_all() {
        sck.insert(&name, t);
    }
    let swsc = evaluator.perplexity_of(&sck, &eval_data).unwrap();
    assert!(swsc.perplexity.is_finite());
    assert!(
        swsc.perplexity < fp32.perplexity * 20.0,
        "SWSC damage out of range: {} vs fp32 {}",
        swsc.perplexity,
        fp32.perplexity
    );
}

#[test]
fn eval_service_batches_and_answers_everyone() {
    let Some(man) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let (_, eval_data) = tiny_data(&cfg);

    // Host-side params for the service (zeros = uniform model is fine —
    // the service test is about plumbing, not quality).
    let ck = init_params(&cfg, 4);
    let host_params: Vec<swsc::tensor::Tensor> = param_specs(&cfg)
        .iter()
        .map(|s| ck.get(&s.name).unwrap().clone())
        .collect();

    let service = EvalService::start(man, cfg.clone(), host_params, ServiceConfig::default()).unwrap();

    // Submit an odd number of requests (forces a padded final batch).
    let n_req = cfg.batch * 2 + 3;
    let mut rxs = Vec::new();
    let b0 = eval_data.batch(0);
    for i in 0..n_req {
        let mut window: Vec<i32> = b0.inputs[..cfg.seq].to_vec();
        window.push(b0.targets[cfg.seq - 1]);
        // Perturb each request so they are distinct.
        window[0] = (window[0] + i as i32) % cfg.vocab as i32;
        rxs.push(service.submit(EvalRequest { tokens: window }).unwrap());
    }
    let mut responses = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.nll_sum.is_finite() && resp.nll_sum > 0.0);
        assert_eq!(resp.tokens, cfg.seq);
        responses.push(resp);
    }
    assert_eq!(responses.len(), n_req);
    assert!(service.metrics.counter("service.requests") as usize == n_req);
    assert!(service.metrics.counter("service.batches") >= 3);
    service.shutdown();
}

#[test]
fn service_results_match_direct_evaluator() {
    let Some(man) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let (_, eval_data) = tiny_data(&cfg);
    let ck = init_params(&cfg, 5);

    // Direct evaluator on one batch.
    let engine = Engine::new(ArtifactManifest::load(Path::new("artifacts"), "tiny").unwrap()).unwrap();
    let evaluator = Evaluator::new(engine, cfg.clone()).unwrap();
    let one_batch = {
        let b = eval_data.batch(0);
        Dataset::from_ids(
            {
                // Rebuild the exact stream for row 0: inputs + final target.
                let mut ids = b.inputs[..cfg.seq].to_vec();
                ids.push(b.targets[cfg.seq - 1]);
                // Pad to fill a full batch of identical rows.
                let row = ids.clone();
                let mut all = Vec::new();
                for _ in 0..cfg.batch {
                    all.extend_from_slice(&row[..cfg.seq]);
                }
                all.push(row[cfg.seq]);
                all
            },
            cfg.batch,
            cfg.seq,
        )
    };
    // NOTE: from_ids builds shifted windows over a contiguous stream, so
    // row boundaries differ from the service's per-request windows; compare
    // only the first row's window, which is identical in both layouts.
    let direct = evaluator.perplexity_of(&ck, &one_batch).unwrap();

    let host_params: Vec<swsc::tensor::Tensor> = param_specs(&cfg)
        .iter()
        .map(|s| ck.get(&s.name).unwrap().clone())
        .collect();
    let man2 = ArtifactManifest::load(Path::new("artifacts"), "tiny").unwrap();
    let service = EvalService::start(man2, cfg.clone(), host_params, ServiceConfig::default()).unwrap();

    let b = eval_data.batch(0);
    let mut window: Vec<i32> = b.inputs[..cfg.seq].to_vec();
    window.push(b.targets[cfg.seq - 1]);
    let resp = service.eval_blocking(EvalRequest { tokens: window }).unwrap();
    let per_tok_service = resp.nll_sum / resp.tokens as f64;

    // Same model, same kind of stream ⇒ per-token NLL in the same ballpark
    // (uniform-ish model: both ≈ log vocab).
    assert!(
        (per_tok_service - direct.nll_per_token).abs() < 0.2,
        "service {per_tok_service} vs direct {}",
        direct.nll_per_token
    );
    service.shutdown();
}

#[test]
fn wrong_window_size_rejected() {
    let Some(man) = manifest() else { return };
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 6);
    let host_params: Vec<swsc::tensor::Tensor> = param_specs(&cfg)
        .iter()
        .map(|s| ck.get(&s.name).unwrap().clone())
        .collect();
    let service = EvalService::start(man, cfg.clone(), host_params, ServiceConfig::default()).unwrap();
    assert!(service.submit(EvalRequest { tokens: vec![1; 3] }).is_err());
    service.shutdown();
}
