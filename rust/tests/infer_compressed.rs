//! Compressed-domain inference invariants (ISSUE 4).
//!
//! Two contracts pinned here, mirroring the PR 1–3 parity discipline:
//!
//! 1. **Thread parity, bitwise.** Bucket sums and every
//!    `CompressedLinear` entry point are bit-identical at
//!    `SWSC_THREADS`-style thread counts ∈ {1, 2, 4, 8}, including
//!    remainder cases: channel counts not divisible by `CHANNEL_CHUNK`,
//!    empty clusters, and `r = 0`.
//! 2. **Exactness vs the dense route.** Where the compressed-domain
//!    accumulation order matches the dense `reconstruct()` + GEMM order
//!    (the gather orientations at `r = 0`), results are **bitwise equal**.
//!    Where the order must differ (bucket-sum orientation; any `r > 0`
//!    split into two dots), results agree to the ULP bound recorded in
//!    `tests/fixtures/README.md` (asserted here as atol/rtol 1e-3 — the
//!    same bound the packed-vs-naive GEMM tests use).
//!
//! Plus the serving surface: `EvalService::start_with_swsc` answers
//! linear requests from the compressed domain without artifacts (the
//! PJRT engine is lazily constructed and never touched).

use swsc::compress::{compress_matrix, CompressedMatrix, SwscConfig};
use swsc::coordinator::{EvalRequest, EvalService, LinearRequest, ServiceConfig};
use swsc::exec::ExecConfig;
use swsc::infer::{
    bucket_sums_indexed, bucket_sums_with, BucketIndex, CompressedLinear, CompressedModel,
    InferMode, CHANNEL_CHUNK,
};
use swsc::io::SwscFile;
use swsc::model::ModelConfig;
use swsc::tensor::Tensor;
use swsc::util::prop::{assert_close, check};
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Synthetic compressed matrix with `empty` guaranteed-empty trailing
/// clusters (k-means never produces these on sane data, but a `.swsc`
/// container legally can — the engine must serve them as zero buckets).
fn synthetic(
    m: usize,
    n: usize,
    k: usize,
    r: usize,
    empty: usize,
    rng: &mut Rng,
) -> CompressedMatrix {
    let live = (k - empty).max(1);
    CompressedMatrix {
        shape: (m, n),
        labels: (0..n).map(|_| rng.below(live) as u32).collect(),
        centroids: Tensor::randn(&[m, k], rng),
        factor_a: Tensor::randn(&[m, r], rng),
        factor_b: Tensor::randn(&[r, n], rng),
    }
}

/// Weights with clustered channel structure — the regime the paper
/// targets, so the exactness test runs on a *real* compression output.
fn structured_weights(m: usize, n: usize, groups: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> =
        (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let mut w = Tensor::zeros(&[m, n]);
    for j in 0..n {
        let col: Vec<f32> =
            centers[j % groups].iter().map(|&v| v + rng.normal_f32(0.0, 0.1)).collect();
        w.set_col(j, &col);
    }
    w
}

/// ISSUE 4 satellite: thread-parity property over bucket sums and every
/// CompressedLinear entry point, with remainder cases baked into the
/// generator (ragged n, empty clusters, r = 0).
#[test]
fn prop_infer_thread_parity_bitwise() {
    const THREADS: [usize; 3] = [2, 4, 8];
    check(
        "infer threads ∈ {1,2,4,8} are bit-identical",
        401,
        8,
        |r| {
            let m = 16 + r.below(80);
            // Ragged around the chunk edge on purpose.
            let n = CHANNEL_CHUNK - 20 + r.below(2 * CHANNEL_CHUNK + 41);
            let k = 2 + r.below(10);
            let empty = r.below(k.min(3));
            let rank = if r.below(4) == 0 { 0 } else { 1 + r.below(8) };
            let b = 1 + r.below(40);
            let c = synthetic(m, n, k, rank, empty, r);
            (c, Tensor::randn(&[n, b], r), m, b)
        },
        |(c, x, m, b)| {
            let lin = CompressedLinear::from_matrix(c);
            let idx = BucketIndex::new(&c.labels, c.k());
            let xt = Tensor::randn(&[*m, *b], &mut Rng::new(402));
            let xa = Tensor::randn(&[*b, *m], &mut Rng::new(403));

            let s_base = bits(&bucket_sums_with(x, &c.labels, c.k(), ExecConfig::serial()));
            let mm_base = bits(&lin.matmul_with(x, ExecConfig::serial()));
            let tm_base = bits(&lin.t_matmul_with(&xt, ExecConfig::serial()));
            let ap_base = bits(&lin.apply_with(&xa, ExecConfig::serial()));
            for t in THREADS {
                let cfg = ExecConfig::with_threads(t);
                if bits(&bucket_sums_with(x, &c.labels, c.k(), cfg)) != s_base {
                    return Err(format!("bucket sums differ at {t} threads"));
                }
                if bits(&bucket_sums_indexed(x, &idx, cfg)) != s_base {
                    return Err(format!("CSR bucket sums differ at {t} threads"));
                }
                if bits(&lin.matmul_with(x, cfg)) != mm_base {
                    return Err(format!("matmul differs at {t} threads"));
                }
                if bits(&lin.t_matmul_with(&xt, cfg)) != tm_base {
                    return Err(format!("t_matmul differs at {t} threads"));
                }
                if bits(&lin.apply_with(&xa, cfg)) != ap_base {
                    return Err(format!("apply differs at {t} threads"));
                }
            }
            // Panels pack lazily under the *first* call's config — a fresh
            // operator whose first use is parallel must match the
            // serial-first baseline (packing is thread-invariant).
            let lin2 = CompressedLinear::from_matrix(c);
            if bits(&lin2.matmul_with(x, ExecConfig::with_threads(8))) != mm_base {
                return Err("parallel first-use packing differs".into());
            }
            Ok(())
        },
    );
}

/// The exactness contract on a real compression output (structured
/// weights → k-means → SVD): compressed-domain results vs
/// `reconstruct()` + GEMM, at the documented bound.
#[test]
fn exactness_contract_vs_dense_route() {
    let w = structured_weights(96, 160, 8, 404);
    let c = compress_matrix(&w, &SwscConfig::new(8, 6));
    let lin = CompressedLinear::from_matrix(&c);
    let dense = c.reconstruct();
    let mut rng = Rng::new(405);

    let x = Tensor::randn(&[160, 24], &mut rng);
    assert_close(lin.matmul(&x).data(), dense.matmul(&x).data(), 1e-3, 1e-3).unwrap();

    let xt = Tensor::randn(&[96, 24], &mut rng);
    assert_close(lin.t_matmul(&xt).data(), dense.t_matmul(&xt).data(), 1e-3, 1e-3).unwrap();

    let xa = Tensor::randn(&[24, 96], &mut rng);
    assert_close(lin.apply(&xa).data(), xa.matmul(&dense).data(), 1e-3, 1e-3).unwrap();

    // matvec is bitwise the b = 1 matmul (shared numeric contract between
    // the chunked and CSR bucket-sum paths).
    let v: Vec<f32> = (0..160).map(|_| rng.normal() as f32).collect();
    let mv = lin.matvec(&v);
    let mm = lin.matmul(&Tensor::from_vec(&[160, 1], v.clone()));
    assert_eq!(
        mv.iter().map(|x| x.to_bits()).collect::<Vec<u32>>(),
        mm.data().iter().map(|x| x.to_bits()).collect::<Vec<u32>>()
    );
}

/// Where no accumulation order changes — the gather orientations at
/// r = 0 — the compressed domain is bit-for-bit the dense route.
#[test]
fn rank_zero_gather_orientations_bitwise_equal_dense() {
    let w = structured_weights(80, 112, 6, 406);
    let c = compress_matrix(&w, &SwscConfig::new(6, 0));
    assert_eq!(c.rank(), 0);
    let lin = CompressedLinear::from_matrix(&c);
    let dense = c.reconstruct();
    let mut rng = Rng::new(407);
    let xt = Tensor::randn(&[80, 16], &mut rng);
    assert_eq!(bits(&lin.t_matmul(&xt)), bits(&dense.t_matmul(&xt)), "t_matmul r=0");
    let xa = Tensor::randn(&[12, 80], &mut rng);
    assert_eq!(bits(&lin.apply(&xa)), bits(&xa.matmul(&dense)), "apply r=0");
}

/// Remainder cases called out by the ISSUE: n not divisible by the chunk,
/// empty clusters, r = 0 — all still correct vs the dense route.
#[test]
fn remainder_cases_match_dense_route() {
    let mut rng = Rng::new(408);
    for &(n, k, empty, r) in &[
        (CHANNEL_CHUNK + 37, 5usize, 2usize, 0usize),
        (3 * CHANNEL_CHUNK + 1, 7, 3, 4),
        (CHANNEL_CHUNK - 1, 3, 0, 2),
        (2 * CHANNEL_CHUNK, 4, 1, 0),
    ] {
        let c = synthetic(48, n, k, r, empty, &mut rng);
        let lin = CompressedLinear::from_matrix(&c);
        assert!(lin.index().empty_buckets() >= empty, "n={n} k={k}");
        let dense = c.reconstruct();
        let x = Tensor::randn(&[n, 9], &mut rng);
        assert_close(lin.matmul(&x).data(), dense.matmul(&x).data(), 1e-2, 1e-2)
            .unwrap_or_else(|e| panic!("n={n} k={k} empty={empty} r={r}: {e}"));
        // Empty buckets produce exactly-zero bucket sums.
        let s = bucket_sums_with(&x, &c.labels, k, ExecConfig::serial());
        for l in 0..k {
            if BucketIndex::new(&c.labels, k).bucket(l).is_empty() {
                assert!(s.row(l).iter().all(|&v| v == 0.0), "bucket {l} not zero");
            }
        }
    }
}

/// CompressedModel: both modes serve every entry, compressed ≈
/// reconstructed, dense passthrough exact — through a full
/// save-to-bytes/load round trip.
#[test]
fn compressed_model_round_trips_and_modes_agree() {
    let mut rng = Rng::new(409);
    let mut file = SwscFile::new();
    // Distinct seed per entry: identical weights would let a cross-entry
    // mixup during the round trip slip through unnoticed.
    for (i, name) in ["layers.0.attn.wq", "layers.1.attn.wk"].iter().enumerate() {
        let w = structured_weights(64, 64, 6, 410 + i as u64);
        file.compressed.insert((*name).into(), compress_matrix(&w, &SwscConfig::new(6, 4)));
    }
    file.dense.insert("embed.tok".into(), Tensor::randn(&[32, 64], &mut rng));

    let loaded = SwscFile::from_bytes(&file.to_bytes()).unwrap();
    let comp = CompressedModel::from_file(&loaded, InferMode::Compressed);
    let reco = CompressedModel::from_file(&loaded, InferMode::Reconstructed);
    assert_eq!(comp.num_compressed(), 2);
    assert_eq!(reco.num_compressed(), 0);

    let x = Tensor::randn(&[7, 64], &mut rng);
    for name in ["layers.0.attn.wq", "layers.1.attn.wk"] {
        let a = comp.apply(name, &x).unwrap();
        let b = reco.apply(name, &x).unwrap();
        assert_eq!(a.shape(), &[7, 64]);
        assert_close(a.data(), b.data(), 1e-3, 1e-3).unwrap_or_else(|e| panic!("{name}: {e}"));
        let xn = Tensor::randn(&[64, 7], &mut rng);
        let ma = comp.matmul(name, &xn).unwrap();
        let mb = reco.matmul(name, &xn).unwrap();
        assert_close(ma.data(), mb.data(), 1e-3, 1e-3).unwrap_or_else(|e| panic!("{name}: {e}"));
    }
    let xe = Tensor::randn(&[3, 32], &mut rng);
    assert_eq!(
        comp.apply("embed.tok", &xe).unwrap(),
        xe.matmul(&loaded.dense["embed.tok"])
    );
}

/// The serving surface: a linear-only service over a `.swsc` container,
/// no artifacts anywhere — concurrent clients, every request answered,
/// responses bitwise equal to a direct CompressedModel::apply, and the
/// eval surface cleanly reports itself disabled.
#[test]
fn service_serves_compressed_domain_linear_requests() {
    let cfg = ModelConfig::tiny();
    let mut file = SwscFile::new();
    let names = ["layers.0.attn.wq", "layers.0.attn.wk", "layers.1.attn.wq"];
    for (i, name) in names.iter().enumerate() {
        let w = structured_weights(cfg.d_model, cfg.d_model, 4, 500 + i as u64);
        file.compressed.insert((*name).into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
    }

    for mode in [InferMode::Compressed, InferMode::Reconstructed] {
        let svc_cfg = ServiceConfig { infer_mode: mode, ..Default::default() };
        let oracle = CompressedModel::from_file(&file, mode);
        let service = std::sync::Arc::new(
            EvalService::start_with_swsc(None, cfg.clone(), &file, svc_cfg).unwrap(),
        );

        let clients = 3;
        let per_client = 8;
        let mut handles = Vec::new();
        for cl in 0..clients {
            let service = service.clone();
            let names: Vec<String> = names.iter().map(|s| s.to_string()).collect();
            let d = cfg.d_model;
            handles.push(std::thread::spawn(move || -> Vec<(String, Tensor, Tensor)> {
                let mut rng = Rng::new(600 + cl as u64);
                let mut out = Vec::new();
                for i in 0..per_client {
                    let name = names[(cl + i) % names.len()].clone();
                    let x = Tensor::randn(&[2, d], &mut rng);
                    let resp = service
                        .linear_blocking(LinearRequest::new(&name, x.clone()))
                        .unwrap();
                    out.push((name, x, resp.y));
                }
                out
            }));
        }
        let mut answered = 0;
        for h in handles {
            for (name, x, y) in h.join().unwrap() {
                let want = oracle.apply(&name, &x).unwrap();
                assert_eq!(bits(&y), bits(&want), "{name} response differs from direct apply");
                answered += 1;
            }
        }
        assert_eq!(answered, clients * per_client);
        assert_eq!(
            service.metrics.counter("service.linear_requests"),
            (clients * per_client) as u64
        );

        // Unknown weight → error response, not a hang or a crash.
        let bad = LinearRequest::new("nope", Tensor::zeros(&[1, cfg.d_model]));
        assert!(service.linear_blocking(bad).is_err());

        // Eval surface is disabled (no manifest) but answers cleanly.
        let eval_err = service.eval_blocking(EvalRequest { tokens: vec![1; cfg.seq + 1] });
        assert!(eval_err.is_err());
        assert!(
            format!("{:#}", eval_err.unwrap_err()).contains("eval serving disabled"),
            "unexpected eval error"
        );

        if let Ok(s) = std::sync::Arc::try_unwrap(service) {
            s.shutdown();
        }
    }
}
