//! ISSUE 3 tentpole invariants for the packed register-tiled GEMM engine,
//! checked through the public API (the exact-shape packed-vs-naive sweep
//! over every MR/NR remainder lives in `tensor::gemm`'s unit tests, where
//! the packing internals are reachable directly).
//!
//! Everything here leans on one design fact: every GEMM kernel in the
//! crate — packed, blocked baseline, naive — accumulates each output
//! element in a single f32 register over strictly increasing k, with no
//! FMA contraction. So the packed engine must match the blocked kernel,
//! the explicit transpose-then-matmul route, and itself at any thread
//! count *bitwise*, and a full SWSC compression must produce identical
//! artifacts under either kernel. These tests stay correct even if another
//! test in the binary flips the process-wide kernel concurrently — the
//! kernels are interchangeable bit-for-bit, which is exactly the property
//! under test.

use swsc::compress::{compress_matrix, SvdBackend, SwscConfig};
use swsc::exec::ExecConfig;
use swsc::kmeans::{assign_blocked_with, assign_gemm_with};
use swsc::tensor::gemm::{self, GemmKernel};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Strided-A packing (no transpose materialization) must equal the
/// explicit transpose-then-matmul route bitwise, at every thread count.
/// Sized above the serial-fallback threshold (260·120·350 ≈ 2²³ MACs) so
/// the banded parallel path actually runs.
#[test]
fn t_matmul_strided_matches_transpose_matmul_bitwise() {
    let mut r = Rng::new(91);
    let a = Tensor::randn(&[350, 260], &mut r); // k × m source
    let b = Tensor::randn(&[350, 120], &mut r);
    let want = bits(&a.transpose_with(ExecConfig::serial()).matmul_with(&b, ExecConfig::serial()));
    for threads in [1usize, 2, 4, 8] {
        let got = bits(&a.t_matmul_with(&b, ExecConfig::with_threads(threads)));
        assert_eq!(got, want, "t_matmul differs at {threads} threads");
    }
}

/// Thread-parity bits for the packed default path: matmul and t_matmul at
/// threads ∈ {2, 4, 8} against the serial reference.
#[test]
fn packed_matmul_thread_parity_bits() {
    let mut r = Rng::new(92);
    let a = Tensor::randn(&[260, 190], &mut r);
    let b = Tensor::randn(&[190, 170], &mut r);
    let q = Tensor::randn(&[260, 64], &mut r);
    let base_mm = bits(&a.matmul_with(&b, ExecConfig::serial()));
    let base_tm = bits(&a.t_matmul_with(&q, ExecConfig::serial()));
    for threads in [2usize, 4, 8] {
        let cfg = ExecConfig::with_threads(threads);
        assert_eq!(bits(&a.matmul_with(&b, cfg)), base_mm, "matmul, {threads} threads");
        assert_eq!(bits(&a.t_matmul_with(&q, cfg)), base_tm, "t_matmul, {threads} threads");
    }
}

/// Kernel interchangeability end-to-end: a full SWSC compression (k-means
/// on the shared engine, randomized-SVD GEMMs, factor split) and its
/// reconstruction produce identical bits under the packed engine and the
/// blocked baseline. This is the guard that says kernel swaps can never
/// silently move the golden `.swsc` bytes.
#[test]
fn compression_bitwise_identical_under_both_kernels() {
    let mut r = Rng::new(93);
    let w = Tensor::randn(&[96, 96], &mut r);
    let mut cfg = SwscConfig::new(8, 6);
    cfg.seed = 7;
    cfg.svd = SvdBackend::Randomized; // force the subspace-iteration GEMMs
    let run = |kern: GemmKernel| {
        gemm::set_kernel(kern);
        let c = compress_matrix(&w, &cfg);
        let rec = c.reconstruct();
        gemm::set_kernel(GemmKernel::Packed);
        (c, rec)
    };
    let (cp, rp) = run(GemmKernel::Packed);
    let (cb, rb) = run(GemmKernel::Blocked);
    assert_eq!(cp.labels, cb.labels, "labels differ between kernels");
    assert_eq!(bits(&cp.centroids), bits(&cb.centroids), "centroids differ");
    assert_eq!(bits(&cp.factor_a), bits(&cb.factor_a), "factor A differs");
    assert_eq!(bits(&cp.factor_b), bits(&cb.factor_b), "factor B differs");
    assert_eq!(bits(&rp), bits(&rb), "reconstruction differs");
}

/// The blocked Lloyd assign rides the shared engine too: packed-kernel
/// per-chunk tiles vs the full-GEMM reference, equal labels and inertia
/// bits at every thread count (ragged n, k, dims on purpose).
#[test]
fn blocked_assign_on_packed_engine_equals_reference() {
    let mut r = Rng::new(94);
    let pts = Tensor::randn(&[3 * 128 + 45, 37], &mut r);
    let cen = Tensor::randn(&[11, 37], &mut r);
    for threads in [1usize, 2, 4, 8] {
        let cfg = ExecConfig::with_threads(threads);
        let (bl, bi) = assign_blocked_with(&pts, &cen, cfg);
        let (gl, gi) = assign_gemm_with(&pts, &cen, cfg);
        assert_eq!(bl, gl, "labels, {threads} threads");
        assert_eq!(bi.to_bits(), gi.to_bits(), "inertia, {threads} threads");
    }
}
