//! Observability invariants (PR 9).
//!
//! The load-bearing contract: **tracing is pure observation.** Enabling
//! the [`swsc::obs::TraceSink`] must not move a single bit of any
//! response, at any `SWSC_THREADS` (CI sweeps 1 and 4; the solo oracle
//! below additionally sweeps explicit thread configs {1, 2, 4}), and
//! for a pinned fault seed and a sequential schedule the span/event
//! *structure* (ids, kinds, labels — not durations) is identical across
//! independent server lifecycles.
//!
//! Pinned here:
//!
//! 1. traced vs untraced serving, mixed linear + forward stream: both
//!    servers' responses bitwise equal each other AND the solo oracle
//!    (which itself is thread-invariant across {1, 2, 4});
//! 2. chaos structure determinism: same `FaultConfig` seed + sequential
//!    submission ⇒ byte-identical `TraceSink::structure()` and the same
//!    per-request outcome classification across two full lifecycles;
//! 3. the export surfaces: Chrome trace JSON is structurally valid and
//!    complete per admitted request, the ring stays bounded under real
//!    traffic, and `dump_trace()` is `None` when tracing is off.

use std::sync::Arc;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::exec::ExecConfig;
use swsc::infer::{CompressedForward, CompressedModel, InferMode};
use swsc::io::SwscFile;
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::obs::{EventKind, SpanKind, TraceConfig, TraceData};
use swsc::serve::{
    BatchConfig, BatchServer, FaultConfig, FaultInjector, ForwardRequest, LinearRequest,
    ModelRegistry, ServeError, ServerOptions, DEFAULT_MODEL,
};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// A tiny-config container covering every model parameter (the
/// `serve_forward.rs` fixture: wide 2-D weights SWSC-compressed, the
/// rest dense).
fn tiny_file(cfg: &ModelConfig, seed: u64) -> SwscFile {
    let ck = init_params(cfg, seed);
    let mut file = SwscFile::new();
    for spec in param_specs(cfg) {
        let t = ck.get(&spec.name).unwrap().clone();
        if spec.shape.len() == 2 && spec.shape[1] >= 16 {
            file.compressed.insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
        } else {
            file.dense.insert(spec.name.clone(), t);
        }
    }
    file
}

/// Seeded mixed workload: linear (weight, activations) pairs plus
/// forward token windows — the same streams every comparison replays.
#[allow(clippy::type_complexity)]
fn mixed_stream(
    model: &CompressedModel,
    cfg: &ModelConfig,
    seed: u64,
    linears: usize,
    forwards: usize,
) -> (Vec<(String, Tensor)>, Vec<Vec<u32>>) {
    let mut rng = Rng::new(seed);
    // Only 2-D entries answer `LinearRequest`s (1-D dense params —
    // biases, layer norms — have no `shape`).
    let weights: Vec<String> = model
        .names()
        .into_iter()
        .filter(|w| model.shape(w).is_some())
        .map(String::from)
        .collect();
    let lin: Vec<(String, Tensor)> = (0..linears)
        .map(|_| {
            let w = weights[rng.below(weights.len())].clone();
            let (m, _) = model.shape(&w).unwrap();
            let rows = 1 + rng.below(4);
            (w, Tensor::randn(&[rows, m], &mut rng))
        })
        .collect();
    let windows: Vec<Vec<u32>> = (0..forwards)
        .map(|_| {
            let t = 1 + rng.below(cfg.seq.min(8));
            (0..t).map(|_| rng.below(cfg.vocab) as u32).collect()
        })
        .collect();
    (lin, windows)
}

/// Serve the whole mixed stream (overlapping submissions, so coalescing
/// and layer-step grouping actually happen) and return the response
/// bits, plus the trace record count (0 when tracing is off).
fn serve_stream(
    fwd: &Arc<CompressedForward>,
    lin: &[(String, Tensor)],
    windows: &[Vec<u32>],
    trace: Option<TraceConfig>,
) -> (Vec<Vec<u32>>, Vec<Vec<u32>>, usize) {
    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        // Faults pinned off: the chaos determinism test below owns that
        // axis, and the chaos CI job exports SWSC_CHAOS_* env that the
        // default options would otherwise pick up.
        ServerOptions { trace, faults: None, ..ServerOptions::default() },
    );
    let lrx: Vec<_> = lin
        .iter()
        .map(|(w, x)| {
            server.submit(DEFAULT_MODEL, LinearRequest::new(w.clone(), x.clone())).unwrap()
        })
        .collect();
    let frx: Vec<_> = windows
        .iter()
        .map(|w| server.submit_forward(DEFAULT_MODEL, ForwardRequest::new(w.clone())).unwrap())
        .collect();
    let lin_bits: Vec<Vec<u32>> =
        lrx.into_iter().map(|rx| bits(&rx.recv().unwrap().unwrap().y)).collect();
    let fwd_bits: Vec<Vec<u32>> =
        frx.into_iter().map(|rx| bits(&rx.recv().unwrap().unwrap().logits)).collect();
    let traced_records = server.trace_sink().map(|t| t.len()).unwrap_or(0);
    server.shutdown();
    (lin_bits, fwd_bits, traced_records)
}

/// Tentpole invariant: traced and untraced serving are **bitwise
/// identical** — and both equal the solo oracle, which is itself
/// bitwise invariant across explicit thread configs {1, 2, 4}. So the
/// parity holds at any `SWSC_THREADS` by transitivity.
#[test]
fn traced_vs_untraced_serving_is_bitwise_identical() {
    let cfg = ModelConfig::tiny();
    let file = tiny_file(&cfg, 950);
    let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
    let fwd = Arc::new(CompressedForward::new(model.clone(), cfg.clone()).unwrap());
    let (lin, windows) = mixed_stream(&model, &cfg, 951, 12, 8);

    // Solo oracle, serial reference.
    let lin_oracle: Vec<Vec<u32>> = lin
        .iter()
        .map(|(w, x)| bits(&model.apply_with(w, x, ExecConfig::serial()).unwrap()))
        .collect();
    let fwd_oracle: Vec<Vec<u32>> = windows
        .iter()
        .map(|w| bits(&fwd.forward_with(w, ExecConfig::serial()).unwrap()))
        .collect();
    // The oracle itself is thread-invariant (satellite 4's sweep).
    for t in [1usize, 2, 4] {
        let exec = ExecConfig::with_threads(t);
        for ((w, x), want) in lin.iter().zip(&lin_oracle) {
            assert_eq!(
                &bits(&model.apply_with(w, x, exec).unwrap()),
                want,
                "oracle apply({w}) not thread-invariant at {t} threads"
            );
        }
        for (w, want) in windows.iter().zip(&fwd_oracle) {
            assert_eq!(
                &bits(&fwd.forward_with(w, exec).unwrap()),
                want,
                "oracle forward ({} tokens) not thread-invariant at {t} threads",
                w.len()
            );
        }
    }

    let (lin_off, fwd_off, rec_off) = serve_stream(&fwd, &lin, &windows, None);
    let (lin_on, fwd_on, rec_on) = serve_stream(&fwd, &lin, &windows, Some(TraceConfig::default()));
    assert_eq!(rec_off, 0, "untraced server must record nothing");
    assert!(rec_on > 0, "traced server must have recorded spans/events");
    assert_eq!(lin_off, lin_on, "tracing moved linear response bits");
    assert_eq!(fwd_off, fwd_on, "tracing moved forward response bits");
    assert_eq!(lin_on, lin_oracle, "traced linear responses diverged from the solo oracle");
    assert_eq!(fwd_on, fwd_oracle, "traced forward responses diverged from the solo oracle");
}

/// One sequential (submit → recv, one request at a time) lifecycle
/// against a fault-injecting traced server: returns the duration-free
/// span/event structure and the per-request outcome classification.
fn chaos_lifecycle(
    fwd: &Arc<CompressedForward>,
    lin: &[(String, Tensor)],
    windows: &[Vec<u32>],
    faults: FaultConfig,
) -> (Vec<String>, Vec<&'static str>) {
    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        ServerOptions {
            trace: Some(TraceConfig::default()),
            faults: Some(faults),
            ..ServerOptions::default()
        },
    );
    let mut outcomes = Vec::new();
    let mut classify = |res: Result<Result<(), ServeError>, ()>| {
        outcomes.push(match res {
            Err(()) => "rejected",
            Ok(Ok(())) => "ok",
            Ok(Err(ServeError::Panicked { .. })) => "panicked",
            Ok(Err(_)) => "error",
        })
    };
    // Strictly sequential: each request is fully answered (or rejected)
    // before the next is submitted, so batch composition — and with it
    // the span structure — is a pure function of the fault schedule.
    for (w, x) in lin {
        match server.submit(DEFAULT_MODEL, LinearRequest::new(w.clone(), x.clone())) {
            Ok(rx) => classify(Ok(rx.recv().unwrap().map(|_| ()))),
            Err(_) => classify(Err(())),
        }
    }
    for w in windows {
        match server.submit_forward(DEFAULT_MODEL, ForwardRequest::new(w.clone())) {
            Ok(rx) => classify(Ok(rx.recv().unwrap().map(|_| ()))),
            Err(_) => classify(Err(())),
        }
    }
    let sink = server.trace_sink().expect("tracing enabled").clone();
    server.shutdown();
    (sink.structure(), outcomes)
}

/// Chaos structure determinism: for a pinned fault seed (the CI chaos
/// job's `SWSC_CHAOS_SEED=0` by default) and a sequential schedule, two
/// independent server lifecycles produce the identical span/event
/// structure and outcome classification — including the injected
/// faults' own events.
#[test]
fn chaos_span_structure_is_deterministic_for_pinned_seed() {
    let cfg = ModelConfig::tiny();
    let file = tiny_file(&cfg, 960);
    let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
    let fwd = Arc::new(CompressedForward::new(model.clone(), cfg.clone()).unwrap());
    let (lin, windows) = mixed_stream(&model, &cfg, 961, 10, 4);
    let n = (lin.len() + windows.len()) as u64;

    let env_seed: u64 = std::env::var("SWSC_CHAOS_SEED")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0);
    // Alongside the pinned env seed, scan for a seed whose schedule
    // mixes rejections, panics, and clean requests, so the comparison
    // provably covers every event kind the injector can emit.
    let base = FaultConfig { panic_rate: 0.3, reject_rate: 0.2, ..FaultConfig::default() };
    let mixed_seed = (0..1000)
        .find(|&s| {
            let probe = FaultInjector::new(FaultConfig { seed: s, ..base.clone() });
            let rejects = (0..n).filter(|&id| probe.injects_rejection(id)).count() as u64;
            let panics = (0..n)
                .filter(|&id| !probe.injects_rejection(id) && probe.injects_panic(id))
                .count() as u64;
            rejects > 0 && panics > 0 && rejects + panics < n
        })
        .expect("some seed under 1000 must mix outcomes");

    for seed in [env_seed, mixed_seed] {
        let faults = FaultConfig { seed, ..base.clone() };
        let (s1, o1) = chaos_lifecycle(&fwd, &lin, &windows, faults.clone());
        let (s2, o2) = chaos_lifecycle(&fwd, &lin, &windows, faults);
        assert_eq!(o1, o2, "seed {seed}: outcome classification must be deterministic");
        assert_eq!(s1, s2, "seed {seed}: span/event structure must be deterministic");
        assert!(!s1.is_empty(), "seed {seed}: traced lifecycle recorded nothing");
        if seed == mixed_seed {
            let has = |needle: &str| s1.iter().any(|l| l.contains(needle));
            assert!(has(":fault_injected:"), "mixed seed must record injected faults");
            assert!(has(":rejected:"), "mixed seed must record rejections");
            assert!(has(":panic:"), "mixed seed must record contained panics");
        }
    }
}

/// Scan one JSON document for structural soundness: braces/brackets
/// balanced outside strings, escapes honored.
fn assert_balanced_json(json: &str) {
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in json.chars() {
        if esc {
            esc = false;
            continue;
        }
        match c {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '{' | '[' if !in_str => depth += 1,
            '}' | ']' if !in_str => depth -= 1,
            _ => {}
        }
        assert!(depth >= 0, "unbalanced close in export");
    }
    assert_eq!(depth, 0, "unbalanced export");
    assert!(!in_str, "unterminated string in export");
}

/// Export surface: the Chrome trace from a real serving run is valid,
/// complete per admitted request (one queue-wait span and at least one
/// apply/layer-step span each), and timestamp-sane.
#[test]
fn chrome_export_is_valid_and_complete_per_request() {
    let cfg = ModelConfig::tiny();
    let file = tiny_file(&cfg, 970);
    let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
    let fwd = Arc::new(CompressedForward::new(model.clone(), cfg.clone()).unwrap());
    let (lin, windows) = mixed_stream(&model, &cfg, 971, 8, 4);

    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        ServerOptions {
            trace: Some(TraceConfig::default()),
            faults: None,
            ..ServerOptions::default()
        },
    );
    for (w, x) in &lin {
        server
            .submit(DEFAULT_MODEL, LinearRequest::new(w.clone(), x.clone()))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
    }
    for w in &windows {
        server
            .submit_forward(DEFAULT_MODEL, ForwardRequest::new(w.clone()))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
    }
    let sink = server.trace_sink().expect("tracing enabled").clone();
    let json = server.dump_trace().expect("tracing enabled");
    server.shutdown();

    assert!(json.starts_with('['), "chrome export must be a JSON array");
    assert_balanced_json(&json);
    for key in ["\"ph\":\"X\"", "\"ph\":\"i\"", "\"pid\":1", "\"tid\":"] {
        assert!(json.contains(key), "chrome export missing {key}");
    }

    // Per-request completeness, from the structured records.
    let records = sink.records();
    assert_eq!(sink.dropped(), 0, "default capacity must hold this whole run");
    let admitted: Vec<u64> = records
        .iter()
        .filter(|r| matches!(r.data, TraceData::Event { kind: EventKind::Admitted }))
        .map(|r| r.trace)
        .collect();
    assert_eq!(admitted.len(), lin.len() + windows.len(), "every request must be admitted");
    for id in admitted {
        let spans: Vec<SpanKind> = records
            .iter()
            .filter(|r| r.trace == id)
            .filter_map(|r| match r.data {
                TraceData::Span { kind, .. } => Some(kind),
                TraceData::Event { .. } => None,
            })
            .collect();
        assert_eq!(
            spans.iter().filter(|k| **k == SpanKind::QueueWait).count(),
            1,
            "request {id} must close exactly one queue-wait span"
        );
        assert!(
            spans.iter().any(|k| matches!(k, SpanKind::GroupApply | SpanKind::LayerStep)),
            "request {id} must record compute spans"
        );
    }
    assert!(
        records.iter().any(|r| {
            r.trace == 0 && matches!(r.data, TraceData::Span { kind: SpanKind::BatchPick, .. })
        }),
        "server track must record batch picks"
    );
}

/// The ring is bounded under real traffic, and a server without tracing
/// exposes no sink at all.
#[test]
fn ring_stays_bounded_and_disabled_tracing_costs_nothing() {
    let cfg = ModelConfig::tiny();
    let file = tiny_file(&cfg, 980);
    let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
    let fwd = Arc::new(CompressedForward::new(model.clone(), cfg.clone()).unwrap());
    let (lin, _) = mixed_stream(&model, &cfg, 981, 16, 0);

    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        ServerOptions {
            trace: Some(TraceConfig { capacity: 8 }),
            faults: None,
            ..ServerOptions::default()
        },
    );
    for (w, x) in &lin {
        server
            .submit(DEFAULT_MODEL, LinearRequest::new(w.clone(), x.clone()))
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
    }
    let sink = server.trace_sink().expect("tracing enabled");
    assert!(sink.len() <= 8, "ring exceeded its capacity: {}", sink.len());
    assert!(sink.dropped() > 0, "16 requests must overflow an 8-record ring");
    assert_balanced_json(&server.dump_trace().unwrap());
    server.shutdown();

    let reg = ModelRegistry::new();
    reg.insert_forward(DEFAULT_MODEL, fwd.clone());
    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        ServerOptions { trace: None, faults: None, ..ServerOptions::default() },
    );
    server
        .submit(DEFAULT_MODEL, LinearRequest::new(lin[0].0.clone(), lin[0].1.clone()))
        .unwrap()
        .recv()
        .unwrap()
        .unwrap();
    assert!(server.trace_sink().is_none(), "untraced server must expose no sink");
    assert!(server.dump_trace().is_none(), "untraced server must export nothing");
    server.shutdown();
}
