//! Cross-layer integration: the AOT artifacts (L1/L2) executed from rust
//! must agree with the rust-side (L3) CPU implementations of the same math.
//!
//! Requires `make artifacts` (skipped with a clear message otherwise).

use swsc::compress::{compress_matrix, SwscConfig};
use swsc::kmeans::{assign, update};
use swsc::model::ModelConfig;
use swsc::quant::{rtn_quantize, RtnConfig, RtnMode};
use swsc::runtime::{literal_to_tensor, tensor_to_literal, ArtifactManifest, Engine};
use swsc::tensor::Tensor;
use swsc::util::prop::assert_close;
use swsc::util::rng::Rng;
use std::path::Path;

fn engine() -> Option<(Engine, ModelConfig)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let man = ArtifactManifest::load(dir, "tiny").expect("manifest parse");
    let cfg = ModelConfig::tiny();
    man.verify_config(&cfg).expect("fingerprint");
    Some((Engine::new(man).expect("engine"), cfg))
}

#[test]
fn manifest_param_contract_holds() {
    let Some((engine, _cfg)) = engine() else { return };
    // verify_config already ran; double-check params non-empty and ordered.
    let params = &engine.manifest().params;
    assert_eq!(params[0].0, "embed.tok");
    assert!(params.len() > 10);
}

#[test]
fn hlo_kmeans_step_matches_rust_lloyd_step() {
    let Some((engine, cfg)) = engine() else { return };
    let d = cfg.d_model;
    let k = 4; // tiny preset 2-bit budget
    let exe = engine.load(&format!("kmeans_step_k{k}")).expect("load");

    let mut rng = Rng::new(201);
    let points = Tensor::randn(&[d, d], &mut rng); // channels as rows
    let centroids = {
        let mut c = Tensor::zeros(&[k, d]);
        for i in 0..k {
            c.row_mut(i).copy_from_slice(points.row(i * 3));
        }
        c
    };

    let outs = exe
        .run(&[tensor_to_literal(&points).unwrap(), tensor_to_literal(&centroids).unwrap()])
        .expect("run");
    let hlo_labels = outs[0].to_vec::<i32>().expect("labels");
    let hlo_inertia = literal_to_tensor(&outs[1]).unwrap().data()[0] as f64;
    let hlo_newc = literal_to_tensor(&outs[2]).unwrap();

    // Rust-side equivalent step.
    let (labels, inertia) = assign(&points, &centroids);
    let mut newc = centroids.clone();
    update(&points, &labels, &mut newc);

    let rust_labels: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
    assert_eq!(hlo_labels, rust_labels, "assignment disagrees");
    assert!(
        (hlo_inertia - inertia).abs() / inertia.max(1e-9) < 1e-3,
        "inertia {hlo_inertia} vs {inertia}"
    );
    assert_close(hlo_newc.data(), newc.data(), 1e-4, 1e-4).expect("centroid update");
}

#[test]
fn hlo_reconstruct_matches_rust_reconstruct() {
    let Some((engine, cfg)) = engine() else { return };
    let d = cfg.d_model;
    let (k, r) = (4, 2);
    let exe = engine.load(&format!("reconstruct_k{k}_r{r}")).expect("load");

    let mut rng = Rng::new(202);
    let w = Tensor::randn(&[d, d], &mut rng);
    let c = compress_matrix(&w, &SwscConfig::new(k, r));

    let labels_i32: Vec<i32> = c.labels.iter().map(|&l| l as i32).collect();
    let labels_lit = xla::Literal::vec1(&labels_i32);
    let outs = exe
        .run(&[
            labels_lit,
            tensor_to_literal(&c.centroids).unwrap(),
            tensor_to_literal(&c.factor_a).unwrap(),
            tensor_to_literal(&c.factor_b).unwrap(),
        ])
        .expect("run");
    let hlo_w = literal_to_tensor(&outs[0]).unwrap();
    let rust_w = c.reconstruct();
    assert_close(hlo_w.data(), rust_w.data(), 1e-4, 1e-4).expect("reconstruct parity");
}

#[test]
fn hlo_rtn_matches_rust_rtn() {
    let Some((engine, cfg)) = engine() else { return };
    let d = cfg.d_model;
    for bits in [2u32, 3] {
        let exe = engine.load(&format!("rtn_b{bits}")).expect("load");
        let mut rng = Rng::new(203 + bits as u64);
        let w = Tensor::randn(&[d, d], &mut rng);
        let outs = exe.run(&[tensor_to_literal(&w).unwrap()]).expect("run");
        let hlo_q = literal_to_tensor(&outs[0]).unwrap();
        let rust_q = rtn_quantize(&w, &RtnConfig { bits, mode: RtnMode::Asymmetric });
        assert_close(hlo_q.data(), rust_q.data(), 1e-4, 1e-4)
            .unwrap_or_else(|e| panic!("rtn_b{bits} parity: {e}"));
    }
}

#[test]
fn hlo_decode_matmul_matches_dense_path() {
    let Some((engine, cfg)) = engine() else { return };
    let d = cfg.d_model;
    let (k, r) = (4, 2);
    let exe = engine.load(&format!("decode_matmul_k{k}_r{r}")).expect("load");

    let mut rng = Rng::new(204);
    let w = Tensor::randn(&[d, d], &mut rng);
    let c = compress_matrix(&w, &SwscConfig::new(k, r));
    let b = cfg.batch * cfg.seq;
    let x = Tensor::randn(&[b, d], &mut rng);

    let labels_i32: Vec<i32> = c.labels.iter().map(|&l| l as i32).collect();
    let outs = exe
        .run(&[
            tensor_to_literal(&x).unwrap(),
            xla::Literal::vec1(&labels_i32),
            tensor_to_literal(&c.centroids).unwrap(),
            tensor_to_literal(&c.factor_a).unwrap(),
            tensor_to_literal(&c.factor_b).unwrap(),
        ])
        .expect("run");
    let y_fused = literal_to_tensor(&outs[0]).unwrap();
    let y_dense = x.matmul(&c.reconstruct());
    assert_close(y_fused.data(), y_dense.data(), 1e-2, 1e-2).expect("fused == dense");
}

#[test]
fn fwd_eval_perplexity_of_uniform_model_is_vocab() {
    // With all-zero weights the logits are uniform ⇒ ppl == vocab size.
    let Some((engine, cfg)) = engine() else { return };
    use swsc::eval::Evaluator;
    use swsc::io::Checkpoint;
    use swsc::model::param_specs;
    use swsc::text::Dataset;

    let mut ck = Checkpoint::new();
    for spec in param_specs(&cfg) {
        // zeros everywhere (incl. LN gain: output = bias = 0 -> uniform).
        ck.insert(&spec.name, Tensor::zeros(&spec.shape));
    }
    let ids: Vec<i32> = (0..(cfg.batch * cfg.seq * 2 + 1) as i32)
        .map(|i| i % cfg.vocab as i32)
        .collect();
    let data = Dataset::from_ids(ids, cfg.batch, cfg.seq);
    let ev = Evaluator::new(engine, cfg.clone()).expect("evaluator");
    let res = ev.perplexity_of(&ck, &data).expect("ppl");
    let want = cfg.vocab as f64;
    assert!(
        (res.perplexity - want).abs() / want < 1e-3,
        "uniform ppl {} != vocab {want}",
        res.perplexity
    );
}
