//! Quantized-serving invariants (ISSUE 6).
//!
//! Contracts pinned here, extending the PR 1–5 parity discipline to the
//! double-compressed (grouped-int8) path:
//!
//! 1. **Fused == dequantize-then-f32, bitwise.** The fused
//!    dequantize-in-register `apply` is bit-identical to serving the
//!    dequantized factors through the f32 `CompressedLinear` — at thread
//!    counts ∈ {1, 2, 4, 8}, over random shapes covering all MR/NR
//!    microkernel remainders and ragged quantization groups. The fused
//!    kernel and `QuantizedTensor::dequantize` share one `dequant_u8`
//!    expression, which is what makes this an equality, not a tolerance.
//! 2. **Documented error bound vs the pre-quantization f32 weights.**
//!    Each dequantized factor entry sits within its block's grid step of
//!    the original value, so the serving product differs from the f32
//!    oracle by at most the accumulated `Σ |x|·step` terms — asserted
//!    per element against a bound computed from the *actual* dequant
//!    error matrices (see `tests/fixtures/README.md`).
//! 3. **Round trip through the container.** A version-2 `.swsc` file
//!    serializes the codes exactly (u8 + f32 LE), so save → load →
//!    `CompressedModel::apply` at `Precision::Int8` is bitwise equal to
//!    serving the in-memory original.

use swsc::compress::{compress_matrix, CompressedMatrix, SwscConfig};
use swsc::exec::ExecConfig;
use swsc::infer::{CompressedLinear, CompressedModel, InferMode, Precision, QuantizedLinear};
use swsc::io::SwscFile;
use swsc::quant::QuantConfig;
use swsc::tensor::Tensor;
use swsc::util::prop::{check, default_cases};
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Random compressed matrix built directly in the storage layout (cluster
/// quality is irrelevant to these invariants; skipping the real k-means +
/// SVD keeps the property loop fast).
fn synthetic(m: usize, n: usize, k: usize, r: usize, rng: &mut Rng) -> CompressedMatrix {
    CompressedMatrix {
        shape: (m, n),
        labels: (0..n).map(|_| rng.below(k) as u32).collect(),
        centroids: Tensor::randn(&[m, k], rng),
        factor_a: Tensor::randn(&[m, r], rng),
        factor_b: Tensor::randn(&[r, n], rng),
    }
}

#[derive(Debug)]
struct Case {
    m: usize,
    n: usize,
    k: usize,
    r: usize,
    group: usize,
    bsz: usize,
    seed: u64,
}

/// Contract 1: fused apply is bitwise the dequantize-then-f32 oracle, at
/// every thread count, over shapes hitting all microkernel remainders
/// (m, n, bsz not tile-aligned) and ragged groups (group ∤ rows, group >
/// rows, group = 1).
#[test]
fn prop_fused_apply_bitwise_matches_dequant_oracle_across_threads() {
    check(
        "fused_apply_bitwise",
        0x5106,
        default_cases().min(40),
        |rng| Case {
            m: 1 + rng.below(40),
            n: 1 + rng.below(40),
            k: 1 + rng.below(8),
            r: rng.below(6),
            group: 1 + rng.below(24),
            bsz: rng.below(10),
            seed: rng.below(1 << 30) as u64,
        },
        |c| {
            let mut rng = Rng::new(c.seed);
            let q = synthetic(c.m, c.n, c.k, c.r, &mut rng).quantize(&QuantConfig { group: c.group });
            let lin = QuantizedLinear::from_matrix(&q);
            let oracle = CompressedLinear::from_matrix(&q.dequantize());
            let x = Tensor::randn(&[c.bsz, c.m], &mut rng);
            let want = bits(&oracle.apply_with(&x, ExecConfig::serial()));
            for threads in [1usize, 2, 4, 8] {
                let got = bits(&lin.apply_with(&x, ExecConfig::with_threads(threads)));
                if got != want {
                    return Err(format!("fused != oracle at {threads} threads"));
                }
            }
            Ok(())
        },
    );
}

/// Contract 2: per-element error vs the pre-quantization f32 weights is
/// bounded by the accumulated grid steps. The bound is computed from the
/// actual dequantization error matrices `e_R`, `e_A`, `e_B`:
///
/// ```text
/// Y_q − Y = (X·e_R)[:, labels] + (X·e_A)·B_q + (X·A)·e_B
/// |Y_q − Y| ≤ (|X|·|e_R|)[:, labels] + (|X|·|e_A|)·|B_q| + (|X|·|A|)·|e_B|
/// ```
///
/// plus a small float-rounding slack for the differing accumulation
/// orders. This is the numeric contract recorded in
/// `tests/fixtures/README.md` for the quantized serving path.
#[test]
fn quantized_apply_error_bounded_by_grid_steps() {
    let mut rng = Rng::new(0x5107);
    let w = Tensor::randn(&[48, 64], &mut rng);
    let c = compress_matrix(&w, &SwscConfig::new(6, 4));
    for group in [4usize, 16, 64] {
        let q = c.quantize(&QuantConfig { group });
        let lin = QuantizedLinear::from_matrix(&q);
        let f32_lin = CompressedLinear::from_matrix(&c);
        let x = Tensor::randn(&[7, 48], &mut rng);
        let got = lin.apply(&x);
        let want = f32_lin.apply(&x);

        let abs = |t: &Tensor| Tensor::from_vec(t.shape(), t.data().iter().map(|v| v.abs()).collect());
        let diff = |a: &Tensor, b: &Tensor| {
            Tensor::from_vec(
                a.shape(),
                a.data().iter().zip(b.data()).map(|(p, q)| (p - q).abs()).collect(),
            )
        };
        let dq = q.dequantize();
        let (e_r, e_a, e_b) = (
            diff(&dq.centroids, &c.centroids),
            diff(&dq.factor_a, &c.factor_a),
            diff(&dq.factor_b, &c.factor_b),
        );
        let ax = abs(&x);
        // (|X|·|e_R|)[:, labels]
        let xer = ax.matmul(&e_r);
        // (|X|·|e_A|)·|B_q| + (|X|·|A|)·|e_B|
        let low_rank = {
            let t1 = ax.matmul(&e_a).matmul(&abs(&dq.factor_b));
            let t2 = ax.matmul(&abs(&c.factor_a)).matmul(&e_b);
            Tensor::from_vec(
                t1.shape(),
                t1.data().iter().zip(t2.data()).map(|(p, q)| p + q).collect(),
            )
        };
        for t in 0..got.rows() {
            for (j, &label) in q.labels.iter().enumerate() {
                let bound = xer.at(t, label as usize)
                    + low_rank.at(t, j)
                    + 1e-4 * (1.0 + want.at(t, j).abs());
                let err = (got.at(t, j) - want.at(t, j)).abs();
                assert!(
                    err <= bound,
                    "group {group} [{t},{j}]: err {err} > bound {bound}"
                );
            }
        }
    }
}

/// Contract 3: save → load → serve is bitwise the in-memory quantized
/// path, and `Precision::F32` on the same file is the dequantized oracle.
#[test]
fn v2_container_round_trips_through_compressed_model_apply() {
    let mut rng = Rng::new(0x5108);
    let w = Tensor::randn(&[40, 56], &mut rng);
    let c = compress_matrix(&w, &SwscConfig::new(5, 3));
    let mut file = SwscFile::new();
    file.quantized.insert("w".into(), c.quantize(&QuantConfig { group: 16 }));
    file.dense.insert("d".into(), Tensor::randn(&[8, 8], &mut rng));

    let restored = SwscFile::from_bytes(&file.to_bytes()).expect("v2 round trip");
    assert_eq!(restored.quantized["w"], file.quantized["w"]);

    let before = CompressedModel::from_file_with(&file, InferMode::Compressed, Precision::Int8);
    let after = CompressedModel::from_file_with(&restored, InferMode::Compressed, Precision::Int8);
    assert_eq!(after.num_quantized(), 1);
    let x = Tensor::randn(&[6, 40], &mut rng);
    let (a, b) = (before.apply("w", &x).unwrap(), after.apply("w", &x).unwrap());
    assert_eq!(bits(&a), bits(&b), "serve after save/load is bitwise");

    // F32 on the same file = the dequantized oracle: identical to the
    // fused path by contract 1.
    let oracle = CompressedModel::from_file_with(&restored, InferMode::Compressed, Precision::F32);
    assert_eq!(oracle.num_quantized(), 0);
    assert_eq!(bits(&oracle.apply("w", &x).unwrap()), bits(&a));
}

/// The serving path is thread-invariant end to end through the model
/// surface (the bitwise contract the service relies on).
#[test]
fn model_int8_apply_thread_invariant() {
    let mut rng = Rng::new(0x5109);
    let mut file = SwscFile::new();
    file.compressed.insert("w".into(), synthetic(64, 80, 8, 5, &mut rng));
    let model = CompressedModel::from_file_with(&file, InferMode::Compressed, Precision::Int8);
    let x = Tensor::randn(&[9, 64], &mut rng);
    let base = bits(&model.apply_with("w", &x, ExecConfig::serial()).unwrap());
    for threads in [2usize, 4, 8] {
        let got = bits(&model.apply_with("w", &x, ExecConfig::with_threads(threads)).unwrap());
        assert_eq!(got, base, "{threads} threads");
    }
}
