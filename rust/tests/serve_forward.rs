//! Forward-serving invariants (ISSUE 7).
//!
//! The load-bearing contract: **continuous batching is invisible in the
//! results.** Every cross-request op in [`CompressedForward::step_group`]
//! is a row-independent `apply` over the stacked token rows (the
//! crate-wide single-register increasing-k kernel policy); embedding,
//! attention mixing, and the LM head are strictly per-request. So the
//! composition of the in-flight set at any layer boundary — who joined,
//! who left, how the cohort was partitioned — changes *which call*
//! computes a row, never its bits. Pinned here:
//!
//! 1. the property itself, at the state-machine level: **arbitrary
//!    arrival interleavings** (random arrival rounds, random cohort
//!    partitions re-formed at every layer boundary) produce logits
//!    bitwise equal to solo execution, swept over explicit thread
//!    configs {1, 2, 4} (satellite 4);
//! 2. `BatchServer` end to end: `ForwardScheduling::Continuous` and
//!    `::Flush` responses both bitwise equal the solo
//!    `CompressedForward::forward` oracle over a concurrent
//!    mixed-length stream;
//! 3. the `EvalService` forward surface: batching Enabled vs Disabled
//!    bitwise parity, and the explicit refusal (never a mid-request
//!    panic) when the `.swsc` container doesn't cover the full model.

use std::sync::Arc;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::coordinator::{EvalService, ServiceConfig};
use swsc::exec::ExecConfig;
use swsc::infer::{CompressedForward, CompressedModel, ForwardState, InferMode};
use swsc::io::SwscFile;
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::serve::{
    AdmissionError, BatchConfig, BatchServer, Batching, ForwardRequest, ForwardScheduling,
    ModelRegistry, DEFAULT_MODEL,
};
use swsc::tensor::Tensor;
use swsc::util::prop::check;
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// A tiny-config container covering every model parameter (2-D weights
/// wide enough to cluster are SWSC-compressed, the rest dense).
fn tiny_file(cfg: &ModelConfig, seed: u64) -> SwscFile {
    let ck = init_params(cfg, seed);
    let mut file = SwscFile::new();
    for spec in param_specs(cfg) {
        let t = ck.get(&spec.name).unwrap().clone();
        if spec.shape.len() == 2 && spec.shape[1] >= 16 {
            file.compressed.insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
        } else {
            file.dense.insert(spec.name.clone(), t);
        }
    }
    file
}

fn tiny_forward(seed: u64) -> (ModelConfig, SwscFile, Arc<CompressedForward>) {
    let cfg = ModelConfig::tiny();
    let file = tiny_file(&cfg, seed);
    let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
    let fwd = Arc::new(CompressedForward::new(model, cfg.clone()).unwrap());
    (cfg, file, fwd)
}

/// One continuous-batching replay at the state-machine level: requests
/// arrive at their configured round, join the in-flight set at layer 0,
/// and at every layer boundary the same-layer population is re-shuffled
/// and re-partitioned into random cohorts (`schedule_seed` makes the
/// partition sequence reproducible across thread sweeps). Finished
/// states `finish` immediately and leave. Returns per-request logits.
fn replay_continuous(
    fwd: &CompressedForward,
    windows: &[Vec<u32>],
    arrivals: &[usize],
    schedule_seed: u64,
    exec: ExecConfig,
) -> Result<Vec<Tensor>, String> {
    let n_layers = fwd.n_layers();
    let mut sched = Rng::new(schedule_seed);
    let mut started = vec![false; windows.len()];
    let mut logits: Vec<Option<Tensor>> = (0..windows.len()).map(|_| None).collect();
    let mut inflight: Vec<(usize, ForwardState)> = Vec::new();
    let mut round = 0usize;
    while started.iter().any(|s| !s) || !inflight.is_empty() {
        // Admit everything whose arrival round has come (joins at layer 0,
        // mid-flight relative to earlier arrivals).
        for (i, &due) in arrivals.iter().enumerate() {
            if due <= round && !started[i] {
                started[i] = true;
                inflight.push((i, fwd.start(&windows[i]).map_err(|e| e.to_string())?));
            }
        }
        // Re-form cohorts at each layer boundary present this round.
        let layers: std::collections::BTreeSet<usize> =
            inflight.iter().map(|(_, s)| s.layer()).collect();
        for layer in layers {
            let (mut pool, rest): (Vec<_>, Vec<_>) =
                inflight.into_iter().partition(|(_, s)| s.layer() == layer);
            inflight = rest;
            // Random shuffle + random contiguous split = an arbitrary
            // cohort composition for this boundary.
            for i in (1..pool.len()).rev() {
                pool.swap(i, sched.below(i + 1));
            }
            let mut at = 0;
            while at < pool.len() {
                let take = 1 + sched.below(pool.len() - at);
                let chunk = &mut pool[at..at + take];
                let mut refs: Vec<&mut ForwardState> =
                    chunk.iter_mut().map(|(_, s)| s).collect();
                fwd.step_group(&mut refs, exec).map_err(|e| e.to_string())?;
                at += take;
            }
            for (i, s) in pool {
                if s.layer() == n_layers {
                    logits[i] = Some(fwd.finish(&s, exec).map_err(|e| e.to_string())?);
                } else {
                    inflight.push((i, s));
                }
            }
        }
        round += 1;
    }
    Ok(logits.into_iter().map(|l| l.unwrap()).collect())
}

/// Satellite 4: arbitrary arrival interleavings × thread configs
/// {1, 2, 4} are bitwise equal to solo execution.
#[test]
fn prop_continuous_batching_is_bitwise_invisible() {
    let (cfg, _file, fwd) = tiny_forward(901);
    let (seq, vocab) = (cfg.seq, cfg.vocab);
    check(
        "continuous-batched logits == solo logits, bitwise, any interleaving x threads",
        902,
        10,
        |r| {
            let g = 1 + r.below(5);
            let windows: Vec<Vec<u32>> = (0..g)
                .map(|_| {
                    let t = 1 + r.below(seq.min(10));
                    (0..t).map(|_| r.below(vocab) as u32).collect()
                })
                .collect();
            let arrivals: Vec<usize> = (0..g).map(|_| r.below(4)).collect();
            (windows, arrivals, r.next_u64())
        },
        |(windows, arrivals, schedule_seed)| {
            let solo: Vec<Tensor> = windows
                .iter()
                .map(|w| fwd.forward_with(w, ExecConfig::serial()).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            for t in [1usize, 2, 4] {
                let exec = ExecConfig::with_threads(t);
                let got = replay_continuous(&fwd, windows, arrivals, *schedule_seed, exec)?;
                for (i, (g, s)) in got.iter().zip(&solo).enumerate() {
                    if bits(g) != bits(s) {
                        return Err(format!(
                            "request {i} ({} tokens, arrival round {}) not bitwise equal \
                             to solo at {t} threads",
                            windows[i].len(),
                            arrivals[i]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// [`replay_continuous`] plus deadline-style eviction (PR 8): before the
/// cohorts of each round form, any request whose fated eviction boundary
/// has come is dropped from the in-flight set — exactly where the
/// coalescer evicts expired deadlines. Evicted requests return `None`.
fn replay_continuous_with_evictions(
    fwd: &CompressedForward,
    windows: &[Vec<u32>],
    arrivals: &[usize],
    evict_at: &[Option<usize>],
    schedule_seed: u64,
    exec: ExecConfig,
) -> Result<Vec<Option<Tensor>>, String> {
    let n_layers = fwd.n_layers();
    let mut sched = Rng::new(schedule_seed);
    let mut started = vec![false; windows.len()];
    let mut logits: Vec<Option<Tensor>> = (0..windows.len()).map(|_| None).collect();
    let mut inflight: Vec<(usize, ForwardState)> = Vec::new();
    let mut round = 0usize;
    while started.iter().any(|s| !s) || !inflight.is_empty() {
        for (i, &due) in arrivals.iter().enumerate() {
            if due <= round && !started[i] {
                started[i] = true;
                inflight.push((i, fwd.start(&windows[i]).map_err(|e| e.to_string())?));
            }
        }
        // The eviction sweep: purely subtractive, survivors' cohorts
        // re-form without the evicted members.
        inflight.retain(|(i, s)| evict_at[*i] != Some(s.layer()));
        let layers: std::collections::BTreeSet<usize> =
            inflight.iter().map(|(_, s)| s.layer()).collect();
        for layer in layers {
            let (mut pool, rest): (Vec<_>, Vec<_>) =
                inflight.into_iter().partition(|(_, s)| s.layer() == layer);
            inflight = rest;
            for i in (1..pool.len()).rev() {
                pool.swap(i, sched.below(i + 1));
            }
            let mut at = 0;
            while at < pool.len() {
                let take = 1 + sched.below(pool.len() - at);
                let chunk = &mut pool[at..at + take];
                let mut refs: Vec<&mut ForwardState> =
                    chunk.iter_mut().map(|(_, s)| s).collect();
                fwd.step_group(&mut refs, exec).map_err(|e| e.to_string())?;
                at += take;
            }
            for (i, s) in pool {
                if s.layer() == n_layers {
                    logits[i] = Some(fwd.finish(&s, exec).map_err(|e| e.to_string())?);
                } else {
                    inflight.push((i, s));
                }
            }
        }
        round += 1;
    }
    Ok(logits)
}

/// PR 8 acceptance: deadline eviction at **any layer boundary** is pure
/// scheduling — the surviving requests' logits are bitwise equal to solo
/// execution at threads {1, 2, 4}, no matter who was evicted, when, or
/// how the survivors' cohorts re-formed around the hole.
#[test]
fn prop_deadline_eviction_never_moves_survivor_bits() {
    let (cfg, _file, fwd) = tiny_forward(940);
    let (seq, vocab) = (cfg.seq, cfg.vocab);
    let n_layers = fwd.n_layers();
    check(
        "evicting requests at random layer boundaries never changes survivors' bits",
        941,
        10,
        |r| {
            let g = 2 + r.below(5);
            let windows: Vec<Vec<u32>> = (0..g)
                .map(|_| {
                    let t = 1 + r.below(seq.min(10));
                    (0..t).map(|_| r.below(vocab) as u32).collect()
                })
                .collect();
            let arrivals: Vec<usize> = (0..g).map(|_| r.below(4)).collect();
            // About half the requests carry a "deadline": a fated eviction
            // at a random boundary (0 = evicted before their first step).
            let evict_at: Vec<Option<usize>> = (0..g)
                .map(|_| if r.below(2) == 0 { Some(r.below(n_layers)) } else { None })
                .collect();
            (windows, arrivals, evict_at, r.next_u64())
        },
        |(windows, arrivals, evict_at, schedule_seed)| {
            let solo: Vec<Tensor> = windows
                .iter()
                .map(|w| fwd.forward_with(w, ExecConfig::serial()).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            for t in [1usize, 2, 4] {
                let exec = ExecConfig::with_threads(t);
                let got = replay_continuous_with_evictions(
                    &fwd,
                    windows,
                    arrivals,
                    evict_at,
                    *schedule_seed,
                    exec,
                )?;
                for (i, g) in got.iter().enumerate() {
                    match (g, evict_at[i]) {
                        (None, Some(_)) => {} // evicted as fated
                        (None, None) => {
                            return Err(format!("request {i} lost without an eviction"))
                        }
                        (Some(g), _) => {
                            if bits(g) != bits(&solo[i]) {
                                return Err(format!(
                                    "survivor {i} ({} tokens) not bitwise equal to solo at \
                                     {t} threads after evictions",
                                    windows[i].len()
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// End to end through the server: a concurrent mixed-length stream under
/// both schedulers, every response bitwise equal to the solo oracle.
#[test]
fn server_scheduling_bitwise_equals_solo() {
    let (cfg, _file, fwd) = tiny_forward(910);
    let mut rng = Rng::new(911);
    let streams: Vec<Vec<u32>> = (0..16)
        .map(|_| {
            let t = 1 + rng.below(cfg.seq);
            (0..t).map(|_| rng.below(cfg.vocab) as u32).collect()
        })
        .collect();
    let oracle: Vec<Tensor> = streams.iter().map(|w| fwd.forward(w).unwrap()).collect();
    for scheduling in [ForwardScheduling::Continuous, ForwardScheduling::Flush] {
        let reg = ModelRegistry::new();
        reg.insert_forward(DEFAULT_MODEL, fwd.clone());
        let server = BatchServer::start(
            Arc::new(reg),
            BatchConfig::default().with_forward_scheduling(scheduling),
        );
        // Submit the whole stream before collecting, so requests overlap
        // and the scheduler actually has an in-flight set to re-form.
        let rxs: Vec<_> = streams
            .iter()
            .map(|w| {
                server.submit_forward(DEFAULT_MODEL, ForwardRequest::new(w.clone())).unwrap()
            })
            .collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(
                bits(&got.logits),
                bits(&oracle[i]),
                "{scheduling:?}: request {i} ({} tokens) diverged from solo",
                streams[i].len()
            );
        }
        assert!(
            server.metrics().counter("serve.forward_requests") >= streams.len() as u64,
            "{scheduling:?}: forward requests not accounted"
        );
        assert!(
            server.metrics().counter("serve.forward_steps") >= cfg.n_layers as u64,
            "{scheduling:?}: layer steps not accounted"
        );
        server.shutdown();
    }
}

/// The `EvalService` forward surface: batching Enabled routes through the
/// continuous scheduler, Disabled serves inline — both bitwise equal the
/// solo oracle, and `service.forward_requests` is accounted.
#[test]
fn eval_service_forward_enabled_bitwise_equals_disabled() {
    let (cfg, file, fwd) = tiny_forward(920);
    let mut rng = Rng::new(921);
    let windows: Vec<Vec<u32>> = (0..6)
        .map(|_| {
            let t = 1 + rng.below(cfg.seq);
            (0..t).map(|_| rng.below(cfg.vocab) as u32).collect()
        })
        .collect();
    for batching in [Batching::default(), Batching::Disabled] {
        let service = EvalService::start_with_swsc(
            None,
            cfg.clone(),
            &file,
            ServiceConfig { batching, ..Default::default() },
        )
        .unwrap();
        assert!(service.has_forward(), "full container must enable forward serving");
        for w in &windows {
            let got = service.forward_blocking(ForwardRequest::new(w.clone())).unwrap();
            let want = fwd.forward(w).unwrap();
            assert_eq!(
                bits(&got.logits),
                bits(&want),
                "{batching:?}: {} tokens diverged from solo",
                w.len()
            );
        }
        assert_eq!(
            service.metrics.counter("service.forward_requests"),
            windows.len() as u64
        );
        service.shutdown();
    }
}

/// A container that doesn't cover the full model keeps serving linears
/// but refuses forwards with an explicit error up front — never a
/// mid-request panic — under both submission paths.
#[test]
fn partial_container_refuses_forwards_explicitly() {
    let cfg = ModelConfig::tiny();
    let mut rng = Rng::new(930);
    let mut file = SwscFile::new();
    file.compressed.insert(
        "attn.wq".into(),
        compress_matrix(&Tensor::randn(&[cfg.d_model, cfg.d_model], &mut rng), &SwscConfig::new(4, 2)),
    );
    for batching in [Batching::default(), Batching::Disabled] {
        let service = EvalService::start_with_swsc(
            None,
            cfg.clone(),
            &file,
            ServiceConfig { batching, ..Default::default() },
        )
        .unwrap();
        assert!(!service.has_forward(), "partial container must not enable forward");
        let err = service
            .submit_forward(ForwardRequest::new(vec![1, 2, 3]))
            .err()
            .expect("partial container must refuse forward submissions");
        assert!(
            err.to_string().contains("forward serving disabled"),
            "unexpected refusal: {err}"
        );
        assert_eq!(
            service.try_submit_forward(ForwardRequest::new(vec![1])).err(),
            Some(AdmissionError::ShuttingDown),
            "{batching:?}"
        );
        // Linear serving is untouched.
        let resp = service
            .linear_blocking(swsc::coordinator::LinearRequest::new(
                "attn.wq",
                Tensor::randn(&[2, cfg.d_model], &mut rng),
            ))
            .unwrap();
        assert_eq!(resp.y.rows(), 2);
        service.shutdown();
    }
}
