//! Pipeline-profiler + telemetry invariants (PR 10).
//!
//! The load-bearing contract mirrors PR 9's tracing contract one layer
//! down: **profiling and telemetry are pure observation.** With a live
//! [`swsc::obs::prof::Profiler`] and telemetry collection on, the
//! compressed `.swsc` bytes and the bits served from the container are
//! identical to an unprofiled run, at any worker count (CI additionally
//! sweeps `SWSC_THREADS` 1 and 4 over the tier-1 suite). And the
//! telemetry values themselves — not the timings — are deterministic
//! functions of (weights, seed, config): byte-stable across reruns and
//! exactly checkable on analytic fixtures.
//!
//! Pinned here:
//!
//! 1. profiled + telemetry compress vs plain compress: container bytes
//!    and served bits identical at workers ∈ {1, 4};
//! 2. the telemetry report is byte-stable across worker counts and
//!    reruns, its quality fields re-derivable from public
//!    reconstructions, and exact on fixtures with known answers
//!    (identical channels ⇒ zero inertia, zero error);
//! 3. profiler edge cases: nested scopes across `WorkerPool` task
//!    boundaries aggregate under the borrowed parent, the empty tree
//!    renders, and the span ring stays bounded (with exact drop
//!    accounting) under the 4-thread concurrent-push pattern from the
//!    PR 9 regression test.

use swsc::compress::{
    compress_matrix_traced, CompressionPlan, MatrixTelemetry, ProjectorSet, SwscConfig,
};
use swsc::coordinator::{compress_model, compress_model_traced};
use swsc::exec::{self, ExecConfig};
use swsc::infer::{CompressedModel, InferMode};
use swsc::model::{init_params, ModelConfig};
use swsc::obs::prof::{ProfConfig, Profiler};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn bits(t: &Tensor) -> Vec<u32> {
    t.data().iter().map(|v| v.to_bits()).collect()
}

/// Scan one JSON document for structural soundness (the obs_trace
/// helper): braces/brackets balanced outside strings, escapes honored.
fn assert_balanced_json(json: &str) {
    let (mut depth, mut in_str, mut esc) = (0i64, false, false);
    for c in json.chars() {
        if esc {
            esc = false;
            continue;
        }
        match c {
            '\\' if in_str => esc = true,
            '"' => in_str = !in_str,
            '{' | '[' if !in_str => depth += 1,
            '}' | ']' if !in_str => depth -= 1,
            _ => {}
        }
        assert!(depth >= 0, "unbalanced close in export");
    }
    assert_eq!(depth, 0, "unbalanced export");
    assert!(!in_str, "unterminated string in export");
}

/// Tentpole invariant: compressing with a live profiler and telemetry
/// collection produces a byte-identical container — and the bits served
/// *from* that container are identical too — at worker counts 1 and 4.
#[test]
fn profiled_compress_is_observation_only_across_worker_counts() {
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 1200);
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 1200);
    assert!(!plan.is_empty());

    let base = compress_model(&ck, &plan, 2, None).unwrap();
    let base_bytes = base.file.to_bytes();
    let base_model = CompressedModel::from_file(&base.file, InferMode::Compressed);
    let weight = plan.matrices[0].name.clone();
    let (m, _) = base_model.shape(&weight).expect("planned weight is 2-D");
    let mut rng = Rng::new(1201);
    let x = Tensor::randn(&[3, m], &mut rng);
    let base_served = bits(&base_model.apply_with(&weight, &x, ExecConfig::with_threads(2)).unwrap());

    for workers in [1usize, 4] {
        let prof = Profiler::new();
        let out = {
            let root = prof.root("compress");
            compress_model_traced(&ck, &plan, workers, None, Some(&root), true).unwrap()
        };
        assert_eq!(
            out.file.to_bytes(),
            base_bytes,
            "profiling/telemetry moved container bytes at {workers} workers"
        );
        let model = CompressedModel::from_file(&out.file, InferMode::Compressed);
        for threads in [1usize, 4] {
            assert_eq!(
                bits(&model.apply_with(&weight, &x, ExecConfig::with_threads(threads)).unwrap()),
                base_served,
                "served bits moved ({workers} workers, {threads} serve threads)"
            );
        }
        // The profiler did observe the run: the root, one child per
        // matrix, and kmeans grandchildren all aggregated.
        let phases = prof.phases();
        assert_eq!(phases["compress"].count, 1);
        for mp in &plan.matrices {
            let child = format!("compress/{}", mp.name);
            assert_eq!(phases[&child].count, 1, "missing per-matrix phase {child}");
            assert!(phases.contains_key(&format!("{child}/kmeans")), "missing {child}/kmeans");
        }
        assert_balanced_json(&prof.to_chrome_json());
    }
}

/// Telemetry values are pure functions of (weights, seed, config): the
/// report renders byte-identically across worker counts and reruns, and
/// every quality field is re-derivable from public reconstructions.
#[test]
fn telemetry_is_byte_stable_and_rederivable() {
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 1300);
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 1300);
    let a = compress_model_traced(&ck, &plan, 1, None, None, true).unwrap().telemetry.unwrap();
    let b = compress_model_traced(&ck, &plan, 4, None, None, true).unwrap().telemetry.unwrap();
    assert_eq!(a.to_json(), b.to_json(), "telemetry must not depend on worker count");
    let c = compress_model_traced(&ck, &plan, 4, None, None, true).unwrap().telemetry.unwrap();
    assert_eq!(b.to_json(), c.to_json(), "telemetry must be byte-stable across reruns");

    // Single-matrix rederivation: the recorded error energy, spectrum
    // energy fraction, and inertia trace all match what the public API
    // reconstructs after the fact.
    let mut rng = Rng::new(1301);
    let w = Tensor::randn(&[32, 40], &mut rng);
    let scfg = SwscConfig::new(4, 3);
    let mut tel = MatrixTelemetry { name: "m".into(), ..Default::default() };
    let cm = compress_matrix_traced(&w, &scfg, None, Some(&mut tel));
    assert_eq!(tel.shape, (32, 40));
    assert_eq!(tel.clusters, 4);
    assert_eq!(tel.rank, 3);
    assert_eq!(tel.inertia_trace.len(), tel.kmeans_iterations);
    assert_eq!(
        tel.inertia_trace.last().copied().unwrap().to_bits(),
        tel.inertia.to_bits(),
        "trace must end at the final inertia"
    );
    let diff = w.sub(&cm.reconstruct_uncompensated());
    let fro2 = diff.fro_norm() * diff.fro_norm();
    assert!(
        (tel.error_fro2 - fro2).abs() <= 1e-6 * fro2.max(1.0),
        "error_fro2 {} vs rederived {fro2}",
        tel.error_fro2
    );
    assert_eq!(tel.spectrum.len(), 3, "one singular value per retained rank");
    assert!(tel.spectrum.windows(2).all(|p| p[0] >= p[1]), "spectrum must be descending");
    let energy: f64 = tel.spectrum.iter().map(|&s| (s as f64) * (s as f64)).sum();
    assert!(
        (tel.compensation_energy - energy / fro2).abs() <= 1e-6,
        "compensation_energy {} vs rederived {}",
        tel.compensation_energy,
        energy / fro2
    );
    assert!(tel.compensation_energy > 0.0 && tel.compensation_energy <= 1.0);
}

/// Exact known answers on analytic fixtures: identical channels make
/// k-means lossless (zero inertia at every iteration, zero residual
/// error), and two distinct repeated channels with k = 2 are separated
/// exactly by the seeded k-means++ init.
#[test]
fn telemetry_exact_on_analytic_fixtures() {
    // 6×8, every channel (column) identical.
    let col: Vec<f32> = (0..6).map(|i| i as f32 - 2.5).collect();
    let mut data = vec![0.0f32; 6 * 8];
    for (i, row) in data.chunks_exact_mut(8).enumerate() {
        row.fill(col[i]);
    }
    let w = Tensor::from_vec(&[6, 8], data);
    let mut tel = MatrixTelemetry { name: "const".into(), ..Default::default() };
    let cm = compress_matrix_traced(&w, &SwscConfig::new(1, 0), None, Some(&mut tel));
    assert_eq!(tel.clusters, 1);
    assert_eq!(tel.rank, 0);
    assert_eq!(tel.inertia, 0.0, "identical channels cluster losslessly");
    assert!(tel.inertia_trace.iter().all(|&v| v == 0.0), "{:?}", tel.inertia_trace);
    assert_eq!(tel.error_fro2, 0.0);
    assert_eq!(tel.spectrum, Vec::<f32>::new());
    assert_eq!(tel.compensation_energy, 0.0);
    assert_eq!(bits(&cm.reconstruct_uncompensated()), bits(&w));

    // Two distinct channel types, k = 2: the k-means++ second seed is
    // distance-weighted, so it lands on the other type and the very
    // first assignment is already exact.
    let mut data = vec![0.0f32; 6 * 8];
    for (i, row) in data.chunks_exact_mut(8).enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            *v = if j % 2 == 0 { col[i] } else { -2.0 * col[i] + 1.0 };
        }
    }
    let w2 = Tensor::from_vec(&[6, 8], data);
    let mut tel2 = MatrixTelemetry { name: "two".into(), ..Default::default() };
    let cm2 = compress_matrix_traced(&w2, &SwscConfig::new(2, 0), None, Some(&mut tel2));
    assert_eq!(tel2.clusters, 2);
    assert_eq!(tel2.inertia, 0.0, "two exact channel types, two clusters");
    assert_eq!(tel2.error_fro2, 0.0);
    assert_eq!(bits(&cm2.reconstruct_uncompensated()), bits(&w2));
}

/// Nested scopes cross `WorkerPool` task boundaries via explicit
/// parenting: the parent scope is borrowed into every worker closure and
/// each task's children aggregate under it, whatever thread ran them.
#[test]
fn scopes_cross_worker_pool_task_boundaries() {
    let p = Profiler::new();
    {
        let root = p.root("fanout");
        let results = exec::map_indexed_balanced(ExecConfig::with_threads(4), 16, |i| {
            let job = root.child(&format!("job{i:02}"));
            let _work = job.child("work");
            i
        });
        assert_eq!(results, (0..16).collect::<Vec<_>>());
    }
    let phases = p.phases();
    assert_eq!(phases["fanout"].count, 1);
    for i in 0..16 {
        assert_eq!(phases[&format!("fanout/job{i:02}")].count, 1, "job {i}");
        assert_eq!(phases[&format!("fanout/job{i:02}/work")].count, 1, "job {i} child");
    }
    // 1 root + 16 jobs + 16 children, one span each.
    assert_eq!(p.sink().len(), 33);
    assert_balanced_json(&p.to_chrome_json());
}

/// The PR 9 concurrent-push regression, against the profiler's embedded
/// ring: 4 threads × 500 scopes into an 8-record ring. Aggregation is
/// lossless (the stat map is unbounded), the ring stays exactly bounded,
/// and the drop accounting adds up.
#[test]
fn aggregation_lossless_and_ring_bounded_under_concurrent_push() {
    let p = Profiler::with_capacity(8);
    std::thread::scope(|s| {
        for _ in 0..4 {
            let p = &p;
            s.spawn(move || {
                for _ in 0..500 {
                    let _sc = p.root("worker");
                }
            });
        }
    });
    let phases = p.phases();
    assert_eq!(phases["worker"].count, 2000, "every scope must aggregate");
    assert_eq!(p.sink().len(), 8, "ring must sit exactly at capacity");
    assert_eq!(p.sink().dropped(), 2000 - 8, "drop accounting must add up");
    assert_balanced_json(&p.to_chrome_json());
}

/// Empty-tree renders and the env gate, at the integration surface.
#[test]
fn empty_renders_and_env_gate() {
    let p = Profiler::new();
    assert_eq!(p.render_text(), "(no phases recorded)\n");
    assert_eq!(p.render_json(), "{\"phases\":{}}\n");
    assert_balanced_json(&p.to_chrome_json());

    assert_eq!(ProfConfig::from_lookup(|_| None), None);
    assert_eq!(
        ProfConfig::from_lookup(|k| match k {
            "SWSC_PROF" => Some("1".into()),
            "SWSC_PROF_OUT" => Some("prof.json".into()),
            _ => None,
        }),
        Some(ProfConfig { chrome_out: Some("prof.json".into()) })
    );
}
