//! Integration over the on-disk formats: checkpoint → compress → container
//! → restore, plus tokenizer/corpus/dataset plumbing.

use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::compress_model;
use swsc::io::{Checkpoint, SwscFile};
use swsc::model::{init_params, ModelConfig};
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus, Tokenizer};

#[test]
fn checkpoint_compress_container_restore_round_trip() {
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 11);
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 0);
    let out = compress_model(&ck, &plan, 4, None).unwrap();

    let dir = std::env::temp_dir().join("swsc_int_formats");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.swsc");
    out.file.save(&path).unwrap();
    let loaded = SwscFile::load(&path).unwrap();

    // Restored model has every parameter with the right shape.
    let all = loaded.restore_all();
    assert_eq!(all.len(), ck.len());
    for (name, t) in ck.iter() {
        assert_eq!(all[name].shape(), t.shape(), "{name}");
    }
    // Compressed entries are close to the pre-save reconstruction (only
    // fp16 payload rounding in between).
    for (name, c) in &out.file.compressed {
        let pre = c.reconstruct();
        let post = loaded.compressed[name].reconstruct();
        assert!(pre.mse(&post) < 1e-5, "{name}: {}", pre.mse(&post));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn container_is_actually_smaller_on_disk() {
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 12);
    // Compress everything 2-D that matches Q/K plus check total size drops
    // vs the raw checkpoint for those matrices.
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 0);
    let out = compress_model(&ck, &plan, 2, None).unwrap();

    let d = cfg.d_model;
    let dense_bits_per_matrix = (d * d * 16) as u64; // fp16 dense reference
    for c in out.file.compressed.values() {
        let total = c.bits().total_bits;
        assert!(
            total < dense_bits_per_matrix / 4,
            "2-bit target should be ≤ 1/8 of fp16: {total} vs {dense_bits_per_matrix}"
        );
    }
}

#[test]
fn tokenizer_corpus_dataset_pipeline() {
    let corpus = SyntheticCorpus::generate(&CorpusConfig { articles: 12, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, 300);
    assert!(tok.vocab_size() > 256);

    // Round trip fidelity on eval text.
    let ids = tok.encode(&corpus.eval_text);
    assert_eq!(tok.decode(&ids), corpus.eval_text);

    // Dataset slices line up with the stream.
    let ds = Dataset::from_text(&corpus.train_text, &tok, 2, 16);
    assert!(ds.num_batches() > 10);
    let b0 = ds.batch(0);
    assert_eq!(b0.inputs.len(), 32);
    assert_eq!(&b0.inputs[1..16], &b0.targets[0..15], "targets are inputs shifted by one");
}

#[test]
fn v_projector_stays_dense_in_qk_plan() {
    // §IV-B of the paper: V must not be compressed. Verify the QK plan
    // leaves wv untouched bit-for-bit through the container round trip.
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 13);
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 0);
    let out = compress_model(&ck, &plan, 2, None).unwrap();
    let restored = SwscFile::from_bytes(&out.file.to_bytes()).unwrap().restore_all();
    for i in 0..cfg.n_layers {
        let name = format!("layers.{i}.attn.wv");
        assert_eq!(&restored[&name], ck.get(&name).unwrap(), "{name} was modified");
    }
}

#[test]
fn corrupted_container_rejected_end_to_end() {
    let cfg = ModelConfig::tiny();
    let ck = init_params(&cfg, 14);
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::Q, 2.0, 0.5, 0);
    let out = compress_model(&ck, &plan, 2, None).unwrap();
    let mut bytes = out.file.to_bytes();
    let at = bytes.len() * 2 / 3;
    bytes[at] ^= 0x40;
    assert!(SwscFile::from_bytes(&bytes).is_err());
}
