//! Ablation over the clustering design choices DESIGN.md calls out:
//! k-means++ vs random seeding, mean vs medoid representatives, full Lloyd
//! vs mini-batch — measured on both quality (inertia / reconstruction MSE)
//! and wallclock.

use swsc::bench::Bench;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::kmeans::{
    cluster_channels, init_kmeans_pp, init_random, minibatch_kmeans, InitMethod, KMeansConfig,
    Representative,
};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn weights(m: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let groups = 24;
    let centers: Vec<Vec<f32>> =
        (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let mut w = Tensor::zeros(&[m, m]);
    for j in 0..m {
        let c = &centers[j % groups];
        let col: Vec<f32> = c.iter().map(|&v| v + rng.normal_f32(0.0, 0.25)).collect();
        w.set_col(j, &col);
    }
    w
}

fn main() {
    let bench = Bench::new("ablation_kmeans");
    let m = 256;
    let k = 16;
    let w = weights(m, 31);

    bench.section("seeding: k-means++ vs random (k=16, m=256, 10 restarts)");
    for (label, init) in [("kmeans++", InitMethod::KMeansPlusPlus), ("random", InitMethod::Random)] {
        let mut inertias = Vec::new();
        for seed in 0..10u64 {
            let res = cluster_channels(
                &w,
                &KMeansConfig { k, init, seed, ..Default::default() },
            );
            inertias.push(res.inertia);
        }
        let mean = inertias.iter().sum::<f64>() / inertias.len() as f64;
        let worst = inertias.iter().cloned().fold(0.0f64, f64::max);
        println!("  {label:<9}: mean inertia {mean:10.3}  worst {worst:10.3}");
    }

    bench.section("representative: mean vs medoid (reconstruction MSE)");
    for (label, rep) in [("mean", Representative::Mean), ("medoid", Representative::Medoid)] {
        let c = compress_matrix(&w, &SwscConfig::new(k, 8).with_representative(rep));
        println!("  {label:<7}: mse {:.4e}  avg_bits {:.3}", c.reconstruct().mse(&w), c.avg_bits());
    }

    bench.section("full Lloyd vs mini-batch (quality)");
    {
        let channels = w.transpose();
        let mut rng = Rng::new(7);
        let full = cluster_channels(&w, &KMeansConfig { k, seed: 7, ..Default::default() });
        let init = init_kmeans_pp(&channels, k, &mut rng);
        let (_, _, mb_inertia) = minibatch_kmeans(&channels, init, 64, 100, &mut rng);
        println!("  full lloyd inertia {:.3}  minibatch inertia {:.3}", full.inertia, mb_inertia);
    }

    bench.section("wallclock");
    bench.case("lloyd_k16_m256", || {
        cluster_channels(&w, &KMeansConfig { k, seed: 1, ..Default::default() })
    });
    bench.case("lloyd_k24_m256", || {
        cluster_channels(&w, &KMeansConfig { k: 24, seed: 1, ..Default::default() })
    });
    let channels = w.transpose();
    bench.case("minibatch_k16_b64_s100", || {
        let mut rng = Rng::new(2);
        let init = init_random(&channels, k, &mut rng);
        minibatch_kmeans(&channels, init, 64, 100, &mut rng)
    });
    bench.case("init_kmeanspp_k16", || {
        let mut rng = Rng::new(3);
        init_kmeans_pp(&channels, k, &mut rng)
    });
}
