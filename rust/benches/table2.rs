//! Bench/reproduction of paper Table II: average bits as a function of the
//! cluster count and the retained singular rank. Pure accounting — this
//! regenerates the table verbatim at the paper's m = 4096 and at our
//! model's m = 256, and times the (cheap) accounting path.

use swsc::bench::Bench;
use swsc::quant::bits::{swsc_avg_bits, swsc_avg_bits_paper};
use swsc::report::render_table2;

fn main() {
    let b = Bench::new("table2");
    b.section("paper Table II — m = 4096 (verbatim)");
    println!("{}", render_table2(4096));

    // Verify the three anchor points the paper prints.
    assert_eq!(swsc_avg_bits_paper(4096, 128, 0), 0.5);
    assert_eq!(swsc_avg_bits_paper(4096, 256, 0), 1.0);
    assert_eq!(swsc_avg_bits_paper(4096, 512, 0), 2.0);
    assert_eq!(swsc_avg_bits_paper(4096, 0, 64), 0.5);
    assert_eq!(swsc_avg_bits_paper(4096, 0, 128), 1.0);
    assert_eq!(swsc_avg_bits_paper(4096, 0, 256), 2.0);
    println!("anchor points match the paper exactly.\n");

    b.section("scaled to this repo's model — m = 256");
    println!("{}", render_table2(256));

    b.section("exact accounting (incl. label bits the paper drops)");
    println!("| m    | k   | r   | paper formula | exact (w/ labels) |");
    println!("|------|-----|-----|---------------|-------------------|");
    for (m, k, r) in [(4096, 256, 128), (4096, 512, 256), (256, 16, 8), (256, 24, 12)] {
        let paper = swsc_avg_bits_paper(m, k, r);
        let exact = swsc_avg_bits(m, m, k, r).avg_bits;
        println!("| {m:<4} | {k:<3} | {r:<3} | {paper:<13.4} | {exact:<17.4} |");
    }
    println!();

    b.case("avg_bits accounting (4096, full grid)", || {
        let mut acc = 0.0;
        for k in (64..=512).step_by(64) {
            for r in (32..=256).step_by(32) {
                acc += swsc_avg_bits(4096, 4096, k, r).avg_bits;
            }
        }
        acc
    });
}
