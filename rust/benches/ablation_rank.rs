//! Fig. 3 ablation: how much does the SVD error compensation buy?
//!
//! Sweeps the retained rank r (r = 0 == no compensation, the paper's
//! clustering-only variant) at fixed cluster count and reports matrix MSE,
//! energy removed, and avg-bits — the storage/quality trade the paper's
//! §III-C motivates. Also times the compensation step (SVD backends).

use swsc::bench::Bench;
use swsc::compress::{compress_matrix, matrix_stats, SwscConfig};
use swsc::linalg::{svd_jacobi, svd_randomized, truncate};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn trained_like(m: usize, seed: u64) -> Tensor {
    // Clustered channels + heavy-tailed outliers.
    let mut rng = Rng::new(seed);
    let groups = 20;
    let centers: Vec<Vec<f32>> =
        (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let mut w = Tensor::zeros(&[m, m]);
    for j in 0..m {
        let c = &centers[j % groups];
        let col: Vec<f32> = c.iter().map(|&v| v + rng.normal_f32(0.0, 0.2)).collect();
        w.set_col(j, &col);
    }
    for _ in 0..m {
        let i = rng.below(m * m);
        w.data_mut()[i] += rng.normal_f32(0.0, 5.0);
    }
    w
}

fn main() {
    let bench = Bench::new("ablation_rank");
    let m = 256;
    let k = 16;
    let w = trained_like(m, 77);

    bench.section("rank sweep at fixed k=16 (m=256)");
    println!("| rank | avg bits | MSE        | err energy removed |");
    println!("|------|----------|------------|--------------------|");
    for r in [0usize, 2, 4, 8, 16, 32, 64] {
        let c = compress_matrix(&w, &SwscConfig::new(k, r));
        let s = matrix_stats("w", &w, &c);
        println!(
            "| {r:<4} | {:<8.3} | {:<10.3e} | {:<18.1}% |",
            s.avg_bits,
            s.mse_compensated,
            100.0 * s.error_energy_removed
        );
    }

    bench.section("SVD backend timing on the 256x256 error matrix (r=8)");
    let err = {
        let c = compress_matrix(&w, &SwscConfig::new(k, 0));
        w.sub(&c.reconstruct_uncompensated())
    };
    bench.case("jacobi_full", || svd_jacobi(&err));
    bench.case("jacobi_then_truncate_r8", || truncate(&svd_jacobi(&err), 8));
    let mut rng = Rng::new(5);
    bench.case("randomized_r8_q2", || svd_randomized(&err, 8, 8, 2, &mut rng));

    bench.section("quality: randomized vs exact at r=8");
    let exact = {
        let s = truncate(&svd_jacobi(&err), 8);
        err.sub(&s.reconstruct()).fro_norm()
    };
    let mut rng = Rng::new(6);
    let approx = {
        let s = svd_randomized(&err, 8, 8, 2, &mut rng);
        err.sub(&s.reconstruct()).fro_norm()
    };
    println!("residual: exact {exact:.4}  randomized {approx:.4}  (ratio {:.4})", approx / exact);
}
