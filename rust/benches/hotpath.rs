//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 CPU kernels (matmul, SVD, kmeans assign, packing) and the PJRT
//! round trip (literal conversion + fwd_eval execution, artifact-gated).
//!
//! The parallel cases sweep thread counts {1, 2, 4, max} through the
//! deterministic executor; because results are bit-identical at any thread
//! count, the sweep is purely a wall-clock comparison. Every case lands in
//! `BENCH_hotpath.json` (op, size, threads, ns/iter) for cross-PR perf
//! tracking.
//!
//! ISSUE 2 additions:
//!
//! - Every major op also emits a `pool_vs_spawn_<op>` comparison row: the
//!   identical workload timed under the persistent-pool backend and under
//!   the legacy spawn-per-call backend (`speedup_vs_spawn` = spawn/pool).
//!   Backends are bit-identical, so this is a pure scheduling comparison —
//!   including the pool's lower serial-fallback thresholds, which are part
//!   of what "persistent pool" buys.
//! - A many-small-matrices workload (64 sequential 128² SWSC compressions,
//!   in-matrix parallelism only) — the regime the pool exists for: under
//!   spawn-per-call the per-op work is below the spawn threshold and runs
//!   serial, while the pool profitably fans it out.
//! - A wide-matrix Lloyd case comparing the blocked cross-term assign
//!   against the un-blocked full-GEMM reference.
//! - A CI gate: if the pool regresses >10% vs spawn on any op ≥ 512², the
//!   bench exits non-zero.

use std::path::Path;
use swsc::bench::Bench;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::exec::{self, ExecBackend, ExecConfig};
use swsc::io::{pack_u32, unpack_u32};
use swsc::kmeans::{assign_blocked_with, assign_gemm_with, assign_with};
use swsc::linalg::{qr_householder, svd_jacobi, svd_randomized_with};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

/// Thread counts to sweep: 1, 2, 4 (where available), always ending at the
/// machine max so the full-parallelism data point is recorded.
fn thread_sweep() -> Vec<usize> {
    let max = exec::global().threads;
    let mut t: Vec<usize> = [1, 2, 4].iter().copied().filter(|&t| t <= max).collect();
    if !t.contains(&max) {
        t.push(max);
    }
    t
}

/// Time `f` under both backends at `threads` and record one
/// `pool_vs_spawn_<op>` comparison row. Ops ≥ 512² regressing >10% are
/// queued for the CI gate — after one full re-measurement, so a single
/// descheduled iteration on a noisy shared runner doesn't fail CI. Probe
/// timings go through `probe` (same warmup/iteration policy, not written
/// to the JSON trajectory).
#[allow(clippy::too_many_arguments)]
fn pool_vs_spawn<F: FnMut()>(
    bench: &Bench,
    probe: &Bench,
    regressions: &mut Vec<String>,
    op: &str,
    size: usize,
    threads: usize,
    mut f: F,
) -> f64 {
    let prior = exec::backend();
    let mut measure = |tag: &str| {
        exec::set_backend(ExecBackend::Pool);
        let pool = probe.case_at(&format!("{op}_pool{tag}"), size, threads, &mut f);
        exec::set_backend(ExecBackend::SpawnPerCall);
        let spawn = probe.case_at(&format!("{op}_spawn{tag}"), size, threads, &mut f);
        (pool, spawn)
    };
    let (mut pool, mut spawn) = measure("");
    if size >= 512 && spawn / pool.max(1e-12) < 0.9 {
        let (pool2, spawn2) = measure("_retry");
        if spawn2 / pool2.max(1e-12) > spawn / pool.max(1e-12) {
            (pool, spawn) = (pool2, spawn2);
        }
    }
    // Restore whatever backend the surrounding sweeps run under (the
    // module docs advertise SWSC_EXEC_BACKEND=spawn for whole-run
    // comparisons — don't silently mix backends in the JSON trajectory).
    exec::set_backend(prior);
    let speedup = bench.comparison(op, size, threads, pool, spawn);
    if size >= 512 && speedup < 0.9 {
        regressions.push(format!("{op} (size {size}, t{threads}): {speedup:.2}x vs spawn"));
    }
    speedup
}

fn main() {
    let bench = Bench::new("hotpath");
    let probe = Bench::new("probe");
    let mut regressions: Vec<String> = Vec::new();
    let mut rng = Rng::new(404);
    let sweep = thread_sweep();
    // Comparison thread count: 4 where the machine has it, else the max.
    let cmp_t = sweep.iter().copied().filter(|&t| t <= 4).max().unwrap_or(1);

    bench.section("L3 tensor kernels (threads sweep)");
    for &size in &[256usize, 512, 1024] {
        let a = Tensor::randn(&[size, size], &mut rng);
        let b = Tensor::randn(&[size, size], &mut rng);
        let flops = 2.0 * (size as f64).powi(3);
        let mut serial_mean = f64::NAN;
        for &t in &sweep {
            let cfg = ExecConfig::with_threads(t);
            let m = bench.case_at(&format!("matmul_{size}_t{t}"), size, t, || a.matmul_with(&b, cfg));
            if t == 1 {
                serial_mean = m;
            }
            println!("  -> {:.2} GFLOP/s ({:.2}x vs t1)", flops / m / 1e9, serial_mean / m);
        }
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, &format!("matmul_{size}"), size, cmp_t, || {
            a.matmul_with(&b, cfg);
        });
    }
    let a512 = Tensor::randn(&[512, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("transpose_512_t{t}"), 512, t, || a512.transpose_with(cfg));
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "transpose_512", 512, cmp_t, || {
            a512.transpose_with(cfg);
        });
    }

    bench.section("L3 linalg");
    let err = Tensor::randn(&[256, 256], &mut rng);
    bench.case_at("svd_jacobi_256", 256, 1, || svd_jacobi(&err));
    let err512 = Tensor::randn(&[512, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        let mut r2 = Rng::new(405);
        bench.case_at(&format!("svd_randomized_512_r8_t{t}"), 512, t, || {
            svd_randomized_with(&err512, 8, 8, 2, &mut r2, cfg)
        });
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        let mut r2 = Rng::new(405);
        pool_vs_spawn(&bench, &probe, &mut regressions, "svd_randomized_512_r8", 512, cmp_t, || {
            svd_randomized_with(&err512, 8, 8, 2, &mut r2, cfg);
        });
    }
    let tall = Tensor::randn(&[256, 24], &mut rng);
    bench.case_at("qr_256x24", 256, 1, || qr_householder(&tall));

    bench.section("L3 kmeans");
    let pts512 = Tensor::randn(&[512, 512], &mut rng);
    let cen = Tensor::randn(&[16, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("assign_n512_k16_t{t}"), 512, t, || assign_with(&pts512, &cen, cfg));
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "assign_n512_k16", 512, cmp_t, || {
            assign_with(&pts512, &cen, cfg);
        });
    }

    // Wide-matrix Lloyd: blocked cross-term tiles vs the un-blocked
    // full-GEMM reference on an 8192-channel assignment (the 11008-channel
    // MLP regime, scaled to bench budget). Outputs are bit-identical; this
    // row tracks the wall-clock effect of fusing the argmin into the tiles.
    bench.section("L3 kmeans — wide-matrix blocked assign");
    let wide = Tensor::randn(&[8192, 128], &mut rng);
    let wide_cen = Tensor::randn(&[64, 128], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("assign_blocked_n8192_k64_t{t}"), 8192, t, || {
            assign_blocked_with(&wide, &wide_cen, cfg)
        });
        bench.case_at(&format!("assign_gemm_n8192_k64_t{t}"), 8192, t, || {
            assign_gemm_with(&wide, &wide_cen, cfg)
        });
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "assign_blocked_n8192_k64", 8192, cmp_t, || {
            assign_blocked_with(&wide, &wide_cen, cfg);
        });
    }

    bench.section("pipeline: full matrix compression (threads sweep)");
    for &t in &sweep {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(t);
        bench.case_at(&format!("compress_512_k16_r8_t{t}"), 512, t, || {
            compress_matrix(&pts512, &cfg)
        });
    }
    {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "compress_512_k16_r8", 512, cmp_t, || {
            compress_matrix(&pts512, &cfg);
        });
    }
    let pts256 = Tensor::randn(&[256, 256], &mut rng);
    bench.case_at("compress_256_k16_r8", 256, exec::global().threads, || {
        compress_matrix(&pts256, &SwscConfig::new(16, 8))
    });
    bench.case_at("compress_256_k24_r12", 256, exec::global().threads, || {
        compress_matrix(&pts256, &SwscConfig::new(24, 12))
    });

    // The pool's target regime: many small per-matrix jobs back to back,
    // parallelism only *inside* each op. Spawn-per-call leaves these ops
    // serial (their work sits below its spawn threshold); the persistent
    // pool fans them out for ~µs dispatch cost. ISSUE 2 acceptance floor:
    // ≥ 1.5× at 4 threads.
    bench.section("pipeline: many small matrices (64 × 128²)");
    let mats: Vec<Tensor> = (0..64).map(|_| Tensor::randn(&[128, 128], &mut rng)).collect();
    {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(cmp_t);
        let speedup = pool_vs_spawn(
            &bench,
            &probe,
            &mut regressions,
            "compress_many_small_64x128",
            128,
            cmp_t,
            || {
                for w in &mats {
                    std::hint::black_box(compress_matrix(w, &cfg));
                }
            },
        );
        if speedup < 1.5 && cmp_t >= 4 {
            println!(
                "  !! many-small workload speedup {speedup:.2}x is below the 1.5x acceptance floor"
            );
        }
    }

    bench.section("label packing");
    let labels: Vec<u32> = (0..4096).map(|i| (i * 7) as u32 % 16).collect();
    bench.case_at("pack_4096_labels_4bit", 4096, 1, || pack_u32(&labels, 4));
    let packed = pack_u32(&labels, 4);
    bench.case_at("unpack_4096_labels_4bit", 4096, 1, || unpack_u32(&packed, 4096, 4));

    // PJRT round trip (needs artifacts).
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use swsc::model::{init_params, param_specs, ModelConfig};
        use swsc::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine};

        bench.section("PJRT runtime (tiny preset)");
        let cfg = ModelConfig::tiny();
        let man = ArtifactManifest::load(dir, "tiny").unwrap();
        let engine = Engine::new(man).unwrap();
        let exe = engine.load("fwd_eval").unwrap();
        let ck = init_params(&cfg, 1);
        let host: Vec<Tensor> =
            param_specs(&cfg).iter().map(|s| ck.get(&s.name).unwrap().clone()).collect();
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();

        bench.case("literal_convert_all_params", || {
            host.iter().map(|t| tensor_to_literal(t).unwrap()).count()
        });
        bench.case("fwd_eval_execute", || {
            let mut args: Vec<xla::Literal> =
                host.iter().map(|t| tensor_to_literal(t).unwrap()).collect();
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            exe.run(&args).unwrap()
        });
    } else {
        println!("(skipping PJRT section — run `make artifacts`)");
    }

    let json_path = Path::new("BENCH_hotpath.json");
    match bench.write_json(json_path) {
        Ok(()) => println!("\nwrote {} ({} records)", json_path.display(), bench.records().len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", json_path.display()),
    }

    if !regressions.is_empty() {
        eprintln!("\nPOOL REGRESSION (>10% slower than spawn-per-call on ops ≥ 512²):");
        for r in &regressions {
            eprintln!("  {r}");
        }
        std::process::exit(1);
    }
    println!("pool_vs_spawn gate: pool within 10% of (or faster than) spawn on all ops ≥ 512²");
}
