//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 CPU kernels (matmul, SVD, kmeans assign, packing) and the PJRT
//! round trip (literal conversion + fwd_eval execution, artifact-gated).
//!
//! The parallel cases sweep thread counts {1, 2, 4, max} through the
//! deterministic executor; because results are bit-identical at any thread
//! count, the sweep is purely a wall-clock comparison. Every case lands in
//! `BENCH_hotpath.json` (op, size, threads, ns/iter) for cross-PR perf
//! tracking.

use std::path::Path;
use swsc::bench::Bench;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::exec::{self, ExecConfig};
use swsc::io::{pack_u32, unpack_u32};
use swsc::kmeans::assign_with;
use swsc::linalg::{qr_householder, svd_jacobi, svd_randomized_with};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

/// Thread counts to sweep: 1, 2, 4 (where available), always ending at the
/// machine max so the full-parallelism data point is recorded.
fn thread_sweep() -> Vec<usize> {
    let max = exec::global().threads;
    let mut t: Vec<usize> = [1, 2, 4].iter().copied().filter(|&t| t <= max).collect();
    if !t.contains(&max) {
        t.push(max);
    }
    t
}

fn main() {
    let bench = Bench::new("hotpath");
    let mut rng = Rng::new(404);
    let sweep = thread_sweep();

    bench.section("L3 tensor kernels (threads sweep)");
    for &size in &[256usize, 512, 1024] {
        let a = Tensor::randn(&[size, size], &mut rng);
        let b = Tensor::randn(&[size, size], &mut rng);
        let flops = 2.0 * (size as f64).powi(3);
        let mut serial_mean = f64::NAN;
        for &t in &sweep {
            let cfg = ExecConfig::with_threads(t);
            let m = bench.case_at(&format!("matmul_{size}_t{t}"), size, t, || a.matmul_with(&b, cfg));
            if t == 1 {
                serial_mean = m;
            }
            println!("  -> {:.2} GFLOP/s ({:.2}x vs t1)", flops / m / 1e9, serial_mean / m);
        }
    }
    let a512 = Tensor::randn(&[512, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("transpose_512_t{t}"), 512, t, || a512.transpose_with(cfg));
    }

    bench.section("L3 linalg");
    let err = Tensor::randn(&[256, 256], &mut rng);
    bench.case_at("svd_jacobi_256", 256, 1, || svd_jacobi(&err));
    let err512 = Tensor::randn(&[512, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        let mut r2 = Rng::new(405);
        bench.case_at(&format!("svd_randomized_512_r8_t{t}"), 512, t, || {
            svd_randomized_with(&err512, 8, 8, 2, &mut r2, cfg)
        });
    }
    let tall = Tensor::randn(&[256, 24], &mut rng);
    bench.case_at("qr_256x24", 256, 1, || qr_householder(&tall));

    bench.section("L3 kmeans");
    let pts512 = Tensor::randn(&[512, 512], &mut rng);
    let cen = Tensor::randn(&[16, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("assign_n512_k16_t{t}"), 512, t, || assign_with(&pts512, &cen, cfg));
    }

    bench.section("pipeline: full matrix compression (threads sweep)");
    for &t in &sweep {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(t);
        bench.case_at(&format!("compress_512_k16_r8_t{t}"), 512, t, || {
            compress_matrix(&pts512, &cfg)
        });
    }
    let pts256 = Tensor::randn(&[256, 256], &mut rng);
    bench.case_at("compress_256_k16_r8", 256, exec::global().threads, || {
        compress_matrix(&pts256, &SwscConfig::new(16, 8))
    });
    bench.case_at("compress_256_k24_r12", 256, exec::global().threads, || {
        compress_matrix(&pts256, &SwscConfig::new(24, 12))
    });

    bench.section("label packing");
    let labels: Vec<u32> = (0..4096).map(|i| (i * 7) as u32 % 16).collect();
    bench.case_at("pack_4096_labels_4bit", 4096, 1, || pack_u32(&labels, 4));
    let packed = pack_u32(&labels, 4);
    bench.case_at("unpack_4096_labels_4bit", 4096, 1, || unpack_u32(&packed, 4096, 4));

    // PJRT round trip (needs artifacts).
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use swsc::model::{init_params, param_specs, ModelConfig};
        use swsc::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine};

        bench.section("PJRT runtime (tiny preset)");
        let cfg = ModelConfig::tiny();
        let man = ArtifactManifest::load(dir, "tiny").unwrap();
        let engine = Engine::new(man).unwrap();
        let exe = engine.load("fwd_eval").unwrap();
        let ck = init_params(&cfg, 1);
        let host: Vec<Tensor> =
            param_specs(&cfg).iter().map(|s| ck.get(&s.name).unwrap().clone()).collect();
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();

        bench.case("literal_convert_all_params", || {
            host.iter().map(|t| tensor_to_literal(t).unwrap()).count()
        });
        bench.case("fwd_eval_execute", || {
            let mut args: Vec<xla::Literal> =
                host.iter().map(|t| tensor_to_literal(t).unwrap()).collect();
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            exe.run(&args).unwrap()
        });
    } else {
        println!("(skipping PJRT section — run `make artifacts`)");
    }

    let json_path = Path::new("BENCH_hotpath.json");
    match bench.write_json(json_path) {
        Ok(()) => println!("\nwrote {} ({} records)", json_path.display(), bench.records().len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", json_path.display()),
    }
}
