//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 CPU kernels (matmul, SVD, kmeans assign, packing) and the PJRT
//! round trip (literal conversion + fwd_eval execution, artifact-gated).
//!
//! The parallel cases sweep thread counts {1, 2, 4, max} through the
//! deterministic executor; because results are bit-identical at any thread
//! count, the sweep is purely a wall-clock comparison. Every case lands in
//! `BENCH_hotpath.json` (op, size, threads, ns/iter) for cross-PR perf
//! tracking.
//!
//! ISSUE 2 additions:
//!
//! - Every major op also emits a `pool_vs_spawn_<op>` comparison row: the
//!   identical workload timed under the persistent-pool backend and under
//!   the legacy spawn-per-call backend (JSON `speedup` = spawn/pool,
//!   `vs = "spawn"`).
//!   Backends are bit-identical, so this is a pure scheduling comparison —
//!   including the pool's lower serial-fallback thresholds, which are part
//!   of what "persistent pool" buys.
//! - A many-small-matrices workload (64 sequential 128² SWSC compressions,
//!   in-matrix parallelism only) — the regime the pool exists for: under
//!   spawn-per-call the per-op work is below the spawn threshold and runs
//!   serial, while the pool profitably fans it out.
//! - A wide-matrix Lloyd case comparing the blocked cross-term assign
//!   against the un-blocked full-GEMM reference.
//! - A CI gate: if the pool regresses >10% vs spawn on any op ≥ 512², the
//!   bench exits non-zero.
//!
//! ISSUE 3 additions:
//!
//! - Every GEMM-bound op also emits a `packed_vs_blocked_<op>` row: the
//!   identical workload under the packed register-tiled engine
//!   (`GemmKernel::Packed`, shipping default) and under the legacy
//!   cache-blocked kernel (`GemmKernel::Blocked`). Kernels are
//!   bit-identical, so this is a pure codegen/memory-traffic comparison;
//!   packed regressing >10% on any op ≥ 512² fails the run.
//! - GFLOP/s fields on the flop-counted cases (matmul, tall-skinny
//!   t_matmul) via `case_at_flops`.
//! - A tall-skinny (m ≫ n, SVD-shaped) `t_matmul` sweep: the shape where
//!   strided-A packing replaces the old full `m × n` transpose
//!   materialization paid on every `AᵀQ` power-iteration GEMM.
//! - Baseline trajectory: after writing `BENCH_hotpath.json` the run
//!   compares per-op against the committed `BENCH_baseline.json`
//!   (bootstrapped from the current run if missing — commit it, like the
//!   golden fixture) and prints before/after ratios.
//!
//! ISSUE 4 additions:
//!
//! - `compressed_vs_dense_*` rows: the compressed-domain product
//!   (`Y = R·S + A·(B·X)`, `infer::CompressedLinear`) against the dense
//!   route every consumer used to take (reconstruct + full GEMM). CI
//!   gate: compressed ≥ 1.5× dense at k ≤ n/8, r ≤ 32 on ops ≥ 512².
//!   `compressed_vs_prebuilt_*` rows add the steady-state comparison
//!   against a pre-reconstructed dense GEMM (ungated), and a build-cost
//!   row prices the one-time serving-form construction.
//!
//! ISSUE 5 additions:
//!
//! - `batched_vs_solo_*` rows: the serve loadgen replays the identical
//!   seeded request stream through a coalescing `BatchServer` and
//!   through a solo server (`BatchConfig::solo()` — one `apply` per
//!   request, the bitwise-identical baseline). Gate: batched ≥ 1.5×
//!   solo throughput at ≥ 8 rows/request on ops ≥ 512 columns —
//!   **warn-only until `BENCH_baseline.json` is committed** (the
//!   baseline's presence at startup marks the bootstrap phase over),
//!   then hard like the other gates.
//! - The loadgen rows themselves (`loadgen_*_batched` / `_solo`) land in
//!   the JSON with the new `p95_us` / `batch_mean` fields.
//!
//! ISSUE 6 additions:
//!
//! - `quantized_vs_f32_*` rows: the serving `apply` orientation through
//!   `infer::QuantizedLinear` (grouped-int8 panels, dequantize-in-register
//!   fused GEMM) against `infer::CompressedLinear` (f32 panels) on the
//!   identical operator, both panel-warmed. Gate: quantized ≥ 1.2× f32 at
//!   k ≤ n/8 on ops ≥ 512² — warn-only until `BENCH_baseline.json` is
//!   committed, retry-once like the other gates.
//! - Each row is annotated with `bytes_per_param` (actual serialized
//!   quantized `.swsc` bytes ÷ `m·n`), and the quantized payload must be
//!   ≤ 0.35× of the f32 factor payload — a deterministic storage gate.
//!
//! ISSUE 7 additions:
//!
//! - `forward_batched_vs_flush_*` rows: the forward loadgen replays the
//!   identical seeded **mixed-length** whole-model request stream through
//!   a continuous-batched server (requests join/leave the in-flight batch
//!   at layer boundaries) and a flush-the-batch server (every batch
//!   member waits out the longest member). Gate: continuous p95 latency
//!   ≤ flush p95 — **warn-only until `BENCH_baseline.json` is
//!   committed**, retry-once like the other gates. Both schedulers are
//!   bitwise identical to solo serving (see `tests/serve_forward.rs`),
//!   so this row is purely a latency comparison.

use std::path::Path;
use std::sync::Arc;
use swsc::bench::loadgen::{run_forward_loadgen, run_loadgen, ForwardLoadgenConfig, LoadgenConfig};
use swsc::bench::Bench;
use swsc::compress::{compress_matrix, CompressedMatrix, SwscConfig};
use swsc::exec::{self, ExecBackend, ExecConfig};
use swsc::infer::{CompressedForward, CompressedLinear, CompressedModel, InferMode, QuantizedLinear};
use swsc::model::{init_params, param_specs, ModelConfig};
use swsc::quant::QuantConfig;
use swsc::io::SwscFile;
use swsc::serve::{BatchConfig, BatchServer, ForwardScheduling, ModelRegistry, DEFAULT_MODEL};
use swsc::io::{pack_u32, unpack_u32};
use swsc::kmeans::{assign_blocked_with, assign_gemm_with, assign_with};
use swsc::linalg::{qr_householder, svd_jacobi, svd_randomized_with};
use swsc::tensor::gemm::{self, GemmKernel};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

/// A synthetic compressed matrix for the infer rows: perf depends only on
/// shapes/labels, not on cluster quality, so skip the (slow) real k-means
/// + SVD and build the storage layout directly.
fn synthetic_compressed(m: usize, n: usize, k: usize, r: usize, rng: &mut Rng) -> CompressedMatrix {
    CompressedMatrix {
        shape: (m, n),
        labels: (0..n).map(|j| ((j * 7 + 3) % k) as u32).collect(),
        centroids: Tensor::randn(&[m, k], rng),
        factor_a: Tensor::randn(&[m, r], rng),
        factor_b: Tensor::randn(&[r, n], rng),
    }
}

/// Thread counts to sweep: 1, 2, 4 (where available), always ending at the
/// machine max so the full-parallelism data point is recorded.
fn thread_sweep() -> Vec<usize> {
    let max = exec::global().threads;
    let mut t: Vec<usize> = [1, 2, 4].iter().copied().filter(|&t| t <= max).collect();
    if !t.contains(&max) {
        t.push(max);
    }
    t
}

/// Time `f` under both backends at `threads` and record one
/// `pool_vs_spawn_<op>` comparison row. Ops ≥ 512² regressing >10% are
/// queued for the CI gate — after one full re-measurement, so a single
/// descheduled iteration on a noisy shared runner doesn't fail CI. Probe
/// timings go through `probe` (same warmup/iteration policy, not written
/// to the JSON trajectory).
#[allow(clippy::too_many_arguments)]
fn pool_vs_spawn<F: FnMut()>(
    bench: &Bench,
    probe: &Bench,
    regressions: &mut Vec<String>,
    op: &str,
    size: usize,
    threads: usize,
    mut f: F,
) -> f64 {
    let prior = exec::backend();
    let mut measure = |tag: &str| {
        exec::set_backend(ExecBackend::Pool);
        let pool = probe.case_at(&format!("{op}_pool{tag}"), size, threads, &mut f);
        exec::set_backend(ExecBackend::SpawnPerCall);
        let spawn = probe.case_at(&format!("{op}_spawn{tag}"), size, threads, &mut f);
        (pool, spawn)
    };
    let (mut pool, mut spawn) = measure("");
    if size >= 512 && spawn / pool.max(1e-12) < 0.9 {
        let (pool2, spawn2) = measure("_retry");
        if spawn2 / pool2.max(1e-12) > spawn / pool.max(1e-12) {
            (pool, spawn) = (pool2, spawn2);
        }
    }
    // Restore whatever backend the surrounding sweeps run under (the
    // module docs advertise SWSC_EXEC_BACKEND=spawn for whole-run
    // comparisons — don't silently mix backends in the JSON trajectory).
    exec::set_backend(prior);
    let speedup = bench.comparison(op, size, threads, pool, spawn);
    if size >= 512 && speedup < 0.9 {
        regressions.push(format!("{op} (size {size}, t{threads}): {speedup:.2}x vs spawn"));
    }
    speedup
}

/// Time `f` under the packed GEMM engine and under the legacy blocked
/// kernel and record one `packed_vs_blocked_<op>` comparison row. Same
/// retry-once policy as [`pool_vs_spawn`]; packed regressing >10% on an op
/// ≥ 512² is queued for the CI gate.
#[allow(clippy::too_many_arguments)]
fn packed_vs_blocked<F: FnMut()>(
    bench: &Bench,
    probe: &Bench,
    regressions: &mut Vec<String>,
    op: &str,
    size: usize,
    threads: usize,
    mut f: F,
) -> f64 {
    let prior = gemm::kernel();
    let mut measure = |tag: &str| {
        gemm::set_kernel(GemmKernel::Packed);
        let packed = probe.case_at(&format!("{op}_packed{tag}"), size, threads, &mut f);
        gemm::set_kernel(GemmKernel::Blocked);
        let blocked = probe.case_at(&format!("{op}_blocked{tag}"), size, threads, &mut f);
        (packed, blocked)
    };
    let (mut packed, mut blocked) = measure("");
    if size >= 512 && blocked / packed.max(1e-12) < 0.9 {
        let (packed2, blocked2) = measure("_retry");
        if blocked2 / packed2.max(1e-12) > blocked / packed.max(1e-12) {
            (packed, blocked) = (packed2, blocked2);
        }
    }
    gemm::set_kernel(prior);
    let speedup = bench.comparison_labeled(
        "packed_vs_blocked",
        "packed",
        "blocked",
        op,
        size,
        threads,
        packed,
        blocked,
    );
    if size >= 512 && speedup < 0.9 {
        regressions.push(format!(
            "{op} (size {size}, t{threads}): packed GEMM {speedup:.2}x vs blocked"
        ));
    }
    speedup
}

fn main() {
    let bench = Bench::new("hotpath");
    let probe = Bench::new("probe");
    let mut regressions: Vec<String> = Vec::new();
    let mut rng = Rng::new(404);
    let sweep = thread_sweep();
    // Comparison thread count: 4 where the machine has it, else the max.
    let cmp_t = sweep.iter().copied().filter(|&t| t <= 4).max().unwrap_or(1);
    let tile = gemm::tile();
    println!("gemm: packed tile MR={} x NR={} (kernel {:?})", tile.mr, tile.nr, gemm::kernel());

    bench.section("L3 tensor kernels (threads sweep)");
    for &size in &[256usize, 512, 1024] {
        let a = Tensor::randn(&[size, size], &mut rng);
        let b = Tensor::randn(&[size, size], &mut rng);
        let flops = 2.0 * (size as f64).powi(3);
        let mut serial_mean = f64::NAN;
        for &t in &sweep {
            let cfg = ExecConfig::with_threads(t);
            let m = bench
                .case_at_flops(&format!("matmul_{size}_t{t}"), size, t, flops, || {
                    a.matmul_with(&b, cfg)
                });
            if t == 1 {
                serial_mean = m;
            }
            println!("  -> {:.2}x vs t1", serial_mean / m);
        }
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, &format!("matmul_{size}"), size, cmp_t, || {
            a.matmul_with(&b, cfg);
        });
        packed_vs_blocked(
            &bench,
            &probe,
            &mut regressions,
            &format!("matmul_{size}"),
            size,
            cmp_t,
            || {
                a.matmul_with(&b, cfg);
            },
        );
    }
    let a512 = Tensor::randn(&[512, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("transpose_512_t{t}"), 512, t, || a512.transpose_with(cfg));
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "transpose_512", 512, cmp_t, || {
            a512.transpose_with(cfg);
        });
    }

    // Tall-skinny t_matmul — the SVD power-iteration shape (AᵀQ with
    // m ≫ n). Under the blocked baseline every iteration materializes the
    // full m × n transpose before the GEMM; the packed engine packs A
    // panels straight from the strided source, so this row is where the
    // strided-A packing payoff (and the killed allocation) shows up.
    bench.section("L3 tensor kernels — tall-skinny t_matmul (SVD-shaped)");
    for &(m, n, r) in &[(4096usize, 128usize, 16usize), (8192, 128, 16)] {
        let a = Tensor::randn(&[m, n], &mut rng);
        let q = Tensor::randn(&[m, r], &mut rng);
        let flops = 2.0 * (m as f64) * (n as f64) * (r as f64);
        for &t in &sweep {
            let cfg = ExecConfig::with_threads(t);
            bench.case_at_flops(&format!("t_matmul_tall_{m}x{n}_r{r}_t{t}"), m, t, flops, || {
                a.t_matmul_with(&q, cfg)
            });
        }
        let cfg = ExecConfig::with_threads(cmp_t);
        packed_vs_blocked(
            &bench,
            &probe,
            &mut regressions,
            &format!("t_matmul_tall_{m}x{n}_r{r}"),
            m,
            cmp_t,
            || {
                a.t_matmul_with(&q, cfg);
            },
        );
    }

    bench.section("L3 linalg");
    let err = Tensor::randn(&[256, 256], &mut rng);
    bench.case_at("svd_jacobi_256", 256, 1, || svd_jacobi(&err));
    let err512 = Tensor::randn(&[512, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        let mut r2 = Rng::new(405);
        bench.case_at(&format!("svd_randomized_512_r8_t{t}"), 512, t, || {
            svd_randomized_with(&err512, 8, 8, 2, &mut r2, cfg)
        });
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        let mut r2 = Rng::new(405);
        pool_vs_spawn(&bench, &probe, &mut regressions, "svd_randomized_512_r8", 512, cmp_t, || {
            svd_randomized_with(&err512, 8, 8, 2, &mut r2, cfg);
        });
        let mut r3 = Rng::new(405);
        packed_vs_blocked(&bench, &probe, &mut regressions, "svd_randomized_512_r8", 512, cmp_t, || {
            svd_randomized_with(&err512, 8, 8, 2, &mut r3, cfg);
        });
    }
    let tall = Tensor::randn(&[256, 24], &mut rng);
    bench.case_at("qr_256x24", 256, 1, || qr_householder(&tall));

    bench.section("L3 kmeans");
    let pts512 = Tensor::randn(&[512, 512], &mut rng);
    let cen = Tensor::randn(&[16, 512], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("assign_n512_k16_t{t}"), 512, t, || assign_with(&pts512, &cen, cfg));
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "assign_n512_k16", 512, cmp_t, || {
            assign_with(&pts512, &cen, cfg);
        });
    }

    // Wide-matrix Lloyd: blocked cross-term tiles vs the un-blocked
    // full-GEMM reference on an 8192-channel assignment (the 11008-channel
    // MLP regime, scaled to bench budget). Outputs are bit-identical; this
    // row tracks the wall-clock effect of fusing the argmin into the tiles.
    bench.section("L3 kmeans — wide-matrix blocked assign");
    let wide = Tensor::randn(&[8192, 128], &mut rng);
    let wide_cen = Tensor::randn(&[64, 128], &mut rng);
    for &t in &sweep {
        let cfg = ExecConfig::with_threads(t);
        bench.case_at(&format!("assign_blocked_n8192_k64_t{t}"), 8192, t, || {
            assign_blocked_with(&wide, &wide_cen, cfg)
        });
        bench.case_at(&format!("assign_gemm_n8192_k64_t{t}"), 8192, t, || {
            assign_gemm_with(&wide, &wide_cen, cfg)
        });
    }
    {
        let cfg = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "assign_blocked_n8192_k64", 8192, cmp_t, || {
            assign_blocked_with(&wide, &wide_cen, cfg);
        });
        packed_vs_blocked(
            &bench,
            &probe,
            &mut regressions,
            "assign_blocked_n8192_k64",
            8192,
            cmp_t,
            || {
                assign_blocked_with(&wide, &wide_cen, cfg);
            },
        );
    }

    bench.section("pipeline: full matrix compression (threads sweep)");
    for &t in &sweep {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(t);
        bench.case_at(&format!("compress_512_k16_r8_t{t}"), 512, t, || {
            compress_matrix(&pts512, &cfg)
        });
    }
    {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(cmp_t);
        pool_vs_spawn(&bench, &probe, &mut regressions, "compress_512_k16_r8", 512, cmp_t, || {
            compress_matrix(&pts512, &cfg);
        });
    }
    let pts256 = Tensor::randn(&[256, 256], &mut rng);
    bench.case_at("compress_256_k16_r8", 256, exec::global().threads, || {
        compress_matrix(&pts256, &SwscConfig::new(16, 8))
    });
    bench.case_at("compress_256_k24_r12", 256, exec::global().threads, || {
        compress_matrix(&pts256, &SwscConfig::new(24, 12))
    });

    // The pool's target regime: many small per-matrix jobs back to back,
    // parallelism only *inside* each op. Spawn-per-call leaves these ops
    // serial (their work sits below its spawn threshold); the persistent
    // pool fans them out for ~µs dispatch cost. ISSUE 2 acceptance floor:
    // ≥ 1.5× at 4 threads.
    bench.section("pipeline: many small matrices (64 × 128²)");
    let mats: Vec<Tensor> = (0..64).map(|_| Tensor::randn(&[128, 128], &mut rng)).collect();
    {
        let mut cfg = SwscConfig::new(16, 8);
        cfg.exec = ExecConfig::with_threads(cmp_t);
        let speedup = pool_vs_spawn(
            &bench,
            &probe,
            &mut regressions,
            "compress_many_small_64x128",
            128,
            cmp_t,
            || {
                for w in &mats {
                    std::hint::black_box(compress_matrix(w, &cfg));
                }
            },
        );
        if speedup < 1.5 && cmp_t >= 4 {
            println!(
                "  !! many-small workload speedup {speedup:.2}x is below the 1.5x acceptance floor"
            );
        }
    }

    // ISSUE 4: compressed-domain inference vs the dense route every
    // consumer used to take (reconstruct + full GEMM, per call). Gate: at
    // the paper's operating points (k ≤ n/8, r ≤ 32, ops ≥ 512²) the
    // compressed product must be ≥ 1.5× the dense route. A second,
    // ungated row compares against a *pre*-reconstructed dense GEMM —
    // the steady-state serving comparison where the dense side amortizes
    // its reconstruction.
    bench.section("infer: compressed-domain matmul (Y = R·S + A·(B·X)) vs dense");
    for &(n, k, r, b) in
        &[(512usize, 64usize, 16usize, 512usize), (512, 64, 32, 512), (1024, 128, 32, 512)]
    {
        let c = synthetic_compressed(n, n, k, r, &mut rng);
        let lin = CompressedLinear::from_matrix(&c);
        let x = Tensor::randn(&[n, b], &mut rng);
        let cfg = ExecConfig::with_threads(cmp_t);
        let op = format!("matmul_{n}_k{k}_r{r}_b{b}");
        let measure = |tag: &str| {
            let comp = probe.case_at(&format!("{op}_compressed{tag}"), n, cmp_t, || {
                lin.matmul_with(&x, cfg)
            });
            let dense = probe.case_at(&format!("{op}_dense{tag}"), n, cmp_t, || {
                c.reconstruct().matmul_with(&x, cfg)
            });
            (comp, dense)
        };
        let (mut comp, mut dense) = measure("");
        if dense / comp.max(1e-12) < 1.5 {
            // Same retry-once policy as the pool/kernel gates: a single
            // descheduled iteration must not fail CI.
            let (comp2, dense2) = measure("_retry");
            if dense2 / comp2.max(1e-12) > dense / comp.max(1e-12) {
                (comp, dense) = (comp2, dense2);
            }
        }
        let speedup = bench.comparison_labeled(
            "compressed_vs_dense",
            "compressed",
            "dense",
            &op,
            n,
            cmp_t,
            comp,
            dense,
        );
        if n >= 512 && k * 8 <= n && r <= 32 && speedup < 1.5 {
            regressions.push(format!(
                "{op}: compressed {speedup:.2}x vs dense reconstruct+matmul (< 1.5x floor)"
            ));
        }
        let w = c.reconstruct();
        let pre = probe.case_at(&format!("{op}_dense_prebuilt"), n, cmp_t, || {
            w.matmul_with(&x, cfg)
        });
        bench.comparison_labeled(
            "compressed_vs_prebuilt",
            "compressed",
            "prebuilt",
            &op,
            n,
            cmp_t,
            comp,
            pre,
        );
    }
    // One-time serving-form cost: build (validation + CSR index) plus the
    // lazy panel packing a first matmul triggers — the price a cold
    // operator pays before steady-state requests get cheap. Serial config
    // so the row's threads axis is honest across machines.
    {
        let c = synthetic_compressed(512, 512, 64, 16, &mut rng);
        let x1 = Tensor::randn(&[512, 1], &mut rng);
        let serial = ExecConfig::serial();
        bench.case_at("compressed_linear_build_pack_512_k64_r16", 512, 1, || {
            let lin = CompressedLinear::from_matrix(&c);
            lin.matmul_with(&x1, serial)
        });
    }

    // ISSUE 5: micro-batch coalescing vs solo serving. The loadgen
    // replays one seeded stream (saturation mode: submit as fast as
    // admission allows) through a coalescing server and a solo server;
    // the servers share one Arc'd model, so packed panels are warmed once
    // up front and neither side pays first-touch packing. Speedup is
    // wall-clock per request, solo / batched.
    bench.section("serve: micro-batch coalescing vs solo (loadgen)");
    let baseline_committed = Path::new("BENCH_baseline.json").exists();
    for &(n, k, r, rows, requests) in
        &[(512usize, 64usize, 16usize, 8usize, 96usize), (1024, 128, 32, 8, 48)]
    {
        let mut file = SwscFile::new();
        file.compressed.insert("w".into(), synthetic_compressed(n, n, k, r, &mut rng));
        let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
        model
            .apply("w", &Tensor::randn(&[rows, n], &mut rng))
            .expect("panel warmup apply failed");
        let lg = LoadgenConfig {
            seed: 0x5E12,
            requests,
            rows_per_request: rows,
            ragged: false,
            rate_rps: 0.0,
            targets: vec![(DEFAULT_MODEL.to_string(), "w".to_string())],
            deadline: None,
        };
        let run_with = |cfg: BatchConfig| {
            let reg = ModelRegistry::new();
            reg.insert(DEFAULT_MODEL, model.clone());
            let server = BatchServer::start(Arc::new(reg), cfg);
            let rep = run_loadgen(&server, &lg).expect("loadgen replay failed");
            server.shutdown();
            rep
        };
        let measure = || {
            let batched = run_with(BatchConfig::with_wait_us(256, 200));
            let solo = run_with(BatchConfig::solo());
            (batched, solo)
        };
        let (mut batched, mut solo) = measure();
        if solo.wall_seconds / batched.wall_seconds.max(1e-12) < 1.5 {
            // Retry-once policy, like the other gates: one descheduled
            // run on a noisy shared runner must not fail CI.
            let (b2, s2) = measure();
            if s2.wall_seconds / b2.wall_seconds.max(1e-12)
                > solo.wall_seconds / batched.wall_seconds.max(1e-12)
            {
                (batched, solo) = (b2, s2);
            }
        }
        let op = format!("serve_{n}_k{k}_r{r}_rows{rows}");
        let threads = exec::global().threads;
        bench.push_record(batched.to_record(&format!("loadgen_{op}_batched"), n, threads));
        bench.push_record(solo.to_record(&format!("loadgen_{op}_solo"), n, threads));
        let speedup = bench.comparison_labeled(
            "batched_vs_solo",
            "batched",
            "solo",
            &op,
            n,
            threads,
            batched.wall_seconds / requests as f64,
            solo.wall_seconds / requests as f64,
        );
        println!(
            "  batched: {:.0} req/s, p95 {:.0} µs, mean batch {:.1} rows over {} batches; \
             solo: {:.0} req/s",
            batched.rps, batched.p95_us, batched.batch_mean, batched.batches, solo.rps
        );
        if n >= 512 && rows >= 8 && speedup < 1.5 {
            let msg =
                format!("{op}: batched serving {speedup:.2}x vs solo (< 1.5x throughput floor)");
            if baseline_committed {
                regressions.push(msg);
            } else {
                println!("  !! {msg} — warn-only until BENCH_baseline.json is committed");
            }
        }
    }

    // ISSUE 6: quantized serving vs the f32 oracle. Both operators serve
    // the same compressed matrix through the `apply` orientation with
    // panels pre-warmed, so the comparison is pure steady-state kernel +
    // panel-traffic: int8 codes dequantized in-register vs f32 panels.
    // The storage axis rides along: each row is annotated with the actual
    // serialized bytes per parameter, and the quantized payload is gated
    // (deterministically) at ≤ 0.35× of the f32 factor payload.
    bench.section("infer: quantized (int8 fused-dequant) vs f32 apply");
    for &(n, k, r, b) in &[(512usize, 64usize, 16usize, 256usize), (1024, 128, 32, 256)] {
        let c = synthetic_compressed(n, n, k, r, &mut rng);
        let q = c.quantize(&QuantConfig::default());
        let qlin = QuantizedLinear::from_matrix(&q);
        let flin = CompressedLinear::from_matrix(&c);
        let x = Tensor::randn(&[b, n], &mut rng);
        let cfg = ExecConfig::with_threads(cmp_t);
        std::hint::black_box(qlin.apply_with(&x, cfg));
        std::hint::black_box(flin.apply_with(&x, cfg));
        let op = format!("apply_{n}_k{k}_r{r}_b{b}");
        let measure = |tag: &str| {
            let qt = probe
                .case_at(&format!("{op}_int8{tag}"), n, cmp_t, || qlin.apply_with(&x, cfg));
            let ft =
                probe.case_at(&format!("{op}_f32{tag}"), n, cmp_t, || flin.apply_with(&x, cfg));
            (qt, ft)
        };
        let (mut qt, mut ft) = measure("");
        if ft / qt.max(1e-12) < 1.2 {
            // Retry-once policy, like the other gates.
            let (qt2, ft2) = measure("_retry");
            if ft2 / qt2.max(1e-12) > ft / qt.max(1e-12) {
                (qt, ft) = (qt2, ft2);
            }
        }
        let speedup =
            bench.comparison_labeled("quantized_vs_f32", "int8", "f32", &op, n, cmp_t, qt, ft);
        // Actual on-disk cost of what just served: one quantized entry,
        // serialized for real, divided by the original parameter count.
        let mut qfile = SwscFile::new();
        qfile.quantized.insert("w".into(), q.clone());
        let q_bytes = qfile.to_bytes().len() as f64;
        bench.annotate_bytes_per_param(&format!("quantized_vs_f32_{op}"), q_bytes / (n * n) as f64);
        let f32_payload = (4 * (n * k + n * r + r * n) + q.labels.len()) as f64;
        let ratio = q_bytes / f32_payload;
        println!(
            "  int8 payload {q_bytes:.0} B = {ratio:.3}x of the f32 factor payload \
             ({:.3} B/param)",
            q_bytes / (n * n) as f64
        );
        if ratio > 0.35 {
            regressions.push(format!(
                "{op}: quantized payload {ratio:.3}x of f32 factors (> 0.35x storage gate)"
            ));
        }
        if n >= 512 && k * 8 <= n && speedup < 1.2 {
            let msg =
                format!("{op}: quantized apply {speedup:.2}x vs f32 (< 1.2x throughput floor)");
            if baseline_committed {
                regressions.push(msg);
            } else {
                println!("  !! {msg} — warn-only until BENCH_baseline.json is committed");
            }
        }
    }

    // ISSUE 7: continuous batching vs flush-the-batch on whole-model
    // forwards. One tiny compressed forward (panels warmed by a solo
    // forward up front) is shared by both servers via its Arc; the
    // loadgen then replays the identical seeded mixed-length token
    // stream through each. The workload is convoy-prone by construction
    // — window lengths drawn uniformly from 1..=seq — so a flush server
    // makes every short request wait out the longest member of its
    // batch, while the continuous server lets requests exit (and join)
    // at layer boundaries. Both schedulers are bitwise identical to solo
    // serving, so the only axis compared here is p95 latency.
    bench.section("serve: continuous batching vs flush (forward loadgen)");
    {
        let mcfg = ModelConfig::tiny();
        let ck = init_params(&mcfg, 7);
        let mut file = SwscFile::new();
        for spec in param_specs(&mcfg) {
            let t = ck.get(&spec.name).unwrap().clone();
            if spec.shape.len() == 2 && spec.shape[1] >= 16 {
                file.compressed
                    .insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
            } else {
                file.dense.insert(spec.name.clone(), t);
            }
        }
        let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
        let fwd = Arc::new(
            CompressedForward::new(model, mcfg.clone()).expect("forward build failed"),
        );
        let warm: Vec<u32> = (0..mcfg.seq).map(|i| (i % mcfg.vocab) as u32).collect();
        fwd.forward(&warm).expect("panel warmup forward failed");
        let lg = ForwardLoadgenConfig {
            seed: 0xF0F7,
            requests: 48,
            max_tokens: mcfg.seq,
            mixed: true,
            rate_rps: 0.0,
            models: vec![DEFAULT_MODEL.to_string()],
            deadline: None,
        };
        let run_with = |scheduling: ForwardScheduling| {
            let reg = ModelRegistry::new();
            reg.insert_forward(DEFAULT_MODEL, fwd.clone());
            let server = BatchServer::start(
                Arc::new(reg),
                BatchConfig::default().with_forward_scheduling(scheduling),
            );
            let rep = run_forward_loadgen(&server, &lg).expect("forward loadgen replay failed");
            server.shutdown();
            rep
        };
        let measure = || {
            let cont = run_with(ForwardScheduling::Continuous);
            let flush = run_with(ForwardScheduling::Flush);
            (cont, flush)
        };
        let (mut cont, mut flush) = measure();
        if flush.p95_us / cont.p95_us.max(1e-12) < 1.0 {
            // Retry-once policy, like the other gates.
            let (c2, f2) = measure();
            if f2.p95_us / c2.p95_us.max(1e-12) > flush.p95_us / cont.p95_us.max(1e-12) {
                (cont, flush) = (c2, f2);
            }
        }
        let size = mcfg.d_model;
        let threads = exec::global().threads;
        let op = format!("forward_tiny_d{}_l{}_seq{}", mcfg.d_model, mcfg.n_layers, mcfg.seq);
        bench.push_record(cont.to_record(&format!("loadgen_{op}_continuous"), size, threads));
        bench.push_record(flush.to_record(&format!("loadgen_{op}_flush"), size, threads));
        let speedup = bench.comparison_labeled(
            "forward_batched_vs_flush",
            "continuous",
            "flush",
            &op,
            size,
            threads,
            cont.p95_us * 1e-6,
            flush.p95_us * 1e-6,
        );
        println!(
            "  continuous: p95 {:.0} µs, {:.0} req/s, {} layer steps (mean {:.1} rows); \
             flush: p95 {:.0} µs, {:.0} req/s",
            cont.p95_us, cont.rps, cont.batches, cont.batch_mean, flush.p95_us, flush.rps
        );
        if speedup < 1.0 {
            let msg = format!(
                "{op}: continuous batching p95 {speedup:.2}x vs flush (< 1.0x latency floor)"
            );
            if baseline_committed {
                regressions.push(msg);
            } else {
                println!("  !! {msg} — warn-only until BENCH_baseline.json is committed");
            }
        }
    }

    bench.section("label packing");
    let labels: Vec<u32> = (0..4096).map(|i| (i * 7) as u32 % 16).collect();
    bench.case_at("pack_4096_labels_4bit", 4096, 1, || pack_u32(&labels, 4));
    let packed = pack_u32(&labels, 4);
    bench.case_at("unpack_4096_labels_4bit", 4096, 1, || unpack_u32(&packed, 4096, 4));

    // PJRT round trip (needs artifacts).
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use swsc::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine};

        bench.section("PJRT runtime (tiny preset)");
        let cfg = ModelConfig::tiny();
        let man = ArtifactManifest::load(dir, "tiny").unwrap();
        let engine = Engine::new(man).unwrap();
        let exe = engine.load("fwd_eval").unwrap();
        let ck = init_params(&cfg, 1);
        let host: Vec<Tensor> =
            param_specs(&cfg).iter().map(|s| ck.get(&s.name).unwrap().clone()).collect();
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();

        bench.case("literal_convert_all_params", || {
            host.iter().map(|t| tensor_to_literal(t).unwrap()).count()
        });
        bench.case("fwd_eval_execute", || {
            let mut args: Vec<xla::Literal> =
                host.iter().map(|t| tensor_to_literal(t).unwrap()).collect();
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            exe.run(&args).unwrap()
        });
    } else {
        println!("(skipping PJRT section — run `make artifacts`)");
    }

    let json_path = Path::new("BENCH_hotpath.json");
    match bench.write_json(json_path) {
        Ok(()) => println!("\nwrote {} ({} records)", json_path.display(), bench.records().len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", json_path.display()),
    }

    // Cross-PR perf trajectory: compare this run against the committed
    // baseline.
    let baseline_path = Path::new("BENCH_baseline.json");
    if baseline_path.exists() {
        bench.compare_against_baseline(baseline_path);
    }

    if !regressions.is_empty() {
        eprintln!("\nPERF REGRESSION (gate failures on ops ≥ 512²):");
        for r in &regressions {
            eprintln!("  {r}");
        }
        // Deliberately no baseline bootstrap on a failed run: a regressed
        // run must never seed the perf trajectory.
        std::process::exit(1);
    }
    println!(
        "gates: pool within 10% of spawn, packed GEMM within 10% of blocked, \
         compressed-domain matmul ≥ 1.5x dense reconstruct+matmul (k ≤ n/8, r ≤ 32) \
         on all ops ≥ 512², batched serving ≥ 1.5x solo throughput at ≥ 8 \
         rows/request on ops ≥ 512 cols, quantized apply ≥ 1.2x f32 at k ≤ n/8 on \
         ops ≥ 512², continuous forward batching p95 ≤ flush p95 on the mixed-length \
         stream (all three warn-only until BENCH_baseline.json is committed), AND \
         quantized payload ≤ 0.35x of the f32 factor payload"
    );

    // Bootstrap a missing baseline only from a gate-clean run (same policy
    // as the golden fixture: commit it, then future perf PRs have an
    // in-repo before/after to cite).
    if !baseline_path.exists() {
        match std::fs::copy(json_path, baseline_path) {
            Ok(_) => println!(
                "bootstrapped {} from this run — commit it so future perf PRs compare against it",
                baseline_path.display()
            ),
            Err(e) => eprintln!("failed to bootstrap {}: {e}", baseline_path.display()),
        }
    }
}
