//! Hot-path microbenchmarks for the performance pass (EXPERIMENTS.md §Perf):
//! the L3 CPU kernels (matmul, SVD, kmeans assign, packing) and the PJRT
//! round trip (literal conversion + fwd_eval execution, artifact-gated).

use std::path::Path;
use swsc::bench::Bench;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::io::{pack_u32, unpack_u32};
use swsc::kmeans::assign;
use swsc::linalg::{qr_householder, svd_jacobi, svd_randomized};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

fn main() {
    let bench = Bench::new("hotpath");
    let mut rng = Rng::new(404);

    bench.section("L3 tensor kernels");
    let a = Tensor::randn(&[256, 256], &mut rng);
    let b = Tensor::randn(&[256, 256], &mut rng);
    let m = bench.case("matmul_256", || a.matmul(&b));
    let flops = 2.0 * 256f64.powi(3);
    println!("  -> {:.2} GFLOP/s", flops / m / 1e9);
    let a512 = Tensor::randn(&[512, 512], &mut rng);
    let b512 = Tensor::randn(&[512, 512], &mut rng);
    let m = bench.case("matmul_512", || a512.matmul(&b512));
    println!("  -> {:.2} GFLOP/s", 2.0 * 512f64.powi(3) / m / 1e9);
    bench.case("transpose_512", || a512.transpose());

    bench.section("L3 linalg");
    let err = Tensor::randn(&[256, 256], &mut rng);
    bench.case("svd_jacobi_256", || svd_jacobi(&err));
    let mut r2 = Rng::new(405);
    bench.case("svd_randomized_256_r8", || svd_randomized(&err, 8, 8, 2, &mut r2));
    let tall = Tensor::randn(&[256, 24], &mut rng);
    bench.case("qr_256x24", || qr_householder(&tall));

    bench.section("L3 kmeans");
    let pts = Tensor::randn(&[256, 256], &mut rng);
    let cen = Tensor::randn(&[16, 256], &mut rng);
    bench.case("assign_n256_k16", || assign(&pts, &cen));

    bench.section("pipeline: full matrix compression");
    bench.case("compress_256_k16_r8", || compress_matrix(&pts, &SwscConfig::new(16, 8)));
    bench.case("compress_256_k24_r12", || compress_matrix(&pts, &SwscConfig::new(24, 12)));

    bench.section("label packing");
    let labels: Vec<u32> = (0..4096).map(|i| (i * 7) as u32 % 16).collect();
    bench.case("pack_4096_labels_4bit", || pack_u32(&labels, 4));
    let packed = pack_u32(&labels, 4);
    bench.case("unpack_4096_labels_4bit", || unpack_u32(&packed, 4096, 4));

    // PJRT round trip (needs artifacts).
    let dir = Path::new("artifacts");
    if dir.join("manifest.txt").exists() {
        use swsc::model::{init_params, param_specs, ModelConfig};
        use swsc::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine};

        bench.section("PJRT runtime (tiny preset)");
        let cfg = ModelConfig::tiny();
        let man = ArtifactManifest::load(dir, "tiny").unwrap();
        let engine = Engine::new(man).unwrap();
        let exe = engine.load("fwd_eval").unwrap();
        let ck = init_params(&cfg, 1);
        let host: Vec<Tensor> =
            param_specs(&cfg).iter().map(|s| ck.get(&s.name).unwrap().clone()).collect();
        let toks: Vec<i32> = (0..cfg.batch * cfg.seq).map(|i| (i % cfg.vocab) as i32).collect();

        bench.case("literal_convert_all_params", || {
            host.iter().map(|t| tensor_to_literal(t).unwrap()).count()
        });
        bench.case("fwd_eval_execute", || {
            let mut args: Vec<xla::Literal> =
                host.iter().map(|t| tensor_to_literal(t).unwrap()).collect();
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            args.push(tokens_to_literal(&toks, cfg.batch, cfg.seq).unwrap());
            exe.run(&args).unwrap()
        });
    } else {
        println!("(skipping PJRT section — run `make artifacts`)");
    }
}
