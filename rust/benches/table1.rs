//! Bench/reproduction of paper Table I: perplexity of Q / K / Q&K under
//! RTN vs SWSC at matched 3- and 2-bit budgets.
//!
//! Uses the trained checkpoint at `runs/default/model.swck` if present
//! (produced by `swsc train` / `make train`); otherwise trains a short run
//! through the AOT train step first. Requires `make artifacts`.

use std::path::Path;
use swsc::bench::Bench;
use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::compress_model;
use swsc::eval::Evaluator;
use swsc::io::Checkpoint;
use swsc::model::{init_params, ModelConfig};
use swsc::quant::{rtn_quantize, RtnConfig};
use swsc::report::{render_table1, Table1Row};
use swsc::runtime::{ArtifactManifest, Engine};
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus};
use swsc::train::{LrSchedule, Trainer};

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        println!("table1: artifacts missing — run `make artifacts` first");
        return;
    }
    let cfg = ModelConfig::small();
    let man = ArtifactManifest::load(dir, "small").expect("manifest");
    let engine = Engine::new(man).expect("engine");

    // Data identical to the CLI path (seed 42).
    let corpus = SyntheticCorpus::generate(&CorpusConfig { seed: 42, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, cfg.vocab);
    let eval_data = Dataset::from_text(&corpus.eval_text, &tok, cfg.batch, cfg.seq);

    // Checkpoint: prefer the trained run, else a quick warmup train.
    let ck_path = Path::new("runs/default/model.swck");
    let ck: Checkpoint = if ck_path.exists() {
        println!("using trained checkpoint {}", ck_path.display());
        Checkpoint::load(ck_path).expect("load ckpt")
    } else {
        println!("no trained checkpoint; running 60 warmup steps (slower, less contrast)");
        let train_data = Dataset::from_text(&corpus.train_text, &tok, cfg.batch, cfg.seq);
        let mut trainer =
            Trainer::new(engine.clone(), cfg.clone(), &init_params(&cfg, 42)).expect("trainer");
        let sched = LrSchedule::new(3e-4, 5, 60);
        for step in 0..60 {
            trainer.step(&train_data.batch(step), sched.at(step)).expect("step");
        }
        trainer.to_checkpoint().expect("ckpt")
    };

    let bench = Bench::new("table1").with_iters(3);
    let evaluator = Evaluator::new(engine, cfg.clone()).expect("evaluator");
    let fp32 = evaluator.perplexity_of(&ck, &eval_data).expect("fp32 eval").perplexity;
    println!("fp32 baseline ppl: {fp32:.3}");

    let mut rows = Vec::new();
    for proj in [ProjectorSet::Q, ProjectorSet::K, ProjectorSet::QAndK] {
        for bits in [3.0f64, 2.0] {
            // RTN arm.
            let mut qck = ck.clone();
            let rtn_cfg = RtnConfig { bits: bits as u32, ..Default::default() };
            for (name, _) in ck.shapes() {
                if proj.matches(&name) {
                    let q = rtn_quantize(qck.get(&name).unwrap(), &rtn_cfg);
                    qck.insert(&name, q);
                }
            }
            let rtn_ppl = evaluator.perplexity_of(&qck, &eval_data).unwrap().perplexity;

            // SWSC arm (timed — this is the pipeline's hot path).
            let plan = CompressionPlan::for_target_bits(&ck.shapes(), proj, bits, 0.5, 42);
            let mut file = None;
            bench.case(&format!("swsc_compress/{}@{bits}b", proj.label()), || {
                file = Some(compress_model(&ck, &plan, 8, None).unwrap());
            });
            let mut sck = ck.clone();
            for (name, t) in file.unwrap().file.restore_all() {
                sck.insert(&name, t);
            }
            let swsc_ppl = evaluator.perplexity_of(&sck, &eval_data).unwrap().perplexity;

            println!(
                "{:<5} {bits} bits:  RTN {rtn_ppl:>12.3}   SWSC {swsc_ppl:>10.3}",
                proj.label()
            );
            rows.push(Table1Row {
                projector: proj.label().into(),
                method: "RTN".into(),
                avg_bits: bits,
                perplexity: rtn_ppl,
            });
            rows.push(Table1Row {
                projector: proj.label().into(),
                method: "SWSC".into(),
                avg_bits: bits,
                perplexity: swsc_ppl,
            });
        }
    }

    println!();
    println!(
        "{}",
        render_table1(
            &format!("{} on synthetic tiny-wiki (paper: Llama-2-7B / WikiText-2)", cfg.fingerprint()),
            fp32,
            &rows
        )
    );
    println!(
        "shape check vs paper: degradation monotone 3→2 bits and worst for Q&K (✓ paper's ordering);\n\
         SWSC degrades gracefully, no collapse/nan (✓). Note: at this scale RTN ≤ SWSC — inverted vs\n\
         the paper because briefly-trained 4.8M-param projectors lack 7B-scale channel similarity;\n\
         see EXPERIMENTS.md §Table-I and the fig2_motivation bench for the mechanism."
    );
}
