//! Reproduction of the paper's Fig. 2 / §III-A motivation claim:
//!
//!   "under the condition of constant storage space, the mean square error
//!    of vectors in the same cluster is lower than that after RTN
//!    quantization"
//!
//! We sweep storage budgets on (a) channel-structured weights like trained
//! attention projectors and (b) unstructured i.i.d. weights, and print the
//! cluster-restore MSE vs RTN MSE at matched avg-bits. Also times the two
//! transforms (clustering vs RTN) at the default matrix size.

use swsc::bench::Bench;
use swsc::compress::{compress_matrix, SwscConfig};
use swsc::quant::bits::{rtn_avg_bits, swsc_avg_bits_paper, swsc_params_for_bits};
use swsc::quant::{rtn_quantize, RtnConfig, RtnMode};
use swsc::tensor::Tensor;
use swsc::util::rng::Rng;

/// Channel-clustered weights + outliers (the regime trained projectors
/// live in; see compress::swsc tests for the same generator).
fn structured(m: usize, groups: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    let centers: Vec<Vec<f32>> =
        (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    let mut w = Tensor::zeros(&[m, m]);
    for j in 0..m {
        let c = &centers[j % groups];
        let col: Vec<f32> = c.iter().map(|&v| v + rng.normal_f32(0.0, 0.15)).collect();
        w.set_col(j, &col);
    }
    for _ in 0..(m * m / 200).max(1) {
        let i = rng.below(m * m);
        w.data_mut()[i] += rng.normal_f32(0.0, 6.0);
    }
    w
}

fn run_sweep(label: &str, w: &Tensor) {
    let m = w.rows();
    println!("\n--- {label} (m = {m}) ---");
    println!("| budget | SWSC (k,r)     | SWSC bits | SWSC MSE   | RTN bits | RTN MSE    | winner |");
    println!("|--------|----------------|-----------|------------|----------|------------|--------|");
    for bits in [1.0f64, 2.0, 3.0, 4.0] {
        let (k, r) = swsc_params_for_bits(m, bits, 0.5);
        let c = compress_matrix(w, &SwscConfig::new(k, r));
        let swsc_mse = c.reconstruct().mse(w);
        let rtn = rtn_quantize(w, &RtnConfig { bits: bits.round() as u32, mode: RtnMode::Asymmetric });
        let rtn_mse = w.mse(&rtn);
        println!(
            "| {bits:<6} | k={k:<4} r={r:<4} | {:<9.3} | {swsc_mse:<10.3e} | {:<8.3} | {rtn_mse:<10.3e} | {} |",
            swsc_avg_bits_paper(m, k, r),
            rtn_avg_bits(m, m, bits.round() as u32),
            if swsc_mse < rtn_mse { "SWSC" } else { "RTN" },
        );
    }
}

fn main() {
    let bench = Bench::new("fig2_motivation");
    bench.section("paper §III-A feasibility: within-cluster MSE vs RTN at equal storage");

    let structured_w = structured(256, 24, 1234);
    run_sweep("channel-structured weights (trained-projector regime)", &structured_w);

    let mut rng = Rng::new(99);
    let iid = Tensor::randn(&[256, 256], &mut rng);
    run_sweep("unstructured i.i.d. gaussian (adversarial for SWSC)", &iid);

    println!();
    bench.case("SWSC transform 256x256 (k=16, r=8)", || {
        compress_matrix(&structured_w, &SwscConfig::new(16, 8))
    });
    bench.case("RTN transform 256x256 (2-bit)", || {
        rtn_quantize(&structured_w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric })
    });
}
