//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io access, so the
//! manifest points here instead. Only the subset the workspace uses is
//! implemented: [`Error`] (a context chain over any `std::error::Error`),
//! [`Result`], the [`Context`] extension trait for `Result`/`Option`, and
//! the `anyhow!` / `bail!` / `ensure!` macros. Formatting matches anyhow's
//! conventions: `{}` prints the outermost message, `{:#}` joins the chain
//! with `: `, and `{:?}` prints a `Caused by:` list. Swap in the real crate
//! by deleting `vendor/anyhow` and pointing the dependency at crates.io.

use std::fmt;

/// A context-chain error. Unlike the real anyhow this stores the chain as
/// rendered strings (no downcasting), which is all this workspace needs.
pub struct Error {
    /// `chain[0]` is the outermost context, the last entry the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error` — that
// is what makes the blanket `From` below coherent (same trick as anyhow).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`, defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err()).context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_and_macros() {
        let e = None::<u32>.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");

        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert_eq!(f(true).unwrap(), 1);
        assert_eq!(format!("{}", f(false).unwrap_err()), "flag was false");
        assert_eq!(format!("{}", anyhow!("x = {}", 3)), "x = 3");
        assert_eq!(format!("{}", anyhow!(String::from("boom"))), "boom");
    }

    #[test]
    fn identity_from_keeps_chain() {
        let e = Error::msg("root").context("outer");
        let e2: Error = Err::<(), _>(e).context("outermost").unwrap_err();
        assert_eq!(format!("{e2:#}"), "outermost: outer: root");
    }
}
