//! Vendored host-side stand-in for the `xla` (PJRT) bindings.
//!
//! The container this repo builds in has neither crates.io access nor the
//! `xla_extension` C++ runtime, so the manifest points here. The split:
//!
//! - **[`Literal`] is fully functional** — a typed host buffer with shape,
//!   reshape, dtype conversion, and tuple support. Everything in
//!   `runtime::convert`, the trainer's scalar plumbing, and the literal
//!   round-trip tests works unchanged.
//! - **PJRT execution is stubbed** — [`PjRtClient::cpu`] returns an error,
//!   so artifact-gated paths (`Engine`, `Trainer`, `Evaluator` execution)
//!   report "PJRT runtime not available" instead of running. Those paths
//!   already gate on `artifacts/manifest.txt` existing, so tests skip
//!   cleanly.
//!
//! Swap in the real bindings by deleting `vendor/xla` and pointing the
//! dependency at the `xla` crate built against `xla_extension`.

use std::fmt;

/// Error type mirroring the real crate's: a plain message, usable with `?`
/// into `anyhow::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime not available in this build (vendored host-only xla stub; \
         link the real xla_extension bindings to execute artifacts)"
    ))
}

/// Element dtypes the workspace uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Alias the real crate exposes for conversion targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimitiveType {
    F32,
    S32,
}

/// Host types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(data: Vec<Self>) -> Payload;
    fn unwrap(payload: &Payload) -> Option<Vec<Self>>;
}

/// Typed storage behind a literal. Public only because `NativeType`
/// mentions it; not part of the real crate's API surface.
#[derive(Debug, Clone)]
pub enum Payload {
    F32(Vec<f32>),
    S32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(payload: &Payload) -> Option<Vec<f32>> {
        match payload {
            Payload::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(data: Vec<i32>) -> Payload {
        Payload::S32(data)
    }
    fn unwrap(payload: &Payload) -> Option<Vec<i32>> {
        match payload {
            Payload::S32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Array shape: dimensions + element type.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host literal: typed data plus shape (or a tuple of literals).
#[derive(Debug, Clone)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal { payload: T::wrap(values.to_vec()), dims: vec![values.len() as i64] }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { payload: T::wrap(vec![value]), dims: Vec::new() }
    }

    /// Tuple literal (what `return_tuple=True` executables produce).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { payload: Payload::Tuple(elements), dims: Vec::new() }
    }

    /// Reshape to `dims`; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if want != have {
            return Err(Error(format!("reshape {:?} -> {dims:?}: {have} elements vs {want}", self.dims)));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    /// Number of elements (1 for scalars, sum over leaves for tuples).
    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::S32(v) => v.len(),
            Payload::Tuple(es) => es.iter().map(Literal::element_count).sum(),
        }
    }

    /// Shape of an array literal; errors on tuples.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = match &self.payload {
            Payload::F32(_) => ElementType::F32,
            Payload::S32(_) => ElementType::S32,
            Payload::Tuple(_) => return Err(Error("array_shape on tuple literal".into())),
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    /// Copy out the data as `T`; dtype must match exactly (use
    /// [`Literal::convert`] to cast first, as the real crate requires).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.payload)
            .ok_or_else(|| Error(format!("to_vec: literal is not {:?}", T::TY)))
    }

    /// Elementwise dtype conversion (value cast, like XLA's `convert`).
    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal> {
        let payload = match (&self.payload, ty) {
            (Payload::F32(v), PrimitiveType::F32) => Payload::F32(v.clone()),
            (Payload::S32(v), PrimitiveType::S32) => Payload::S32(v.clone()),
            (Payload::F32(v), PrimitiveType::S32) => {
                Payload::S32(v.iter().map(|&x| x as i32).collect())
            }
            (Payload::S32(v), PrimitiveType::F32) => {
                Payload::F32(v.iter().map(|&x| x as f32).collect())
            }
            (Payload::Tuple(_), _) => return Err(Error("convert on tuple literal".into())),
        };
        Ok(Literal { payload, dims: self.dims.clone() })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(es) => Ok(es),
            _ => Err(Error("to_tuple on non-tuple literal".into())),
        }
    }
}

/// Parsed HLO module handle. Parsing needs the real runtime.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("parse HLO text `{path}`")))
    }
}

/// Computation handle built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle. Unreachable in the stub (no client can exist).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Loaded executable handle. Unreachable in the stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(&self, _inputs: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// PJRT client. [`PjRtClient::cpu`] always errors in the stub, so the
/// handles above can never actually be reached at runtime.
#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_literal"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec1_reshape_round_trip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]).reshape(&[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_and_convert() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.element_count(), 1);
        assert!(s.array_shape().unwrap().dims().is_empty());
        let i = Literal::vec1(&[1i32, 2, 3]).convert(PrimitiveType::F32).unwrap();
        assert_eq!(i.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0]);
        assert!(Literal::vec1(&[1i32]).to_vec::<f32>().is_err());
    }

    #[test]
    fn tuple_decompose() {
        let t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::vec1(&[1i32, 2])]);
        assert_eq!(t.element_count(), 3);
        assert!(t.clone().array_shape().is_err());
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::scalar(0i32).to_tuple().is_err());
    }

    #[test]
    fn pjrt_is_stubbed_with_clear_error() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("PJRT runtime not available"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
    }
}
