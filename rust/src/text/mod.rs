//! Text substrate: tokenizer, synthetic corpus, and batch iterator.
//!
//! WikiText-2 is not available in this environment (see DESIGN.md §2), so
//! [`corpus`] synthesizes a deterministic "tiny-wiki": Zipf-distributed
//! vocabulary, order-2 Markov word transitions, article/heading structure.
//! Perplexity measured on a held-out split of this corpus plays the role
//! the paper gives WikiText-2: a fixed eval stream on which compression
//! damage is measured.

pub mod corpus;
pub mod dataset;
pub mod tokenizer;

pub use corpus::{CorpusConfig, SyntheticCorpus};
pub use dataset::{Batch, Dataset};
pub use tokenizer::{BpeTokenizer, Tokenizer};
