//! Deterministic synthetic "tiny-wiki" corpus.
//!
//! Stands in for WikiText-2 (unavailable offline — DESIGN.md §2). The
//! generator builds a pseudo-English lexicon, assigns Zipf-distributed
//! unigram frequencies, and samples sentences from an order-2 word-level
//! Markov chain whose transitions are themselves deterministically derived
//! from the seed. Articles get headings and paragraph breaks so the token
//! stream has WikiText-like structure (headings, punctuation, topic drift).
//!
//! What matters for the reproduction is not Englishness but that the
//! stream is (a) learnable — a small LM reaches low perplexity, leaving
//! headroom for compression damage to show, (b) fixed — every method is
//! evaluated on byte-identical text, and (c) **attention-dependent**: each
//! article carries a hidden *topic* that mixes topic-specific vocabulary
//! into the Markov stream. A bigram model (embedding→MLP) cannot predict
//! topic words; only attention over earlier context can — so the Q/K
//! projectors the paper compresses carry real, measurable function, and
//! damaging them moves perplexity (the Table-I signal).

use crate::util::rng::Rng;

/// Corpus generation parameters.
#[derive(Debug, Clone)]
pub struct CorpusConfig {
    /// Number of distinct words in the lexicon.
    pub lexicon: usize,
    /// Number of articles.
    pub articles: usize,
    /// Sentences per article (mean; actual is uniform ±50%).
    pub sentences_per_article: usize,
    /// Zipf exponent for unigram frequencies.
    pub zipf_s: f64,
    /// Number of hidden article topics.
    pub topics: usize,
    /// Words per topic vocabulary.
    pub topic_words: usize,
    /// Probability a word is drawn from the article's topic vocabulary
    /// instead of the Markov chain — the attention-only predictable mass.
    pub topic_prob: f64,
    /// Probability a sentence verbatim-repeats an earlier sentence of the
    /// same article. Predicting a repeat is an induction/copy task that
    /// only precise Q/K attention can solve — the strongest lever that
    /// makes the compressed projectors' fidelity measurable.
    pub repeat_prob: f64,
    /// Seed — the corpus is a pure function of this config.
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            lexicon: 800,
            articles: 120,
            sentences_per_article: 30,
            zipf_s: 1.1,
            topics: 16,
            topic_words: 40,
            topic_prob: 0.2,
            repeat_prob: 0.5,
            seed: 42,
        }
    }
}

/// A generated corpus with train/eval splits.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    pub train_text: String,
    pub eval_text: String,
}

impl SyntheticCorpus {
    /// Generate the corpus. ~90% of articles go to train, 10% to eval.
    pub fn generate(cfg: &CorpusConfig) -> SyntheticCorpus {
        let mut rng = Rng::new(cfg.seed);
        let words = build_lexicon(cfg.lexicon, &mut rng);

        // Zipf weights over the lexicon.
        let zipf: Vec<f64> = (0..words.len()).map(|i| 1.0 / ((i + 1) as f64).powf(cfg.zipf_s)).collect();

        // Markov chain: successor candidates per previous word are derived
        // on the fly from a seeded hash — no giant table.
        let chain_salt = rng.next_u64();

        let mut train = String::new();
        let mut eval = String::new();
        for a in 0..cfg.articles {
            let mut art_rng = rng.fork(a as u64);
            let article = generate_article(a, &words, &zipf, chain_salt, cfg, &mut art_rng);
            if a % 10 == 9 {
                eval.push_str(&article);
            } else {
                train.push_str(&article);
            }
        }
        SyntheticCorpus { train_text: train, eval_text: eval }
    }
}

/// Pseudo-English word builder: syllable concatenation.
fn build_lexicon(n: usize, rng: &mut Rng) -> Vec<String> {
    const ONSETS: [&str; 12] = ["b", "c", "d", "f", "g", "l", "m", "n", "p", "r", "s", "t"];
    const NUCLEI: [&str; 6] = ["a", "e", "i", "o", "u", "ia"];
    const CODAS: [&str; 8] = ["", "n", "s", "r", "l", "t", "m", "nd"];
    let mut words = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::new();
    while words.len() < n {
        let syllables = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push_str(ONSETS[rng.below(ONSETS.len())]);
            w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
            w.push_str(CODAS[rng.below(CODAS.len())]);
        }
        if seen.insert(w.clone()) {
            words.push(w);
        }
    }
    words
}

/// The word-id slice owned by topic `t`: a contiguous window of the
/// mid-frequency lexicon, so topic words are distinctive but not rare.
fn topic_slice(t: usize, cfg: &CorpusConfig, lexicon: usize) -> (usize, usize) {
    let start = (50 + t * cfg.topic_words).min(lexicon.saturating_sub(cfg.topic_words));
    (start, (start + cfg.topic_words).min(lexicon))
}

fn generate_article(
    index: usize,
    words: &[String],
    zipf: &[f64],
    chain_salt: u64,
    cfg: &CorpusConfig,
    rng: &mut Rng,
) -> String {
    let mut out = String::new();
    // Hidden topic for the whole article; announced by the heading so the
    // model can pick it up early.
    let topic = rng.below(cfg.topics.max(1));
    let (ts, te) = topic_slice(topic, cfg, words.len());

    // Heading, WikiText style, built from topic vocabulary.
    let title_len = 1 + rng.below(3);
    out.push_str("\n = ");
    for t in 0..title_len {
        if t > 0 {
            out.push(' ');
        }
        out.push_str(&words[ts + rng.below(te - ts)]);
    }
    out.push_str(" = \n\n");

    let n_sent = {
        let base = cfg.sentences_per_article;
        base / 2 + rng.below(base.max(1))
    };
    let mut prev1 = index % words.len();
    let mut history: Vec<String> = Vec::new();
    for s in 0..n_sent {
        // Induction structure: verbatim-replay one of the *last two*
        // sentences with probability repeat_prob. Locality matters: the
        // source must fall inside the model's attention window (seq
        // tokens) for the copy to be predictable at all — a repeat of a
        // far-away sentence is unlearnable and just adds noise.
        let sentence = if !history.is_empty() && rng.uniform() < cfg.repeat_prob {
            // Adjacent repeat ("X. X.") — source guaranteed in-window.
            history[history.len() - 1].clone()
        } else {
            let len = 5 + rng.below(14);
            let mut sent = String::new();
            for w in 0..len {
                // Topic mixture: attention-only predictable mass.
                let next = if rng.uniform() < cfg.topic_prob {
                    ts + rng.below(te - ts)
                } else {
                    next_word(prev1, words.len(), zipf, chain_salt, rng)
                };
                if w == 0 {
                    // Capitalize sentence start.
                    let word = &words[next];
                    let mut c = word.chars();
                    if let Some(f) = c.next() {
                        sent.push(f.to_ascii_uppercase());
                        sent.push_str(c.as_str());
                    }
                } else {
                    sent.push_str(&words[next]);
                }
                prev1 = next;
                if w + 1 < len {
                    // Occasional comma.
                    if rng.uniform() < 0.08 {
                        sent.push(',');
                    }
                    sent.push(' ');
                }
            }
            history.push(sent.clone());
            sent
        };
        out.push_str(&sentence);
        out.push_str(". ");
        if s % 8 == 7 {
            out.push_str("\n\n");
        }
    }
    out.push('\n');
    out
}

/// Deterministic order-1 Markov successor: each previous word picks a small
/// candidate set via hashing; the next word is Zipf-weighted within that
/// set. Order 1 with ~8 successors per word gives dense, repeated bigram
/// structure a small LM can actually learn (order 2 would make nearly every
/// bigram unique at our corpus sizes).
fn next_word(prev1: usize, vocab: usize, zipf: &[f64], salt: u64, rng: &mut Rng) -> usize {
    const CANDIDATES: usize = 8;
    let ctx = (prev1 as u64).wrapping_mul(0xC2B2AE3D27D4EB4F) ^ salt;
    let mut weights = [0.0f64; CANDIDATES];
    let mut cands = [0usize; CANDIDATES];
    for c in 0..CANDIDATES {
        // splitmix-style candidate derivation
        let mut z = ctx.wrapping_add((c as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        let cand = (z >> 33) as usize % vocab;
        cands[c] = cand;
        weights[c] = zipf[cand];
    }
    cands[rng.weighted(&weights)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let cfg = CorpusConfig { articles: 6, ..Default::default() };
        let a = SyntheticCorpus::generate(&cfg);
        let b = SyntheticCorpus::generate(&cfg);
        assert_eq!(a.train_text, b.train_text);
        assert_eq!(a.eval_text, b.eval_text);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SyntheticCorpus::generate(&CorpusConfig { articles: 4, seed: 1, ..Default::default() });
        let b = SyntheticCorpus::generate(&CorpusConfig { articles: 4, seed: 2, ..Default::default() });
        assert_ne!(a.train_text, b.train_text);
    }

    #[test]
    fn has_train_eval_split_and_structure() {
        let c = SyntheticCorpus::generate(&CorpusConfig { articles: 20, ..Default::default() });
        assert!(!c.train_text.is_empty());
        assert!(!c.eval_text.is_empty());
        assert!(c.train_text.len() > c.eval_text.len() * 4, "≈90/10 split");
        assert!(c.train_text.contains(" = "), "headings present");
        assert!(c.train_text.contains(". "), "sentences present");
    }

    #[test]
    fn text_is_learnable_not_uniform() {
        // Markov structure ⇒ repeated bigrams at the word level; verify the
        // corpus repeats itself far more than an i.i.d. stream would.
        let c = SyntheticCorpus::generate(&CorpusConfig { articles: 40, ..Default::default() });
        let words: Vec<&str> = c.train_text.split_whitespace().collect();
        let mut bigrams = std::collections::HashMap::new();
        for w in words.windows(2) {
            *bigrams.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let repeats = bigrams.values().filter(|&&v| v > 1).count();
        // The Markov share (1 - topic_prob) keeps bigram structure dense;
        // topic words add attention-only structure instead.
        assert!(
            repeats * 8 > bigrams.len(),
            "too few repeated bigrams: {repeats}/{}",
            bigrams.len()
        );
    }

    #[test]
    fn articles_have_topic_concentration() {
        // Within one article, the modal topic's vocabulary share must be
        // far above its global share — the attention-only signal.
        let cfg = CorpusConfig { articles: 20, ..Default::default() };
        let c = SyntheticCorpus::generate(&cfg);
        let words_list = build_lexicon(cfg.lexicon, &mut Rng::new(cfg.seed));
        let word_id: std::collections::HashMap<&str, usize> =
            words_list.iter().enumerate().map(|(i, w)| (w.as_str(), i)).collect();

        let mut concentrated = 0;
        let mut total_articles = 0;
        for article in c.train_text.split("\n = ").skip(1) {
            let body: Vec<usize> = article
                .split_whitespace()
                .filter_map(|w| {
                    let lw = w.trim_matches(|ch: char| !ch.is_ascii_lowercase());
                    word_id.get(lw).copied()
                })
                .collect();
            if body.len() < 50 {
                continue;
            }
            total_articles += 1;
            let mut best = 0.0f64;
            for t in 0..cfg.topics {
                let (ts, te) = topic_slice(t, &cfg, cfg.lexicon);
                let share = body.iter().filter(|&&id| id >= ts && id < te).count() as f64
                    / body.len() as f64;
                best = best.max(share);
            }
            // Global share of one 40-word slice is ~5-8%; topic articles
            // should be >20%.
            if best > 0.15 {
                concentrated += 1;
            }
        }
        assert!(total_articles > 5, "article split failed");
        assert!(
            concentrated * 10 >= total_articles * 7,
            "only {concentrated}/{total_articles} articles topic-concentrated"
        );
    }

    #[test]
    fn scale_with_articles() {
        let small = SyntheticCorpus::generate(&CorpusConfig { articles: 5, ..Default::default() });
        let large = SyntheticCorpus::generate(&CorpusConfig { articles: 50, ..Default::default() });
        assert!(large.train_text.len() > small.train_text.len() * 5);
    }
}
