//! Byte-level BPE tokenizer, trained from scratch.
//!
//! Base alphabet is the 256 bytes; `train` greedily merges the most
//! frequent adjacent pair until the requested vocab size. Encoding applies
//! merges in training order (classic BPE), decoding concatenates the byte
//! sequences. Round-trip is exact for any input.

use std::collections::HashMap;

/// Common tokenizer interface (byte-level fallback + BPE).
pub trait Tokenizer {
    fn encode(&self, text: &str) -> Vec<u32>;
    fn decode(&self, ids: &[u32]) -> String;
    fn vocab_size(&self) -> usize;
}

/// Byte-level BPE.
#[derive(Debug, Clone)]
pub struct BpeTokenizer {
    /// token id -> byte string
    vocab: Vec<Vec<u8>>,
    /// merge rules in priority order: (left, right) -> merged id
    merges: Vec<(u32, u32)>,
    merge_lookup: HashMap<(u32, u32), u32>,
}

impl BpeTokenizer {
    /// Byte-level tokenizer with no merges (vocab = 256).
    pub fn byte_level() -> Self {
        let vocab = (0..256u32).map(|b| vec![b as u8]).collect();
        BpeTokenizer { vocab, merges: Vec::new(), merge_lookup: HashMap::new() }
    }

    /// Train BPE on `text` until `vocab_size` tokens (≥ 256).
    pub fn train(text: &str, vocab_size: usize) -> Self {
        let mut tok = Self::byte_level();
        let target = vocab_size.max(256);
        // Work on the corpus as a sequence of token ids.
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();

        while tok.vocab.len() < target {
            // Count adjacent pairs.
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in ids.windows(2) {
                *counts.entry((w[0], w[1])).or_insert(0) += 1;
            }
            // Deterministic argmax: highest count, ties by smallest pair.
            let best = counts.iter().max_by_key(|(&pair, &c)| (c, std::cmp::Reverse(pair)));
            let (&pair, &count) = match best {
                Some(kv) => kv,
                None => break,
            };
            if count < 2 {
                break; // nothing worth merging
            }
            let new_id = tok.vocab.len() as u32;
            let mut merged_bytes = tok.vocab[pair.0 as usize].clone();
            merged_bytes.extend_from_slice(&tok.vocab[pair.1 as usize]);
            tok.vocab.push(merged_bytes);
            tok.merges.push(pair);
            tok.merge_lookup.insert(pair, new_id);
            ids = merge_pair(&ids, pair, new_id);
        }
        tok
    }

    /// Serialize to a compact text form (one merge per line).
    pub fn to_text(&self) -> String {
        let mut s = String::new();
        for (a, b) in &self.merges {
            s.push_str(&format!("{a} {b}\n"));
        }
        s
    }

    /// Deserialize from [`Self::to_text`] output.
    pub fn from_text(s: &str) -> anyhow::Result<Self> {
        let mut tok = Self::byte_level();
        for (lineno, line) in s.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.split_whitespace();
            let a: u32 = it.next().ok_or_else(|| anyhow::anyhow!("line {lineno}: missing left"))?.parse()?;
            let b: u32 = it.next().ok_or_else(|| anyhow::anyhow!("line {lineno}: missing right"))?.parse()?;
            if a as usize >= tok.vocab.len() || b as usize >= tok.vocab.len() {
                anyhow::bail!("line {lineno}: merge refers to unknown token ({a},{b})");
            }
            let new_id = tok.vocab.len() as u32;
            let mut bytes = tok.vocab[a as usize].clone();
            bytes.extend_from_slice(&tok.vocab[b as usize]);
            tok.vocab.push(bytes);
            tok.merges.push((a, b));
            tok.merge_lookup.insert((a, b), new_id);
        }
        Ok(tok)
    }
}

fn merge_pair(ids: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(ids.len());
    let mut i = 0;
    while i < ids.len() {
        if i + 1 < ids.len() && ids[i] == pair.0 && ids[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(ids[i]);
            i += 1;
        }
    }
    out
}

impl Tokenizer for BpeTokenizer {
    fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        // Apply merges in training order. For our corpus sizes this simple
        // pass-per-merge scheme is fast enough and exactly mirrors training.
        for (rank, &pair) in self.merges.iter().enumerate() {
            let new_id = 256 + rank as u32;
            if ids.len() < 2 {
                break;
            }
            ids = merge_pair(&ids, pair, new_id);
        }
        ids
    }

    fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(tok) = self.vocab.get(id as usize) {
                bytes.extend_from_slice(tok);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    fn vocab_size(&self) -> usize {
        self.vocab.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_level_round_trip() {
        let tok = BpeTokenizer::byte_level();
        let s = "hello, wörld! 123";
        assert_eq!(tok.decode(&tok.encode(s)), s);
        assert_eq!(tok.vocab_size(), 256);
    }

    #[test]
    fn training_grows_vocab_and_compresses() {
        let text = "the cat sat on the mat. the cat sat on the hat. ".repeat(50);
        let tok = BpeTokenizer::train(&text, 300);
        assert!(tok.vocab_size() > 256);
        let ids = tok.encode(&text);
        assert!(ids.len() < text.len(), "BPE should shorten: {} vs {}", ids.len(), text.len());
        assert_eq!(tok.decode(&ids), text);
    }

    #[test]
    fn round_trip_on_unseen_text() {
        let train = "aaabbb ababab aabb ".repeat(100);
        let tok = BpeTokenizer::train(&train, 280);
        let unseen = "zebra aab xyz ab";
        assert_eq!(tok.decode(&tok.encode(unseen)), unseen);
    }

    #[test]
    fn serialization_round_trip() {
        let text = "low lower lowest newer newest wide wider widest ".repeat(40);
        let tok = BpeTokenizer::train(&text, 320);
        let restored = BpeTokenizer::from_text(&tok.to_text()).unwrap();
        assert_eq!(restored.vocab_size(), tok.vocab_size());
        let sample = "lower and wider than the newest";
        assert_eq!(restored.encode(sample), tok.encode(sample));
    }

    #[test]
    fn from_text_rejects_bad_merge() {
        assert!(BpeTokenizer::from_text("999 1000\n").is_err());
        assert!(BpeTokenizer::from_text("abc def\n").is_err());
    }

    #[test]
    fn training_is_deterministic() {
        let text = "some repeated text some repeated text ".repeat(30);
        let a = BpeTokenizer::train(&text, 290);
        let b = BpeTokenizer::train(&text, 290);
        assert_eq!(a.to_text(), b.to_text());
    }

    #[test]
    fn ids_below_vocab_size() {
        let text = "abc abd abe abf ".repeat(60);
        let tok = BpeTokenizer::train(&text, 270);
        for id in tok.encode(&text) {
            assert!((id as usize) < tok.vocab_size());
        }
    }
}
