//! Token stream → fixed-shape training/eval batches.
//!
//! The AOT-compiled executables have static shapes `(batch, seq)`, so the
//! dataset packs the tokenized corpus into a contiguous stream and slices
//! non-overlapping windows: inputs `t[i..i+S]`, targets `t[i+1..i+S+1]`
//! (next-token prediction).

use super::tokenizer::Tokenizer;

/// One fixed-shape batch of token ids (row-major `[batch, seq]`).
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub inputs: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// A tokenized corpus with deterministic batch slicing.
#[derive(Debug, Clone)]
pub struct Dataset {
    stream: Vec<i32>,
    batch: usize,
    seq: usize,
}

impl Dataset {
    /// Tokenize `text` and build a dataset producing `[batch, seq]` windows.
    pub fn from_text(text: &str, tok: &dyn Tokenizer, batch: usize, seq: usize) -> Dataset {
        let stream: Vec<i32> = tok.encode(text).into_iter().map(|t| t as i32).collect();
        Dataset { stream, batch, seq }
    }

    /// Build directly from token ids (tests / pre-tokenized caches).
    pub fn from_ids(stream: Vec<i32>, batch: usize, seq: usize) -> Dataset {
        Dataset { stream, batch, seq }
    }

    pub fn tokens(&self) -> usize {
        self.stream.len()
    }

    /// Number of non-overlapping batches available.
    pub fn num_batches(&self) -> usize {
        let span = self.batch * self.seq;
        if self.stream.len() <= span {
            0
        } else {
            (self.stream.len() - 1) / span
        }
    }

    /// Fetch batch `index` (wraps modulo [`Self::num_batches`], so a
    /// training loop can run more steps than the corpus has windows).
    pub fn batch(&self, index: usize) -> Batch {
        let nb = self.num_batches();
        assert!(nb > 0, "corpus too small for a single {}x{} batch", self.batch, self.seq);
        let b = index % nb;
        let span = self.batch * self.seq;
        let start = b * span;
        let mut inputs = Vec::with_capacity(span);
        let mut targets = Vec::with_capacity(span);
        for row in 0..self.batch {
            let s = start + row * self.seq;
            inputs.extend_from_slice(&self.stream[s..s + self.seq]);
            targets.extend_from_slice(&self.stream[s + 1..s + self.seq + 1]);
        }
        Batch { inputs, targets, batch: self.batch, seq: self.seq }
    }

    /// Iterator over every full batch once (eval pass).
    pub fn iter(&self) -> impl Iterator<Item = Batch> + '_ {
        (0..self.num_batches()).map(|i| self.batch(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::text::tokenizer::BpeTokenizer;

    #[test]
    fn windows_are_shifted_by_one() {
        let ids: Vec<i32> = (0..100).collect();
        let ds = Dataset::from_ids(ids, 2, 5);
        let b = ds.batch(0);
        assert_eq!(b.inputs[..5], [0, 1, 2, 3, 4]);
        assert_eq!(b.targets[..5], [1, 2, 3, 4, 5]);
        assert_eq!(b.inputs[5..10], [5, 6, 7, 8, 9]);
        assert_eq!(b.targets[5..10], [6, 7, 8, 9, 10]);
    }

    #[test]
    fn num_batches_and_wraparound() {
        let ids: Vec<i32> = (0..101).collect();
        let ds = Dataset::from_ids(ids, 2, 5);
        assert_eq!(ds.num_batches(), 10);
        assert_eq!(ds.batch(0), ds.batch(10), "index wraps");
    }

    #[test]
    fn too_small_corpus_has_zero_batches() {
        let ds = Dataset::from_ids(vec![1, 2, 3], 2, 5);
        assert_eq!(ds.num_batches(), 0);
    }

    #[test]
    #[should_panic(expected = "corpus too small")]
    fn batch_on_empty_panics() {
        Dataset::from_ids(vec![1, 2], 2, 5).batch(0);
    }

    #[test]
    fn from_text_uses_tokenizer() {
        let tok = BpeTokenizer::byte_level();
        let ds = Dataset::from_text("abcdefghijklmnopqrstuvwxyz", &tok, 1, 4);
        assert_eq!(ds.tokens(), 26);
        let b = ds.batch(0);
        assert_eq!(b.inputs, vec!['a' as i32, 'b' as i32, 'c' as i32, 'd' as i32]);
        assert_eq!(b.targets, vec!['b' as i32, 'c' as i32, 'd' as i32, 'e' as i32]);
    }

    #[test]
    fn eval_iter_covers_all_batches() {
        let ids: Vec<i32> = (0..201).collect();
        let ds = Dataset::from_ids(ids, 4, 5);
        assert_eq!(ds.iter().count(), ds.num_batches());
    }
}
