//! Transformer configuration shared between rust and the AOT artifacts.

use anyhow::{bail, Result};

/// GPT-style decoder configuration. Must match the configuration the
/// artifacts were lowered with; `runtime::manifest` verifies this at load.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// MLP hidden dim (conventionally 4·d_model).
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self::small()
    }
}

impl ModelConfig {
    /// Default experiment config (~4.8 M params): CPU-trainable in minutes,
    /// d_model = 256 channels so SWSC's (k, r) scale matches the paper's
    /// m = 4096 at the same avg-bits points (DESIGN.md §2).
    pub fn small() -> Self {
        ModelConfig { vocab: 512, d_model: 256, n_layers: 4, n_heads: 4, d_ff: 1024, seq: 128, batch: 8 }
    }

    /// Tiny config for tests (fast to train for a handful of steps).
    pub fn tiny() -> Self {
        ModelConfig { vocab: 256, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 128, seq: 32, batch: 4 }
    }

    /// ~110 M params — the "prove it scales" preset (slow on CPU).
    pub fn big() -> Self {
        ModelConfig { vocab: 8192, d_model: 768, n_layers: 12, n_heads: 12, d_ff: 3072, seq: 256, batch: 8 }
    }

    pub fn by_name(name: &str) -> Result<Self> {
        Ok(match name {
            "tiny" => Self::tiny(),
            "small" => Self::small(),
            "big" => Self::big(),
            other => bail!("unknown model preset `{other}` (tiny|small|big)"),
        })
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        super::params::param_specs(self).iter().map(|s| s.shape.iter().product::<usize>()).sum()
    }

    pub fn validate(&self) -> Result<()> {
        if self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        if self.vocab == 0 || self.seq == 0 || self.batch == 0 || self.n_layers == 0 {
            bail!("zero-sized model dimension");
        }
        Ok(())
    }

    /// Stable textual form, embedded in the artifact manifest so the rust
    /// side can verify it loaded artifacts for the right model.
    pub fn fingerprint(&self) -> String {
        format!(
            "v{}_d{}_l{}_h{}_f{}_s{}_b{}",
            self.vocab, self.d_model, self.n_layers, self.n_heads, self.d_ff, self.seq, self.batch
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for cfg in [ModelConfig::tiny(), ModelConfig::small(), ModelConfig::big()] {
            cfg.validate().unwrap();
        }
    }

    #[test]
    fn small_param_count_in_expected_range() {
        let n = ModelConfig::small().param_count();
        assert!((3_000_000..8_000_000).contains(&n), "small = {n}");
    }

    #[test]
    fn big_is_about_100m() {
        let n = ModelConfig::big().param_count();
        assert!((80_000_000..150_000_000).contains(&n), "big = {n}");
    }

    #[test]
    fn by_name_and_fingerprint() {
        assert_eq!(ModelConfig::by_name("small").unwrap(), ModelConfig::small());
        assert!(ModelConfig::by_name("huge").is_err());
        assert_eq!(ModelConfig::small().fingerprint(), "v512_d256_l4_h4_f1024_s128_b8");
    }

    #[test]
    fn invalid_heads_rejected() {
        let mut c = ModelConfig::small();
        c.n_heads = 5;
        assert!(c.validate().is_err());
    }
}
