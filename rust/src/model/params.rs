//! Canonical parameter registry: names, shapes, order, initialization.
//!
//! Order matters: the AOT train/forward executables take parameters as a
//! flat argument list, and `python/compile/model.py` uses the *same*
//! generation logic (layer-major, fixed per-layer order), so index `i` here
//! is argument `i` there. The artifact manifest additionally records every
//! name so `runtime::manifest` can assert the two sides agree.

use super::config::ModelConfig;
use crate::io::Checkpoint;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One parameter's name + shape + init scale.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// Std-dev for gaussian init; 0.0 ⇒ zeros, 1.0-with-ones ⇒ see `ones`.
    pub init_std: f64,
    /// LayerNorm gains start at one.
    pub ones: bool,
}

/// The canonical, ordered parameter list for a config.
///
/// Naming: `embed.tok`, `embed.pos`, `layers.{i}.ln1.{g,b}`,
/// `layers.{i}.attn.{wq,wk,wv,wo}`, `layers.{i}.ln2.{g,b}`,
/// `layers.{i}.mlp.{w1,b1,w2,b2}`, `final_ln.{g,b}`. The LM head is tied to
/// `embed.tok`.
pub fn param_specs(cfg: &ModelConfig) -> Vec<ParamSpec> {
    let d = cfg.d_model;
    let std_embed = 0.02;
    // GPT-2-style scaled init for residual-writing projections.
    let std_resid = 0.02 / (2.0 * cfg.n_layers as f64).sqrt();
    let mut specs = Vec::new();
    let mut push = |name: String, shape: Vec<usize>, init_std: f64, ones: bool| {
        specs.push(ParamSpec { name, shape, init_std, ones });
    };

    push("embed.tok".into(), vec![cfg.vocab, d], std_embed, false);
    push("embed.pos".into(), vec![cfg.seq, d], std_embed, false);
    for i in 0..cfg.n_layers {
        let p = format!("layers.{i}");
        push(format!("{p}.ln1.g"), vec![d], 0.0, true);
        push(format!("{p}.ln1.b"), vec![d], 0.0, false);
        push(format!("{p}.attn.wq"), vec![d, d], 0.02, false);
        push(format!("{p}.attn.wk"), vec![d, d], 0.02, false);
        push(format!("{p}.attn.wv"), vec![d, d], 0.02, false);
        push(format!("{p}.attn.wo"), vec![d, d], std_resid, false);
        push(format!("{p}.ln2.g"), vec![d], 0.0, true);
        push(format!("{p}.ln2.b"), vec![d], 0.0, false);
        push(format!("{p}.mlp.w1"), vec![d, cfg.d_ff], 0.02, false);
        push(format!("{p}.mlp.b1"), vec![cfg.d_ff], 0.0, false);
        push(format!("{p}.mlp.w2"), vec![cfg.d_ff, d], std_resid, false);
        push(format!("{p}.mlp.b2"), vec![d], 0.0, false);
    }
    push("final_ln.g".into(), vec![d], 0.0, true);
    push("final_ln.b".into(), vec![d], 0.0, false);
    specs
}

/// Initialize a fresh parameter checkpoint.
pub fn init_params(cfg: &ModelConfig, seed: u64) -> Checkpoint {
    let mut ck = Checkpoint::new();
    let mut rng = Rng::new(seed);
    for spec in param_specs(cfg) {
        let t = if spec.ones {
            Tensor::full(&spec.shape, 1.0)
        } else if spec.init_std == 0.0 {
            Tensor::zeros(&spec.shape)
        } else {
            let mut t = Tensor::randn(&spec.shape, &mut rng);
            for v in t.data_mut() {
                *v *= spec.init_std as f32;
            }
            t
        };
        ck.insert(&spec.name, t);
    }
    ck
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_count_matches_formula() {
        let cfg = ModelConfig::small();
        let specs = param_specs(&cfg);
        assert_eq!(specs.len(), 2 + cfg.n_layers * 12 + 2);
    }

    #[test]
    fn order_is_layer_major_and_stable() {
        let cfg = ModelConfig::tiny();
        let names: Vec<String> = param_specs(&cfg).into_iter().map(|s| s.name).collect();
        assert_eq!(names[0], "embed.tok");
        assert_eq!(names[1], "embed.pos");
        assert_eq!(names[2], "layers.0.ln1.g");
        assert!(names.iter().position(|n| n == "layers.0.attn.wq").unwrap()
            < names.iter().position(|n| n == "layers.1.attn.wq").unwrap());
        assert_eq!(names.last().unwrap(), "final_ln.b");
    }

    #[test]
    fn init_shapes_match_specs() {
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, 1);
        for spec in param_specs(&cfg) {
            let t = ck.get(&spec.name).expect(&spec.name);
            assert_eq!(t.shape(), &spec.shape[..], "{}", spec.name);
        }
    }

    #[test]
    fn layernorm_gains_are_ones_biases_zero() {
        let ck = init_params(&ModelConfig::tiny(), 2);
        assert!(ck.get("layers.0.ln1.g").unwrap().data().iter().all(|&v| v == 1.0));
        assert!(ck.get("layers.0.ln1.b").unwrap().data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn weights_have_roughly_requested_std() {
        let ck = init_params(&ModelConfig::small(), 3);
        let w = ck.get("layers.0.attn.wq").unwrap();
        let n = w.len() as f64;
        let mean: f64 = w.data().iter().map(|&v| v as f64).sum::<f64>() / n;
        let var: f64 = w.data().iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!((var.sqrt() - 0.02).abs() < 0.002, "std {}", var.sqrt());
    }

    #[test]
    fn init_deterministic() {
        let a = init_params(&ModelConfig::tiny(), 7);
        let b = init_params(&ModelConfig::tiny(), 7);
        assert_eq!(a.to_bytes(), b.to_bytes());
    }
}
