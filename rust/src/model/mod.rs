//! Model definition (host side).
//!
//! The transformer's *compute* lives in JAX (layer 2) and is AOT-lowered to
//! HLO; this module owns the host-side picture of it: the configuration
//! (must match what `python/compile/aot.py` lowered), the canonical
//! parameter naming/ordering (rust and python agree on it by construction —
//! the manifest pins the order), and parameter initialization for
//! from-scratch training.

pub mod config;
pub mod params;

pub use config::ModelConfig;
pub use params::{init_params, param_specs, ParamSpec};
