//! Custom benchmark harness.
//!
//! The vendored crate set has no criterion, so `cargo bench` targets are
//! declared `harness = false` and drive this module instead: warmup, timed
//! iterations, and a stable text report (mean ± std, min, p50). Benches
//! that reproduce a paper table print the table rows after the timings.
//!
//! Each timed case is also recorded as a machine-readable
//! [`BenchRecord`]; [`Bench::write_json`] dumps them as a JSON array
//! (`op`, `size`, `threads`, `ns_per_iter`, plus `gflops` on flop-counted
//! cases, `speedup`/`vs` on comparison rows, `p95_us`/`batch_mean`/
//! `queue_p95_us` on the serve-loadgen rows pushed via
//! [`Bench::push_record`], and
//! `bytes_per_param` on rows annotated via
//! [`Bench::annotate_bytes_per_param`]) so
//! successive PRs have a perf trajectory to diff against. [`Bench::compare_against_baseline`]
//! reads a committed baseline JSON (`BENCH_baseline.json`, bootstrapped by
//! the hotpath bench on first run) and prints per-op before/after ratios —
//! the in-repo trajectory perf PRs cite.

pub mod loadgen;

use crate::obs::prof::{ProfConfig, Profiler, Stats};
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

/// One machine-readable timing row.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Case label, e.g. `matmul`.
    pub op: String,
    /// Problem size (side length, element count — case-defined; 0 if n/a).
    pub size: usize,
    /// Worker threads the case ran with.
    pub threads: usize,
    /// Mean wall-clock per iteration, nanoseconds.
    pub ns_per_iter: f64,
    /// Sustained GFLOP/s, for cases with a known flop count
    /// ([`Bench::case_at_flops`]). `None` otherwise.
    pub gflops: Option<f64>,
    /// For comparison rows (`pool_vs_spawn_*`, `packed_vs_blocked_*`):
    /// baseline mean divided by new mean (> 1 ⇒ the new configuration is
    /// faster). `None` for plain timing rows.
    pub speedup: Option<f64>,
    /// What a comparison row is measured against (`"spawn"`, `"blocked"`).
    pub vs: Option<String>,
    /// Server-side p95 latency in microseconds — set on rows emitted by
    /// the serve loadgen ([`loadgen::LoadgenReport::to_record`]). `None`
    /// elsewhere.
    pub p95_us: Option<f64>,
    /// Mean coalesced batch size (stacked activation rows per executed
    /// batch) on loadgen rows. `None` elsewhere.
    pub batch_mean: Option<f64>,
    /// Server-side p95 **queue wait** in microseconds (admission to batch
    /// pick) on loadgen rows — the queueing share of `p95_us`. `None`
    /// elsewhere.
    pub queue_p95_us: Option<f64>,
    /// Storage cost of the weights the row served, in **bytes per
    /// original parameter** (actual file payload ÷ `m·n`) — set on the
    /// `quantized_vs_f32_*` rows so the perf trajectory carries the
    /// compression axis next to the throughput axis. `None` elsewhere.
    pub bytes_per_param: Option<f64>,
}

/// One benchmark group with shared formatting.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    records: RefCell<Vec<BenchRecord>>,
    /// `SWSC_PROF=1` attaches a phase profiler: every timed case becomes a
    /// `bench/{group}/{label}` phase (count = timed iterations) so bench
    /// runs render the same call-tree/Chrome timeline as `swsc compress`.
    prof: Option<(Profiler, ProfConfig)>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let iters = std::env::var("SWSC_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
        Bench {
            name: name.to_string(),
            warmup: 2,
            iters,
            records: RefCell::new(Vec::new()),
            prof: ProfConfig::from_env().map(|cfg| (Profiler::new(), cfg)),
        }
    }

    pub fn with_iters(mut self, iters: usize) -> Self {
        self.iters = iters.max(1);
        self
    }

    /// Run one case: calls `f` warmup+iters times, prints a line, returns
    /// the mean seconds. Recorded with size 0 and threads 1 (cases that go
    /// through the executor should use [`Bench::case_at`] with the real
    /// axes so the JSON perf trajectory stays comparable across machines).
    pub fn case<T>(&self, label: &str, f: impl FnMut() -> T) -> f64 {
        self.case_at(label, 0, 1, f)
    }

    /// Run one case with explicit size/threads axes for the JSON record.
    pub fn case_at<T>(
        &self,
        label: &str,
        size: usize,
        threads: usize,
        f: impl FnMut() -> T,
    ) -> f64 {
        self.run_case(label, size, threads, None, f)
    }

    /// Like [`Bench::case_at`], with a known flop count per iteration: the
    /// record (and the printed line) carries sustained GFLOP/s.
    pub fn case_at_flops<T>(
        &self,
        label: &str,
        size: usize,
        threads: usize,
        flops: f64,
        f: impl FnMut() -> T,
    ) -> f64 {
        self.run_case(label, size, threads, Some(flops), f)
    }

    fn run_case<T>(
        &self,
        label: &str,
        size: usize,
        threads: usize,
        flops: Option<f64>,
        mut f: impl FnMut() -> T,
    ) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        // Scope the timed loop (not warmup) so the profiler's phase tree
        // and Chrome timeline cover exactly what the printed stats cover.
        let scope = self
            .prof
            .as_ref()
            .map(|(p, _)| p.root(&format!("bench/{}/{label}", self.name)));
        let mut stats = Stats::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            stats.push(t0.elapsed().as_secs_f64());
        }
        if let (Some(s), Some((p, _))) = (&scope, &self.prof) {
            // Mirror the compress pipeline's `kmeans/iters` convention:
            // a synthetic child whose count is the iteration count.
            p.add(&format!("{}/iters", s.path()), self.iters as u64, (stats.sum() * 1e9) as u64);
        }
        drop(scope);
        let mean = stats.mean();
        let gflops = flops.map(|fl| fl / mean.max(1e-12) / 1e9);
        let gf_note = gflops.map(|g| format!("  {g:>7.2} GFLOP/s")).unwrap_or_default();
        println!(
            "bench {:<40} {:>12} ± {:>10}  min {:>10}  p50 {:>10}  (n={}){gf_note}",
            format!("{}/{}", self.name, label),
            fmt_secs(mean),
            fmt_secs(stats.std()),
            fmt_secs(stats.min()),
            fmt_secs(stats.percentile(50.0)),
            stats.count(),
        );
        self.records.borrow_mut().push(BenchRecord {
            op: label.to_string(),
            size,
            threads,
            ns_per_iter: mean * 1e9,
            gflops,
            speedup: None,
            vs: None,
            p95_us: None,
            batch_mean: None,
            queue_p95_us: None,
            bytes_per_param: None,
        });
        mean
    }

    /// Record an externally measured row. The serve loadgen times its own
    /// open-loop replay (wall clock over many in-flight requests), so its
    /// rows can't go through `case`'s iteration loop — they land here,
    /// carrying the loadgen-only fields (`p95_us`, `batch_mean`).
    pub fn push_record(&self, r: BenchRecord) {
        let mut extra = String::new();
        if let Some(p) = r.p95_us {
            extra.push_str(&format!("  p95 {:>10}", fmt_secs(p / 1e6)));
        }
        if let Some(q) = r.queue_p95_us {
            extra.push_str(&format!("  queue_p95 {:>10}", fmt_secs(q / 1e6)));
        }
        if let Some(bm) = r.batch_mean {
            extra.push_str(&format!("  batch_mean {bm:.1}"));
        }
        println!(
            "bench {:<40} {:>12} /req{extra}",
            format!("{}/{}", self.name, r.op),
            fmt_secs(r.ns_per_iter / 1e9),
        );
        self.records.borrow_mut().push(r);
    }

    /// Attach a bytes-per-parameter figure to the most recent record
    /// whose op matches `op` — how the `quantized_vs_f32_*` rows carry
    /// the storage axis alongside the timing the comparison recorded.
    pub fn annotate_bytes_per_param(&self, op: &str, bytes: f64) {
        let mut records = self.records.borrow_mut();
        if let Some(r) = records.iter_mut().rev().find(|r| r.op == op) {
            r.bytes_per_param = Some(bytes);
            println!("bench {:<40} {bytes:.3} B/param", format!("{}/{op}", self.name));
        }
    }

    /// Record a `pool_vs_spawn` comparison row for one op/size: the op's
    /// mean seconds under the persistent-pool backend vs under the
    /// spawn-per-call backend on the identical workload. The row's
    /// `ns_per_iter` is the pool time (the shipping configuration);
    /// `speedup` is `spawn / pool`. Returns the speedup.
    pub fn comparison(
        &self,
        op: &str,
        size: usize,
        threads: usize,
        pool_secs: f64,
        spawn_secs: f64,
    ) -> f64 {
        self.comparison_labeled("pool_vs_spawn", "pool", "spawn", op, size, threads, pool_secs, spawn_secs)
    }

    /// Generic comparison row: `new_secs` is the shipping configuration,
    /// `base_secs` the baseline it replaces; the row lands as
    /// `{prefix}_{op}` with `speedup = base / new` and `vs = base_name`.
    /// Also used for the `packed_vs_blocked_*` GEMM-kernel rows.
    #[allow(clippy::too_many_arguments)]
    pub fn comparison_labeled(
        &self,
        prefix: &str,
        new_name: &str,
        base_name: &str,
        op: &str,
        size: usize,
        threads: usize,
        new_secs: f64,
        base_secs: f64,
    ) -> f64 {
        let speedup = base_secs / new_secs.max(1e-12);
        println!(
            "bench {:<40} {new_name} {:>10} vs {base_name} {:>10}  ({speedup:.2}x)",
            format!("{}/{prefix}_{op}", self.name),
            fmt_secs(new_secs),
            fmt_secs(base_secs),
        );
        self.records.borrow_mut().push(BenchRecord {
            op: format!("{prefix}_{op}"),
            size,
            threads,
            ns_per_iter: new_secs * 1e9,
            gflops: None,
            speedup: Some(speedup),
            vs: Some(base_name.to_string()),
            p95_us: None,
            batch_mean: None,
            queue_p95_us: None,
            bytes_per_param: None,
        });
        speedup
    }

    /// Print per-op before/after ratios against a committed baseline JSON
    /// (as written by [`Bench::write_json`] on an earlier run — the
    /// cross-PR perf trajectory). Rows are matched by exact op label;
    /// missing or unreadable baselines just report and return.
    pub fn compare_against_baseline(&self, path: &Path) {
        let Ok(body) = std::fs::read_to_string(path) else {
            println!("(baseline {} unreadable — skipping comparison)", path.display());
            return;
        };
        let mut base: Vec<(String, f64)> = Vec::new();
        for line in body.lines() {
            let (Some(op), Some(ns)) = (
                extract_json_str(line, "\"op\": \""),
                extract_json_num(line, "\"ns_per_iter\": "),
            ) else {
                continue;
            };
            base.push((op, ns));
        }
        if base.is_empty() {
            println!("(baseline {} has no records — skipping comparison)", path.display());
            return;
        }
        println!("\n=== {} — vs baseline {} ===", self.name, path.display());
        let mut matched = 0usize;
        for r in self.records.borrow().iter() {
            let Some(entry) = base.iter().find(|e| e.0 == r.op) else { continue };
            let b = entry.1;
            let ratio = b / r.ns_per_iter.max(1e-3);
            matched += 1;
            println!(
                "  {:<44} baseline {:>10} -> now {:>10}  ({ratio:.2}x)",
                r.op,
                fmt_secs(b / 1e9),
                fmt_secs(r.ns_per_iter / 1e9),
            );
        }
        println!("  ({matched} ops matched against {} baseline records)", base.len());
    }

    /// All records so far, in run order.
    pub fn records(&self) -> Vec<BenchRecord> {
        self.records.borrow().clone()
    }

    /// Write every recorded case as a JSON array. Labels are plain
    /// identifiers (no quoting/escaping needed).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let records = self.records.borrow();
        let mut s = String::from("[\n");
        for (i, r) in records.iter().enumerate() {
            if i > 0 {
                s.push_str(",\n");
            }
            s.push_str(&format!(
                "  {{\"op\": \"{}\", \"size\": {}, \"threads\": {}, \"ns_per_iter\": {:.1}",
                r.op, r.size, r.threads, r.ns_per_iter
            ));
            if let Some(g) = r.gflops {
                s.push_str(&format!(", \"gflops\": {g:.2}"));
            }
            if let Some(sp) = r.speedup {
                s.push_str(&format!(", \"speedup\": {sp:.3}"));
            }
            if let Some(vs) = &r.vs {
                s.push_str(&format!(", \"vs\": \"{vs}\""));
            }
            if let Some(p) = r.p95_us {
                s.push_str(&format!(", \"p95_us\": {p:.1}"));
            }
            if let Some(bm) = r.batch_mean {
                s.push_str(&format!(", \"batch_mean\": {bm:.2}"));
            }
            if let Some(q) = r.queue_p95_us {
                s.push_str(&format!(", \"queue_p95_us\": {q:.1}"));
            }
            if let Some(bp) = r.bytes_per_param {
                s.push_str(&format!(", \"bytes_per_param\": {bp:.3}"));
            }
            s.push('}');
        }
        s.push_str("\n]\n");
        std::fs::write(path, s)
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n=== {} — {} ===", self.name, title);
    }
}

impl Drop for Bench {
    /// With `SWSC_PROF=1`, print the phase tree (stderr, like the compress
    /// pipeline) and honor `SWSC_PROF_OUT` with a Chrome timeline once the
    /// group finishes. Timing-only output: records and JSON are untouched.
    fn drop(&mut self) {
        let Some((p, cfg)) = &self.prof else { return };
        if p.phases().is_empty() {
            return;
        }
        eprintln!("--- profile (SWSC_PROF) — {} ---", self.name);
        eprint!("{}", p.render_text());
        if let Some(path) = &cfg.chrome_out {
            match std::fs::write(path, p.to_chrome_json()) {
                Ok(()) => eprintln!("wrote {path} (Chrome trace-event timeline)"),
                Err(e) => eprintln!("warning: could not write {path}: {e}"),
            }
        }
    }
}

/// Pull the string value following `key` out of one JSON line (the bench
/// JSON is written one record per line with plain identifier labels, so a
/// substring scan is sufficient — no vendored JSON parser needed).
fn extract_json_str(line: &str, key: &str) -> Option<String> {
    let start = line.find(key)? + key.len();
    let rest = &line[start..];
    Some(rest[..rest.find('"')?].to_string())
}

/// Pull the numeric value following `key` out of one JSON line.
fn extract_json_num(line: &str, key: &str) -> Option<f64> {
    let start = line.find(key)? + key.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || matches!(c, '.' | '-' | '+' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
    }

    #[test]
    fn case_runs_and_returns_mean() {
        let b = Bench::new("unit").with_iters(3);
        let mean = b.case("noop", || 1 + 1);
        assert!(mean >= 0.0);
    }

    #[test]
    fn records_and_json_round_trip() {
        let b = Bench::new("unit").with_iters(2);
        b.case_at("alpha", 512, 4, || 1 + 1);
        b.case_at("beta", 256, 1, || 2 + 2);
        let recs = b.records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].op, "alpha");
        assert_eq!((recs[0].size, recs[0].threads), (512, 4));
        assert!(recs.iter().all(|r| r.ns_per_iter >= 0.0));
        assert!(recs.iter().all(|r| r.speedup.is_none() && r.gflops.is_none()));

        let path = std::env::temp_dir().join("swsc_bench_unit.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.starts_with("[\n"));
        assert!(body.contains("\"op\": \"alpha\""));
        assert!(body.contains("\"size\": 512"));
        assert!(body.contains("\"threads\": 4"));
        assert!(body.trim_end().ends_with(']'));
    }

    #[test]
    fn comparison_rows_carry_speedup() {
        let b = Bench::new("unit").with_iters(1);
        let sp = b.comparison("matmul_512", 512, 4, 1.0e-3, 2.5e-3);
        assert!((sp - 2.5).abs() < 1e-9);
        let sk = b.comparison_labeled(
            "packed_vs_blocked",
            "packed",
            "blocked",
            "matmul_512",
            512,
            4,
            1.0e-3,
            1.8e-3,
        );
        assert!((sk - 1.8).abs() < 1e-9);
        let recs = b.records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].op, "pool_vs_spawn_matmul_512");
        assert!((recs[0].speedup.unwrap() - 2.5).abs() < 1e-9);
        assert_eq!(recs[1].op, "packed_vs_blocked_matmul_512");
        assert_eq!(recs[1].vs.as_deref(), Some("blocked"));

        let path = std::env::temp_dir().join("swsc_bench_cmp.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.contains("\"op\": \"pool_vs_spawn_matmul_512\""));
        assert!(body.contains("\"speedup\": 2.500"));
        assert!(body.contains("\"vs\": \"spawn\""));
        assert!(body.contains("\"op\": \"packed_vs_blocked_matmul_512\""));
        assert!(body.contains("\"vs\": \"blocked\""));
    }

    #[test]
    fn pushed_loadgen_rows_carry_p95_and_batch_mean() {
        let b = Bench::new("unit").with_iters(1);
        b.push_record(BenchRecord {
            op: "loadgen_serve_512_batched".into(),
            size: 512,
            threads: 4,
            ns_per_iter: 123456.0,
            gflops: None,
            speedup: None,
            vs: None,
            p95_us: Some(987.6),
            batch_mean: Some(42.25),
            queue_p95_us: Some(321.5),
            bytes_per_param: None,
        });
        let recs = b.records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].p95_us, Some(987.6));
        assert_eq!(recs[0].batch_mean, Some(42.25));
        assert_eq!(recs[0].queue_p95_us, Some(321.5));

        let path = std::env::temp_dir().join("swsc_bench_loadgen.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.contains("\"op\": \"loadgen_serve_512_batched\""));
        assert!(body.contains("\"p95_us\": 987.6"));
        assert!(body.contains("\"batch_mean\": 42.25"));
        assert!(body.contains("\"queue_p95_us\": 321.5"));
        // And the line still parses with the baseline field scanners.
        let line = body.lines().find(|l| l.contains("loadgen")).unwrap();
        assert_eq!(extract_json_num(line, "\"p95_us\": "), Some(987.6));
        assert_eq!(extract_json_num(line, "\"batch_mean\": "), Some(42.25));
        assert_eq!(extract_json_num(line, "\"queue_p95_us\": "), Some(321.5));
    }

    #[test]
    fn bytes_per_param_annotation_lands_in_json() {
        let b = Bench::new("unit").with_iters(1);
        b.comparison_labeled("quantized_vs_f32", "int8", "f32", "apply_64", 64, 1, 1e-3, 2e-3);
        b.annotate_bytes_per_param("quantized_vs_f32_apply_64", 1.125);
        b.annotate_bytes_per_param("no_such_op", 9.0); // silently ignored
        let recs = b.records();
        assert_eq!(recs[0].bytes_per_param, Some(1.125));
        let path = std::env::temp_dir().join("swsc_bench_bpp.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.contains("\"bytes_per_param\": 1.125"));
        let line = body.lines().find(|l| l.contains("quantized_vs_f32")).unwrap();
        assert_eq!(extract_json_num(line, "\"bytes_per_param\": "), Some(1.125));
    }

    #[test]
    fn flop_cases_record_gflops() {
        let b = Bench::new("unit").with_iters(1);
        b.case_at_flops("gemm", 64, 1, 2.0 * 64.0 * 64.0 * 64.0, || std::hint::black_box(1 + 1));
        let recs = b.records();
        assert_eq!(recs.len(), 1);
        assert!(recs[0].gflops.unwrap() > 0.0);

        let path = std::env::temp_dir().join("swsc_bench_gflops.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.contains("\"gflops\": "));
    }

    #[test]
    fn baseline_json_fields_parse() {
        let line = "  {\"op\": \"matmul_512_t4\", \"size\": 512, \"threads\": 4, \"ns_per_iter\": 1234.5, \"gflops\": 12.34}";
        assert_eq!(extract_json_str(line, "\"op\": \"").as_deref(), Some("matmul_512_t4"));
        assert_eq!(extract_json_num(line, "\"ns_per_iter\": "), Some(1234.5));
        assert_eq!(extract_json_num(line, "\"size\": "), Some(512.0));
        assert_eq!(extract_json_str(line, "\"missing\": \""), None);

        // Round-trip: write a run, then compare a new run against it.
        let b = Bench::new("unit").with_iters(1);
        b.case_at("alpha", 64, 1, || 1 + 1);
        let path = std::env::temp_dir().join("swsc_bench_baseline.json");
        b.write_json(&path).unwrap();
        b.compare_against_baseline(&path); // prints one matched row; must not panic
        std::fs::remove_file(&path).ok();
    }
}
