//! Custom benchmark harness.
//!
//! The vendored crate set has no criterion, so `cargo bench` targets are
//! declared `harness = false` and drive this module instead: warmup, timed
//! iterations, and a stable text report (mean ± std, min, p50). Benches
//! that reproduce a paper table print the table rows after the timings.

use crate::util::timer::Stats;
use std::time::Instant;

/// One benchmark group with shared formatting.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let iters = std::env::var("SWSC_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
        Bench { name: name.to_string(), warmup: 2, iters }
    }

    pub fn with_iters(mut self, iters: usize) -> Self {
        self.iters = iters.max(1);
        self
    }

    /// Run one case: calls `f` warmup+iters times, prints a line, returns
    /// the mean seconds.
    pub fn case<T>(&self, label: &str, mut f: impl FnMut() -> T) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut stats = Stats::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            stats.push(t0.elapsed().as_secs_f64());
        }
        let mean = stats.mean();
        println!(
            "bench {:<40} {:>12} ± {:>10}  min {:>10}  p50 {:>10}  (n={})",
            format!("{}/{}", self.name, label),
            fmt_secs(mean),
            fmt_secs(stats.std()),
            fmt_secs(stats.min()),
            fmt_secs(stats.percentile(50.0)),
            stats.count(),
        );
        mean
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n=== {} — {} ===", self.name, title);
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
    }

    #[test]
    fn case_runs_and_returns_mean() {
        let b = Bench::new("unit").with_iters(3);
        let mean = b.case("noop", || 1 + 1);
        assert!(mean >= 0.0);
    }
}
