//! Custom benchmark harness.
//!
//! The vendored crate set has no criterion, so `cargo bench` targets are
//! declared `harness = false` and drive this module instead: warmup, timed
//! iterations, and a stable text report (mean ± std, min, p50). Benches
//! that reproduce a paper table print the table rows after the timings.
//!
//! Each timed case is also recorded as a machine-readable
//! [`BenchRecord`]; [`Bench::write_json`] dumps them as a JSON array
//! (`op`, `size`, `threads`, `ns_per_iter`, plus `speedup_vs_spawn` on
//! [`Bench::comparison`] rows) so successive PRs have a perf trajectory to
//! diff against.

use crate::util::timer::Stats;
use std::cell::RefCell;
use std::path::Path;
use std::time::Instant;

/// One machine-readable timing row.
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Case label, e.g. `matmul`.
    pub op: String,
    /// Problem size (side length, element count — case-defined; 0 if n/a).
    pub size: usize,
    /// Worker threads the case ran with.
    pub threads: usize,
    /// Mean wall-clock per iteration, nanoseconds.
    pub ns_per_iter: f64,
    /// For `pool_vs_spawn_*` comparison rows: spawn-backend mean divided by
    /// pool-backend mean (> 1 ⇒ the persistent pool is faster). `None` for
    /// plain timing rows.
    pub speedup_vs_spawn: Option<f64>,
}

/// One benchmark group with shared formatting.
pub struct Bench {
    name: String,
    warmup: usize,
    iters: usize,
    records: RefCell<Vec<BenchRecord>>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        let iters = std::env::var("SWSC_BENCH_ITERS").ok().and_then(|s| s.parse().ok()).unwrap_or(10);
        Bench { name: name.to_string(), warmup: 2, iters, records: RefCell::new(Vec::new()) }
    }

    pub fn with_iters(mut self, iters: usize) -> Self {
        self.iters = iters.max(1);
        self
    }

    /// Run one case: calls `f` warmup+iters times, prints a line, returns
    /// the mean seconds. Recorded with size 0 and threads 1 (cases that go
    /// through the executor should use [`Bench::case_at`] with the real
    /// axes so the JSON perf trajectory stays comparable across machines).
    pub fn case<T>(&self, label: &str, f: impl FnMut() -> T) -> f64 {
        self.case_at(label, 0, 1, f)
    }

    /// Run one case with explicit size/threads axes for the JSON record.
    pub fn case_at<T>(
        &self,
        label: &str,
        size: usize,
        threads: usize,
        mut f: impl FnMut() -> T,
    ) -> f64 {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut stats = Stats::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            stats.push(t0.elapsed().as_secs_f64());
        }
        let mean = stats.mean();
        println!(
            "bench {:<40} {:>12} ± {:>10}  min {:>10}  p50 {:>10}  (n={})",
            format!("{}/{}", self.name, label),
            fmt_secs(mean),
            fmt_secs(stats.std()),
            fmt_secs(stats.min()),
            fmt_secs(stats.percentile(50.0)),
            stats.count(),
        );
        self.records.borrow_mut().push(BenchRecord {
            op: label.to_string(),
            size,
            threads,
            ns_per_iter: mean * 1e9,
            speedup_vs_spawn: None,
        });
        mean
    }

    /// Record a `pool_vs_spawn` comparison row for one op/size: the op's
    /// mean seconds under the persistent-pool backend vs under the
    /// spawn-per-call backend on the identical workload. The row's
    /// `ns_per_iter` is the pool time (the shipping configuration);
    /// `speedup_vs_spawn` is `spawn / pool`. Returns the speedup.
    pub fn comparison(
        &self,
        op: &str,
        size: usize,
        threads: usize,
        pool_secs: f64,
        spawn_secs: f64,
    ) -> f64 {
        let speedup = spawn_secs / pool_secs.max(1e-12);
        println!(
            "bench {:<40} pool {:>10} vs spawn {:>10}  ({speedup:.2}x)",
            format!("{}/pool_vs_spawn_{op}", self.name),
            fmt_secs(pool_secs),
            fmt_secs(spawn_secs),
        );
        self.records.borrow_mut().push(BenchRecord {
            op: format!("pool_vs_spawn_{op}"),
            size,
            threads,
            ns_per_iter: pool_secs * 1e9,
            speedup_vs_spawn: Some(speedup),
        });
        speedup
    }

    /// All records so far, in run order.
    pub fn records(&self) -> Vec<BenchRecord> {
        self.records.borrow().clone()
    }

    /// Write every recorded case as a JSON array. Labels are plain
    /// identifiers (no quoting/escaping needed).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        let records = self.records.borrow();
        let mut s = String::from("[\n");
        for (i, r) in records.iter().enumerate() {
            if i > 0 {
                s.push_str(",\n");
            }
            s.push_str(&format!(
                "  {{\"op\": \"{}\", \"size\": {}, \"threads\": {}, \"ns_per_iter\": {:.1}",
                r.op, r.size, r.threads, r.ns_per_iter
            ));
            if let Some(sp) = r.speedup_vs_spawn {
                s.push_str(&format!(", \"speedup_vs_spawn\": {sp:.3}"));
            }
            s.push('}');
        }
        s.push_str("\n]\n");
        std::fs::write(path, s)
    }

    /// Print a section header.
    pub fn section(&self, title: &str) {
        println!("\n=== {} — {} ===", self.name, title);
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0025), "2.500 ms");
        assert_eq!(fmt_secs(2.5e-6), "2.500 µs");
        assert_eq!(fmt_secs(2.5e-9), "2.5 ns");
    }

    #[test]
    fn case_runs_and_returns_mean() {
        let b = Bench::new("unit").with_iters(3);
        let mean = b.case("noop", || 1 + 1);
        assert!(mean >= 0.0);
    }

    #[test]
    fn records_and_json_round_trip() {
        let b = Bench::new("unit").with_iters(2);
        b.case_at("alpha", 512, 4, || 1 + 1);
        b.case_at("beta", 256, 1, || 2 + 2);
        let recs = b.records();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].op, "alpha");
        assert_eq!((recs[0].size, recs[0].threads), (512, 4));
        assert!(recs.iter().all(|r| r.ns_per_iter >= 0.0));
        assert!(recs.iter().all(|r| r.speedup_vs_spawn.is_none()));

        let path = std::env::temp_dir().join("swsc_bench_unit.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.starts_with("[\n"));
        assert!(body.contains("\"op\": \"alpha\""));
        assert!(body.contains("\"size\": 512"));
        assert!(body.contains("\"threads\": 4"));
        assert!(body.trim_end().ends_with(']'));
    }

    #[test]
    fn comparison_rows_carry_speedup() {
        let b = Bench::new("unit").with_iters(1);
        let sp = b.comparison("matmul_512", 512, 4, 1.0e-3, 2.5e-3);
        assert!((sp - 2.5).abs() < 1e-9);
        let recs = b.records();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].op, "pool_vs_spawn_matmul_512");
        assert!((recs[0].speedup_vs_spawn.unwrap() - 2.5).abs() < 1e-9);

        let path = std::env::temp_dir().join("swsc_bench_cmp.json");
        b.write_json(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert!(body.contains("\"op\": \"pool_vs_spawn_matmul_512\""));
        assert!(body.contains("\"speedup_vs_spawn\": 2.500"));
    }
}
