//! Open-loop load generator for the batched serving layer.
//!
//! Replays a **seeded arrival stream** against a [`BatchServer`]: the
//! request sequence (target weight, activation rows, activation values,
//! inter-arrival gaps) is a pure function of [`LoadgenConfig::seed`], so
//! two runs — e.g. a coalescing server and a solo server — see the
//! *identical* workload and their throughput/latency numbers are directly
//! comparable (`batched_vs_solo_*` rows in `benches/hotpath.rs`).
//!
//! Open-loop means arrivals are scheduled by the stream's clock, not by
//! completions: with `rate_rps > 0` inter-arrival gaps are exponential
//! (Poisson arrivals) and the generator sleeps to honor them; with
//! `rate_rps = 0` requests are submitted as fast as admission allows —
//! the saturation mode, where blocking admission is the backpressure.
//! Latency is recorded server-side (admission → response) into the
//! service histograms; the report quotes their p50/p95/p99.
//!
//! [`run_forward_loadgen`] (PR 7) replays whole-model forward requests
//! with seeded **mixed-length** token windows — the convoy-prone
//! workload that separates continuous batching from flush-the-batch
//! scheduling (`forward_batched_vs_flush_*` rows).

use crate::bench::BenchRecord;
use crate::serve::{AdmissionError, BatchServer, ForwardRequest, LinearRequest, ServeError};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Loadgen knobs. The whole stream derives from `seed`.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub seed: u64,
    /// Total requests to replay.
    pub requests: usize,
    /// Activation rows per request; with `ragged` the row count is drawn
    /// uniformly from `1..=rows_per_request` instead.
    pub rows_per_request: usize,
    pub ragged: bool,
    /// Open-loop arrival rate in requests/s; `0.0` replays at saturation.
    pub rate_rps: f64,
    /// `(model, weight)` pairs; each request samples one from the seeded
    /// stream.
    pub targets: Vec<(String, String)>,
    /// Per-request deadline (from submission), PR 8. `None` = no
    /// deadlines; late responses then never miss.
    pub deadline: Option<Duration>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            seed: 0x10AD,
            requests: 128,
            rows_per_request: 8,
            ragged: false,
            rate_rps: 0.0,
            targets: Vec::new(),
            deadline: None,
        }
    }
}

/// What one replay measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub requests: usize,
    /// Total activation rows submitted.
    pub rows: usize,
    /// Requests answered with an error response (all kinds, including
    /// the typed breakdowns below).
    pub errors: usize,
    /// Requests shed at admission (`Overloaded` / `QuotaExceeded`,
    /// including injected rejections) — the loadgen counts them and moves
    /// on; only `ShuttingDown` aborts a replay (PR 8).
    pub rejected: usize,
    /// Requests answered with [`ServeError::Panicked`].
    pub panicked: usize,
    /// Requests answered with [`ServeError::DeadlineExceeded`].
    pub deadline_missed: usize,
    /// First submission → last response.
    pub wall_seconds: f64,
    pub rps: f64,
    pub rows_per_second: f64,
    /// Server-side admission→response latency percentiles, microseconds.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_latency_us: f64,
    /// Queue-wait half of the end-to-end latency (admission → batch
    /// pick), microseconds (PR 9). `serve.queue_wait_seconds` deltas.
    pub queue_p50_us: f64,
    pub queue_p95_us: f64,
    /// Service half (batch pick → response), microseconds (PR 9).
    /// `serve.service_seconds` deltas. queue + service ≈ end-to-end.
    pub service_p50_us: f64,
    pub service_p95_us: f64,
    /// Mean stacked rows per executed micro-batch (1.0 ⇒ no coalescing).
    pub batch_mean: f64,
    /// Micro-batches the server executed during the run.
    pub batches: u64,
}

impl LoadgenReport {
    /// Fraction of the stream that did not get a successful response:
    /// shed at admission, or answered with any error (panic, deadline
    /// miss, failure, drain). `0.0` on an all-clear replay.
    pub fn error_rate(&self) -> f64 {
        (self.errors + self.rejected) as f64 / self.requests.max(1) as f64
    }

    /// One-line human summary.
    pub fn render(&self) -> String {
        format!(
            "{} req ({} rows) in {:.3}s -> {:.0} req/s ({:.0} rows/s), latency p50 {:.0} µs \
             p95 {:.0} µs p99 {:.0} µs (queue p50 {:.0} µs p95 {:.0} µs, service p50 {:.0} µs \
             p95 {:.0} µs), {} batches (mean {:.1} rows), {} errors \
             ({} panicked, {} deadline-missed), {} rejected, error rate {:.1}%",
            self.requests,
            self.rows,
            self.wall_seconds,
            self.rps,
            self.rows_per_second,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.queue_p50_us,
            self.queue_p95_us,
            self.service_p50_us,
            self.service_p95_us,
            self.batches,
            self.batch_mean,
            self.errors,
            self.panicked,
            self.deadline_missed,
            self.rejected,
            self.error_rate() * 100.0,
        )
    }

    /// The bench-JSON row for this replay: mean wall-clock per request,
    /// plus the loadgen-only `p95_us` / `batch_mean` fields.
    pub fn to_record(&self, op: &str, size: usize, threads: usize) -> BenchRecord {
        BenchRecord {
            op: op.to_string(),
            size,
            threads,
            ns_per_iter: self.wall_seconds / self.requests.max(1) as f64 * 1e9,
            gflops: None,
            speedup: None,
            vs: None,
            p95_us: Some(self.p95_us),
            queue_p95_us: Some(self.queue_p95_us),
            batch_mean: Some(self.batch_mean),
            bytes_per_param: None,
        }
    }
}

/// Replay the configured stream against `server` and report
/// throughput/latency.
///
/// Latency percentiles and the batch-size distribution are read from the
/// server's metrics as **deltas against a pre-run snapshot**, so replays
/// on a shared long-lived server report their own samples — earlier
/// traffic (including earlier replays) never leaks into the numbers.
pub fn run_loadgen(server: &BatchServer, cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(!cfg.targets.is_empty(), "loadgen needs at least one (model, weight) target");
    anyhow::ensure!(cfg.requests > 0, "loadgen needs at least one request");
    let mut rng = Rng::new(cfg.seed);

    // Pre-build the stream so generation cost stays out of the timed
    // window (it's identical across compared runs anyway, but cleaner).
    let mut stream = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let (model, weight) = cfg.targets[rng.below(cfg.targets.len())].clone();
        let in_features = server
            .registry()
            .get(&model)
            .and_then(|m| m.shape(&weight))
            .map(|(m, _)| m)
            .ok_or_else(|| anyhow::anyhow!("loadgen target `{model}/{weight}` not servable"))?;
        let rows = if cfg.ragged {
            1 + rng.below(cfg.rows_per_request.max(1))
        } else {
            cfg.rows_per_request.max(1)
        };
        let x = Tensor::randn(&[rows, in_features], &mut rng);
        let gap = if cfg.rate_rps > 0.0 {
            // Exponential inter-arrival (Poisson process), seeded.
            -(rng.uniform().max(1e-12).ln()) / cfg.rate_rps
        } else {
            0.0
        };
        stream.push((model, weight, x, gap));
    }

    // Snapshot the cumulative server metrics so the report covers THIS
    // replay only. The histograms live for the server's lifetime; quoting
    // them raw would mix every earlier run's samples into this report
    // (the second replay of `stream_is_seeded` used to inherit the
    // first's latency distribution).
    let batches_before = server.metrics().counter("serve.batches");
    let latency_before = server.metrics().hist_snapshot("serve.latency_seconds");
    let batch_rows_before = server.metrics().hist_snapshot("serve.batch_rows");
    let queue_before = server.metrics().hist_snapshot("serve.queue_wait_seconds");
    let service_before = server.metrics().hist_snapshot("serve.service_seconds");
    let t0 = Instant::now();
    let mut clock = 0.0f64;
    let mut rows_total = 0usize;
    let mut rejected = 0usize;
    let mut receivers = Vec::with_capacity(cfg.requests);
    for (model, weight, x, gap) in stream {
        clock += gap;
        if cfg.rate_rps > 0.0 {
            let target = Duration::from_secs_f64(clock);
            let elapsed = t0.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        rows_total += x.rows();
        let mut req = LinearRequest::new(weight, x);
        if let Some(d) = cfg.deadline {
            req = req.with_timeout(d);
        }
        // Shed-and-continue (PR 8): only a shutting-down server aborts
        // the replay; overload and quota rejections are an expected
        // outcome under chaos and are reported, not fatal.
        match server.submit(&model, req) {
            Ok(rx) => receivers.push(rx),
            Err(AdmissionError::ShuttingDown) => {
                anyhow::bail!("loadgen admission failed: server shutting down")
            }
            Err(_) => rejected += 1,
        }
    }
    let (errors, panicked, deadline_missed) = collect_outcomes(receivers);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let m = server.metrics();
    let latency = m.hist_since("serve.latency_seconds", &latency_before);
    let batch_rows = m.hist_since("serve.batch_rows", &batch_rows_before);
    // The latency split (PR 9): queue wait and service time are recorded
    // at pick/response for linear and forward traffic alike, so on a
    // mixed workload these percentiles cover both kinds.
    let queue = m.hist_since("serve.queue_wait_seconds", &queue_before);
    let service = m.hist_since("serve.service_seconds", &service_before);
    Ok(LoadgenReport {
        requests: cfg.requests,
        rows: rows_total,
        errors,
        rejected,
        panicked,
        deadline_missed,
        wall_seconds: wall,
        rps: cfg.requests as f64 / wall,
        rows_per_second: rows_total as f64 / wall,
        p50_us: latency.percentile(50.0) * 1e6,
        p95_us: latency.percentile(95.0) * 1e6,
        p99_us: latency.percentile(99.0) * 1e6,
        mean_latency_us: latency.mean() * 1e6,
        queue_p50_us: queue.percentile(50.0) * 1e6,
        queue_p95_us: queue.percentile(95.0) * 1e6,
        service_p50_us: service.percentile(50.0) * 1e6,
        service_p95_us: service.percentile(95.0) * 1e6,
        batch_mean: batch_rows.mean(),
        batches: m.counter("serve.batches") - batches_before,
    })
}

/// Wait for every admitted request's response and classify the outcomes:
/// `(errors, panicked, deadline_missed)`. A dropped responder (the server
/// died without answering — should never happen under containment) counts
/// as a plain error.
fn collect_outcomes<T>(
    receivers: Vec<std::sync::mpsc::Receiver<std::result::Result<T, ServeError>>>,
) -> (usize, usize, usize) {
    let (mut errors, mut panicked, mut deadline_missed) = (0usize, 0usize, 0usize);
    for rx in receivers {
        match rx.recv() {
            Ok(Ok(_)) => {}
            Ok(Err(e)) => {
                errors += 1;
                match e {
                    ServeError::Panicked { .. } => panicked += 1,
                    ServeError::DeadlineExceeded => deadline_missed += 1,
                    _ => {}
                }
            }
            Err(_) => errors += 1,
        }
    }
    (errors, panicked, deadline_missed)
}

/// Forward-stream loadgen knobs (PR 7): whole-model requests with
/// **mixed-length** token windows — the convoy-prone workload continuous
/// batching exists for. The whole stream derives from `seed`, so a
/// continuous-scheduled server and a flush-scheduled server replay the
/// identical workload (`forward_batched_vs_flush_*` rows in
/// `benches/hotpath.rs`).
#[derive(Debug, Clone)]
pub struct ForwardLoadgenConfig {
    pub seed: u64,
    /// Total forward requests to replay.
    pub requests: usize,
    /// Longest token window; with `mixed` each request draws its length
    /// uniformly from `1..=max_tokens` (clamped to the model's `seq`),
    /// otherwise every request is `max_tokens` long.
    pub max_tokens: usize,
    pub mixed: bool,
    /// Open-loop arrival rate in requests/s; `0.0` replays at saturation.
    pub rate_rps: f64,
    /// Registered forward names; each request samples one.
    pub models: Vec<String>,
    /// Per-request deadline (from submission), PR 8. `None` = no
    /// deadlines; late responses then never miss.
    pub deadline: Option<Duration>,
}

impl Default for ForwardLoadgenConfig {
    fn default() -> Self {
        ForwardLoadgenConfig {
            seed: 0xF02D,
            requests: 64,
            max_tokens: 16,
            mixed: true,
            rate_rps: 0.0,
            models: Vec::new(),
            deadline: None,
        }
    }
}

/// Replay a seeded mixed-length forward stream against `server`.
///
/// The returned [`LoadgenReport`] reuses the linear report's shape with
/// forward semantics: `rows` counts submitted *tokens*, `batches` counts
/// grouped **layer steps**, `batch_mean` is the mean stacked token rows
/// per layer step (1.0 ⇒ no cross-request grouping ever happened), and
/// the latency percentiles come from `serve.forward_latency_seconds` —
/// all as deltas against a pre-run snapshot, like [`run_loadgen`].
pub fn run_forward_loadgen(
    server: &BatchServer,
    cfg: &ForwardLoadgenConfig,
) -> Result<LoadgenReport> {
    anyhow::ensure!(!cfg.models.is_empty(), "forward loadgen needs at least one model");
    anyhow::ensure!(cfg.requests > 0, "forward loadgen needs at least one request");
    anyhow::ensure!(cfg.max_tokens > 0, "forward loadgen needs max_tokens >= 1");
    let mut rng = Rng::new(cfg.seed);

    // Pre-build the stream (identical across compared runs).
    let mut stream = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let model = cfg.models[rng.below(cfg.models.len())].clone();
        let fwd = server
            .registry()
            .forward(&model)
            .ok_or_else(|| anyhow::anyhow!("forward loadgen target `{model}` not registered"))?;
        let cap = cfg.max_tokens.min(fwd.config().seq);
        let t = if cfg.mixed { 1 + rng.below(cap) } else { cap };
        let vocab = fwd.config().vocab;
        let tokens: Vec<u32> = (0..t).map(|_| rng.below(vocab) as u32).collect();
        let gap = if cfg.rate_rps > 0.0 {
            -(rng.uniform().max(1e-12).ln()) / cfg.rate_rps
        } else {
            0.0
        };
        stream.push((model, tokens, gap));
    }

    let steps_before = server.metrics().counter("serve.forward_steps");
    let latency_before = server.metrics().hist_snapshot("serve.forward_latency_seconds");
    let step_rows_before = server.metrics().hist_snapshot("serve.forward_step_rows");
    let queue_before = server.metrics().hist_snapshot("serve.queue_wait_seconds");
    let service_before = server.metrics().hist_snapshot("serve.service_seconds");
    let t0 = Instant::now();
    let mut clock = 0.0f64;
    let mut tokens_total = 0usize;
    let mut rejected = 0usize;
    let mut receivers = Vec::with_capacity(cfg.requests);
    for (model, tokens, gap) in stream {
        clock += gap;
        if cfg.rate_rps > 0.0 {
            let target = Duration::from_secs_f64(clock);
            let elapsed = t0.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        tokens_total += tokens.len();
        let mut req = ForwardRequest::new(tokens);
        if let Some(d) = cfg.deadline {
            req = req.with_timeout(d);
        }
        match server.submit_forward(&model, req) {
            Ok(rx) => receivers.push(rx),
            Err(AdmissionError::ShuttingDown) => {
                anyhow::bail!("forward loadgen admission failed: server shutting down")
            }
            Err(_) => rejected += 1,
        }
    }
    let (errors, panicked, deadline_missed) = collect_outcomes(receivers);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let m = server.metrics();
    let latency = m.hist_since("serve.forward_latency_seconds", &latency_before);
    let step_rows = m.hist_since("serve.forward_step_rows", &step_rows_before);
    let queue = m.hist_since("serve.queue_wait_seconds", &queue_before);
    let service = m.hist_since("serve.service_seconds", &service_before);
    Ok(LoadgenReport {
        requests: cfg.requests,
        rows: tokens_total,
        errors,
        rejected,
        panicked,
        deadline_missed,
        wall_seconds: wall,
        rps: cfg.requests as f64 / wall,
        rows_per_second: tokens_total as f64 / wall,
        p50_us: latency.percentile(50.0) * 1e6,
        p95_us: latency.percentile(95.0) * 1e6,
        p99_us: latency.percentile(99.0) * 1e6,
        mean_latency_us: latency.mean() * 1e6,
        queue_p50_us: queue.percentile(50.0) * 1e6,
        queue_p95_us: queue.percentile(95.0) * 1e6,
        service_p50_us: service.percentile(50.0) * 1e6,
        service_p95_us: service.percentile(95.0) * 1e6,
        batch_mean: step_rows.mean(),
        batches: m.counter("serve.forward_steps") - steps_before,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::infer::InferMode;
    use crate::io::SwscFile;
    use crate::serve::{BatchConfig, ForwardScheduling, ModelRegistry, DEFAULT_MODEL};
    use std::sync::Arc;

    fn server() -> BatchServer {
        let mut rng = Rng::new(60);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[24, 24], &mut rng), &SwscConfig::new(3, 2)),
        );
        let reg = ModelRegistry::new();
        reg.insert_file(DEFAULT_MODEL, &file, InferMode::Compressed);
        BatchServer::start(Arc::new(reg), BatchConfig::default())
    }

    #[test]
    fn replays_and_reports() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 16,
            rows_per_request: 4,
            ragged: true,
            targets: vec![(DEFAULT_MODEL.into(), "w".into())],
            ..Default::default()
        };
        let rep = run_loadgen(&server, &cfg).unwrap();
        assert_eq!(rep.requests, 16);
        assert_eq!(rep.errors, 0);
        assert!(rep.rows >= 16 && rep.rows <= 16 * 4);
        assert!(rep.rps > 0.0 && rep.rows_per_second > 0.0);
        assert!(rep.batches >= 1 && rep.batch_mean >= 1.0);
        assert!(rep.p95_us >= rep.p50_us && rep.p50_us >= 0.0);
        // The latency split (PR 9): both halves observed, and neither can
        // exceed the end-to-end p95 it partitions.
        assert!(rep.queue_p95_us >= rep.queue_p50_us && rep.queue_p50_us >= 0.0);
        assert!(rep.service_p95_us >= rep.service_p50_us && rep.service_p50_us >= 0.0);
        assert!(rep.service_p95_us > 0.0, "served requests must record service time");
        let rec = rep.to_record("loadgen_unit", 24, 1);
        assert_eq!(rec.p95_us, Some(rep.p95_us));
        assert_eq!(rec.queue_p95_us, Some(rep.queue_p95_us));
        assert_eq!(rec.batch_mean, Some(rep.batch_mean));
        assert!(rec.ns_per_iter > 0.0);
        server.shutdown();
    }

    /// The stream is a pure function of the seed: two replays submit the
    /// same rows and targets (observable via total rows).
    #[test]
    fn stream_is_seeded() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 12,
            rows_per_request: 5,
            ragged: true,
            targets: vec![(DEFAULT_MODEL.into(), "w".into())],
            ..Default::default()
        };
        let a = run_loadgen(&server, &cfg).unwrap();
        let b = run_loadgen(&server, &cfg).unwrap();
        assert_eq!(a.rows, b.rows, "same seed must replay the same stream");
        server.shutdown();
    }

    /// Regression (ISSUE 7): sequential replays on one server report
    /// *independent* latency stats. A poison sample planted between the
    /// runs must not surface in the second report — the old code read
    /// the cumulative histograms, so a 1000 s outlier (or simply the
    /// first replay's samples) leaked into every later report's p99.
    #[test]
    fn sequential_replays_report_independent_stats() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 12,
            rows_per_request: 5,
            ragged: true,
            targets: vec![(DEFAULT_MODEL.into(), "w".into())],
            ..Default::default()
        };
        let a = run_loadgen(&server, &cfg).unwrap();
        // Poison the cumulative histograms with an absurd outlier and a
        // giant fake batch, as if earlier traffic had been pathological.
        server.metrics().record("serve.latency_seconds", 1000.0);
        server.metrics().record("serve.batch_rows", 1e6);
        let b = run_loadgen(&server, &cfg).unwrap();
        // With 12 requests, a cumulative read would put the 1000 s
        // outlier at p99 (nearest-rank of 13+ samples = max) — 1e9 µs.
        assert!(
            b.p99_us < 1e8,
            "second replay's p99 ({} µs) saw pre-run samples",
            b.p99_us
        );
        assert!(
            b.mean_latency_us < 1e8,
            "second replay's mean ({} µs) saw pre-run samples",
            b.mean_latency_us
        );
        assert!(
            b.batch_mean <= (12 * 5) as f64,
            "second replay's batch_mean ({}) saw pre-run samples",
            b.batch_mean
        );
        // Both replays' own stats are sane and self-consistent.
        assert!(a.p95_us >= a.p50_us && b.p95_us >= b.p50_us);
        assert!(a.batches >= 1 && b.batches >= 1);
        server.shutdown();
    }

    #[test]
    fn unknown_target_is_an_error() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 2,
            targets: vec![("ghost".into(), "w".into())],
            ..Default::default()
        };
        assert!(run_loadgen(&server, &cfg).is_err());
        server.shutdown();
    }

    fn forward_server(scheduling: ForwardScheduling) -> BatchServer {
        use crate::model::{init_params, param_specs, ModelConfig};
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, 61);
        let mut file = SwscFile::new();
        for spec in param_specs(&cfg) {
            let t = ck.get(&spec.name).unwrap().clone();
            if spec.shape.len() == 2 && spec.shape[1] >= 16 {
                file.compressed
                    .insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
            } else {
                file.dense.insert(spec.name.clone(), t);
            }
        }
        let reg = ModelRegistry::new();
        reg.insert_forward_file(DEFAULT_MODEL, &file, cfg, InferMode::Compressed).unwrap();
        BatchServer::start(
            Arc::new(reg),
            BatchConfig::default().with_forward_scheduling(scheduling),
        )
    }

    /// The forward loadgen replays a mixed-length stream and reports
    /// forward-specific semantics: rows = tokens, batches = layer steps,
    /// batch_mean = stacked token rows per step — under both schedulers.
    #[test]
    fn forward_replays_and_reports() {
        for scheduling in [ForwardScheduling::Continuous, ForwardScheduling::Flush] {
            let server = forward_server(scheduling);
            let cfg = ForwardLoadgenConfig {
                requests: 8,
                max_tokens: 6,
                models: vec![DEFAULT_MODEL.into()],
                ..Default::default()
            };
            let rep = run_forward_loadgen(&server, &cfg).unwrap();
            assert_eq!(rep.requests, 8);
            assert_eq!(rep.errors, 0, "{scheduling:?}");
            assert!(rep.rows >= 8 && rep.rows <= 8 * 6);
            // Every request crosses n_layers = 2 layer boundaries; steps
            // can be shared (grouping) but never skipped.
            assert!(rep.batches >= 2, "{scheduling:?}: {} steps", rep.batches);
            assert!(rep.batch_mean >= 1.0);
            assert!(rep.p95_us >= rep.p50_us && rep.p50_us > 0.0);
            server.shutdown();
        }
    }

    /// Same seed ⇒ same token stream, and (satellite 1 applies here too)
    /// a second replay's latency stats are its own.
    #[test]
    fn forward_stream_is_seeded_and_stats_are_independent() {
        let server = forward_server(ForwardScheduling::Continuous);
        let cfg = ForwardLoadgenConfig {
            requests: 6,
            max_tokens: 5,
            models: vec![DEFAULT_MODEL.into()],
            ..Default::default()
        };
        let a = run_forward_loadgen(&server, &cfg).unwrap();
        server.metrics().record("serve.forward_latency_seconds", 1000.0);
        let b = run_forward_loadgen(&server, &cfg).unwrap();
        assert_eq!(a.rows, b.rows, "same seed must replay the same stream");
        assert!(b.p99_us < 1e8, "second replay's p99 ({} µs) saw pre-run samples", b.p99_us);
        server.shutdown();
    }

    #[test]
    fn forward_unknown_model_is_an_error() {
        let server = forward_server(ForwardScheduling::Continuous);
        let cfg = ForwardLoadgenConfig {
            requests: 2,
            models: vec!["ghost".into()],
            ..Default::default()
        };
        assert!(run_forward_loadgen(&server, &cfg).is_err());
        server.shutdown();
    }

    /// Chaos mode (PR 8): against a fault-injecting server the loadgen
    /// sheds injected admission rejections, classifies panicked responses,
    /// and reports a consistent error rate — and with a zero-duration
    /// deadline every served request is a deadline miss.
    #[test]
    fn chaos_replay_classifies_outcomes() {
        use crate::serve::{FaultConfig, FaultInjector, ServerOptions};
        let n = 64u64;
        // The fault schedule is a pure function of (seed, request id) and
        // this fresh server assigns ids 0..n to the replay in order — so
        // an oracle injector *predicts* the report exactly. Scan for a
        // seed that mixes rejections, panics, and clean requests.
        let base = FaultConfig { panic_rate: 0.3, reject_rate: 0.2, ..FaultConfig::default() };
        let seed = (0..1000)
            .find(|&s| {
                let probe = FaultInjector::new(FaultConfig { seed: s, ..base.clone() });
                let rejects = (0..n).filter(|&id| probe.injects_rejection(id)).count() as u64;
                let panics = (0..n)
                    .filter(|&id| !probe.injects_rejection(id) && probe.injects_panic(id))
                    .count() as u64;
                rejects > 0 && panics > 0 && rejects + panics < n
            })
            .expect("some seed under 1000 must mix outcomes");
        let cfg_faults = FaultConfig { seed, ..base };
        let oracle = FaultInjector::new(cfg_faults.clone());
        let want_rejected =
            (0..n).filter(|&id| oracle.injects_rejection(id)).count();
        let want_panicked = (0..n)
            .filter(|&id| !oracle.injects_rejection(id) && oracle.injects_panic(id))
            .count();

        let mut rng = Rng::new(62);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[24, 24], &mut rng), &SwscConfig::new(3, 2)),
        );
        let reg = ModelRegistry::new();
        reg.insert_file(DEFAULT_MODEL, &file, InferMode::Compressed);
        let server = BatchServer::start_with_opts(
            Arc::new(reg),
            BatchConfig::default(),
            ServerOptions { faults: Some(cfg_faults), ..ServerOptions::default() },
        );
        let cfg = LoadgenConfig {
            requests: n as usize,
            rows_per_request: 2,
            targets: vec![(DEFAULT_MODEL.into(), "w".into())],
            ..Default::default()
        };
        let rep = run_loadgen(&server, &cfg).unwrap();
        assert_eq!(rep.requests, n as usize);
        assert_eq!(rep.rejected, want_rejected, "rejections must match the seeded schedule");
        assert_eq!(rep.panicked, want_panicked, "panics must match the seeded schedule");
        assert_eq!(rep.deadline_missed, 0);
        assert_eq!(rep.errors, want_panicked, "all errors here are injected panics");
        assert!(rep.error_rate() > 0.0 && rep.error_rate() < 1.0);
        assert!(rep.render().contains("error rate"));

        // Zero-duration deadlines: every non-rejected request misses at
        // admission — it never occupies a queue slot or panics.
        let rep2 = run_loadgen(
            &server,
            &LoadgenConfig { deadline: Some(Duration::ZERO), ..cfg.clone() },
        )
        .unwrap();
        assert_eq!(rep2.deadline_missed + rep2.rejected, rep2.requests);
        assert_eq!(rep2.errors + rep2.rejected, rep2.requests);
        assert_eq!(rep2.panicked, 0);
        server.shutdown();
    }
}
