//! Open-loop load generator for the batched serving layer.
//!
//! Replays a **seeded arrival stream** against a [`BatchServer`]: the
//! request sequence (target weight, activation rows, activation values,
//! inter-arrival gaps) is a pure function of [`LoadgenConfig::seed`], so
//! two runs — e.g. a coalescing server and a solo server — see the
//! *identical* workload and their throughput/latency numbers are directly
//! comparable (`batched_vs_solo_*` rows in `benches/hotpath.rs`).
//!
//! Open-loop means arrivals are scheduled by the stream's clock, not by
//! completions: with `rate_rps > 0` inter-arrival gaps are exponential
//! (Poisson arrivals) and the generator sleeps to honor them; with
//! `rate_rps = 0` requests are submitted as fast as admission allows —
//! the saturation mode, where blocking admission is the backpressure.
//! Latency is recorded server-side (admission → response) into the
//! service histograms; the report quotes their p50/p95/p99.

use crate::bench::BenchRecord;
use crate::serve::{BatchServer, LinearRequest};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::time::{Duration, Instant};

/// Loadgen knobs. The whole stream derives from `seed`.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub seed: u64,
    /// Total requests to replay.
    pub requests: usize,
    /// Activation rows per request; with `ragged` the row count is drawn
    /// uniformly from `1..=rows_per_request` instead.
    pub rows_per_request: usize,
    pub ragged: bool,
    /// Open-loop arrival rate in requests/s; `0.0` replays at saturation.
    pub rate_rps: f64,
    /// `(model, weight)` pairs; each request samples one from the seeded
    /// stream.
    pub targets: Vec<(String, String)>,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            seed: 0x10AD,
            requests: 128,
            rows_per_request: 8,
            ragged: false,
            rate_rps: 0.0,
            targets: Vec::new(),
        }
    }
}

/// What one replay measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub requests: usize,
    /// Total activation rows submitted.
    pub rows: usize,
    /// Requests answered with an error (admission failures abort the run
    /// instead — the bench configs keep the queue deeper than the
    /// stream).
    pub errors: usize,
    /// First submission → last response.
    pub wall_seconds: f64,
    pub rps: f64,
    pub rows_per_second: f64,
    /// Server-side admission→response latency percentiles, microseconds.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_latency_us: f64,
    /// Mean stacked rows per executed micro-batch (1.0 ⇒ no coalescing).
    pub batch_mean: f64,
    /// Micro-batches the server executed during the run.
    pub batches: u64,
}

impl LoadgenReport {
    /// One-line human summary.
    pub fn render(&self) -> String {
        format!(
            "{} req ({} rows) in {:.3}s -> {:.0} req/s ({:.0} rows/s), latency p50 {:.0} µs \
             p95 {:.0} µs p99 {:.0} µs, {} batches (mean {:.1} rows), {} errors",
            self.requests,
            self.rows,
            self.wall_seconds,
            self.rps,
            self.rows_per_second,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.batches,
            self.batch_mean,
            self.errors,
        )
    }

    /// The bench-JSON row for this replay: mean wall-clock per request,
    /// plus the loadgen-only `p95_us` / `batch_mean` fields.
    pub fn to_record(&self, op: &str, size: usize, threads: usize) -> BenchRecord {
        BenchRecord {
            op: op.to_string(),
            size,
            threads,
            ns_per_iter: self.wall_seconds / self.requests.max(1) as f64 * 1e9,
            gflops: None,
            speedup: None,
            vs: None,
            p95_us: Some(self.p95_us),
            batch_mean: Some(self.batch_mean),
            bytes_per_param: None,
        }
    }
}

/// Replay the configured stream against `server` and report
/// throughput/latency.
///
/// Latency percentiles and the batch-size distribution are read from the
/// server's metrics, so use a freshly started server per replay when
/// comparing configurations (the bench does).
pub fn run_loadgen(server: &BatchServer, cfg: &LoadgenConfig) -> Result<LoadgenReport> {
    anyhow::ensure!(!cfg.targets.is_empty(), "loadgen needs at least one (model, weight) target");
    anyhow::ensure!(cfg.requests > 0, "loadgen needs at least one request");
    let mut rng = Rng::new(cfg.seed);

    // Pre-build the stream so generation cost stays out of the timed
    // window (it's identical across compared runs anyway, but cleaner).
    let mut stream = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let (model, weight) = cfg.targets[rng.below(cfg.targets.len())].clone();
        let in_features = server
            .registry()
            .get(&model)
            .and_then(|m| m.shape(&weight))
            .map(|(m, _)| m)
            .ok_or_else(|| anyhow::anyhow!("loadgen target `{model}/{weight}` not servable"))?;
        let rows = if cfg.ragged {
            1 + rng.below(cfg.rows_per_request.max(1))
        } else {
            cfg.rows_per_request.max(1)
        };
        let x = Tensor::randn(&[rows, in_features], &mut rng);
        let gap = if cfg.rate_rps > 0.0 {
            // Exponential inter-arrival (Poisson process), seeded.
            -(rng.uniform().max(1e-12).ln()) / cfg.rate_rps
        } else {
            0.0
        };
        stream.push((model, weight, x, gap));
    }

    let batches_before = server.metrics().counter("serve.batches");
    let t0 = Instant::now();
    let mut clock = 0.0f64;
    let mut rows_total = 0usize;
    let mut receivers = Vec::with_capacity(cfg.requests);
    for (model, weight, x, gap) in stream {
        clock += gap;
        if cfg.rate_rps > 0.0 {
            let target = Duration::from_secs_f64(clock);
            let elapsed = t0.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
        }
        rows_total += x.rows();
        let rx = server
            .submit(&model, LinearRequest { name: weight, x })
            .map_err(|e| anyhow::anyhow!("loadgen admission failed: {e}"))?;
        receivers.push(rx);
    }
    let mut errors = 0usize;
    for rx in receivers {
        match rx.recv() {
            Ok(Ok(_)) => {}
            _ => errors += 1,
        }
    }
    let wall = t0.elapsed().as_secs_f64().max(1e-9);

    let m = server.metrics();
    Ok(LoadgenReport {
        requests: cfg.requests,
        rows: rows_total,
        errors,
        wall_seconds: wall,
        rps: cfg.requests as f64 / wall,
        rows_per_second: rows_total as f64 / wall,
        p50_us: m.timing_percentile("serve.latency_seconds", 50.0) * 1e6,
        p95_us: m.timing_percentile("serve.latency_seconds", 95.0) * 1e6,
        p99_us: m.timing_percentile("serve.latency_seconds", 99.0) * 1e6,
        mean_latency_us: m.timing_mean("serve.latency_seconds") * 1e6,
        batch_mean: m.timing_mean("serve.batch_rows"),
        batches: m.counter("serve.batches") - batches_before,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::infer::InferMode;
    use crate::io::SwscFile;
    use crate::serve::{BatchConfig, ModelRegistry, DEFAULT_MODEL};
    use std::sync::Arc;

    fn server() -> BatchServer {
        let mut rng = Rng::new(60);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[24, 24], &mut rng), &SwscConfig::new(3, 2)),
        );
        let mut reg = ModelRegistry::new();
        reg.insert_file(DEFAULT_MODEL, &file, InferMode::Compressed);
        BatchServer::start(Arc::new(reg), BatchConfig::default())
    }

    #[test]
    fn replays_and_reports() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 16,
            rows_per_request: 4,
            ragged: true,
            targets: vec![(DEFAULT_MODEL.into(), "w".into())],
            ..Default::default()
        };
        let rep = run_loadgen(&server, &cfg).unwrap();
        assert_eq!(rep.requests, 16);
        assert_eq!(rep.errors, 0);
        assert!(rep.rows >= 16 && rep.rows <= 16 * 4);
        assert!(rep.rps > 0.0 && rep.rows_per_second > 0.0);
        assert!(rep.batches >= 1 && rep.batch_mean >= 1.0);
        assert!(rep.p95_us >= rep.p50_us && rep.p50_us >= 0.0);
        let rec = rep.to_record("loadgen_unit", 24, 1);
        assert_eq!(rec.p95_us, Some(rep.p95_us));
        assert_eq!(rec.batch_mean, Some(rep.batch_mean));
        assert!(rec.ns_per_iter > 0.0);
        server.shutdown();
    }

    /// The stream is a pure function of the seed: two replays submit the
    /// same rows and targets (observable via total rows).
    #[test]
    fn stream_is_seeded() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 12,
            rows_per_request: 5,
            ragged: true,
            targets: vec![(DEFAULT_MODEL.into(), "w".into())],
            ..Default::default()
        };
        let a = run_loadgen(&server, &cfg).unwrap();
        let b = run_loadgen(&server, &cfg).unwrap();
        assert_eq!(a.rows, b.rows, "same seed must replay the same stream");
        server.shutdown();
    }

    #[test]
    fn unknown_target_is_an_error() {
        let server = server();
        let cfg = LoadgenConfig {
            requests: 2,
            targets: vec![("ghost".into(), "w".into())],
            ..Default::default()
        };
        assert!(run_loadgen(&server, &cfg).is_err());
        server.shutdown();
    }
}
