//! A double-compressed (grouped-int8) weight matrix as a serving-time
//! linear operator — the quantized twin of [`CompressedLinear`].
//!
//! The serving orientation `Y = X·W = (X·R)[:, labels] + (X·A)·B` puts
//! every weight factor on the **right** of its GEMM, so the factors live
//! as [`PackedBQ`] panels: int8 codes plus per-group scale/zero lanes,
//! dequantized in-register inside the microkernel
//! ([`crate::tensor::gemm`]'s fused variant). No dense f32 copy of `R`,
//! `A`, or `B` — let alone of `W` — is ever materialized on this path,
//! and the panel cache holds roughly a quarter of the f32 panels' bytes.
//!
//! The other orientations (`matmul`, `t_matmul`, `matvec`) appear rarely
//! in serving; they route through a lazily built f32
//! [`CompressedLinear`] twin over the dequantized factors (`m·k + m·r +
//! r·n` floats — still never the dense `m × n` weight). The fused
//! kernel's dequantization is the same [`crate::quant::dequant_u8`]
//! expression the twin's factors are built from, so `apply` here is
//! **bitwise equal** to the twin's `apply` at any thread count.

use super::linear::{CompressedLinear, GATHER_BAND, MIN_PARALLEL_GATHER_ELEMS};
use crate::compress::QuantizedMatrix;
use crate::exec::{self, ExecConfig};
use crate::quant::QuantizedTensor;
use crate::tensor::gemm::{self, ASrc, PackedBQ};
use crate::tensor::{gemm_packed_bq_into, gemm_prepacked_bq_into, Tensor};
use std::sync::OnceLock;

/// A [`QuantizedMatrix`] prepared for fused-dequant compressed-domain
/// products. Built once per matrix; the quantized panels pack lazily on
/// first `apply` and are then shared by every later call (and, through
/// `serve::ModelRegistry`'s `Arc`, by every model alias).
pub struct QuantizedLinear {
    matrix: QuantizedMatrix,
    k: usize,
    rank: usize,
    // Right-operand panels for the activation-major `apply`:
    pbq_r: OnceLock<PackedBQ>, // R — XC = X·R
    pbq_a: OnceLock<PackedBQ>, // A — XA = X·A
    pbq_b: OnceLock<PackedBQ>, // B — Y += XA·B
    // f32 oracle for the non-`apply` orientations, built on first use.
    twin: OnceLock<CompressedLinear>,
}

impl QuantizedLinear {
    /// Build the serving form: validate labels and take a copy of the
    /// quantized factors. Panels pack lazily; the operator is identical
    /// at any thread count.
    pub fn from_matrix(q: &QuantizedMatrix) -> QuantizedLinear {
        let (_, n) = q.shape;
        let k = q.k();
        assert!(
            q.labels.iter().all(|&l| (l as usize) < k),
            "quantized matrix has labels out of range (k = {k})"
        );
        assert_eq!(q.labels.len(), n, "one label per channel");
        QuantizedLinear {
            k,
            rank: q.rank(),
            matrix: q.clone(),
            pbq_r: OnceLock::new(),
            pbq_a: OnceLock::new(),
            pbq_b: OnceLock::new(),
            twin: OnceLock::new(),
        }
    }

    fn pack(qt: &QuantizedTensor, exec: ExecConfig) -> PackedBQ {
        gemm::pack_bq(
            qt.data(),
            qt.scales(),
            qt.zeros(),
            qt.rows(),
            qt.cols(),
            qt.group(),
            exec,
        )
    }

    fn pbq_r(&self, exec: ExecConfig) -> &PackedBQ {
        self.pbq_r.get_or_init(|| Self::pack(&self.matrix.centroids, exec))
    }

    fn pbq_a(&self, exec: ExecConfig) -> &PackedBQ {
        self.pbq_a.get_or_init(|| Self::pack(&self.matrix.factor_a, exec))
    }

    fn pbq_b(&self, exec: ExecConfig) -> &PackedBQ {
        self.pbq_b.get_or_init(|| Self::pack(&self.matrix.factor_b, exec))
    }

    /// The f32 [`CompressedLinear`] over the dequantized factors — the
    /// oracle, and the route for the non-`apply` orientations.
    pub fn f32_twin(&self) -> &CompressedLinear {
        self.twin.get_or_init(|| CompressedLinear::from_matrix(&self.matrix.dequantize()))
    }

    /// Original dense shape `(m, n)`.
    pub fn shape(&self) -> (usize, usize) {
        self.matrix.shape
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Quantization group length (rows per scale/zero block).
    pub fn group(&self) -> usize {
        self.matrix.centroids.group()
    }

    /// Bytes held by the `apply`-orientation panel cache (int8 codes +
    /// f32 scale/zero lanes), packing the panels first if needed.
    /// Compare with [`CompressedLinear::apply_panel_bytes`].
    pub fn apply_panel_bytes(&self, exec: ExecConfig) -> usize {
        self.pbq_r(exec).footprint_bytes()
            + self.pbq_a(exec).footprint_bytes()
            + self.pbq_b(exec).footprint_bytes()
    }

    /// Reconstruct one row of `W` into `out` — the embedding-lookup
    /// primitive for the compressed forward pass. Routes through the
    /// f32 twin (row lookups are rare and serial; the fused panels only
    /// pay off on batched GEMMs).
    pub fn row_into(&self, i: usize, out: &mut [f32]) {
        self.f32_twin().row_into(i, out)
    }

    /// `Y = X·W` on the process-wide thread config (`x` is `b × m`).
    pub fn apply(&self, x: &Tensor) -> Tensor {
        self.apply_with(x, exec::global())
    }

    /// `Y = (X·R)[:, labels] + (X·A)·B` with `R`, `A`, `B` consumed as
    /// quantized panels — dequantization happens in-register inside the
    /// microkernel; no dense f32 intermediate of any factor exists.
    /// Bitwise equal to `f32_twin().apply_with(x, exec)` at any
    /// `exec.threads` (the fused kernel's contract).
    pub fn apply_with(&self, x: &Tensor, exec: ExecConfig) -> Tensor {
        let (m, n) = self.shape();
        assert_eq!(x.cols(), m, "apply wants {m} activation columns, got {}", x.cols());
        let bsz = x.rows();
        let mut out = vec![0.0f32; bsz * n];
        if bsz == 0 || n == 0 {
            return Tensor::from_vec(&[bsz, n], out);
        }
        // Activation row panels packed once, reused for X·R and X·A —
        // the same structure as `CompressedLinear::apply_with`.
        let pa_x = gemm::pack_a(ASrc::Rows { data: x.data(), k: m }, bsz, m, exec);
        let mut xc = vec![0.0f32; bsz * self.k];
        gemm_prepacked_bq_into(&pa_x, self.pbq_r(exec), false, exec, &mut xc);
        let gex = if bsz * n < MIN_PARALLEL_GATHER_ELEMS { ExecConfig::serial() } else { exec };
        let (labels, k) = (&self.matrix.labels, self.k);
        exec::for_row_bands(gex, &mut out, bsz, n, GATHER_BAND, |t0, band| {
            for (tr, orow) in band.chunks_exact_mut(n).enumerate() {
                let xrow = &xc[(t0 + tr) * k..][..k];
                for (o, &l) in orow.iter_mut().zip(labels) {
                    *o = xrow[l as usize];
                }
            }
        });
        if self.rank > 0 {
            let mut xa = vec![0.0f32; bsz * self.rank];
            gemm_prepacked_bq_into(&pa_x, self.pbq_a(exec), false, exec, &mut xa);
            gemm_packed_bq_into(
                ASrc::Rows { data: &xa, k: self.rank },
                self.pbq_b(exec),
                bsz,
                true,
                exec,
                &mut out,
            );
        }
        Tensor::from_vec(&[bsz, n], out)
    }

    /// `Y = W·X` (`x` is `n × b`) via the f32 twin's bucket-sum path.
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        self.f32_twin().matmul(x)
    }

    /// [`QuantizedLinear::matmul`] with an explicit thread config.
    pub fn matmul_with(&self, x: &Tensor, exec: ExecConfig) -> Tensor {
        self.f32_twin().matmul_with(x, exec)
    }

    /// `Y = Wᵀ·X` (`x` is `m × b`) via the f32 twin's gather path.
    pub fn t_matmul(&self, x: &Tensor) -> Tensor {
        self.f32_twin().t_matmul(x)
    }

    /// [`QuantizedLinear::t_matmul`] with an explicit thread config.
    pub fn t_matmul_with(&self, x: &Tensor, exec: ExecConfig) -> Tensor {
        self.f32_twin().t_matmul_with(x, exec)
    }

    /// `W·x` for a single activation vector, via the f32 twin.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        self.f32_twin().matvec(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, CompressedMatrix, SwscConfig};
    use crate::quant::QuantConfig;
    use crate::util::rng::Rng;

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    fn quantized(m: usize, n: usize, k: usize, r: usize, group: usize, seed: u64) -> QuantizedMatrix {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[m, n], &mut rng);
        compress_matrix(&w, &SwscConfig::new(k, r)).quantize(&QuantConfig { group })
    }

    #[test]
    fn fused_apply_bitwise_equals_f32_twin() {
        // The core contract: the fused-dequant path and the
        // dequantize-then-f32 path agree to the bit, including at ragged
        // group/tile remainders.
        for (m, n, k, r, group) in
            [(48, 80, 6, 4, 16), (33, 41, 5, 3, 7), (24, 24, 4, 0, 64), (64, 96, 8, 5, 100)]
        {
            let q = quantized(m, n, k, r, group, 810);
            let lin = QuantizedLinear::from_matrix(&q);
            let mut rng = Rng::new(811);
            let x = Tensor::randn(&[9, m], &mut rng);
            let fused = lin.apply(&x);
            let oracle = lin.f32_twin().apply(&x);
            assert_eq!(bits(&fused), bits(&oracle), "{m}x{n} k={k} r={r} g={group}");
        }
    }

    #[test]
    fn apply_is_thread_invariant_bitwise() {
        let q = quantized(56, 72, 6, 4, 16, 812);
        let lin = QuantizedLinear::from_matrix(&q);
        let mut rng = Rng::new(813);
        let x = Tensor::randn(&[11, 56], &mut rng);
        let base = lin.apply_with(&x, ExecConfig::serial());
        for threads in [2, 4, 8] {
            let got = lin.apply_with(&x, ExecConfig::with_threads(threads));
            assert_eq!(bits(&got), bits(&base), "{threads} threads");
        }
    }

    #[test]
    fn other_orientations_route_through_twin() {
        let q = quantized(40, 36, 5, 3, 8, 814);
        let lin = QuantizedLinear::from_matrix(&q);
        let mut rng = Rng::new(815);
        let xn = Tensor::randn(&[36, 6], &mut rng);
        assert_eq!(bits(&lin.matmul(&xn)), bits(&lin.f32_twin().matmul(&xn)));
        let xm = Tensor::randn(&[40, 6], &mut rng);
        assert_eq!(bits(&lin.t_matmul(&xm)), bits(&lin.f32_twin().t_matmul(&xm)));
        let v: Vec<f32> = (0..36).map(|_| rng.normal() as f32).collect();
        assert_eq!(lin.matvec(&v), lin.f32_twin().matvec(&v));
    }

    #[test]
    fn quantized_panels_hold_about_a_quarter_of_f32_bytes() {
        let q = quantized(128, 160, 16, 8, 64, 816);
        let lin = QuantizedLinear::from_matrix(&q);
        let f32_lin = CompressedLinear::from_matrix(&q.dequantize());
        let exec = ExecConfig::serial();
        let (qb, fb) = (lin.apply_panel_bytes(exec), f32_lin.apply_panel_bytes(exec));
        let ratio = qb as f64 / fb as f64;
        assert!(ratio < 0.32, "quantized panels {qb} B vs f32 {fb} B (ratio {ratio:.3})");
    }

    #[test]
    fn zero_width_and_rank_zero_are_fine() {
        let q = quantized(16, 20, 3, 0, 4, 817);
        let lin = QuantizedLinear::from_matrix(&q);
        assert_eq!(lin.apply(&Tensor::zeros(&[0, 16])).shape(), &[0, 20]);
        assert_eq!(lin.rank(), 0);
        assert_eq!(lin.group(), 4);
        let mut rng = Rng::new(818);
        let x = Tensor::randn(&[3, 16], &mut rng);
        assert_eq!(bits(&lin.apply(&x)), bits(&lin.f32_twin().apply(&x)));
    }

    /// The fused path vs the ORIGINAL (pre-quantization) weights obeys
    /// the documented per-element bound: each dequantized factor entry
    /// sits within its block's grid step of the f32 value, so
    /// `|Y_q − Y_f32| ≤ Σ_i |x_i| · step_i` accumulated along each dot.
    #[test]
    fn error_vs_f32_oracle_within_accumulated_step_bound() {
        let mut rng = Rng::new(819);
        let w = Tensor::randn(&[48, 64], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(6, 4));
        let q = c.quantize(&QuantConfig { group: 16 });
        let lin = QuantizedLinear::from_matrix(&q);
        let f32_lin = CompressedLinear::from_matrix(&c);
        let x = Tensor::randn(&[5, 48], &mut rng);
        let got = lin.apply(&x);
        let want = f32_lin.apply(&x);
        // Loose closed-form bound: every factor's worst grid step times
        // the activation L1 mass, once per serving term (R gather + A·B).
        let step = |t: &crate::quant::QuantizedTensor| {
            let mut s = 0.0f32;
            for g in 0..t.rows().div_ceil(t.group()) {
                for j in 0..t.cols() {
                    s = s.max(t.step(g * t.group(), j).abs());
                }
            }
            s
        };
        let smax = step(&q.centroids).max(step(&q.factor_a)).max(step(&q.factor_b));
        let amax = x.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let bmax = c.factor_b.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let amat = c.factor_a.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
        // R term: ≤ 48·|x|·step. A·B term: X·εA·B + X·A·εB + X·εA·εB,
        // each ≤ 48·|x|·step · 4·(|B| or |A| or step) at rank 4.
        let bound = 48.0 * amax * smax * (1.0 + 4.0 * (bmax + amat + smax)) + 1e-3;
        for (g, w) in got.data().iter().zip(want.data()) {
            assert!((g - w).abs() <= bound, "{g} vs {w} (bound {bound})");
        }
    }
}
