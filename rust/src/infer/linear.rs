//! A compressed weight matrix as a serving-time linear operator.

use super::bucket::{self, BucketIndex};
use crate::compress::CompressedMatrix;
use crate::exec::{self, ExecConfig};
use crate::tensor::gemm::{self, ASrc, PackedA, PackedB};
use crate::tensor::{gemm_packed_b_into, gemm_prepacked_into, Tensor};
use std::sync::OnceLock;

/// Below this many elements a gather (row/column copy by label) runs
/// inline serial — pure copies, same bar as the transpose threshold.
/// Shared with the quantized twin so both gathers schedule identically.
pub(crate) const MIN_PARALLEL_GATHER_ELEMS: usize = 1 << 16;

/// Row granularity for parallel gathers (matches the GEMM band size).
pub(crate) const GATHER_BAND: usize = 64;

/// Serve a lazily-packed GEMM panel, counting cache behaviour into the
/// kernel counters: one `panel_build` per pack (inside the `OnceLock`
/// closure, so races count at most one) and one `panel_reuse` per hit on
/// an already-packed panel. Observation-only — the returned panels are
/// exactly what a bare `get_or_init` would serve.
fn cached_panel<T>(lock: &OnceLock<T>, build: impl FnOnce() -> T) -> &T {
    use crate::obs::prof::counters;
    if lock.get().is_some() {
        counters::panel_reuse();
    }
    lock.get_or_init(|| {
        counters::panel_build();
        build()
    })
}

/// A [`CompressedMatrix`] prepared for compressed-domain products:
/// `W ≈ R[labels] + A·B` served without ever materializing the dense
/// `m × n` weight.
///
/// Built once per matrix: the label→bucket CSR index is constructed up
/// front, and each weight-side GEMM panel (R, A, B per orientation) is
/// packed **lazily on first use, then reused by every later call** — a
/// serving process that only ever hits one orientation (the service's
/// `apply` path) holds only that orientation's panels, not all three. A
/// request therefore pays only its own activation packing, the `O(n·b)`
/// bucket aggregation (or label gather), and GEMMs whose flops scale with
/// `k` and `r` instead of `n` — see the cost model in [`crate::infer`]'s
/// module docs. Panel contents are a pure function of the weights
/// (packing is thread-invariant), so laziness never affects results.
pub struct CompressedLinear {
    shape: (usize, usize),
    k: usize,
    rank: usize,
    labels: Vec<u32>,
    index: BucketIndex,
    // The compressed factors themselves (the only payload held eagerly).
    centroids: Tensor, // R  (m × k)
    factor_a: Tensor,  // A  (m × r)
    factor_b: Tensor,  // B  (r × n)
    // Left-operand (A-side) panels, packed on first use:
    pa_r: OnceLock<PackedA>,  // R  (m × k)  — Y = R·S      (matmul)
    pa_rt: OnceLock<PackedA>, // Rᵀ (k × m)  — T = Rᵀ·X     (t_matmul)
    pa_a: OnceLock<PackedA>,  // A  (m × r)  — Y += A·Z     (matmul)
    pa_at: OnceLock<PackedA>, // Aᵀ (r × m)  — Z = Aᵀ·X     (t_matmul)
    pa_bf: OnceLock<PackedA>, // B  (r × n)  — Z = B·X      (matmul)
    pa_bt: OnceLock<PackedA>, // Bᵀ (n × r)  — Y += Bᵀ·Z    (t_matmul)
    // Right-operand (B-side) panels for the activation-major `apply`:
    pb_r: OnceLock<PackedB>, // R — XC = X·R
    pb_a: OnceLock<PackedB>, // A — XA = X·A
    pb_b: OnceLock<PackedB>, // B — Y += XA·B
}

impl CompressedLinear {
    /// Build the serving form: validate labels, build the CSR label
    /// index, and take a copy of the factors; GEMM panels pack lazily on
    /// first use. The operator is identical at any thread count.
    pub fn from_matrix(c: &CompressedMatrix) -> CompressedLinear {
        let (m, n) = c.shape;
        let k = c.k();
        assert!(
            c.labels.iter().all(|&l| (l as usize) < k),
            "compressed matrix has labels out of range (k = {k})"
        );
        assert_eq!(c.labels.len(), n, "one label per channel");
        CompressedLinear {
            shape: (m, n),
            k,
            rank: c.rank(),
            labels: c.labels.clone(),
            index: BucketIndex::new(&c.labels, k),
            centroids: c.centroids.clone(),
            factor_a: c.factor_a.clone(),
            factor_b: c.factor_b.clone(),
            pa_r: OnceLock::new(),
            pa_rt: OnceLock::new(),
            pa_a: OnceLock::new(),
            pa_at: OnceLock::new(),
            pa_bf: OnceLock::new(),
            pa_bt: OnceLock::new(),
            pb_r: OnceLock::new(),
            pb_a: OnceLock::new(),
            pb_b: OnceLock::new(),
        }
    }

    // Lazy panel accessors. Each packs once (under the first caller's
    // thread config — contents are thread-invariant) and serves the
    // cached panels afterwards.

    fn pa_r(&self, exec: ExecConfig) -> &PackedA {
        let (m, _) = self.shape;
        cached_panel(&self.pa_r, || {
            let src = ASrc::Rows { data: self.centroids.data(), k: self.k };
            gemm::pack_a(src, m, self.k, exec)
        })
    }

    fn pa_rt(&self, exec: ExecConfig) -> &PackedA {
        let (m, _) = self.shape;
        cached_panel(&self.pa_rt, || {
            let src = ASrc::Cols { data: self.centroids.data(), ld: self.k };
            gemm::pack_a(src, self.k, m, exec)
        })
    }

    fn pa_a(&self, exec: ExecConfig) -> &PackedA {
        let (m, _) = self.shape;
        cached_panel(&self.pa_a, || {
            let src = ASrc::Rows { data: self.factor_a.data(), k: self.rank };
            gemm::pack_a(src, m, self.rank, exec)
        })
    }

    fn pa_at(&self, exec: ExecConfig) -> &PackedA {
        let (m, _) = self.shape;
        cached_panel(&self.pa_at, || {
            let src = ASrc::Cols { data: self.factor_a.data(), ld: self.rank };
            gemm::pack_a(src, self.rank, m, exec)
        })
    }

    fn pa_bf(&self, exec: ExecConfig) -> &PackedA {
        let (_, n) = self.shape;
        cached_panel(&self.pa_bf, || {
            let src = ASrc::Rows { data: self.factor_b.data(), k: n };
            gemm::pack_a(src, self.rank, n, exec)
        })
    }

    fn pa_bt(&self, exec: ExecConfig) -> &PackedA {
        let (_, n) = self.shape;
        cached_panel(&self.pa_bt, || {
            let src = ASrc::Cols { data: self.factor_b.data(), ld: n };
            gemm::pack_a(src, n, self.rank, exec)
        })
    }

    fn pb_r(&self, exec: ExecConfig) -> &PackedB {
        let (m, _) = self.shape;
        cached_panel(&self.pb_r, || gemm::pack_b(self.centroids.data(), m, self.k, exec))
    }

    fn pb_a(&self, exec: ExecConfig) -> &PackedB {
        let (m, _) = self.shape;
        cached_panel(&self.pb_a, || gemm::pack_b(self.factor_a.data(), m, self.rank, exec))
    }

    fn pb_b(&self, exec: ExecConfig) -> &PackedB {
        let (_, n) = self.shape;
        cached_panel(&self.pb_b, || gemm::pack_b(self.factor_b.data(), self.rank, n, exec))
    }

    /// Original dense shape `(m, n)`.
    pub fn shape(&self) -> (usize, usize) {
        self.shape
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// The label→bucket CSR index (introspection: bucket sizes, empties).
    pub fn index(&self) -> &BucketIndex {
        &self.index
    }

    /// Bytes held by the `apply`-orientation panel cache (R, A, B as
    /// packed right operands), packing them first if needed. The f32
    /// baseline for the quantized panel-footprint comparison.
    pub fn apply_panel_bytes(&self, exec: ExecConfig) -> usize {
        self.pb_r(exec).footprint_bytes()
            + self.pb_a(exec).footprint_bytes()
            + self.pb_b(exec).footprint_bytes()
    }

    /// Multiply-adds of one compressed-domain `W·X` at batch width `b`:
    /// bucket aggregation + `R·S` + `A·(B·X)`.
    pub fn compressed_macs(&self, b: usize) -> usize {
        let (m, n) = self.shape;
        n * b + m * self.k * b + self.rank * n * b + m * self.rank * b
    }

    /// Multiply-adds the dense route pays for the same product:
    /// reconstruct (`m·n·r` for `A·B` plus the gather) + dense `m·n·b`.
    pub fn dense_macs(&self, b: usize) -> usize {
        let (m, n) = self.shape;
        m * n * self.rank + m * n * b
    }

    /// Reconstruct one row of `W` into `out` (`out.len() == n`) without
    /// materializing the matrix:
    /// `out[j] = R[i][labels[j]] + Σᵣ A[i][r]·B[r][j]`, the rank term
    /// added in increasing `r`. This is the embedding-lookup primitive
    /// for the compressed forward pass — `O(n·r)` per token, serial by
    /// construction, so trivially identical at any thread count.
    pub fn row_into(&self, i: usize, out: &mut [f32]) {
        let (m, n) = self.shape;
        assert!(i < m, "row {i} out of range for {m}×{n}");
        assert_eq!(out.len(), n, "row_into wants an n = {n} buffer");
        let crow = self.centroids.row(i);
        for (o, &l) in out.iter_mut().zip(&self.labels) {
            *o = crow[l as usize];
        }
        for ri in 0..self.rank {
            let a = self.factor_a.row(i)[ri];
            let brow = &self.factor_b.data()[ri * n..][..n];
            for (o, &b) in out.iter_mut().zip(brow) {
                *o += a * b;
            }
        }
    }

    /// `Y = W·X` on the process-wide thread config (`x` is `n × b`).
    pub fn matmul(&self, x: &Tensor) -> Tensor {
        self.matmul_with(x, exec::global())
    }

    /// `Y = W·X` in the compressed domain: `Y = R·S + A·(B·X)` where `S`
    /// is the bucket-sum matrix ([`bucket::bucket_sums_with`]). Never
    /// materializes the dense weight; bit-identical at any `exec.threads`.
    pub fn matmul_with(&self, x: &Tensor, exec: ExecConfig) -> Tensor {
        let s = bucket::bucket_sums_with(x, &self.labels, self.k, exec);
        self.matmul_from_sums(&s, x, exec)
    }

    /// `W·x` for a single activation vector (`x.len() == n`). Routes the
    /// aggregation through the per-bucket CSR path — cheaper than chunk
    /// partial tables at width 1, and bitwise identical to
    /// [`CompressedLinear::matmul`] on the `n × 1` reshape.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        self.matvec_with(x, exec::global())
    }

    /// [`CompressedLinear::matvec`] with an explicit thread config.
    pub fn matvec_with(&self, x: &[f32], exec: ExecConfig) -> Vec<f32> {
        let (_, n) = self.shape;
        assert_eq!(x.len(), n, "matvec wants {n} activations, got {}", x.len());
        let xt = Tensor::from_vec(&[n, 1], x.to_vec());
        let s = bucket::bucket_sums_indexed(&xt, &self.index, exec);
        self.matmul_from_sums(&s, &xt, exec).into_vec()
    }

    /// Shared tail of the `W·X` paths: `Y = R·S [+ A·(B·X)]`.
    fn matmul_from_sums(&self, s: &Tensor, x: &Tensor, exec: ExecConfig) -> Tensor {
        let (m, n) = self.shape;
        assert_eq!(x.rows(), n, "matmul wants {n} activation rows, got {}", x.rows());
        let b = x.cols();
        let mut out = vec![0.0f32; m * b];
        if b == 0 {
            return Tensor::from_vec(&[m, b], out);
        }
        let pb_s = gemm::pack_b(s.data(), self.k, b, exec);
        gemm_prepacked_into(self.pa_r(exec), &pb_s, false, exec, &mut out);
        if self.rank > 0 {
            let pb_x = gemm::pack_b(x.data(), n, b, exec);
            let mut z = vec![0.0f32; self.rank * b];
            gemm_prepacked_into(self.pa_bf(exec), &pb_x, false, exec, &mut z);
            let pb_z = gemm::pack_b(&z, self.rank, b, exec);
            gemm_prepacked_into(self.pa_a(exec), &pb_z, true, exec, &mut out);
        }
        Tensor::from_vec(&[m, b], out)
    }

    /// `Y = Wᵀ·X` on the process-wide thread config (`x` is `m × b`).
    pub fn t_matmul(&self, x: &Tensor) -> Tensor {
        self.t_matmul_with(x, exec::global())
    }

    /// `Y = Wᵀ·X` in the compressed domain: `T = Rᵀ·X`, then row `j` of
    /// the output is the gathered `T[labels[j]]`, plus `Bᵀ·(Aᵀ·X)`. The
    /// gather replaces the bucket sum on this side — each output element
    /// is the same single-accumulator dot the dense path computes, so at
    /// `r = 0` this is bitwise equal to `reconstruct().t_matmul(x)`.
    pub fn t_matmul_with(&self, x: &Tensor, exec: ExecConfig) -> Tensor {
        let (m, n) = self.shape;
        assert_eq!(x.rows(), m, "t_matmul wants {m} activation rows, got {}", x.rows());
        let b = x.cols();
        let mut out = vec![0.0f32; n * b];
        if b == 0 || n == 0 {
            return Tensor::from_vec(&[n, b], out);
        }
        let pb_x = gemm::pack_b(x.data(), m, b, exec);
        let mut t = vec![0.0f32; self.k * b];
        gemm_prepacked_into(self.pa_rt(exec), &pb_x, false, exec, &mut t);
        let gex = if n * b < MIN_PARALLEL_GATHER_ELEMS { ExecConfig::serial() } else { exec };
        let labels = &self.labels;
        exec::for_row_bands(gex, &mut out, n, b, GATHER_BAND, |j0, band| {
            for (jr, row) in band.chunks_exact_mut(b).enumerate() {
                row.copy_from_slice(&t[labels[j0 + jr] as usize * b..][..b]);
            }
        });
        if self.rank > 0 {
            let mut z = vec![0.0f32; self.rank * b];
            gemm_prepacked_into(self.pa_at(exec), &pb_x, false, exec, &mut z);
            let pb_z = gemm::pack_b(&z, self.rank, b, exec);
            gemm_prepacked_into(self.pa_bt(exec), &pb_z, true, exec, &mut out);
        }
        Tensor::from_vec(&[n, b], out)
    }

    /// `Y = X·W` on the process-wide thread config (`x` is `b × m`).
    pub fn apply(&self, x: &Tensor) -> Tensor {
        self.apply_with(x, exec::global())
    }

    /// `Y = X·W` for row-major activation batches — the serving shape, and
    /// the L3 analog of the L1 `decode_matmul` Pallas kernel:
    /// `Y = (X·R)[:, labels] + (X·A)·B`. The weight-side operands are all
    /// right operands here, so their pre-packed B panels are reused across
    /// calls. At `r = 0` this is bitwise equal to
    /// `x.matmul(&reconstruct())` (same single-accumulator dots, gathered).
    pub fn apply_with(&self, x: &Tensor, exec: ExecConfig) -> Tensor {
        let (m, n) = self.shape;
        assert_eq!(x.cols(), m, "apply wants {m} activation columns, got {}", x.cols());
        let bsz = x.rows();
        let mut out = vec![0.0f32; bsz * n];
        if bsz == 0 || n == 0 {
            return Tensor::from_vec(&[bsz, n], out);
        }
        // The activation matrix is the left operand of both X·R and X·A —
        // pack its row panels once and reuse them (mirrors `t_matmul_with`
        // reusing one packed X for Rᵀ·X and Aᵀ·X).
        let pa_x = gemm::pack_a(ASrc::Rows { data: x.data(), k: m }, bsz, m, exec);
        let mut xc = vec![0.0f32; bsz * self.k];
        gemm_prepacked_into(&pa_x, self.pb_r(exec), false, exec, &mut xc);
        let gex = if bsz * n < MIN_PARALLEL_GATHER_ELEMS { ExecConfig::serial() } else { exec };
        let (labels, k) = (&self.labels, self.k);
        exec::for_row_bands(gex, &mut out, bsz, n, GATHER_BAND, |t0, band| {
            for (tr, orow) in band.chunks_exact_mut(n).enumerate() {
                let xrow = &xc[(t0 + tr) * k..][..k];
                for (o, &l) in orow.iter_mut().zip(labels) {
                    *o = xrow[l as usize];
                }
            }
        });
        if self.rank > 0 {
            let mut xa = vec![0.0f32; bsz * self.rank];
            gemm_prepacked_into(&pa_x, self.pb_a(exec), false, exec, &mut xa);
            gemm_packed_b_into(
                ASrc::Rows { data: &xa, k: self.rank },
                self.pb_b(exec),
                bsz,
                true,
                exec,
                &mut out,
            );
        }
        Tensor::from_vec(&[bsz, n], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    fn compressed(m: usize, n: usize, k: usize, r: usize, seed: u64) -> CompressedMatrix {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(&[m, n], &mut rng);
        compress_matrix(&w, &SwscConfig::new(k, r))
    }

    #[test]
    fn matmul_matches_dense_route() {
        let c = compressed(48, 80, 6, 4, 800);
        let lin = CompressedLinear::from_matrix(&c);
        let mut rng = Rng::new(801);
        let x = Tensor::randn(&[80, 10], &mut rng);
        let want = c.reconstruct().matmul(&x);
        let got = lin.matmul(&x);
        assert_eq!(got.shape(), want.shape());
        assert_close(got.data(), want.data(), 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn t_matmul_and_apply_match_dense_route() {
        let c = compressed(56, 40, 5, 3, 802);
        let lin = CompressedLinear::from_matrix(&c);
        let mut rng = Rng::new(803);
        let w = c.reconstruct();
        let xt = Tensor::randn(&[56, 7], &mut rng);
        assert_close(lin.t_matmul(&xt).data(), w.t_matmul(&xt).data(), 1e-3, 1e-3).unwrap();
        let xa = Tensor::randn(&[9, 56], &mut rng);
        assert_close(lin.apply(&xa).data(), xa.matmul(&w).data(), 1e-3, 1e-3).unwrap();
    }

    /// At r = 0 the gather paths preserve the dense accumulation order
    /// exactly — bitwise equality, not a tolerance (the contract recorded
    /// in tests/fixtures/README.md).
    #[test]
    fn rank_zero_gather_paths_bitwise_equal_dense() {
        let c = compressed(40, 36, 5, 0, 804);
        let lin = CompressedLinear::from_matrix(&c);
        let w = c.reconstruct();
        let mut rng = Rng::new(805);
        let xt = Tensor::randn(&[40, 6], &mut rng);
        assert_eq!(bits(&lin.t_matmul(&xt)), bits(&w.t_matmul(&xt)), "t_matmul r=0");
        let xa = Tensor::randn(&[5, 40], &mut rng);
        assert_eq!(bits(&lin.apply(&xa)), bits(&xa.matmul(&w)), "apply r=0");
    }

    /// `row_into` reconstructs exactly the rows `reconstruct()` builds
    /// (same gather + increasing-r accumulation per element).
    #[test]
    fn row_into_matches_reconstruct_rows() {
        for (m, n, k, r, seed) in [(24, 30, 4, 3, 810), (16, 20, 3, 0, 811)] {
            let c = compressed(m, n, k, r, seed);
            let lin = CompressedLinear::from_matrix(&c);
            let w = c.reconstruct();
            let mut row = vec![0.0f32; n];
            for i in 0..m {
                lin.row_into(i, &mut row);
                assert_close(&row, w.row(i), 1e-5, 1e-5)
                    .unwrap_or_else(|e| panic!("row {i}: {e}"));
            }
        }
    }

    #[test]
    fn matvec_bitwise_equals_matmul_width_one() {
        let c = compressed(32, 50, 4, 2, 806);
        let lin = CompressedLinear::from_matrix(&c);
        let mut rng = Rng::new(807);
        let x: Vec<f32> = (0..50).map(|_| rng.normal() as f32).collect();
        let via_matmul = lin.matmul(&Tensor::from_vec(&[50, 1], x.clone()));
        let via_matvec = lin.matvec(&x);
        let b1: Vec<u32> = via_matmul.data().iter().map(|v| v.to_bits()).collect();
        let b2: Vec<u32> = via_matvec.iter().map(|v| v.to_bits()).collect();
        assert_eq!(b1, b2);
    }

    /// The lazy panel cache reports builds and reuses to the kernel
    /// counters. Globals are shared across the parallel test binary, so
    /// only lower-bound deltas are asserted.
    #[test]
    fn panel_cache_counts_builds_then_reuses() {
        use crate::obs::prof::counters;
        let c = compressed(24, 30, 4, 2, 812);
        let lin = CompressedLinear::from_matrix(&c);
        let mut rng = Rng::new(813);
        let x = Tensor::randn(&[30, 3], &mut rng);
        let before = counters::snapshot();
        lin.matmul(&x); // packs pa_r, pa_bf, pa_a
        let mid = counters::snapshot();
        assert!(mid.panel_builds - before.panel_builds >= 3, "first call must pack panels");
        lin.matmul(&x); // every panel served from cache
        let after = counters::snapshot();
        assert!(after.panel_reuses - mid.panel_reuses >= 3, "second call must reuse panels");
    }

    #[test]
    fn zero_width_batches_are_fine() {
        let c = compressed(16, 20, 3, 2, 808);
        let lin = CompressedLinear::from_matrix(&c);
        assert_eq!(lin.matmul(&Tensor::zeros(&[20, 0])).shape(), &[16, 0]);
        assert_eq!(lin.t_matmul(&Tensor::zeros(&[16, 0])).shape(), &[20, 0]);
        assert_eq!(lin.apply(&Tensor::zeros(&[0, 16])).shape(), &[0, 20]);
    }

    #[test]
    fn cost_model_favors_compressed_in_paper_regime() {
        // k = n/8, r = 32 at 512² — the gate regime from the bench.
        let c = CompressedMatrix {
            shape: (512, 512),
            labels: (0..512).map(|j| (j % 64) as u32).collect(),
            centroids: Tensor::zeros(&[512, 64]),
            factor_a: Tensor::zeros(&[512, 32]),
            factor_b: Tensor::zeros(&[32, 512]),
        };
        let lin = CompressedLinear::from_matrix(&c);
        assert!(lin.compressed_macs(512) * 2 < lin.dense_macs(512));
        assert_eq!(lin.k(), 64);
        assert_eq!(lin.rank(), 32);
        assert_eq!(lin.shape(), (512, 512));
        assert_eq!(lin.index().empty_buckets(), 0);
    }
}
