//! Model-level compressed-domain serving: every matrix of an [`SwscFile`]
//! as a ready-to-serve linear operator.

use super::linear::CompressedLinear;
use super::quantized::QuantizedLinear;
use crate::exec::{self, ExecConfig};
use crate::io::SwscFile;
use crate::quant::QuantConfig;
use crate::tensor::Tensor;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// How a [`CompressedModel`] serves the compressed entries of its file.
///
/// The two modes produce results within the documented ULP bound of each
/// other (see `tests/fixtures/README.md`); `Reconstructed` is the oracle
/// and bench baseline, mirroring `ExecBackend::SpawnPerCall` and
/// `GemmKernel::Blocked`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InferMode {
    /// Serve straight from the compressed factors (`R`, labels, `A`, `B`)
    /// — no dense `m × n` weight is ever materialized.
    Compressed,
    /// Materialize `W = R[labels] + A·B` once at load and serve dense
    /// GEMMs — what every consumer did before the infer layer existed.
    Reconstructed,
}

/// Arithmetic the compressed entries are served with.
///
/// `F32` is the default and the oracle — the precision every pre-PR-6
/// consumer got — mirroring `InferMode::Reconstructed`,
/// `ExecBackend::SpawnPerCall`, `GemmKernel::Blocked`, and
/// `Batching::Disabled` as the keep-the-old-path-as-baseline flag.
/// `Int8` serves through [`QuantizedLinear`]'s fused dequantize-in-
/// register panels: ≈¼ the panel-cache bytes, bitwise-deterministic
/// within itself, within the documented grid-step bound of `F32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// f32 factors and f32 GEMM panels — the oracle path.
    #[default]
    F32,
    /// Grouped-int8 factors, dequantized in-register inside the GEMM
    /// microkernel; f32-entry files are quantized at load.
    Int8,
}

/// A loaded `.swsc` container in serving form: compressed entries become
/// [`CompressedLinear`] or [`QuantizedLinear`] operators (per
/// [`InferMode`] and [`Precision`]), dense entries pass through.
pub struct CompressedModel {
    mode: InferMode,
    precision: Precision,
    linears: BTreeMap<String, CompressedLinear>,
    quantized: BTreeMap<String, QuantizedLinear>,
    dense: BTreeMap<String, Tensor>,
}

impl CompressedModel {
    /// [`CompressedModel::from_file_with`] at the default
    /// [`Precision::F32`] — exactly the pre-quantization behavior.
    pub fn from_file(file: &SwscFile, mode: InferMode) -> CompressedModel {
        Self::from_file_with(file, mode, Precision::F32)
    }

    /// Build the serving form of `file`.
    ///
    /// In [`InferMode::Compressed`] each compressed entry becomes a
    /// serving operator whose flavor follows `precision`: at `F32`,
    /// f32 entries stay [`CompressedLinear`] and quantized entries are
    /// dequantized into one; at `Int8`, quantized entries serve their
    /// codes directly through [`QuantizedLinear`] and f32 entries are
    /// quantized at load (default [`QuantConfig`]). In
    /// [`InferMode::Reconstructed`] everything is restored to a dense
    /// tensor up front regardless of precision.
    pub fn from_file_with(
        file: &SwscFile,
        mode: InferMode,
        precision: Precision,
    ) -> CompressedModel {
        let mut linears = BTreeMap::new();
        let mut quantized = BTreeMap::new();
        let mut dense: BTreeMap<String, Tensor> =
            file.dense.iter().map(|(n, t)| (n.clone(), t.clone())).collect();
        match mode {
            InferMode::Compressed => {
                for (name, c) in &file.compressed {
                    match precision {
                        Precision::F32 => {
                            linears.insert(name.clone(), CompressedLinear::from_matrix(c));
                        }
                        Precision::Int8 => {
                            let q = c.quantize(&QuantConfig::default());
                            quantized.insert(name.clone(), QuantizedLinear::from_matrix(&q));
                        }
                    }
                }
                for (name, q) in &file.quantized {
                    match precision {
                        Precision::F32 => {
                            let c = q.dequantize();
                            linears.insert(name.clone(), CompressedLinear::from_matrix(&c));
                        }
                        Precision::Int8 => {
                            quantized.insert(name.clone(), QuantizedLinear::from_matrix(q));
                        }
                    }
                }
            }
            InferMode::Reconstructed => {
                for (name, c) in &file.compressed {
                    dense.insert(name.clone(), c.reconstruct());
                }
                for (name, q) in &file.quantized {
                    dense.insert(name.clone(), q.dequantize().reconstruct());
                }
            }
        }
        CompressedModel { mode, precision, linears, quantized, dense }
    }

    pub fn mode(&self) -> InferMode {
        self.mode
    }

    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Matrices served in the compressed domain (0 in reconstructed
    /// mode) — f32 and quantized operators combined.
    pub fn num_compressed(&self) -> usize {
        self.linears.len() + self.quantized.len()
    }

    /// Matrices served through the fused-dequant quantized path.
    pub fn num_quantized(&self) -> usize {
        self.quantized.len()
    }

    /// Every servable name, in sorted order.
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.linears.keys().map(|s| s.as_str()).collect();
        v.extend(self.quantized.keys().map(|s| s.as_str()));
        v.extend(self.dense.keys().map(|s| s.as_str()));
        v.sort_unstable();
        v
    }

    /// `(rows, cols)` of a 2-D entry; `None` if absent or not a matrix.
    pub fn shape(&self, name: &str) -> Option<(usize, usize)> {
        if let Some(lin) = self.linears.get(name) {
            return Some(lin.shape());
        }
        if let Some(q) = self.quantized.get(name) {
            return Some(q.shape());
        }
        let t = self.dense.get(name)?;
        (t.ndim() == 2).then(|| (t.rows(), t.cols()))
    }

    /// A dense (uncompressed) entry, any rank — layer-norm gains, biases,
    /// and position embeddings stay dense in a `.swsc` file, and the
    /// compressed forward reads them through this.
    pub fn dense_entry(&self, name: &str) -> Option<&Tensor> {
        self.dense.get(name)
    }

    /// Copy row `i` of the 2-D entry `name` into `out` — the embedding
    /// lookup of the compressed forward. Compressed entries reconstruct
    /// just that row (`O(n·r)`, never the matrix); dense entries copy.
    pub fn gather_row(&self, name: &str, i: usize, out: &mut [f32]) -> Result<()> {
        let (m, n) = self
            .shape(name)
            .ok_or_else(|| anyhow::anyhow!("no matrix named `{name}` in the model"))?;
        anyhow::ensure!(i < m, "row {i} out of range for `{name}` ({m}×{n})");
        anyhow::ensure!(out.len() == n, "`{name}` rows are {n} wide, buffer is {}", out.len());
        if let Some(lin) = self.linears.get(name) {
            lin.row_into(i, out);
        } else if let Some(q) = self.quantized.get(name) {
            q.row_into(i, out);
        } else {
            out.copy_from_slice(self.dense[name].row(i));
        }
        Ok(())
    }

    /// `Y = X·W[name]` for a row-major activation batch (`x` is `b × m`)
    /// — the serving entry point. Compressed entries never materialize the
    /// dense weight; dense entries run a plain GEMM.
    pub fn apply(&self, name: &str, x: &Tensor) -> Result<Tensor> {
        self.apply_with(name, x, exec::global())
    }

    /// [`CompressedModel::apply`] with an explicit thread config.
    pub fn apply_with(&self, name: &str, x: &Tensor, exec: ExecConfig) -> Result<Tensor> {
        if let Some(lin) = self.linears.get(name) {
            let (m, _) = lin.shape();
            anyhow::ensure!(
                x.ndim() == 2 && x.cols() == m,
                "`{name}` wants [b, {m}] activations, got {:?}",
                x.shape()
            );
            return Ok(lin.apply_with(x, exec));
        }
        if let Some(q) = self.quantized.get(name) {
            let (m, _) = q.shape();
            anyhow::ensure!(
                x.ndim() == 2 && x.cols() == m,
                "`{name}` wants [b, {m}] activations, got {:?}",
                x.shape()
            );
            return Ok(q.apply_with(x, exec));
        }
        if let Some(w) = self.dense.get(name) {
            anyhow::ensure!(w.ndim() == 2, "`{name}` is not a matrix");
            anyhow::ensure!(
                x.ndim() == 2 && x.cols() == w.rows(),
                "`{name}` wants [b, {}] activations, got {:?}",
                w.rows(),
                x.shape()
            );
            return Ok(x.matmul_with(w, exec));
        }
        bail!("no tensor named `{name}` in the model");
    }

    /// `Y = W[name]·X` (`x` is `n × b`) — the bucket-sum orientation.
    pub fn matmul(&self, name: &str, x: &Tensor) -> Result<Tensor> {
        self.matmul_with(name, x, exec::global())
    }

    /// [`CompressedModel::matmul`] with an explicit thread config.
    pub fn matmul_with(&self, name: &str, x: &Tensor, exec: ExecConfig) -> Result<Tensor> {
        if let Some(lin) = self.linears.get(name) {
            let (_, n) = lin.shape();
            anyhow::ensure!(
                x.ndim() == 2 && x.rows() == n,
                "`{name}` wants [{n}, b] activations, got {:?}",
                x.shape()
            );
            return Ok(lin.matmul_with(x, exec));
        }
        if let Some(q) = self.quantized.get(name) {
            let (_, n) = q.shape();
            anyhow::ensure!(
                x.ndim() == 2 && x.rows() == n,
                "`{name}` wants [{n}, b] activations, got {:?}",
                x.shape()
            );
            return Ok(q.matmul_with(x, exec));
        }
        if let Some(w) = self.dense.get(name) {
            anyhow::ensure!(w.ndim() == 2, "`{name}` is not a matrix");
            anyhow::ensure!(
                x.ndim() == 2 && x.rows() == w.cols(),
                "`{name}` wants [{}, b] activations, got {:?}",
                w.cols(),
                x.shape()
            );
            return Ok(w.matmul_with(x, exec));
        }
        bail!("no tensor named `{name}` in the model");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn small_file() -> SwscFile {
        let mut rng = Rng::new(900);
        let mut file = SwscFile::new();
        for name in ["layers.0.attn.wq", "layers.0.attn.wk"] {
            let w = Tensor::randn(&[32, 32], &mut rng);
            file.compressed.insert(name.into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
        }
        file.dense.insert("layers.0.attn.wv".into(), Tensor::randn(&[32, 32], &mut rng));
        file
    }

    #[test]
    fn modes_agree_within_tolerance() {
        let file = small_file();
        let comp = CompressedModel::from_file(&file, InferMode::Compressed);
        let reco = CompressedModel::from_file(&file, InferMode::Reconstructed);
        assert_eq!(comp.num_compressed(), 2);
        assert_eq!(reco.num_compressed(), 0);
        let mut rng = Rng::new(901);
        let x = Tensor::randn(&[5, 32], &mut rng);
        for name in comp.names() {
            let a = comp.apply(name, &x).unwrap();
            let b = reco.apply(name, &x).unwrap();
            assert_close(a.data(), b.data(), 1e-3, 1e-3).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
    }

    #[test]
    fn dense_passthrough_is_exact() {
        let file = small_file();
        let model = CompressedModel::from_file(&file, InferMode::Compressed);
        let mut rng = Rng::new(902);
        let x = Tensor::randn(&[3, 32], &mut rng);
        let got = model.apply("layers.0.attn.wv", &x).unwrap();
        let want = x.matmul(&file.dense["layers.0.attn.wv"]);
        assert_eq!(got, want);
    }

    #[test]
    fn int8_precision_serves_all_entries_quantized() {
        let mut file = small_file();
        // One entry arrives already quantized in the file, the rest are
        // f32 and get quantized at load.
        let pre = file.compressed.remove("layers.0.attn.wk").unwrap();
        file.quantized.insert("layers.0.attn.wk".into(), pre.quantize(&QuantConfig::default()));
        let int8 = CompressedModel::from_file_with(&file, InferMode::Compressed, Precision::Int8);
        assert_eq!(int8.precision(), Precision::Int8);
        assert_eq!(int8.num_quantized(), 2);
        assert_eq!(int8.num_compressed(), 2);
        assert_eq!(int8.names().len(), 3);
        assert_eq!(int8.shape("layers.0.attn.wk"), Some((32, 32)));
        let f32m = CompressedModel::from_file_with(&file, InferMode::Compressed, Precision::F32);
        assert_eq!(f32m.num_quantized(), 0);
        let mut rng = Rng::new(903);
        let x = Tensor::randn(&[5, 32], &mut rng);
        for name in int8.names() {
            let a = int8.apply(name, &x).unwrap();
            let b = f32m.apply(name, &x).unwrap();
            // Int8 vs the F32 oracle: within the quantization grid-step
            // bound — loose tolerance here; the tight per-element bound
            // is pinned in infer::quantized's tests.
            assert_close(a.data(), b.data(), 0.35, 0.35).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        let xn = Tensor::randn(&[32, 4], &mut rng);
        assert!(int8.matmul("layers.0.attn.wk", &xn).is_ok());
    }

    #[test]
    fn reconstructed_mode_restores_quantized_entries_dense() {
        let mut file = small_file();
        let pre = file.compressed.remove("layers.0.attn.wk").unwrap();
        file.quantized.insert("layers.0.attn.wk".into(), pre.quantize(&QuantConfig::default()));
        for precision in [Precision::F32, Precision::Int8] {
            let m = CompressedModel::from_file_with(&file, InferMode::Reconstructed, precision);
            assert_eq!(m.num_compressed(), 0);
            assert_eq!(m.names().len(), 3);
            assert_eq!(m.shape("layers.0.attn.wk"), Some((32, 32)));
        }
    }

    #[test]
    fn from_file_defaults_to_f32_precision() {
        let model = CompressedModel::from_file(&small_file(), InferMode::Compressed);
        assert_eq!(model.precision(), Precision::F32);
        assert_eq!(model.num_quantized(), 0);
    }

    #[test]
    fn unknown_and_misshapen_requests_error() {
        let file = small_file();
        let model = CompressedModel::from_file(&file, InferMode::Compressed);
        let x = Tensor::zeros(&[2, 32]);
        assert!(model.apply("nope", &x).is_err());
        assert!(model.apply("layers.0.attn.wq", &Tensor::zeros(&[2, 31])).is_err());
        assert!(model.matmul("layers.0.attn.wq", &Tensor::zeros(&[31, 2])).is_err());
        assert_eq!(model.shape("layers.0.attn.wq"), Some((32, 32)));
        assert_eq!(model.shape("nope"), None);
        assert_eq!(model.names().len(), 3);
    }
}
