//! Deterministic bucket sums — the aggregation step of the
//! compressed-domain matmul.
//!
//! For `Y = W·X` with `W ≈ R[labels] + A·B`, the shared-representative
//! term collapses to `R·S` where `S` is the `k × b` matrix of per-cluster
//! sums of X's rows: `S[l] = Σ_{j : labels[j] = l} x[j]`. Computing `S`
//! costs `n·b` adds — it replaces the `m·n·b` multiply-adds the dense path
//! spends re-multiplying the same representative column once per channel.
//!
//! ## Numeric contract (chunk grouping)
//!
//! Channels are cut at fixed [`CHANNEL_CHUNK`] boundaries (depending only
//! on `n`, never on the thread count). Each chunk accumulates its own
//! `k × b` partial bucket table over its channels in increasing `j`, and
//! the partial tables are folded elementwise **in chunk order**
//! ([`crate::exec::fold_chunks`]). Every `(l, c)` cell is therefore the
//! fixed expression `((0 + p₀) + p₁) + …` with `pᵢ` the chunk-`i` partial
//! — bit-identical at any `SWSC_THREADS`, same as the PR 1–3 parity
//! contract. Note this grouping is *not* the same float expression as one
//! flat accumulator over all of a bucket's channels (addition is not
//! associative), which is why the per-bucket CSR path below
//! ([`bucket_sums_indexed`]) reproduces the identical chunk grouping
//! rather than summing each bucket flat: the two implementations are
//! bitwise interchangeable, mirroring the blocked-vs-reference Lloyd
//! assign pair.

use crate::exec::{self, ExecConfig};
use crate::tensor::Tensor;

/// Fixed chunk size (in channels) for the bucket-sum reduction. Part of
/// the numeric contract — like `kmeans::POINT_CHUNK`, it must never depend
/// on the thread count.
pub const CHANNEL_CHUNK: usize = 128;

/// Below this many elements the bucket sum runs inline serial (pure adds —
/// memory-bound, same bar as the transpose threshold in `tensor::ops`).
const MIN_PARALLEL_ELEMS: usize = 1 << 16;

/// Label → bucket index in CSR form: `channels` holds every channel id
/// sorted by `(label, j)`, `starts[l]..starts[l + 1]` delimits bucket `l`.
/// Built once per [`super::CompressedLinear`]; drives the per-bucket
/// bucket-sum path and makes empty clusters explicit.
#[derive(Debug, Clone)]
pub struct BucketIndex {
    starts: Vec<usize>,
    channels: Vec<u32>,
}

impl BucketIndex {
    /// Counting-sort construction — stable, so each bucket's channel list
    /// is in increasing `j`.
    pub fn new(labels: &[u32], k: usize) -> BucketIndex {
        debug_assert!(labels.iter().all(|&l| (l as usize) < k), "label out of range");
        let mut starts = vec![0usize; k + 1];
        for &l in labels {
            starts[l as usize + 1] += 1;
        }
        for i in 0..k {
            starts[i + 1] += starts[i];
        }
        let mut cursor = starts.clone();
        let mut channels = vec![0u32; labels.len()];
        for (j, &l) in labels.iter().enumerate() {
            channels[cursor[l as usize]] = j as u32;
            cursor[l as usize] += 1;
        }
        BucketIndex { starts, channels }
    }

    /// Number of buckets `k`.
    pub fn k(&self) -> usize {
        self.starts.len() - 1
    }

    /// Channel ids of bucket `l`, in increasing `j`.
    pub fn bucket(&self, l: usize) -> &[u32] {
        &self.channels[self.starts[l]..self.starts[l + 1]]
    }

    /// How many buckets have no channels (possible after k-means repair on
    /// adversarial data, and legal in a `.swsc` container).
    pub fn empty_buckets(&self) -> usize {
        (0..self.k()).filter(|&l| self.bucket(l).is_empty()).count()
    }
}

/// [`bucket_sums_with`] on the process-wide thread config.
pub fn bucket_sums(x: &Tensor, labels: &[u32], k: usize) -> Tensor {
    bucket_sums_with(x, labels, k, exec::global())
}

/// Per-cluster sums of X's rows: `x` is `n × b` (row `j` = channel `j`),
/// returns the `k × b` matrix `S` with `S[l] = Σ_{j : labels[j] = l} x[j]`.
///
/// Parallel over fixed [`CHANNEL_CHUNK`] channel chunks; per-chunk partial
/// bucket tables are folded in chunk order with bounded memory
/// ([`exec::fold_chunks`]), so the result is bit-identical at any
/// `exec.threads` — see the module docs for the exact grouping contract.
pub fn bucket_sums_with(x: &Tensor, labels: &[u32], k: usize, exec: ExecConfig) -> Tensor {
    let (n, b) = (x.rows(), x.cols());
    assert_eq!(labels.len(), n, "one label per channel");
    debug_assert!(labels.iter().all(|&l| (l as usize) < k), "label out of range");
    let mut sums = vec![0.0f32; k * b];
    if n == 0 || b == 0 || k == 0 {
        return Tensor::from_vec(&[k, b], sums);
    }
    crate::obs::prof::counters::bucket_call(n.div_ceil(CHANNEL_CHUNK) as u64);
    let exec = if n * b < MIN_PARALLEL_ELEMS { ExecConfig::serial() } else { exec };
    exec::fold_chunks(
        exec,
        n,
        CHANNEL_CHUNK,
        |range| {
            let mut partial = vec![0.0f32; k * b];
            for j in range {
                let acc = &mut partial[labels[j] as usize * b..][..b];
                for (a, &v) in acc.iter_mut().zip(x.row(j)) {
                    *a += v;
                }
            }
            partial
        },
        |partial| {
            for (a, &v) in sums.iter_mut().zip(&partial) {
                *a += v;
            }
        },
    );
    Tensor::from_vec(&[k, b], sums)
}

/// Per-bucket bucket sums over a prebuilt [`BucketIndex`] — bitwise
/// identical to [`bucket_sums_with`].
///
/// Parallelism here is over *buckets* (each S row is a pre-assigned
/// disjoint slot; no reduction at all), which wins when `b` is small and
/// the `k × b` partial tables of the chunked path would dominate — the
/// matvec path uses it. To stay on the shared numeric contract it
/// reproduces the chunk grouping exactly: within a bucket, channels are
/// summed into a fresh accumulator per [`CHANNEL_CHUNK`] span and the span
/// sums are added in order — the same expression tree as the chunked
/// fold (skipped spans contribute `+0.0`, which is bitwise inert because
/// a span partial that starts from `+0.0` can never be `-0.0`).
pub fn bucket_sums_indexed(x: &Tensor, index: &BucketIndex, exec: ExecConfig) -> Tensor {
    let (n, b) = (x.rows(), x.cols());
    let k = index.k();
    // Hard assert (not debug): a stale index would silently drop channels
    // in release builds; the chunked sibling fails loudly, so must this.
    assert_eq!(index.channels.len(), n, "index built for a different channel count");
    let mut sums = vec![0.0f32; k * b];
    if n == 0 || b == 0 || k == 0 {
        return Tensor::from_vec(&[k, b], sums);
    }
    crate::obs::prof::counters::bucket_call(n.div_ceil(CHANNEL_CHUNK) as u64);
    let exec = if n * b < MIN_PARALLEL_ELEMS { ExecConfig::serial() } else { exec };
    // One band row per bucket; a modest rows_per_chunk keeps uneven bucket
    // sizes from serializing on one worker.
    exec::for_row_bands(exec, &mut sums, k, b, 4, |l0, band| {
        let mut span = vec![0.0f32; b];
        for (li, row) in band.chunks_exact_mut(b).enumerate() {
            let chans = index.bucket(l0 + li);
            let mut i = 0;
            while i < chans.len() {
                let chunk_id = chans[i] as usize / CHANNEL_CHUNK;
                span.fill(0.0);
                while i < chans.len() && chans[i] as usize / CHANNEL_CHUNK == chunk_id {
                    for (a, &v) in span.iter_mut().zip(x.row(chans[i] as usize)) {
                        *a += v;
                    }
                    i += 1;
                }
                for (r, &s) in row.iter_mut().zip(&span) {
                    *r += s;
                }
            }
        }
    });
    Tensor::from_vec(&[k, b], sums)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Labels with a configurable number of guaranteed-empty trailing
    /// buckets.
    fn labels_for(n: usize, k: usize, empty: usize, rng: &mut Rng) -> Vec<u32> {
        let live = (k - empty).max(1);
        (0..n).map(|_| rng.below(live) as u32).collect()
    }

    #[test]
    fn index_structure_is_sound() {
        let labels = vec![2u32, 0, 2, 1, 0, 2];
        let idx = BucketIndex::new(&labels, 4);
        assert_eq!(idx.k(), 4);
        assert_eq!(idx.bucket(0), &[1, 4]);
        assert_eq!(idx.bucket(1), &[3]);
        assert_eq!(idx.bucket(2), &[0, 2, 5]);
        assert_eq!(idx.bucket(3), &[] as &[u32]);
        assert_eq!(idx.empty_buckets(), 1);
    }

    #[test]
    fn sums_match_f64_reference() {
        let mut rng = Rng::new(700);
        let (n, b, k) = (3 * CHANNEL_CHUNK + 17, 9, 6);
        let x = Tensor::randn(&[n, b], &mut rng);
        let labels = labels_for(n, k, 1, &mut rng);
        let s = bucket_sums(&x, &labels, k);
        for l in 0..k {
            for c in 0..b {
                let want: f64 = (0..n)
                    .filter(|&j| labels[j] as usize == l)
                    .map(|j| x.at(j, c) as f64)
                    .sum();
                assert!(
                    (s.at(l, c) as f64 - want).abs() < 1e-3,
                    "S[{l}][{c}] = {} vs {want}",
                    s.at(l, c)
                );
            }
        }
        // The guaranteed-empty bucket is exactly zero.
        assert!(s.row(k - 1).iter().all(|&v| v == 0.0));
    }

    /// The two implementations share one numeric contract: chunked partial
    /// tables folded in chunk order == per-bucket CSR spans — bitwise,
    /// including adversarial magnitudes where any grouping drift would
    /// change low bits.
    #[test]
    fn chunked_and_indexed_bitwise_identical() {
        prop::check(
            "bucket sums: chunked == CSR",
            701,
            24,
            |r| {
                let n = 1 + r.below(3 * CHANNEL_CHUNK + 40);
                let b = 1 + r.below(12);
                let k = 1 + r.below(9);
                let empty = r.below(k.min(3));
                let mut x = Tensor::randn(&[n, b], r);
                // Mixed magnitudes: cancellation exposes grouping drift.
                for (i, v) in x.data_mut().iter_mut().enumerate() {
                    if i % 7 == 0 {
                        *v *= 1e8;
                    } else if i % 11 == 0 {
                        *v *= 1e-8;
                    }
                }
                (x, labels_for(n, k, empty, r), k)
            },
            |(x, labels, k)| {
                let idx = BucketIndex::new(labels, *k);
                let chunked = bucket_sums_with(x, labels, *k, ExecConfig::serial());
                let indexed = bucket_sums_indexed(x, &idx, ExecConfig::serial());
                if bits(&chunked) == bits(&indexed) {
                    Ok(())
                } else {
                    Err("chunked and CSR bucket sums diverge".into())
                }
            },
        );
    }

    #[test]
    fn thread_parity_bitwise_both_paths() {
        let mut rng = Rng::new(702);
        // Ragged channel count (partial final chunk) and enough elements to
        // clear the serial-fallback threshold so parallelism actually runs.
        let (n, b, k) = (5 * CHANNEL_CHUNK + 31, 120, 7);
        let x = Tensor::randn(&[n, b], &mut rng);
        let labels = labels_for(n, k, 2, &mut rng);
        let idx = BucketIndex::new(&labels, k);
        assert!(n * b >= super::MIN_PARALLEL_ELEMS);
        let base_c = bits(&bucket_sums_with(&x, &labels, k, ExecConfig::serial()));
        let base_i = bits(&bucket_sums_indexed(&x, &idx, ExecConfig::serial()));
        assert_eq!(base_c, base_i);
        for threads in [2, 4, 8] {
            let cfg = ExecConfig::with_threads(threads);
            let chunked = bucket_sums_with(&x, &labels, k, cfg);
            assert_eq!(bits(&chunked), base_c, "chunked, {threads} threads");
            let indexed = bucket_sums_indexed(&x, &idx, cfg);
            assert_eq!(bits(&indexed), base_i, "indexed, {threads} threads");
        }
    }

    #[test]
    fn degenerate_shapes() {
        let empty = Tensor::zeros(&[0, 4]);
        let s = bucket_sums(&empty, &[], 3);
        assert_eq!(s.shape(), &[3, 4]);
        assert!(s.data().iter().all(|&v| v == 0.0));
        let one = Tensor::from_vec(&[2, 1], vec![1.5, 2.5]);
        let s1 = bucket_sums(&one, &[0, 0], 1);
        assert_eq!(s1.shape(), &[1, 1]);
        assert_eq!(s1.data(), &[4.0]);
        let wide = Tensor::zeros(&[3, 0]);
        assert_eq!(bucket_sums(&wide, &[0, 1, 0], 2).shape(), &[2, 0]);
    }
}
