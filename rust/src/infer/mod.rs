//! Compressed-domain inference: serve products of SWSC-compressed weights
//! without ever reconstructing the dense matrix.
//!
//! Every consumer of a [`crate::compress::CompressedMatrix`] used to call
//! `reconstruct()` — an `m × n` materialization plus a full dense GEMM per
//! product. But the paper's storage layout admits a cheaper product
//! directly, the same operational win DeltaLLM (shared weights + low-rank
//! deltas) and head-wise weight sharing exploit at inference time.
//!
//! ## The compressed-domain product
//!
//! With `W ≈ R[labels] + A·B` (`R`: `m × k` representatives as columns,
//! `A`: `m × r`, `B`: `r × n`, `labels[j] < k` per channel):
//!
//! ```text
//! Y = W·X = R·S + A·(B·X)          S[l] = Σ_{j : labels[j] = l} x[j]
//! ```
//!
//! because every channel in cluster `l` multiplies the *same*
//! representative column — so the `n` per-channel multiplies collapse to
//! one multiply against the bucket sum `S` (`k × b`, see
//! [`bucket_sums_with`]). The transposed orientations replace the bucket
//! sum with a label *gather*:
//!
//! ```text
//! Wᵀ·X = (Rᵀ·X)[labels] + Bᵀ·(Aᵀ·X)        (rows gathered by label)
//! X·W  = (X·R)[:, labels] + (X·A)·B        (the L1 decode_matmul form)
//! ```
//!
//! ## Cost model (multiply-adds per product, batch width `b`)
//!
//! ```text
//! dense:       m·n·r (reconstruct A·B)  +  m·n·b (GEMM)  + m·n gather
//! compressed:  n·b (bucket sums / gather) + m·k·b + r·n·b + m·r·b
//! ```
//!
//! At the paper's operating points (`k ≤ n/8`, `r ≤ 32 ≪ n`) the
//! compressed product is a 4–8× flop reduction at `b = n = 512` — the
//! `compressed_vs_dense_*` rows in `benches/hotpath.rs` gate ≥ 1.5×
//! wall-clock on exactly that regime. [`CompressedLinear`] amortizes
//! everything reusable: the label→bucket CSR index is built once, and the
//! packed GEMM panels of `R`/`A`/`B` pack lazily per orientation on first
//! use and are reused by every later request — so a call pays only its
//! own activation packing, and a process that serves one orientation
//! holds one orientation's panels.
//!
//! ## Numeric contract
//!
//! All three GEMMs ride the shared packed engine (`tensor::gemm`) and the
//! bucket sums ride the deterministic executor with fixed
//! [`CHANNEL_CHUNK`] boundaries — every entry point is **bit-identical at
//! any `SWSC_THREADS`**, extending the PR 1–3 parity contract to serving.
//! Against the dense `reconstruct()` route the gather orientations are
//! bitwise equal at `r = 0` (same single-accumulator dots); everywhere
//! else the accumulation order necessarily differs (cluster-grouped vs
//! column-order sums) and results agree to the documented ULP bound — the
//! decision is recorded in `tests/fixtures/README.md` and pinned by
//! `tests/infer_compressed.rs`.
//!
//! [`CompressedModel`] lifts this to a whole `.swsc` file and is wired
//! into `coordinator::EvalService` behind the [`InferMode`] flag
//! (`ServiceConfig::infer_mode`): linear requests are served from the
//! compressed domain, with [`InferMode::Reconstructed`] kept as the
//! dense oracle/baseline — mirroring `ExecBackend::SpawnPerCall` and
//! `GemmKernel::Blocked`. (The PJRT `fwd_eval` executable still takes
//! dense parameter literals, so perplexity evaluation restores host-side;
//! the accelerator-side analog is the L1 `decode_matmul` Pallas kernel.)

//!
//! ## Precision
//!
//! PR 6 adds a second axis: [`Precision::Int8`] serves the `apply`
//! orientation from grouped-int8 factors through [`QuantizedLinear`],
//! whose GEMM panels hold the quantization *codes* — dequantization
//! happens in-register inside the microkernel, so the factors are never
//! expanded to f32 and the shared panel cache is ≈4× smaller.
//! [`Precision::F32`] (the default) is the oracle, and the quantized
//! path is bitwise equal to dequantize-then-f32 at any thread count;
//! only against the pre-quantization weights is there a (documented,
//! grid-step) tolerance.

//!
//! ## The whole model (PR 7)
//!
//! [`CompressedForward`] chains these operators through the GPT-style
//! decoder end to end — attention, MLP, embeddings, tied LM head — so a
//! forward pass never materializes a weight matrix, closing the PR 4
//! headroom note above. It is exposed as a start/step/finish state
//! machine at **layer granularity**, which is what lets the serving
//! layer re-form batches between layers (continuous batching) while
//! staying bitwise equal to solo execution — see `forward.rs`'s module
//! docs for the argument and `tests/serve_forward.rs` for the pins.

mod bucket;
mod forward;
mod linear;
mod model;
mod quantized;

pub use bucket::{bucket_sums, bucket_sums_indexed, bucket_sums_with, BucketIndex, CHANNEL_CHUNK};
pub use forward::{CompressedForward, ForwardState};
pub use linear::CompressedLinear;
pub use model::{CompressedModel, InferMode, Precision};
pub use quantized::QuantizedLinear;
