//! Compressed-domain transformer forward pass.
//!
//! [`CompressedForward`] chains [`CompressedModel::apply_with`] through
//! the GPT-style decoder blocks of [`crate::model::param_specs`] —
//! attention (wq/wk/wv/wo) and MLP (w1/w2) all served straight from the
//! factored form `R[labels] + A·B` (or its int8 double-compressed twin),
//! with **no weight matrix ever reconstructed**. Embedding lookups
//! reconstruct single rows on demand ([`CompressedLinear::row_into`]);
//! the tied LM head reuses `embed.tok` through the bucket-sum `matmul`
//! orientation. Layer norms, biases, GELU, softmax, and the causal
//! attention mixing are per-token / per-request scalar f32 loops.
//!
//! ## The layer-boundary batching contract
//!
//! The pass is exposed as an explicit state machine so a scheduler can
//! re-form batches **between layers** (continuous batching,
//! `serve::Coalescer`):
//!
//! - [`CompressedForward::start`] embeds one request's tokens into a
//!   [`ForwardState`] (`[t, d_model]` activations, layer counter 0);
//! - [`CompressedForward::step_group`] advances any set of states that
//!   sit at the *same* layer by exactly one decoder block, stacking
//!   their token rows into one activation matrix per linear op;
//! - [`CompressedForward::finish`] turns a fully stepped state into
//!   `[t, vocab]` logits.
//!
//! Batched equals solo **bitwise**, at any `SWSC_THREADS` and any group
//! composition, because every cross-request op is an `apply` — and
//! `apply` is row-independent: each output row is a single-register
//! increasing-k dot over that row's own activations (the crate-wide
//! kernel policy, pinned by
//! `tests/serve_batched.rs::prop_apply_is_row_independent_bitwise`).
//! Everything between the applies touches one token row (layer norm,
//! bias, GELU) or one request's own rows (attention), so which requests
//! share a group — and when they join or leave — is pure scheduling,
//! like `SWSC_THREADS`. `tests/serve_forward.rs` pins this end to end.
//!
//! ## Observability
//!
//! This module carries **no instrumentation**: tracing and per-layer
//! timing live entirely in the caller (`serve::Coalescer` emits one
//! `layer_step` span per request per `step_group` call via
//! [`crate::obs::TraceSink`]). [`ForwardState::layer`] and
//! [`ForwardState::tokens`] are the labeling surface the coalescer
//! reads; keeping the clock out of this module is what makes the
//! traced-vs-untraced bitwise parity invariant
//! (`tests/obs_trace.rs`) trivially auditable — there is nothing here
//! a timing read could perturb.
//!
//! [`CompressedLinear::row_into`]: super::CompressedLinear::row_into

use super::model::CompressedModel;
use crate::exec::{self, ExecConfig};
use crate::model::{param_specs, ModelConfig};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::Arc;

/// One in-flight request's activations plus its layer cursor.
///
/// Created by [`CompressedForward::start`], advanced by
/// [`CompressedForward::step_group`], consumed by
/// [`CompressedForward::finish`].
pub struct ForwardState {
    /// `[t, d_model]` activations, one row per token position.
    x: Tensor,
    /// Next decoder block to run; `n_layers` ⇒ ready to finish.
    layer: usize,
}

impl ForwardState {
    /// Next decoder block this state will run.
    pub fn layer(&self) -> usize {
        self.layer
    }

    /// Token positions (activation rows) in this request.
    pub fn tokens(&self) -> usize {
        self.x.rows()
    }
}

/// A whole transformer served from compressed weights — see the module
/// docs for the state-machine surface and the batching contract.
pub struct CompressedForward {
    model: Arc<CompressedModel>,
    cfg: ModelConfig,
}

impl CompressedForward {
    /// Bind a model to a config, validating up front that every
    /// parameter the pass will touch exists with its canonical shape
    /// (matrices servable through `apply`/`gather_row`, 1-D params
    /// dense) — so a missing or misshapen weight fails at build time,
    /// not mid-request.
    pub fn new(model: Arc<CompressedModel>, cfg: ModelConfig) -> Result<CompressedForward> {
        cfg.validate()?;
        for spec in param_specs(&cfg) {
            if spec.shape.len() == 2 {
                let got = model
                    .shape(&spec.name)
                    .with_context(|| format!("forward needs matrix `{}`", spec.name))?;
                anyhow::ensure!(
                    got == (spec.shape[0], spec.shape[1]),
                    "`{}` is {:?}, config wants {:?}",
                    spec.name,
                    got,
                    spec.shape
                );
            } else {
                let t = model
                    .dense_entry(&spec.name)
                    .with_context(|| format!("forward needs dense param `{}`", spec.name))?;
                anyhow::ensure!(
                    t.shape() == &spec.shape[..],
                    "`{}` is {:?}, config wants {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(CompressedForward { model, cfg })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn model(&self) -> &Arc<CompressedModel> {
        &self.model
    }

    /// Decoder blocks a state must step through before `finish`.
    pub fn n_layers(&self) -> usize {
        self.cfg.n_layers
    }

    fn vec1(&self, name: &str) -> Result<&[f32]> {
        Ok(self
            .model
            .dense_entry(name)
            .with_context(|| format!("dense param `{name}` missing"))?
            .data())
    }

    /// Embed one request: `x[p] = embed.tok[tokens[p]] + embed.pos[p]`.
    /// Both tables go through `gather_row`, so either may itself be
    /// compressed. Per-request and serial — batch-composition free.
    pub fn start(&self, tokens: &[u32]) -> Result<ForwardState> {
        anyhow::ensure!(!tokens.is_empty(), "forward needs at least one token");
        anyhow::ensure!(
            tokens.len() <= self.cfg.seq,
            "request is {} tokens, model seq is {}",
            tokens.len(),
            self.cfg.seq
        );
        let d = self.cfg.d_model;
        let mut x = vec![0.0f32; tokens.len() * d];
        let mut pos = vec![0.0f32; d];
        for (p, &tok) in tokens.iter().enumerate() {
            anyhow::ensure!(
                (tok as usize) < self.cfg.vocab,
                "token {tok} out of range (vocab {})",
                self.cfg.vocab
            );
            let row = &mut x[p * d..(p + 1) * d];
            self.model.gather_row("embed.tok", tok as usize, row)?;
            self.model.gather_row("embed.pos", p, &mut pos)?;
            for (xv, &pv) in row.iter_mut().zip(&pos) {
                *xv += pv;
            }
        }
        Ok(ForwardState { x: Tensor::from_vec(&[tokens.len(), d], x), layer: 0 })
    }

    /// Advance every state in `states` — all at the **same** layer — by
    /// one decoder block. The six linear ops run once over the stacked
    /// token rows of the whole group; everything else is per-row or
    /// per-request. Group composition is invisible in the results (see
    /// module docs).
    pub fn step_group(&self, states: &mut [&mut ForwardState], exec: ExecConfig) -> Result<()> {
        let Some(first) = states.first() else { return Ok(()) };
        let layer = first.layer;
        anyhow::ensure!(
            states.iter().all(|s| s.layer == layer),
            "step_group states must share a layer"
        );
        anyhow::ensure!(
            layer < self.cfg.n_layers,
            "state already stepped past the last layer ({layer})"
        );
        let d = self.cfg.d_model;
        let total: usize = states.iter().map(|s| s.x.rows()).sum();
        let p = format!("layers.{layer}");

        // Attention half: h = ln1(x); q,k,v = h·W; per-request causal
        // mix; x += (mix)·Wo.
        let h = self.stacked_layernorm(states, total, &format!("{p}.ln1"))?;
        let q = self.model.apply_with(&format!("{p}.attn.wq"), &h, exec)?;
        let k = self.model.apply_with(&format!("{p}.attn.wk"), &h, exec)?;
        let v = self.model.apply_with(&format!("{p}.attn.wv"), &h, exec)?;
        let mut mixed = vec![0.0f32; total * d];
        let mut off = 0usize;
        for s in states.iter() {
            let t = s.x.rows();
            let span = off * d..(off + t) * d;
            attention_causal(
                &q.data()[span.clone()],
                &k.data()[span.clone()],
                &v.data()[span.clone()],
                t,
                self.cfg.n_heads,
                d,
                &mut mixed[span],
            );
            off += t;
        }
        let o = self
            .model
            .apply_with(&format!("{p}.attn.wo"), &Tensor::from_vec(&[total, d], mixed), exec)?;
        Self::residual_add(states, o.data(), d);

        // MLP half: h = ln2(x); x += gelu(h·W1 + b1)·W2 + b2.
        let h = self.stacked_layernorm(states, total, &format!("{p}.ln2"))?;
        let mut f = self.model.apply_with(&format!("{p}.mlp.w1"), &h, exec)?;
        let b1 = self.vec1(&format!("{p}.mlp.b1"))?;
        let d_ff = self.cfg.d_ff;
        for row in f.data_mut().chunks_exact_mut(d_ff) {
            for (fv, &bv) in row.iter_mut().zip(b1) {
                *fv = gelu(*fv + bv);
            }
        }
        let mut y = self.model.apply_with(&format!("{p}.mlp.w2"), &f, exec)?;
        let b2 = self.vec1(&format!("{p}.mlp.b2"))?;
        for row in y.data_mut().chunks_exact_mut(d) {
            for (yv, &bv) in row.iter_mut().zip(b2) {
                *yv += bv;
            }
        }
        Self::residual_add(states, y.data(), d);

        for s in states.iter_mut() {
            s.layer += 1;
        }
        Ok(())
    }

    /// Stack `layernorm(x_row)` of every state's rows into one
    /// `[total, d]` activation matrix, in state order.
    fn stacked_layernorm(
        &self,
        states: &[&mut ForwardState],
        total: usize,
        prefix: &str,
    ) -> Result<Tensor> {
        let d = self.cfg.d_model;
        let g = self.vec1(&format!("{prefix}.g"))?;
        let b = self.vec1(&format!("{prefix}.b"))?;
        let mut h = vec![0.0f32; total * d];
        let mut off = 0usize;
        for s in states.iter() {
            for t in 0..s.x.rows() {
                layernorm_row(s.x.row(t), g, b, &mut h[off * d..(off + 1) * d]);
                off += 1;
            }
        }
        Ok(Tensor::from_vec(&[total, d], h))
    }

    /// `state.x += delta` for each state's slice of the stacked rows.
    fn residual_add(states: &mut [&mut ForwardState], delta: &[f32], d: usize) {
        let mut off = 0usize;
        for s in states.iter_mut() {
            for t in 0..s.x.rows() {
                for (xv, &dv) in s.x.row_mut(t).iter_mut().zip(&delta[off * d..(off + 1) * d]) {
                    *xv += dv;
                }
                off += 1;
            }
        }
    }

    /// Final layer norm + tied LM head: `[t, vocab]` logits. Per-request
    /// — never batched across requests, so group composition cannot
    /// touch it. The tied head reuses `embed.tok` through the bucket-sum
    /// `matmul` orientation (logitsᵀ = `embed.tok · hᵀ`).
    pub fn finish(&self, state: &ForwardState, exec: ExecConfig) -> Result<Tensor> {
        anyhow::ensure!(
            state.layer == self.cfg.n_layers,
            "finish at layer {} of {}",
            state.layer,
            self.cfg.n_layers
        );
        let d = self.cfg.d_model;
        let t = state.x.rows();
        let g = self.vec1("final_ln.g")?;
        let b = self.vec1("final_ln.b")?;
        let mut h = vec![0.0f32; t * d];
        for i in 0..t {
            layernorm_row(state.x.row(i), g, b, &mut h[i * d..(i + 1) * d]);
        }
        let ht = Tensor::from_vec(&[t, d], h).transpose_with(exec);
        let logits_t = self.model.matmul_with("embed.tok", &ht, exec)?;
        Ok(logits_t.transpose_with(exec))
    }

    /// Whole pass for one request on the process-wide thread config.
    pub fn forward(&self, tokens: &[u32]) -> Result<Tensor> {
        self.forward_with(tokens, exec::global())
    }

    /// Whole pass for one request — the solo oracle the batched
    /// scheduler is measured against (bitwise).
    pub fn forward_with(&self, tokens: &[u32], exec: ExecConfig) -> Result<Tensor> {
        let mut state = self.start(tokens)?;
        while state.layer < self.cfg.n_layers {
            self.step_group(&mut [&mut state], exec)?;
        }
        self.finish(&state, exec)
    }

    /// Summed negative log-likelihood of `targets` under the compressed
    /// forward of `inputs`, plus the token count — the perplexity
    /// building block (`exp(Σ nll / Σ tokens)`). Log-sum-exp in f64.
    pub fn nll_window(
        &self,
        inputs: &[u32],
        targets: &[u32],
        exec: ExecConfig,
    ) -> Result<(f64, usize)> {
        anyhow::ensure!(
            inputs.len() == targets.len(),
            "inputs ({}) and targets ({}) must align",
            inputs.len(),
            targets.len()
        );
        let logits = self.forward_with(inputs, exec)?;
        let mut nll = 0.0f64;
        for (i, &tgt) in targets.iter().enumerate() {
            anyhow::ensure!(
                (tgt as usize) < self.cfg.vocab,
                "target {tgt} out of range (vocab {})",
                self.cfg.vocab
            );
            let row = logits.row(i);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v)) as f64;
            let sum: f64 = row.iter().map(|&v| (v as f64 - mx).exp()).sum();
            nll += mx + sum.ln() - row[tgt as usize] as f64;
        }
        Ok((nll, targets.len()))
    }
}

/// `out = (x - mean) / sqrt(var + 1e-5) * g + b` over one token row.
/// Plain serial f32 — per-row, so batching can never reorder it.
fn layernorm_row(x: &[f32], g: &[f32], b: &[f32], out: &mut [f32]) {
    let n = x.len();
    let mut mean = 0.0f32;
    for &v in x {
        mean += v;
    }
    let mean = mean / n as f32;
    let mut var = 0.0f32;
    for &v in x {
        let dv = v - mean;
        var += dv * dv;
    }
    let inv = 1.0 / (var / n as f32 + 1e-5).sqrt();
    for i in 0..n {
        out[i] = (x[i] - mean) * inv * g[i] + b[i];
    }
}

/// GELU, tanh approximation (the GPT-2 convention).
fn gelu(v: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/π)
    0.5 * v * (1.0 + (C * (v + 0.044_715 * v * v * v)).tanh())
}

/// Causal multi-head self-attention over one request's `t` rows of
/// stacked `q`/`k`/`v` (`t × d` each, row-major). Scores are
/// `q·k / sqrt(head_dim)` accumulated in increasing channel order,
/// softmax is max-subtracted, and the value mix accumulates in
/// increasing position order — all single-register serial f32, touching
/// only this request's rows.
fn attention_causal(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    n_heads: usize,
    d: usize,
    out: &mut [f32],
) {
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut w = vec![0.0f32; t];
    for h in 0..n_heads {
        let ho = h * hd;
        for i in 0..t {
            for (j, wj) in w.iter_mut().enumerate().take(i + 1) {
                let mut dot = 0.0f32;
                for dd in 0..hd {
                    dot += q[i * d + ho + dd] * k[j * d + ho + dd];
                }
                *wj = dot * scale;
            }
            let mut mx = w[0];
            for &wj in &w[1..=i] {
                if wj > mx {
                    mx = wj;
                }
            }
            let mut sum = 0.0f32;
            for wj in w.iter_mut().take(i + 1) {
                *wj = (*wj - mx).exp();
                sum += *wj;
            }
            let inv = 1.0 / sum;
            for dd in 0..hd {
                let mut acc = 0.0f32;
                for (j, &wj) in w.iter().enumerate().take(i + 1) {
                    acc += wj * inv * v[j * d + ho + dd];
                }
                out[i * d + ho + dd] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::infer::InferMode;
    use crate::io::SwscFile;
    use crate::model::init_params;
    use crate::util::prop::assert_close;
    use crate::util::rng::Rng;

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Compress a tiny model's checkpoint into a servable file: 2-D
    /// params with ≥ 16 columns become compressed entries, the rest pass
    /// through dense.
    fn tiny_file(seed: u64) -> (SwscFile, ModelConfig) {
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, seed);
        let mut file = SwscFile::new();
        for spec in param_specs(&cfg) {
            let t = ck.get(&spec.name).unwrap().clone();
            if spec.shape.len() == 2 && spec.shape[1] >= 16 {
                file.compressed
                    .insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
            } else {
                file.dense.insert(spec.name.clone(), t);
            }
        }
        (file, cfg)
    }

    fn forward(seed: u64, mode: InferMode) -> CompressedForward {
        let (file, cfg) = tiny_file(seed);
        let model = Arc::new(CompressedModel::from_file(&file, mode));
        CompressedForward::new(model, cfg).unwrap()
    }

    #[test]
    fn shapes_and_validation() {
        let fwd = forward(40, InferMode::Compressed);
        let logits = fwd.forward(&[1, 2, 3]).unwrap();
        assert_eq!(logits.shape(), &[3, fwd.config().vocab]);
        assert!(fwd.forward(&[]).is_err(), "empty request");
        assert!(fwd.forward(&[9999]).is_err(), "token out of vocab");
        assert!(fwd.forward(&vec![0; fwd.config().seq + 1]).is_err(), "over seq");
        // A file missing a weight fails at build, not mid-request.
        let (mut file, cfg) = tiny_file(40);
        file.dense.remove("final_ln.g");
        let model = Arc::new(CompressedModel::from_file(&file, InferMode::Compressed));
        assert!(CompressedForward::new(model, cfg).is_err());
    }

    /// Compressed vs the reconstructed-dense oracle: same forward code,
    /// same *effective* weights (`Reconstructed` materializes
    /// `R[labels] + A·B` from the identical factors) — so the logits
    /// agree to accumulation-order rounding (the bucket-sum LM head and
    /// `r > 0` products regroup sums; see tests/fixtures/README.md),
    /// NOT to some loose compression tolerance.
    #[test]
    fn compressed_tracks_reconstructed_oracle() {
        let comp = forward(41, InferMode::Compressed);
        let reco = forward(41, InferMode::Reconstructed);
        let toks = [3u32, 1, 4, 1, 5, 9, 2, 6];
        let a = comp.forward(&toks).unwrap();
        let b = reco.forward(&toks).unwrap();
        assert_eq!(a.shape(), b.shape());
        assert_close(a.data(), b.data(), 1e-3, 1e-3).unwrap();
    }

    /// The layer-boundary batching contract at the state-machine level:
    /// stepping requests as one group is bitwise equal to stepping each
    /// alone, at every thread config.
    #[test]
    fn step_group_bitwise_equals_solo() {
        let fwd = forward(42, InferMode::Compressed);
        let reqs: Vec<Vec<u32>> = vec![vec![7, 8, 9], vec![1], vec![2, 3, 4, 5, 6, 7, 8]];
        let solo: Vec<Tensor> =
            reqs.iter().map(|t| fwd.forward_with(t, ExecConfig::serial()).unwrap()).collect();
        for threads in [1usize, 2, 4] {
            let exec = ExecConfig::with_threads(threads);
            let mut states: Vec<ForwardState> =
                reqs.iter().map(|t| fwd.start(t).unwrap()).collect();
            for _ in 0..fwd.n_layers() {
                let mut group: Vec<&mut ForwardState> = states.iter_mut().collect();
                fwd.step_group(&mut group, exec).unwrap();
            }
            for (st, want) in states.iter().zip(&solo) {
                let got = fwd.finish(st, exec).unwrap();
                assert_eq!(bits(&got), bits(want), "grouped != solo at {threads} threads");
            }
        }
    }

    #[test]
    fn step_group_rejects_mixed_layers() {
        let fwd = forward(43, InferMode::Compressed);
        let mut a = fwd.start(&[1, 2]).unwrap();
        let mut b = fwd.start(&[3]).unwrap();
        fwd.step_group(&mut [&mut a], ExecConfig::serial()).unwrap();
        assert_eq!(a.layer(), 1);
        assert!(fwd.step_group(&mut [&mut a, &mut b], ExecConfig::serial()).is_err());
        assert!(fwd.finish(&b, ExecConfig::serial()).is_err(), "finish before last layer");
    }

    /// NLL is finite, positive, and near uniform for a fresh init (the
    /// logits are near zero ⇒ nll/token ≈ ln(vocab)).
    #[test]
    fn nll_window_is_sane() {
        let fwd = forward(44, InferMode::Compressed);
        let inputs = [1u32, 2, 3, 4];
        let targets = [2u32, 3, 4, 5];
        let (nll, n) = fwd.nll_window(&inputs, &targets, ExecConfig::serial()).unwrap();
        assert_eq!(n, 4);
        let per_tok = nll / n as f64;
        let uniform = (fwd.config().vocab as f64).ln();
        assert!(
            (per_tok - uniform).abs() < 1.0,
            "fresh-init nll/token {per_tok} should be near ln(vocab) = {uniform}"
        );
        assert!(fwd.nll_window(&[1, 2], &[1], ExecConfig::serial()).is_err());
    }

    /// The scalar helpers behave: layernorm normalizes, gelu brackets.
    #[test]
    fn scalar_helpers() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let g = [1.0f32; 4];
        let b = [0.0f32; 4];
        let mut out = [0.0f32; 4];
        layernorm_row(&x, &g, &b, &mut out);
        let mean: f32 = out.iter().sum::<f32>() / 4.0;
        let var: f32 = out.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5 && (var - 1.0).abs() < 1e-3);
        assert!(gelu(0.0) == 0.0 && gelu(10.0) > 9.99 && gelu(-10.0).abs() < 1e-3);
        assert_close(&[gelu(1.0)], &[0.841_192], 1e-4, 1e-4).unwrap();
    }
}
