//! The SWSC compression pipeline — the paper's primary contribution.
//!
//! Per weight matrix: channel K-Means → mean representatives → error matrix
//! → truncated SVD compensation → packed [`CompressedMatrix`]. Model-level
//! planning (which matrices, what budgets) lives in [`plan`], quality
//! metrics in [`stats`].

pub mod plan;
pub mod stats;
mod swsc;

pub use plan::{
    kmeans_method_for_width, CompressionPlan, MatrixPlan, ProjectorSet, MINIBATCH_MIN_CHANNELS,
};
pub use stats::{matrix_stats, CompressionReport, MatrixStats, MatrixTelemetry};
pub use swsc::{
    compress_matrix, compress_matrix_traced, CompressedMatrix, QuantizedMatrix, SvdBackend,
    SwscConfig,
};
