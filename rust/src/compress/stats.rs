//! Quality metrics for compressed matrices (drives Fig-2 motivation bench
//! and the per-matrix report).

use super::swsc::CompressedMatrix;
use crate::tensor::Tensor;

/// Per-matrix compression quality summary.
#[derive(Debug, Clone)]
pub struct MatrixStats {
    pub name: String,
    pub shape: (usize, usize),
    pub clusters: usize,
    pub rank: usize,
    pub avg_bits: f64,
    pub compression_ratio: f64,
    /// MSE of the cluster-only approximation W' (paper Fig. 2).
    pub mse_uncompensated: f64,
    /// MSE after SVD compensation W' + A·B (paper Fig. 3).
    pub mse_compensated: f64,
    /// Fraction of the error energy removed by the compensation step.
    pub error_energy_removed: f64,
}

/// Compute the quality stats of `c` against the original `w`.
pub fn matrix_stats(name: &str, w: &Tensor, c: &CompressedMatrix) -> MatrixStats {
    let mse_un = c.reconstruct_uncompensated().mse(w);
    let mse_comp = c.reconstruct().mse(w);
    let removed = if mse_un > 0.0 { 1.0 - mse_comp / mse_un } else { 0.0 };
    MatrixStats {
        name: name.to_string(),
        shape: c.shape,
        clusters: c.k(),
        rank: c.rank(),
        avg_bits: c.avg_bits(),
        compression_ratio: c.compression_ratio(),
        mse_uncompensated: mse_un,
        mse_compensated: mse_comp,
        error_energy_removed: removed.clamp(0.0, 1.0),
    }
}

impl std::fmt::Display for MatrixStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<24} {:>4}x{:<4} k={:<4} r={:<3} {:>5.2} bits {:>6.2}x  mse {:.3e} -> {:.3e} ({:>4.1}% removed)",
            self.name,
            self.shape.0,
            self.shape.1,
            self.clusters,
            self.rank,
            self.avg_bits,
            self.compression_ratio,
            self.mse_uncompensated,
            self.mse_compensated,
            100.0 * self.error_energy_removed,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::rng::Rng;

    #[test]
    fn stats_fields_consistent() {
        let mut rng = Rng::new(101);
        let w = Tensor::randn(&[32, 32], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(4, 4));
        let s = matrix_stats("test.w", &w, &c);
        assert_eq!(s.clusters, 4);
        assert_eq!(s.rank, 4);
        assert!(s.mse_compensated <= s.mse_uncompensated);
        assert!(s.error_energy_removed >= 0.0 && s.error_energy_removed <= 1.0);
        assert!(s.compression_ratio > 1.0);
        let rendered = format!("{s}");
        assert!(rendered.contains("test.w"));
    }
}
