//! Quality metrics for compressed matrices (drives Fig-2 motivation bench
//! and the per-matrix report), plus the compression-quality telemetry
//! artifact (PR 10): per-matrix k-means inertia traces, error-spectrum
//! data from the compensation SVD, and quantization grid error, bundled
//! into a [`CompressionReport`] JSON file (`swsc compress --telemetry`).
//!
//! The report is the **declared input format for the spectral rank
//! allocator** (ROADMAP, arxiv 2603.17917): the allocator reads each
//! matrix's `spectrum` / `error_fro2` and re-budgets ranks across
//! matrices, so these fields are versioned and their values are
//! deterministic functions of (weights, seed, config) — byte-stable
//! across reruns and golden-testable.

use super::swsc::CompressedMatrix;
use crate::obs::json_escape;
use crate::tensor::Tensor;

/// Per-matrix compression quality summary.
#[derive(Debug, Clone)]
pub struct MatrixStats {
    pub name: String,
    pub shape: (usize, usize),
    pub clusters: usize,
    pub rank: usize,
    pub avg_bits: f64,
    pub compression_ratio: f64,
    /// MSE of the cluster-only approximation W' (paper Fig. 2).
    pub mse_uncompensated: f64,
    /// MSE after SVD compensation W' + A·B (paper Fig. 3).
    pub mse_compensated: f64,
    /// Fraction of the error energy removed by the compensation step.
    pub error_energy_removed: f64,
}

/// Compute the quality stats of `c` against the original `w`.
pub fn matrix_stats(name: &str, w: &Tensor, c: &CompressedMatrix) -> MatrixStats {
    let mse_un = c.reconstruct_uncompensated().mse(w);
    let mse_comp = c.reconstruct().mse(w);
    let removed = if mse_un > 0.0 { 1.0 - mse_comp / mse_un } else { 0.0 };
    MatrixStats {
        name: name.to_string(),
        shape: c.shape,
        clusters: c.k(),
        rank: c.rank(),
        avg_bits: c.avg_bits(),
        compression_ratio: c.compression_ratio(),
        mse_uncompensated: mse_un,
        mse_compensated: mse_comp,
        error_energy_removed: removed.clamp(0.0, 1.0),
    }
}

impl std::fmt::Display for MatrixStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<24} {:>4}x{:<4} k={:<4} r={:<3} {:>5.2} bits {:>6.2}x  mse {:.3e} -> {:.3e} ({:>4.1}% removed)",
            self.name,
            self.shape.0,
            self.shape.1,
            self.clusters,
            self.rank,
            self.avg_bits,
            self.compression_ratio,
            self.mse_uncompensated,
            self.mse_compensated,
            100.0 * self.error_energy_removed,
        )
    }
}

/// Per-matrix quality telemetry captured *inside* the pipeline — values
/// the quality stats above can't see from the outside (per-iteration
/// inertia, the error singular spectrum) plus the quantization grid
/// error. Every field is a pure function of (weights, seed, config);
/// wall-clock never enters.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MatrixTelemetry {
    pub name: String,
    /// Original `(m, n)`.
    pub shape: (usize, usize),
    /// Clusters actually used (`k`, after the `k ≤ n` cap).
    pub clusters: usize,
    /// Compensation rank actually used (after the `r ≤ min(m,n)` cap).
    pub rank: usize,
    /// K-means iterations (or mini-batch steps) run.
    pub kmeans_iterations: usize,
    /// Final full-data inertia.
    pub inertia: f64,
    /// Inertia after each iteration (see
    /// [`crate::kmeans::KMeansResult::inertia_trace`]).
    pub inertia_trace: Vec<f64>,
    /// Retained singular values of the error matrix `W − W'`,
    /// descending — the rank allocator's primary input.
    pub spectrum: Vec<f32>,
    /// `‖W − W'‖²_F`: total error energy before compensation.
    pub error_fro2: f64,
    /// Fraction of `error_fro2` captured by the retained rank
    /// (`Σ σ_i² / error_fro2`, clamped to 1).
    pub compensation_energy: f64,
    /// Worst absolute int8 grid error across the quantized payloads
    /// (0 until the quantize step runs, and for f32 output).
    pub grid_error_max: f64,
    /// Mean squared int8 grid error across the quantized payloads.
    pub grid_error_mse: f64,
}

impl MatrixTelemetry {
    /// One JSON object, hand-rolled (no serde in the vendored set).
    /// Floats use Rust's shortest-round-trip `Display` — deterministic,
    /// so the whole report is byte-stable for a pinned seed.
    pub fn to_json(&self) -> String {
        let floats = |v: &[f64]| {
            let items: Vec<String> = v.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", items.join(","))
        };
        let floats32 = |v: &[f32]| {
            let items: Vec<String> = v.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", items.join(","))
        };
        format!(
            "{{\"name\":\"{}\",\"rows\":{},\"cols\":{},\"clusters\":{},\"rank\":{},\
             \"kmeans_iterations\":{},\"inertia\":{},\"inertia_trace\":{},\
             \"spectrum\":{},\"error_fro2\":{},\"compensation_energy\":{},\
             \"grid_error_max\":{},\"grid_error_mse\":{}}}",
            json_escape(&self.name),
            self.shape.0,
            self.shape.1,
            self.clusters,
            self.rank,
            self.kmeans_iterations,
            self.inertia,
            floats(&self.inertia_trace),
            floats32(&self.spectrum),
            self.error_fro2,
            self.compensation_energy,
            self.grid_error_max,
            self.grid_error_mse,
        )
    }
}

/// The `--telemetry out.json` artifact: one [`MatrixTelemetry`] per
/// compressed matrix, sorted by name (job completion order is
/// thread-dependent; the artifact is not).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CompressionReport {
    /// The model-level seed the per-matrix seeds derive from.
    pub seed: u64,
    pub matrices: Vec<MatrixTelemetry>,
}

impl CompressionReport {
    /// Sort matrices by name — call once after parallel collection.
    pub fn finalize(&mut self) {
        self.matrices.sort_by(|a, b| a.name.cmp(&b.name));
    }

    /// The versioned report JSON. `version` guards the rank allocator's
    /// parser; bump it on any field change.
    pub fn to_json(&self) -> String {
        let mats: Vec<String> = self.matrices.iter().map(|m| m.to_json()).collect();
        format!(
            "{{\"version\":1,\"seed\":{},\"matrices\":[{}]}}\n",
            self.seed,
            mats.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::rng::Rng;

    #[test]
    fn stats_fields_consistent() {
        let mut rng = Rng::new(101);
        let w = Tensor::randn(&[32, 32], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(4, 4));
        let s = matrix_stats("test.w", &w, &c);
        assert_eq!(s.clusters, 4);
        assert_eq!(s.rank, 4);
        assert!(s.mse_compensated <= s.mse_uncompensated);
        assert!(s.error_energy_removed >= 0.0 && s.error_energy_removed <= 1.0);
        assert!(s.compression_ratio > 1.0);
        let rendered = format!("{s}");
        assert!(rendered.contains("test.w"));
    }

    #[test]
    fn report_json_is_sorted_stable_and_balanced() {
        let mut rep = CompressionReport { seed: 5, ..Default::default() };
        rep.matrices.push(MatrixTelemetry {
            name: "b.w".into(),
            shape: (4, 4),
            inertia_trace: vec![2.0, 1.0],
            spectrum: vec![0.5, 0.25],
            ..Default::default()
        });
        rep.matrices.push(MatrixTelemetry { name: "a.w".into(), ..Default::default() });
        rep.finalize();
        assert_eq!(rep.matrices[0].name, "a.w");
        let json = rep.to_json();
        assert_eq!(json, rep.to_json(), "rerender must be byte-identical");
        assert!(json.starts_with("{\"version\":1,\"seed\":5,"));
        assert!(json.contains("\"inertia_trace\":[2,1]"));
        assert!(json.contains("\"spectrum\":[0.5,0.25]"));
        let (mut depth, mut in_str, mut esc) = (0i64, false, false);
        for c in json.chars() {
            if esc {
                esc = false;
                continue;
            }
            match c {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced report JSON: {json}");
    }
}
