//! Core SWSC transform: cluster channels, share the representative vector,
//! compensate the residual with a truncated SVD (paper §III-B, §III-C).

use super::stats::MatrixTelemetry;
use crate::exec::{self, ExecConfig};
use crate::kmeans::{cluster_channels, KMeansConfig, Representative};
use crate::linalg::{svd_jacobi, svd_randomized_with, truncate, Svd};
use crate::obs::prof::{self, time_it, ProfScope};
use crate::quant::bits::{swsc_avg_bits, swsc_quantized_avg_bits, BitsBreakdown};
use crate::quant::{QuantConfig, QuantizedTensor};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which SVD implementation compensates the error matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SvdBackend {
    /// Exact one-sided Jacobi — O(m·n²); default for n ≤ 512.
    Jacobi,
    /// Randomized subspace iteration — near-optimal, O(m·n·r).
    Randomized,
    /// Pick per matrix: randomized when the retained rank is small
    /// relative to the matrix (`r ≤ min(m,n)/3` and `min(m,n) > 96`),
    /// exact Jacobi otherwise. §Perf in EXPERIMENTS.md measured Jacobi at
    /// 1.3 s vs randomized at 6.9 ms on a 256×256 error matrix with a
    /// 0.25% residual-quality gap — randomized is the right default in
    /// exactly the truncated regime the paper's compensation uses.
    Auto,
}

/// SWSC hyper-parameters for one matrix.
#[derive(Debug, Clone)]
pub struct SwscConfig {
    /// Number of channel clusters `k`.
    pub clusters: usize,
    /// Retained singular-vector rank `r` (0 = no error compensation).
    pub rank: usize,
    /// K-Means settings (init, iters, representative).
    pub kmeans: KMeansConfig,
    /// SVD backend for the error matrix.
    pub svd: SvdBackend,
    /// Seed for the randomized SVD sketch.
    pub seed: u64,
    /// Thread config for the k-means and SVD hot paths. The compressed
    /// output is bit-identical at any thread count, so this only trades
    /// wall-clock (deterministic chunked scheduling in [`crate::exec`]).
    pub exec: ExecConfig,
}

impl Default for SwscConfig {
    fn default() -> Self {
        SwscConfig {
            clusters: 16,
            rank: 8,
            kmeans: KMeansConfig::default(),
            svd: SvdBackend::Auto,
            seed: 0,
            exec: exec::global(),
        }
    }
}

impl SwscConfig {
    /// Convenience: `k` clusters, rank `r`, defaults elsewhere.
    pub fn new(clusters: usize, rank: usize) -> Self {
        SwscConfig { clusters, rank, ..Default::default() }
    }

    /// Mean vs medoid representative (ablation).
    pub fn with_representative(mut self, rep: Representative) -> Self {
        self.kmeans.representative = rep;
        self
    }
}

/// A weight matrix in SWSC compressed form. This is exactly the paper's
/// storage layout: cluster label list + representative vectors + the two
/// low-rank compensation factors `A = U_r Σ^{1/2}`, `B = Σ^{1/2} V_rᵀ`.
#[derive(Debug, Clone)]
pub struct CompressedMatrix {
    /// Original shape `(m, n)`; channels are the `n` columns.
    pub shape: (usize, usize),
    /// Per-channel cluster id (`n` entries, each `< k`).
    pub labels: Vec<u32>,
    /// Representative vectors as columns (`m × k`).
    pub centroids: Tensor,
    /// Left compensation factor `U_r Σ^{1/2}` (`m × r`); empty when r = 0.
    pub factor_a: Tensor,
    /// Right compensation factor `Σ^{1/2} V_rᵀ` (`r × n`); empty when r = 0.
    pub factor_b: Tensor,
}

impl CompressedMatrix {
    pub fn k(&self) -> usize {
        self.centroids.cols()
    }

    pub fn rank(&self) -> usize {
        self.factor_a.cols()
    }

    /// Restore the inference weight `W_new = W' + A·B` (paper Fig. 3).
    /// `W'` is gathered row-major (shared [`crate::kmeans`] helper, unit
    /// stride instead of the old column-by-column `at_mut` walk) and the
    /// low-rank compensation is folded into that buffer with the fused
    /// [`Tensor::matmul_add_assign`] — no separate `m × n` product
    /// allocation, same bits as `W'.add(&A.matmul(&B))`.
    pub fn reconstruct(&self) -> Tensor {
        let mut out = crate::kmeans::gather_representatives(&self.centroids, &self.labels);
        if self.rank() > 0 {
            self.factor_a.matmul_add_assign(&self.factor_b, &mut out);
        }
        out
    }

    /// Restore only the cluster approximation `W'` (no compensation) — used
    /// by the rank ablation.
    pub fn reconstruct_uncompensated(&self) -> Tensor {
        crate::kmeans::gather_representatives(&self.centroids, &self.labels)
    }

    /// Exact storage accounting for this matrix.
    pub fn bits(&self) -> BitsBreakdown {
        let (m, n) = self.shape;
        swsc_avg_bits(m, n, self.k(), self.rank())
    }

    /// Bits per original weight element.
    pub fn avg_bits(&self) -> f64 {
        self.bits().avg_bits
    }

    /// Compression ratio vs fp16 storage of the dense matrix.
    pub fn compression_ratio(&self) -> f64 {
        let (m, n) = self.shape;
        let dense_bits = (m * n) as f64 * 16.0;
        dense_bits / self.bits().total_bits as f64
    }

    /// Double-compress (PR 6): grouped int8 quantization of `R`, `A`, `B`
    /// with per-(group, column) f32 scale/zero. The labels are shared
    /// unchanged — quantization touches only the real-valued payloads.
    pub fn quantize(&self, cfg: &QuantConfig) -> QuantizedMatrix {
        QuantizedMatrix {
            shape: self.shape,
            labels: self.labels.clone(),
            centroids: QuantizedTensor::quantize(&self.centroids, cfg),
            factor_a: QuantizedTensor::quantize(&self.factor_a, cfg),
            factor_b: QuantizedTensor::quantize(&self.factor_b, cfg),
        }
    }
}

/// A [`CompressedMatrix`] with its real-valued payloads stored as grouped
/// int8 ([`QuantizedTensor`]) — the quantized `.swsc` section's in-memory
/// form. Serving never dequantizes the full factors: `infer` packs the
/// codes straight into fused-dequant GEMM panels. [`Self::dequantize`]
/// is the f32 oracle path (and the `Precision::F32` loading mode).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedMatrix {
    /// Original shape `(m, n)`.
    pub shape: (usize, usize),
    /// Per-channel cluster id (`n` entries, each `< k`).
    pub labels: Vec<u32>,
    /// Quantized representatives (`m × k`).
    pub centroids: QuantizedTensor,
    /// Quantized left factor (`m × r`).
    pub factor_a: QuantizedTensor,
    /// Quantized right factor (`r × n`).
    pub factor_b: QuantizedTensor,
}

impl QuantizedMatrix {
    pub fn k(&self) -> usize {
        self.centroids.cols()
    }

    pub fn rank(&self) -> usize {
        self.factor_a.cols()
    }

    /// Quantization group (identical across the three payloads).
    pub fn group(&self) -> usize {
        self.centroids.group()
    }

    /// Expand back to an f32 [`CompressedMatrix`] — the oracle route. The
    /// expansion is `k + 2r` columns' worth of payload, never the dense
    /// `m × n` matrix.
    pub fn dequantize(&self) -> CompressedMatrix {
        CompressedMatrix {
            shape: self.shape,
            labels: self.labels.clone(),
            centroids: self.centroids.dequantize(),
            factor_a: self.factor_a.dequantize(),
            factor_b: self.factor_b.dequantize(),
        }
    }

    /// Actual stored-bits accounting (int8 codes + group metadata +
    /// packed labels).
    pub fn bits(&self) -> BitsBreakdown {
        let (m, n) = self.shape;
        swsc_quantized_avg_bits(m, n, self.k(), self.rank(), self.group())
    }

    /// Bits per original weight element as stored.
    pub fn avg_bits(&self) -> f64 {
        self.bits().avg_bits
    }
}

/// Run the full SWSC transform on one matrix (paper Fig. 1):
/// cluster → share → error SVD → pack.
pub fn compress_matrix(w: &Tensor, cfg: &SwscConfig) -> CompressedMatrix {
    compress_matrix_traced(w, cfg, None, None)
}

/// [`compress_matrix`] with optional observation hooks (PR 10): a parent
/// profiler scope (opens `kmeans` / `rsvd` children plus a synthetic
/// `kmeans/iters` node carrying the iteration count) and a telemetry
/// record to fill with quality data computed in passing. Both are
/// observation-only: the compressed output is bitwise identical whether
/// they are `None` or `Some` — pinned by `tests/obs_prof.rs`.
pub fn compress_matrix_traced(
    w: &Tensor,
    cfg: &SwscConfig,
    parent: Option<&ProfScope<'_>>,
    mut telemetry: Option<&mut MatrixTelemetry>,
) -> CompressedMatrix {
    let (m, n) = (w.rows(), w.cols());

    // Step 1-2: channel clustering and representative sharing.
    let mut km_cfg = cfg.kmeans.clone();
    km_cfg.k = cfg.clusters;
    km_cfg.seed = cfg.seed;
    km_cfg.exec = cfg.exec;
    let km = {
        let sc = prof::scope(parent, "kmeans");
        let (km, secs) = time_it(|| cluster_channels(w, &km_cfg));
        if let Some(sc) = &sc {
            // Iteration boundaries live inside the Lloyd loop; fold the
            // count in as a synthetic child so the tree shows mean
            // time-per-iteration.
            sc.profiler().add(
                &format!("{}/iters", sc.path()),
                km.iterations as u64,
                (secs * 1e9) as u64,
            );
        }
        km
    };
    let w_prime = km.reconstruct();

    if let Some(t) = telemetry.as_deref_mut() {
        t.shape = (m, n);
        t.clusters = km.centroids.cols();
        t.kmeans_iterations = km.iterations;
        t.inertia = km.inertia;
        t.inertia_trace = km.inertia_trace.clone();
    }

    // Step 3: error compensation via truncated SVD of W_err = W − W'.
    let rank = cfg.rank.min(m.min(n));
    let (factor_a, factor_b) = if rank == 0 {
        if let Some(t) = telemetry.as_deref_mut() {
            t.rank = 0;
            let f = w.sub(&w_prime).fro_norm();
            t.error_fro2 = f * f;
        }
        (Tensor::zeros(&[m, 0]), Tensor::zeros(&[0, n]))
    } else {
        let err = w.sub(&w_prime);
        let svd = {
            let _sc = prof::scope(parent, "rsvd");
            run_svd(&err, rank, cfg)
        };
        if let Some(t) = telemetry.as_deref_mut() {
            t.rank = rank;
            let f = err.fro_norm();
            t.error_fro2 = f * f;
            t.spectrum = svd.s.clone();
            t.compensation_energy = svd.energy_fraction(t.error_fro2);
        }
        svd.split_factors()
    };

    CompressedMatrix { shape: (m, n), labels: km.labels, centroids: km.centroids, factor_a, factor_b }
}

fn run_svd(err: &Tensor, rank: usize, cfg: &SwscConfig) -> Svd {
    let min_dim = err.rows().min(err.cols());
    let truncated_regime = min_dim > 96 && rank * 3 <= min_dim;
    let use_jacobi = match cfg.svd {
        SvdBackend::Jacobi => true,
        SvdBackend::Randomized => false,
        SvdBackend::Auto => !truncated_regime,
    };
    if use_jacobi {
        truncate(&svd_jacobi(err), rank)
    } else {
        let mut rng = Rng::new(cfg.seed ^ 0x5D5C_77E1);
        svd_randomized_with(err, rank, 8, 2, &mut rng, cfg.exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{rtn_quantize, RtnConfig, RtnMode};
    use crate::util::prop;

    /// Weights with clustered channel structure + a few outliers — the
    /// regime the paper targets.
    fn structured_weights(m: usize, n: usize, groups: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let centers: Vec<Vec<f32>> =
            (0..groups).map(|_| (0..m).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
        let mut w = Tensor::zeros(&[m, n]);
        for j in 0..n {
            let c = &centers[j % groups];
            let col: Vec<f32> = c.iter().map(|&v| v + rng.normal_f32(0.0, 0.1)).collect();
            w.set_col(j, &col);
        }
        // Outliers: a handful of large entries.
        for _ in 0..(m * n / 200).max(1) {
            let i = rng.below(m * n);
            w.data_mut()[i] += rng.normal_f32(0.0, 8.0);
        }
        w
    }

    #[test]
    fn reconstruct_shapes() {
        let w = structured_weights(32, 48, 6, 91);
        let c = compress_matrix(&w, &SwscConfig::new(6, 4));
        assert_eq!(c.shape, (32, 48));
        assert_eq!(c.labels.len(), 48);
        assert_eq!(c.centroids.shape(), &[32, 6]);
        assert_eq!(c.factor_a.shape(), &[32, 4]);
        assert_eq!(c.factor_b.shape(), &[4, 48]);
        assert_eq!(c.reconstruct().shape(), w.shape());
    }

    #[test]
    fn compensation_strictly_helps() {
        let w = structured_weights(48, 48, 8, 92);
        let c = compress_matrix(&w, &SwscConfig::new(8, 8));
        let with = c.reconstruct().mse(&w);
        let without = c.reconstruct_uncompensated().mse(&w);
        assert!(with < without, "compensated {with} !< uncompensated {without}");
    }

    #[test]
    fn mse_decreases_with_rank() {
        let w = structured_weights(40, 40, 5, 93);
        let mut last = f64::INFINITY;
        for r in [0usize, 2, 4, 8, 16] {
            let c = compress_matrix(&w, &SwscConfig::new(5, r));
            let mse = c.reconstruct().mse(&w);
            assert!(mse <= last + 1e-9, "rank {r}: {mse} > {last}");
            last = mse;
        }
    }

    #[test]
    fn full_rank_full_clusters_is_lossless() {
        let mut rng = Rng::new(94);
        let w = Tensor::randn(&[12, 12], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(12, 12));
        assert!(c.reconstruct().mse(&w) < 1e-8);
    }

    /// The paper's §III-A feasibility claim: at equal storage, SWSC beats
    /// RTN on MSE for channel-structured weights.
    #[test]
    fn swsc_beats_rtn_at_equal_budget_on_structured_weights() {
        // Channel-group count within reach of the 2-bit cluster budget
        // (k = 8 at m = 128) — the regime the paper's motivation targets.
        let m = 128;
        let w = structured_weights(m, m, 6, 95);
        // 2-bit budget: k = 2·m/16 / 2 ... use the planner split.
        let (k, r) = crate::quant::bits::swsc_params_for_bits(m, 2.0, 0.5);
        let c = compress_matrix(&w, &SwscConfig::new(k, r));
        let swsc_mse = c.reconstruct().mse(&w);
        let rtn = rtn_quantize(&w, &RtnConfig { bits: 2, mode: RtnMode::Asymmetric });
        let rtn_mse = w.mse(&rtn);
        assert!(
            swsc_mse < rtn_mse,
            "SWSC {swsc_mse} should beat RTN {rtn_mse} at 2-bit budget (avg_bits {})",
            c.avg_bits()
        );
    }

    #[test]
    fn avg_bits_matches_accounting() {
        let w = structured_weights(64, 64, 8, 96);
        let c = compress_matrix(&w, &SwscConfig::new(8, 4));
        let direct = crate::quant::bits::swsc_avg_bits(64, 64, 8, 4).avg_bits;
        assert!((c.avg_bits() - direct).abs() < 1e-12);
        assert!(c.compression_ratio() > 1.0);
    }

    #[test]
    fn rank_zero_reconstructions_agree() {
        let w = structured_weights(24, 24, 4, 97);
        let c = compress_matrix(&w, &SwscConfig::new(4, 0));
        prop::assert_close(
            c.reconstruct().data(),
            c.reconstruct_uncompensated().data(),
            1e-9,
            0.0,
        )
        .unwrap();
    }

    #[test]
    fn quantize_round_trip_is_close_and_smaller() {
        let w = structured_weights(64, 64, 8, 99);
        let c = compress_matrix(&w, &SwscConfig::new(8, 4));
        let q = c.quantize(&QuantConfig { group: 16 });
        assert_eq!((q.k(), q.rank(), q.group()), (8, 4, 16));
        let back = q.dequantize();
        assert_eq!(back.labels, c.labels);
        // Per-element error bounded by each block's grid step.
        for (t, b) in [
            (&c.centroids, &back.centroids),
            (&c.factor_a, &back.factor_a),
            (&c.factor_b, &back.factor_b),
        ] {
            let scale = t.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            prop::assert_close(b.data(), t.data(), (scale / 255.0 * 16.0) as f64 + 1e-6, 0.0)
                .unwrap();
        }
        // Stored bits: int8 + metadata beats the fp16 estimate and sits
        // well under 0.35x of an f32 payload of the same counts.
        assert!(q.bits().total_bits < c.bits().total_bits);
        let f32_payload = 2 * c.bits().total_bits - c.bits().label_bits;
        assert!(
            (q.bits().total_bits as f64) < 0.35 * f32_payload as f64,
            "{} vs 0.35x of {}",
            q.bits().total_bits,
            f32_payload
        );
        // Dequantized reconstruction still approximates W.
        let mse = back.reconstruct().mse(&w);
        let base = c.reconstruct().mse(&w);
        assert!(mse < base + 0.05, "quantized mse {mse} vs f32 {base}");
    }

    #[test]
    fn quantize_rank_zero() {
        let w = structured_weights(24, 24, 4, 100);
        let c = compress_matrix(&w, &SwscConfig::new(4, 0));
        let q = c.quantize(&QuantConfig::default());
        assert_eq!(q.rank(), 0);
        let back = q.dequantize();
        assert_eq!(back.factor_a.shape(), &[24, 0]);
        assert_eq!(back.factor_b.shape(), &[0, 24]);
        assert_eq!(back.reconstruct().shape(), w.shape());
    }

    #[test]
    fn traced_compress_is_bitwise_identical_and_fills_telemetry() {
        let w = structured_weights(48, 48, 6, 102);
        let cfg = SwscConfig::new(6, 4);
        let plain = compress_matrix(&w, &cfg);
        let prof = crate::obs::prof::Profiler::new();
        let mut tel = MatrixTelemetry { name: "t.w".into(), ..Default::default() };
        let traced = {
            let root = prof.root("compress");
            compress_matrix_traced(&w, &cfg, Some(&root), Some(&mut tel))
        };
        let bits = |t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(traced.labels, plain.labels);
        assert_eq!(bits(&traced.centroids), bits(&plain.centroids));
        assert_eq!(bits(&traced.factor_a), bits(&plain.factor_a));
        assert_eq!(bits(&traced.factor_b), bits(&plain.factor_b));
        // Telemetry was filled with internally consistent values.
        assert_eq!(tel.shape, (48, 48));
        assert_eq!((tel.clusters, tel.rank), (6, 4));
        assert_eq!(tel.inertia_trace.len(), tel.kmeans_iterations);
        assert_eq!(tel.spectrum.len(), 4);
        for s in tel.spectrum.windows(2) {
            assert!(s[1] <= s[0], "spectrum must descend: {:?}", tel.spectrum);
        }
        assert!(tel.error_fro2 > 0.0);
        assert!(tel.compensation_energy > 0.0 && tel.compensation_energy <= 1.0);
        // The profiler saw the phase tree.
        let phases = prof.phases();
        assert!(phases.contains_key("compress/kmeans"), "{phases:?}");
        assert!(phases.contains_key("compress/kmeans/iters"), "{phases:?}");
        assert!(phases.contains_key("compress/rsvd"), "{phases:?}");
        assert_eq!(phases["compress/kmeans/iters"].count, tel.kmeans_iterations as u64);
    }

    #[test]
    fn jacobi_and_randomized_backends_close() {
        let w = structured_weights(64, 64, 8, 98);
        let mut cj = SwscConfig::new(8, 6);
        cj.svd = SvdBackend::Jacobi;
        let mut cr = SwscConfig::new(8, 6);
        cr.svd = SvdBackend::Randomized;
        let ej = compress_matrix(&w, &cj).reconstruct().mse(&w);
        let er = compress_matrix(&w, &cr).reconstruct().mse(&w);
        assert!(er <= ej * 1.2 + 1e-9, "randomized {er} vs jacobi {ej}");
    }
}
