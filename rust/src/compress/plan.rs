//! Model-level compression planning.
//!
//! The paper compresses the Q and/or K projectors of every self-attention
//! layer (and deliberately *not* V — §IV-B). A [`CompressionPlan`] maps
//! parameter names to per-matrix [`SwscConfig`]s (or RTN budgets) so the
//! coordinator can schedule each matrix as an independent job.

use super::swsc::SwscConfig;
use crate::quant::bits::swsc_params_for_bits;
use crate::quant::RtnConfig;

/// Which attention projectors to compress — the paper's Table I rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProjectorSet {
    Q,
    K,
    QAndK,
    /// Ablation only: the paper argues V must not be compressed.
    V,
}

impl ProjectorSet {
    /// Suffixes of parameter names this set selects (see `model::params`
    /// naming convention `layers.{i}.attn.{wq,wk,wv,wo}`).
    pub fn suffixes(&self) -> &'static [&'static str] {
        match self {
            ProjectorSet::Q => &["attn.wq"],
            ProjectorSet::K => &["attn.wk"],
            ProjectorSet::QAndK => &["attn.wq", "attn.wk"],
            ProjectorSet::V => &["attn.wv"],
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ProjectorSet::Q => "Q",
            ProjectorSet::K => "K",
            ProjectorSet::QAndK => "Q & K",
            ProjectorSet::V => "V",
        }
    }

    pub fn matches(&self, param_name: &str) -> bool {
        self.suffixes().iter().any(|s| param_name.ends_with(s))
    }
}

/// One matrix's job spec.
#[derive(Debug, Clone)]
pub struct MatrixPlan {
    pub name: String,
    pub config: SwscConfig,
}

/// A full-model compression plan.
#[derive(Debug, Clone)]
pub struct CompressionPlan {
    pub matrices: Vec<MatrixPlan>,
    /// The matched RTN baseline budget, if this plan was built from a
    /// target-bits spec.
    pub rtn_baseline: Option<RtnConfig>,
    pub target_bits: f64,
}

impl CompressionPlan {
    /// Build a plan for `projectors` at `target_bits` average bits, given
    /// the model's parameter names and their shapes. `rank_share` splits
    /// the budget between clusters and rank (0.5 = even, paper-style).
    pub fn for_target_bits(
        param_shapes: &[(String, Vec<usize>)],
        projectors: ProjectorSet,
        target_bits: f64,
        rank_share: f64,
        seed: u64,
    ) -> CompressionPlan {
        let mut matrices = Vec::new();
        for (name, shape) in param_shapes {
            if !projectors.matches(name) || shape.len() != 2 {
                continue;
            }
            let m = shape[0];
            let (k, r) = swsc_params_for_bits(m, target_bits, rank_share);
            let mut cfg = SwscConfig::new(k, r);
            // Derive a stable per-matrix seed from the name so jobs are
            // reproducible regardless of scheduling order.
            cfg.seed = seed ^ fnv1a(name);
            cfg.kmeans.seed = cfg.seed;
            matrices.push(MatrixPlan { name: name.clone(), config: cfg });
        }
        CompressionPlan {
            matrices,
            rtn_baseline: Some(RtnConfig { bits: target_bits.round() as u32, ..Default::default() }),
            target_bits,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.matrices.is_empty()
    }

    pub fn len(&self) -> usize {
        self.matrices.len()
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<(String, Vec<usize>)> {
        let mut v = Vec::new();
        for i in 0..3 {
            for p in ["wq", "wk", "wv", "wo"] {
                v.push((format!("layers.{i}.attn.{p}"), vec![256, 256]));
            }
            v.push((format!("layers.{i}.mlp.w1"), vec![256, 1024]));
        }
        v.push(("embed.tok".into(), vec![512, 256]));
        v
    }

    #[test]
    fn q_plan_selects_only_wq() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::Q, 2.0, 0.5, 0);
        assert_eq!(p.len(), 3);
        assert!(p.matrices.iter().all(|m| m.name.ends_with("attn.wq")));
    }

    #[test]
    fn qk_plan_selects_both() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::QAndK, 3.0, 0.5, 0);
        assert_eq!(p.len(), 6);
    }

    #[test]
    fn per_matrix_seeds_differ_but_are_stable() {
        let a = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::QAndK, 2.0, 0.5, 7);
        let b = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::QAndK, 2.0, 0.5, 7);
        for (x, y) in a.matrices.iter().zip(&b.matrices) {
            assert_eq!(x.config.seed, y.config.seed);
        }
        let seeds: std::collections::HashSet<u64> =
            a.matrices.iter().map(|m| m.config.seed).collect();
        assert_eq!(seeds.len(), a.len(), "seeds must be distinct per matrix");
    }

    #[test]
    fn budget_lands_near_target() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::Q, 2.0, 0.5, 0);
        for m in &p.matrices {
            let bits =
                crate::quant::bits::swsc_avg_bits_paper(256, m.config.clusters, m.config.rank);
            assert!((bits - 2.0).abs() < 0.3, "{}: {bits}", m.name);
        }
    }

    #[test]
    fn v_ablation_selects_wv() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::V, 2.0, 0.5, 0);
        assert_eq!(p.len(), 3);
        assert!(p.matrices.iter().all(|m| m.name.ends_with("attn.wv")));
    }
}
