//! Model-level compression planning.
//!
//! The paper compresses the Q and/or K projectors of every self-attention
//! layer (and deliberately *not* V — §IV-B). A [`CompressionPlan`] maps
//! parameter names to per-matrix [`SwscConfig`]s (or RTN budgets) so the
//! coordinator can schedule each matrix as an independent job.

use super::swsc::SwscConfig;
use crate::kmeans::KMeansMethod;
use crate::quant::bits::swsc_params_for_bits;
use crate::quant::RtnConfig;

/// Which projectors to compress — the paper's Table I rows, plus the MLP
/// scaling workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProjectorSet {
    Q,
    K,
    QAndK,
    /// Ablation only: the paper argues V must not be compressed.
    V,
    /// Beyond the paper: the MLP matrices — `mlp.w1` is the widest matrix
    /// in the model (`d × 4d` channels; 11008 on Llama-scale configs),
    /// which is exactly the regime the planner routes through mini-batch
    /// k-means.
    Mlp,
}

impl ProjectorSet {
    /// Suffixes of parameter names this set selects (see `model::params`
    /// naming convention `layers.{i}.attn.{wq,wk,wv,wo}`,
    /// `layers.{i}.mlp.{w1,w2}`).
    pub fn suffixes(&self) -> &'static [&'static str] {
        match self {
            ProjectorSet::Q => &["attn.wq"],
            ProjectorSet::K => &["attn.wk"],
            ProjectorSet::QAndK => &["attn.wq", "attn.wk"],
            ProjectorSet::V => &["attn.wv"],
            ProjectorSet::Mlp => &["mlp.w1", "mlp.w2"],
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ProjectorSet::Q => "Q",
            ProjectorSet::K => "K",
            ProjectorSet::QAndK => "Q & K",
            ProjectorSet::V => "V",
            ProjectorSet::Mlp => "MLP",
        }
    }

    pub fn matches(&self, param_name: &str) -> bool {
        self.suffixes().iter().any(|s| param_name.ends_with(s))
    }
}

/// Channel count at/above which the planner routes a matrix's clustering
/// through mini-batch k-means: full Lloyd is `O(iters·n·k·m)` in the
/// channel count, and past a few thousand channels (the MLP `w1` regime)
/// the sampled variant reaches the same inertia basin in a fraction of
/// the assignments (PR 2 measured the blocked assign at 8192×128; this
/// closes the remaining headroom named in ROADMAP.md).
pub const MINIBATCH_MIN_CHANNELS: usize = 2048;

/// Deterministic method choice for an `n`-channel matrix: Lloyd below
/// [`MINIBATCH_MIN_CHANNELS`]; above it, ~4 sampled passes in
/// 1024-channel batches (floor of 40 steps so narrow-but-routed matrices
/// still converge). Pure function of `n` — plans stay reproducible.
pub fn kmeans_method_for_width(n: usize) -> KMeansMethod {
    if n >= MINIBATCH_MIN_CHANNELS {
        let batch = 1024.min(n);
        KMeansMethod::Minibatch { batch, steps: (4 * n / batch).max(40) }
    } else {
        KMeansMethod::Lloyd
    }
}

/// One matrix's job spec.
#[derive(Debug, Clone)]
pub struct MatrixPlan {
    pub name: String,
    pub config: SwscConfig,
}

/// A full-model compression plan.
#[derive(Debug, Clone)]
pub struct CompressionPlan {
    pub matrices: Vec<MatrixPlan>,
    /// The matched RTN baseline budget, if this plan was built from a
    /// target-bits spec.
    pub rtn_baseline: Option<RtnConfig>,
    pub target_bits: f64,
}

impl CompressionPlan {
    /// Build a plan for `projectors` at `target_bits` average bits, given
    /// the model's parameter names and their shapes. `rank_share` splits
    /// the budget between clusters and rank (0.5 = even, paper-style).
    pub fn for_target_bits(
        param_shapes: &[(String, Vec<usize>)],
        projectors: ProjectorSet,
        target_bits: f64,
        rank_share: f64,
        seed: u64,
    ) -> CompressionPlan {
        let mut matrices = Vec::new();
        for (name, shape) in param_shapes {
            if !projectors.matches(name) || shape.len() != 2 {
                continue;
            }
            let m = shape[0];
            let (k, r) = swsc_params_for_bits(m, target_bits, rank_share);
            let mut cfg = SwscConfig::new(k, r);
            // Derive a stable per-matrix seed from the name so jobs are
            // reproducible regardless of scheduling order.
            cfg.seed = seed ^ fnv1a(name);
            cfg.kmeans.seed = cfg.seed;
            // Widest matrices (MLP w1 channels) go through mini-batch.
            cfg.kmeans.method = kmeans_method_for_width(shape[1]);
            matrices.push(MatrixPlan { name: name.clone(), config: cfg });
        }
        CompressionPlan {
            matrices,
            rtn_baseline: Some(RtnConfig { bits: target_bits.round() as u32, ..Default::default() }),
            target_bits,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.matrices.is_empty()
    }

    pub fn len(&self) -> usize {
        self.matrices.len()
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<(String, Vec<usize>)> {
        let mut v = Vec::new();
        for i in 0..3 {
            for p in ["wq", "wk", "wv", "wo"] {
                v.push((format!("layers.{i}.attn.{p}"), vec![256, 256]));
            }
            v.push((format!("layers.{i}.mlp.w1"), vec![256, 1024]));
        }
        v.push(("embed.tok".into(), vec![512, 256]));
        v
    }

    #[test]
    fn q_plan_selects_only_wq() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::Q, 2.0, 0.5, 0);
        assert_eq!(p.len(), 3);
        assert!(p.matrices.iter().all(|m| m.name.ends_with("attn.wq")));
    }

    #[test]
    fn qk_plan_selects_both() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::QAndK, 3.0, 0.5, 0);
        assert_eq!(p.len(), 6);
    }

    #[test]
    fn per_matrix_seeds_differ_but_are_stable() {
        let a = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::QAndK, 2.0, 0.5, 7);
        let b = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::QAndK, 2.0, 0.5, 7);
        for (x, y) in a.matrices.iter().zip(&b.matrices) {
            assert_eq!(x.config.seed, y.config.seed);
        }
        let seeds: std::collections::HashSet<u64> =
            a.matrices.iter().map(|m| m.config.seed).collect();
        assert_eq!(seeds.len(), a.len(), "seeds must be distinct per matrix");
    }

    #[test]
    fn budget_lands_near_target() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::Q, 2.0, 0.5, 0);
        for m in &p.matrices {
            let bits =
                crate::quant::bits::swsc_avg_bits_paper(256, m.config.clusters, m.config.rank);
            assert!((bits - 2.0).abs() < 0.3, "{}: {bits}", m.name);
        }
    }

    #[test]
    fn v_ablation_selects_wv() {
        let p = CompressionPlan::for_target_bits(&shapes(), ProjectorSet::V, 2.0, 0.5, 0);
        assert_eq!(p.len(), 3);
        assert!(p.matrices.iter().all(|m| m.name.ends_with("attn.wv")));
    }

    #[test]
    fn mlp_plan_selects_w1_and_w2() {
        let mut s = shapes();
        for i in 0..3 {
            s.push((format!("layers.{i}.mlp.w2"), vec![1024, 256]));
        }
        let p = CompressionPlan::for_target_bits(&s, ProjectorSet::Mlp, 2.0, 0.5, 0);
        assert_eq!(p.len(), 6);
        assert!(p.matrices.iter().all(|m| m.name.contains(".mlp.w")));
    }

    /// The PR 2 headroom item: the widest matrices (MLP w1 channels) route
    /// through mini-batch k-means; everything narrower stays on full
    /// Lloyd. The choice is a pure function of the channel count, so plans
    /// remain reproducible.
    #[test]
    fn widest_mlp_matrices_route_through_minibatch() {
        let s = vec![
            ("layers.0.attn.wq".to_string(), vec![256usize, 256usize]),
            ("layers.0.mlp.w1".to_string(), vec![256, 4096]),
            ("layers.0.mlp.w2".to_string(), vec![4096, 256]),
        ];
        let p = CompressionPlan::for_target_bits(&s, ProjectorSet::Mlp, 2.0, 0.5, 0);
        assert_eq!(p.len(), 2);
        for m in &p.matrices {
            let method = m.config.kmeans.method;
            if m.name.ends_with("mlp.w1") {
                // 4096 channels ≥ the threshold: sampled passes.
                match method {
                    KMeansMethod::Minibatch { batch, steps } => {
                        assert_eq!(batch, 1024);
                        assert_eq!(steps, 16.max(40));
                    }
                    KMeansMethod::Lloyd => panic!("wide w1 should use minibatch"),
                }
            } else {
                // w2 has only 256 channels: full Lloyd.
                assert_eq!(method, KMeansMethod::Lloyd, "{} should stay on Lloyd", m.name);
            }
        }
        // Attention plans at paper widths are untouched by the routing.
        let q = CompressionPlan::for_target_bits(&s, ProjectorSet::Q, 2.0, 0.5, 0);
        assert!(q.matrices.iter().all(|m| m.config.kmeans.method == KMeansMethod::Lloyd));
        // Boundary behavior of the pure routing function.
        assert_eq!(kmeans_method_for_width(MINIBATCH_MIN_CHANNELS - 1), KMeansMethod::Lloyd);
        assert!(matches!(
            kmeans_method_for_width(MINIBATCH_MIN_CHANNELS),
            KMeansMethod::Minibatch { .. }
        ));
        assert_eq!(
            kmeans_method_for_width(11008),
            KMeansMethod::Minibatch { batch: 1024, steps: 43 }
        );
    }
}
