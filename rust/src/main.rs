//! `swsc` — the SWSC coordinator CLI.
//!
//! Subcommands:
//! - `train`     train the LM from scratch on the synthetic corpus
//! - `compress`  run the SWSC pipeline on a checkpoint → `.swsc` container
//! - `eval`      perplexity of a checkpoint or `.swsc` container
//! - `table1`    reproduce the paper's Table I end-to-end
//! - `table2`    print the paper's Table II (avg-bits accounting)
//! - `pipeline`  train → compress → eval in one go (Fig. 1)
//! - `trace`     serve a seeded replay with tracing on; export Chrome
//!               trace JSON + Prometheus/JSON metrics (PR 9)
//! - `info`      model/artifact info
//!
//! Arg parsing is hand-rolled (`--key value` pairs) — the vendored crate
//! set has no clap.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use swsc::compress::{CompressionPlan, ProjectorSet};
use swsc::coordinator::compress_model;
use swsc::eval::Evaluator;
use swsc::io::{Checkpoint, SwscFile};
use swsc::model::{init_params, ModelConfig};
use swsc::quant::{rtn_quantize, QuantConfig, RtnConfig};
use swsc::report::{render_storage, render_table1, render_table2, StorageRow, Table1Row};
use swsc::runtime::{ArtifactManifest, Engine};
use swsc::text::{BpeTokenizer, CorpusConfig, Dataset, SyntheticCorpus};
use swsc::train::{LrSchedule, Trainer};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        print_help();
        return Ok(());
    }
    let cmd = args.remove(0);
    let opts = parse_opts(&args)?;
    match cmd.as_str() {
        "train" => cmd_train(&opts),
        "compress" => cmd_compress(&opts),
        "eval" => cmd_eval(&opts),
        "table1" => cmd_table1(&opts),
        "table2" => cmd_table2(&opts),
        "pipeline" => cmd_pipeline(&opts),
        "trace" => cmd_trace(&opts),
        "info" => cmd_info(&opts),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command `{other}` — try `swsc help`"),
    }
}

fn print_help() {
    println!(
        "swsc — Shared Weight for Similar Channel (paper reproduction)\n\
         \n\
         usage: swsc <command> [--key value]...\n\
         \n\
         commands:\n\
           train     --preset small --steps 300 --out runs/default [--artifacts artifacts]\n\
           compress  --ckpt runs/default/model.swck --proj qk|mlp --bits 2 --out model.swsc\n\
                     [--precision f32|int8 --group 64]  (int8 = grouped-int8 factors)\n\
                     [--init small]  (synthesize seeded untrained weights — no --ckpt)\n\
                     [--telemetry report.json]  (per-matrix quality telemetry: inertia\n\
                     traces, error spectrum, grid error — the rank allocator's input)\n\
           eval      --ckpt model.swck | --swsc model.swsc  [--preset small]\n\
                     [--engine pjrt|compressed]  (compressed = whole forward from\n\
                     the .swsc factors, no artifacts/PJRT/reconstruction)\n\
           table1    --ckpt runs/default/model.swck [--bits 3,2] [--out table1.txt]\n\
           table2    [--m 4096]\n\
           pipeline  --steps 300 --out runs/pipeline\n\
           trace     [--out trace.json --requests 48 --forward-requests 12 --seed 42]\n\
                     (serves a seeded replay with request tracing on, writes a\n\
                     Perfetto-loadable timeline, prints Prometheus/JSON metrics)\n\
           info      [--preset small]\n\
         \n\
         env:\n\
           SWSC_THREADS   worker threads for compression-time compute\n\
                          (default: all cores; results are bit-identical\n\
                          at any thread count, 1 = serial reference)\n\
           SWSC_PROF      enable the pipeline phase profiler (timing tree on\n\
                          stderr; observation-only — output bytes unchanged)\n\
           SWSC_PROF_OUT  with SWSC_PROF: also write the phase timeline as\n\
                          Chrome trace-event JSON to this path\n\
           (see docs/observability.md for the full SWSC_* catalogue)\n"
    );
}

type Opts = HashMap<String, String>;

fn parse_opts(args: &[String]) -> Result<Opts> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .with_context(|| format!("expected --key, got `{}`", args[i]))?;
        let val = args.get(i + 1).with_context(|| format!("--{key} needs a value"))?;
        out.insert(key.to_string(), val.clone());
        i += 2;
    }
    Ok(out)
}

fn opt<'a>(opts: &'a Opts, key: &str, default: &'a str) -> &'a str {
    opts.get(key).map(|s| s.as_str()).unwrap_or(default)
}

fn engine_for(opts: &Opts, cfg: &ModelConfig) -> Result<Engine> {
    let dir = PathBuf::from(opt(opts, "artifacts", "artifacts"));
    let preset = opt(opts, "preset", "small");
    let manifest = ArtifactManifest::load(&dir, preset)?;
    manifest.verify_config(cfg)?;
    Engine::new(manifest)
}

/// Build tokenizer + train/eval datasets the same way everywhere.
fn corpus_and_data(cfg: &ModelConfig, seed: u64) -> (BpeTokenizer, Dataset, Dataset) {
    let corpus = SyntheticCorpus::generate(&CorpusConfig { seed, ..Default::default() });
    let tok = BpeTokenizer::train(&corpus.train_text, cfg.vocab);
    let train = Dataset::from_text(&corpus.train_text, &tok, cfg.batch, cfg.seq);
    let eval = Dataset::from_text(&corpus.eval_text, &tok, cfg.batch, cfg.seq);
    (tok, train, eval)
}

fn cmd_train(opts: &Opts) -> Result<()> {
    let cfg = ModelConfig::by_name(opt(opts, "preset", "small"))?;
    cfg.validate()?;
    let steps: usize = opt(opts, "steps", "300").parse()?;
    let out_dir = PathBuf::from(opt(opts, "out", "runs/default"));
    let seed: u64 = opt(opts, "seed", "42").parse()?;

    let engine = engine_for(opts, &cfg)?;
    println!("platform: {}  params: {}", engine.platform(), cfg.param_count());

    let (tok, train_data, eval_data) = corpus_and_data(&cfg, seed);
    println!(
        "corpus: {} train tokens, {} eval tokens, {} batches/epoch",
        train_data.tokens(),
        eval_data.tokens(),
        train_data.num_batches()
    );

    let base_lr: f32 = opt(opts, "lr", "6e-4").parse()?;
    let init = init_params(&cfg, seed);
    let mut trainer = Trainer::new(engine.clone(), cfg.clone(), &init)?;
    let mut sched = LrSchedule::new(base_lr, steps / 20 + 1, steps);
    // Keep a meaningful floor: attention (induction) structure emerges
    // late; decaying to near-zero freezes it half-formed.
    sched.min_lr = base_lr * 0.25;

    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let batch = train_data.batch(step);
        let loss = trainer.step(&batch, sched.at(step))?;
        if step % 20 == 0 || step + 1 == steps {
            println!(
                "step {step:>5}  loss {loss:.4}  lr {:.2e}  {:.1}s",
                sched.at(step),
                t0.elapsed().as_secs_f64()
            );
        }
    }

    let ck = trainer.to_checkpoint()?;
    std::fs::create_dir_all(&out_dir)?;
    ck.save(&out_dir.join("model.swck"))?;
    std::fs::write(out_dir.join("tokenizer.bpe"), tok.to_text())?;
    let loss_log: String =
        trainer.losses.iter().enumerate().map(|(i, l)| format!("{i} {l}\n")).collect();
    std::fs::write(out_dir.join("loss.log"), loss_log)?;

    let evaluator = Evaluator::new(engine, cfg)?;
    let res = evaluator.perplexity(trainer.params(), &eval_data)?;
    println!("final eval: ppl {:.3} ({} tokens)", res.perplexity, res.tokens);
    std::fs::write(out_dir.join("eval.txt"), format!("perplexity {}\n", res.perplexity))?;
    println!("saved to {}", out_dir.display());
    Ok(())
}

fn proj_from_str(s: &str) -> Result<ProjectorSet> {
    Ok(match s {
        "q" => ProjectorSet::Q,
        "k" => ProjectorSet::K,
        "qk" => ProjectorSet::QAndK,
        "v" => ProjectorSet::V,
        "mlp" => ProjectorSet::Mlp,
        other => bail!("unknown projector set `{other}` (q|k|qk|v|mlp)"),
    })
}

fn cmd_compress(opts: &Opts) -> Result<()> {
    let proj = proj_from_str(opt(opts, "proj", "qk"))?;
    let bits: f64 = opt(opts, "bits", "2").parse()?;
    let out = PathBuf::from(opt(opts, "out", "model.swsc"));
    let workers: usize = opt(opts, "workers", "8").parse()?;
    let seed: u64 = opt(opts, "seed", "42").parse()?;
    let precision = opt(opts, "precision", "f32");
    let group: usize = opt(opts, "group", "64").parse()?;
    anyhow::ensure!(
        matches!(precision, "f32" | "int8"),
        "unknown --precision `{precision}` (f32|int8)"
    );

    // `--init preset` synthesizes seeded untrained weights — the CI smoke
    // path, which needs a full pipeline run without a training checkpoint.
    let ck = if let Some(preset) = opts.get("init") {
        let cfg = ModelConfig::by_name(preset)?;
        println!("synthesizing untrained `{preset}` weights (seed {seed})");
        init_params(&cfg, seed)
    } else {
        let ckpt = PathBuf::from(opts.get("ckpt").context("--ckpt or --init required")?);
        Checkpoint::load(&ckpt)?
    };
    let plan = CompressionPlan::for_target_bits(&ck.shapes(), proj, bits, 0.5, seed);
    anyhow::ensure!(!plan.is_empty(), "plan selected no matrices");

    // Observation hooks (PR 10), both off by default and observation-only:
    // the phase profiler (SWSC_PROF) and the quality-telemetry report
    // (--telemetry out.json). The `.swsc` bytes are identical either way.
    let prof_cfg = swsc::obs::prof::ProfConfig::from_env();
    let profiler = prof_cfg.as_ref().map(|_| swsc::obs::prof::Profiler::new());
    let telemetry_out = opts.get("telemetry").map(PathBuf::from);

    println!("compressing {} matrices ({} workers, target {bits} avg bits)...", plan.len(), workers);
    let outcome = {
        let root = profiler.as_ref().map(|p| p.root("compress"));
        swsc::coordinator::compress_model_traced(
            &ck,
            &plan,
            workers,
            None,
            root.as_ref(),
            telemetry_out.is_some(),
        )?
    };
    for s in &outcome.stats {
        println!("  {s}");
    }
    let mut report = outcome.telemetry;
    if let Some(rep) = report.as_mut() {
        rep.seed = seed;
    }
    let mut file = outcome.file;
    if precision == "int8" {
        // Double compression: re-store the factors as grouped int8. The
        // serving path consumes the codes directly (fused dequant GEMM).
        let quant_root = profiler.as_ref().map(|p| p.root("quantize"));
        let names: Vec<String> = file.compressed.keys().cloned().collect();
        for name in names {
            let c = file.compressed.remove(&name).expect("listed name present");
            let q = {
                let _sc = swsc::obs::prof::scope(quant_root.as_ref(), &name);
                c.quantize(&QuantConfig { group })
            };
            if let Some(tel) =
                report.as_mut().and_then(|r| r.matrices.iter_mut().find(|m| m.name == name))
            {
                // Grid error across all three quantized payloads: worst
                // max, element-weighted mean of the MSEs.
                let parts = [
                    (q.centroids.grid_error(&c.centroids), c.centroids.len()),
                    (q.factor_a.grid_error(&c.factor_a), c.factor_a.len()),
                    (q.factor_b.grid_error(&c.factor_b), c.factor_b.len()),
                ];
                let total: usize = parts.iter().map(|(_, n)| n).sum();
                for ((max_abs, mse), n) in parts {
                    tel.grid_error_max = tel.grid_error_max.max(max_abs);
                    if total > 0 {
                        tel.grid_error_mse += mse * n as f64 / total as f64;
                    }
                }
            }
            file.quantized.insert(name, q);
        }
    }
    {
        let _sc = profiler.as_ref().map(|p| p.root("serialize"));
        file.save(&out)?;
    }
    let file_bytes = std::fs::metadata(&out)?.len() as usize;
    println!(
        "wrote {} ({}) in {:.2}s",
        out.display(),
        swsc::util::human_bytes(file_bytes),
        outcome.wall_seconds
    );

    // Storage accounting: per-entry avg-bits estimates, then the actual
    // bytes-per-parameter of the file just written.
    let mut rows: Vec<StorageRow> = Vec::new();
    let mut total_params = 0usize;
    for (name, c) in &file.compressed {
        rows.push(StorageRow {
            name: name.clone(),
            shape: c.shape,
            k: c.k(),
            rank: c.rank(),
            group: None,
        });
        total_params += c.shape.0 * c.shape.1;
    }
    for (name, q) in &file.quantized {
        rows.push(StorageRow {
            name: name.clone(),
            shape: q.shape,
            k: q.k(),
            rank: q.rank(),
            group: Some(q.group()),
        });
        total_params += q.shape.0 * q.shape.1;
    }
    total_params += file.dense.values().map(|t| t.len()).sum::<usize>();
    print!("{}", render_storage(&rows, file_bytes, total_params));

    if let (Some(path), Some(rep)) = (&telemetry_out, &report) {
        std::fs::write(path, rep.to_json())?;
        println!("wrote telemetry {} ({} matrices)", path.display(), rep.matrices.len());
        print!("{}", swsc::report::render_telemetry(rep));
    }
    if let Some(p) = &profiler {
        eprintln!("--- profile (SWSC_PROF) ---");
        eprint!("{}", p.render_text());
        if let Some(chrome) = prof_cfg.as_ref().and_then(|c| c.chrome_out.as_ref()) {
            std::fs::write(chrome, p.to_chrome_json())?;
            eprintln!("wrote profile timeline {chrome} (Perfetto / chrome://tracing)");
        }
    }
    Ok(())
}

fn cmd_eval(opts: &Opts) -> Result<()> {
    let cfg = ModelConfig::by_name(opt(opts, "preset", "small"))?;
    // Same SWSC_PROF gate as cmd_compress: eval phases land in the same
    // call-tree render (observation-only — the result bits never depend
    // on whether a profiler is attached).
    let profiler = swsc::obs::prof::ProfConfig::from_env().map(|_| swsc::obs::prof::Profiler::new());
    let eval_data = {
        let _sc = profiler.as_ref().map(|p| p.root("eval/data"));
        let (_tok, _train, eval_data) = corpus_and_data(&cfg, opt(opts, "seed", "42").parse()?);
        eval_data
    };

    let _eval_scope = profiler.as_ref().map(|p| p.root("eval/perplexity"));
    let res = match opt(opts, "engine", "pjrt") {
        // PR 7: the whole forward in the compressed domain — no PJRT,
        // no artifacts, no reconstructed weights. Only `.swsc` input
        // makes sense here (a checkpoint has nothing compressed to serve).
        "compressed" => {
            let p = opts
                .get("swsc")
                .context("--engine compressed evaluates a container: need --swsc")?;
            let file = SwscFile::load(Path::new(p))?;
            swsc::eval::perplexity_swsc_compressed(
                &file,
                &cfg,
                swsc::infer::InferMode::Compressed,
                &eval_data,
                swsc::exec::global(),
            )?
        }
        "pjrt" => {
            let engine = engine_for(opts, &cfg)?;
            let evaluator = Evaluator::new(engine, cfg)?;
            if let Some(p) = opts.get("swsc") {
                let file = SwscFile::load(Path::new(p))?;
                // fwd_eval takes dense literals (restored host-side); the
                // no-reconstruction route is `--engine compressed` above.
                evaluator.perplexity_of_swsc(&file, &eval_data)?
            } else if let Some(p) = opts.get("ckpt") {
                evaluator.perplexity_of(&Checkpoint::load(Path::new(p))?, &eval_data)?
            } else {
                bail!("need --ckpt or --swsc");
            }
        }
        other => bail!("unknown eval engine `{other}` (pjrt|compressed)"),
    };
    drop(_eval_scope);
    println!("perplexity {:.4}  (nll/token {:.4}, {} tokens, {} batches)", res.perplexity, res.nll_per_token, res.tokens, res.batches);
    if let Some(p) = &profiler {
        eprintln!("--- profile (SWSC_PROF) ---");
        eprint!("{}", p.render_text());
    }
    Ok(())
}

/// The Table-I experiment: for each projector set and bit budget, compare
/// RTN vs SWSC perplexity at equal storage.
fn cmd_table1(opts: &Opts) -> Result<()> {
    let cfg = ModelConfig::by_name(opt(opts, "preset", "small"))?;
    let engine = engine_for(opts, &cfg)?;
    let seed: u64 = opt(opts, "seed", "42").parse()?;
    let workers: usize = opt(opts, "workers", "8").parse()?;
    let ckpt = PathBuf::from(opts.get("ckpt").context("--ckpt required (train first)")?);
    let bits_list: Vec<f64> = opt(opts, "bits", "3,2")
        .split(',')
        .map(|s| s.parse::<f64>().map_err(Into::into))
        .collect::<Result<_>>()?;

    let ck = Checkpoint::load(&ckpt)?;
    let (_tok, _train, eval_data) = corpus_and_data(&cfg, seed);
    let evaluator = Evaluator::new(engine, cfg.clone())?;

    let fp32 = evaluator.perplexity_of(&ck, &eval_data)?.perplexity;
    println!("fp32 baseline perplexity: {fp32:.3}\n");

    let mut rows = Vec::new();
    for proj in [ProjectorSet::Q, ProjectorSet::K, ProjectorSet::QAndK] {
        for &bits in &bits_list {
            // RTN baseline at the same storage budget.
            let rtn_ppl = {
                let mut qck = ck.clone();
                let rtn_cfg = RtnConfig { bits: bits.round() as u32, ..Default::default() };
                for (name, _) in ck.shapes() {
                    if proj.matches(&name) {
                        let t = qck.get(&name).unwrap();
                        let q = rtn_quantize(t, &rtn_cfg);
                        qck.insert(&name, q);
                    }
                }
                evaluator.perplexity_of(&qck, &eval_data)?.perplexity
            };
            rows.push(Table1Row {
                projector: proj.label().into(),
                method: "RTN".into(),
                avg_bits: bits,
                perplexity: rtn_ppl,
            });

            // SWSC at the same budget.
            let plan = CompressionPlan::for_target_bits(&ck.shapes(), proj, bits, 0.5, seed);
            let outcome = compress_model(&ck, &plan, workers, None)?;
            let mut sck = ck.clone();
            for (name, t) in outcome.file.restore_all() {
                sck.insert(&name, t);
            }
            let swsc_ppl = evaluator.perplexity_of(&sck, &eval_data)?.perplexity;
            rows.push(Table1Row {
                projector: proj.label().into(),
                method: "SWSC".into(),
                avg_bits: bits,
                perplexity: swsc_ppl,
            });
            println!(
                "{:<6} {:>4} bits: RTN {:>10.3}  SWSC {:>10.3}",
                proj.label(),
                bits,
                rtn_ppl,
                swsc_ppl
            );
        }
    }

    let table = render_table1(
        &format!("{} on synthetic tiny-wiki (paper: Llama-2-7B on WikiText-2)", cfg.fingerprint()),
        fp32,
        &rows,
    );
    println!("\n{table}");
    if let Some(out) = opts.get("out") {
        std::fs::write(out, &table)?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_table2(opts: &Opts) -> Result<()> {
    let m: usize = opt(opts, "m", "4096").parse()?;
    println!("{}", render_table2(m));
    if m != 4096 {
        println!("(paper reports m = 4096; shown for m = {m})");
    }
    Ok(())
}

/// Fig. 1 end-to-end: train → compress → restore → eval.
fn cmd_pipeline(opts: &Opts) -> Result<()> {
    let mut o = opts.clone();
    let out = opt(opts, "out", "runs/pipeline").to_string();
    o.insert("out".into(), out.clone());
    cmd_train(&o)?;
    o.insert("ckpt".into(), format!("{out}/model.swck"));
    o.insert("out".into(), format!("{out}/model.swsc"));
    cmd_compress(&o)?;
    let mut e = opts.clone();
    e.insert("swsc".into(), format!("{out}/model.swsc"));
    cmd_eval(&e)
}

/// PR 9 observability demo: build a tiny in-memory compressed model,
/// serve a seeded mixed replay (linear + forward, with an alias name so
/// the per-model labels show alias collapsing) with **tracing enabled**,
/// then export the request timeline as Chrome trace-event JSON and print
/// the Prometheus / JSON metric snapshots.
fn cmd_trace(opts: &Opts) -> Result<()> {
    use std::sync::Arc;
    use swsc::bench::loadgen::{
        run_forward_loadgen, run_loadgen, ForwardLoadgenConfig, LoadgenConfig,
    };
    use swsc::compress::{compress_matrix, SwscConfig};
    use swsc::infer::InferMode;
    use swsc::obs::TraceConfig;
    use swsc::serve::{BatchConfig, BatchServer, ModelRegistry, ServerOptions, DEFAULT_MODEL};

    let out = PathBuf::from(opt(opts, "out", "trace.json"));
    let requests: usize = opt(opts, "requests", "48").parse()?;
    let fwd_requests: usize = opt(opts, "forward-requests", "12").parse()?;
    let seed: u64 = opt(opts, "seed", "42").parse()?;

    // Tiny in-memory model — no checkpoint needed. Compress every wide
    // 2-D parameter, keep the rest dense (the loadgen benches' servable
    // split).
    let cfg = ModelConfig::tiny();
    let ck = swsc::model::init_params(&cfg, seed);
    let mut file = SwscFile::new();
    for spec in swsc::model::param_specs(&cfg) {
        let t = ck.get(&spec.name).context("init param present")?.clone();
        if spec.shape.len() == 2 && spec.shape[1] >= 16 {
            file.compressed.insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
        } else {
            file.dense.insert(spec.name.clone(), t);
        }
    }
    let reg = ModelRegistry::new();
    let fwd = reg.insert_forward_file(DEFAULT_MODEL, &file, cfg, InferMode::Compressed)?;
    // Alias the same model under a second name: per-model metric labels
    // collapse aliases to the canonical (lexicographically first) name.
    reg.insert_forward("tiny-alias", fwd);
    let weight = file.compressed.keys().next().context("a compressed weight")?.clone();

    let server = BatchServer::start_with_opts(
        Arc::new(reg),
        BatchConfig::default(),
        // Tracing is always on for this command; SWSC_TRACE_CAPACITY still
        // sizes the ring so long replays can avoid saturating it.
        ServerOptions {
            // Force the gate on, but let SWSC_TRACE_CAPACITY size the ring.
            trace: Some(
                TraceConfig::from_lookup(|k| {
                    if k == "SWSC_TRACE" {
                        Some("1".into())
                    } else {
                        std::env::var(k).ok()
                    }
                })
                .unwrap_or_default(),
            ),
            ..ServerOptions::default()
        },
    );

    let lin = run_loadgen(
        &server,
        &LoadgenConfig {
            seed,
            requests,
            rows_per_request: 4,
            ragged: true,
            targets: vec![
                (DEFAULT_MODEL.into(), weight.clone()),
                ("tiny-alias".into(), weight),
            ],
            ..LoadgenConfig::default()
        },
    )?;
    println!("linear : {}", lin.render());
    let fw = run_forward_loadgen(
        &server,
        &ForwardLoadgenConfig {
            seed,
            requests: fwd_requests,
            max_tokens: 8,
            models: vec![DEFAULT_MODEL.into(), "tiny-alias".into()],
            ..ForwardLoadgenConfig::default()
        },
    )?;
    println!("forward: {}", fw.render());

    let json = server.dump_trace().context("tracing was enabled above")?;
    std::fs::write(&out, &json)?;
    let records = server.trace_sink().map(|t| t.len()).unwrap_or(0);
    println!(
        "wrote {} ({records} trace records) — load it in Perfetto or chrome://tracing",
        out.display()
    );
    // A saturated ring means the timeline silently lost its oldest spans —
    // say so once, and export the loss so scrapes can alert on it.
    let dropped = server.trace_sink().map(|t| t.dropped()).unwrap_or(0);
    if dropped > 0 {
        eprintln!(
            "warning: trace ring saturated — {dropped} record(s) dropped; \
             raise SWSC_TRACE_CAPACITY"
        );
        server.metrics().counter_total("obs.trace_dropped", dropped);
    }
    swsc::obs::prof::counters::export_kernel_counters(server.metrics().as_ref());

    println!("\n--- prometheus ---");
    print!("{}", server.metrics().render_prometheus());
    println!("\n--- json snapshot ---");
    println!("{}", server.metrics().render_json());
    server.shutdown();
    Ok(())
}

fn cmd_info(opts: &Opts) -> Result<()> {
    let cfg = ModelConfig::by_name(opt(opts, "preset", "small"))?;
    println!("preset:      {}", opt(opts, "preset", "small"));
    println!("fingerprint: {}", cfg.fingerprint());
    println!("params:      {}", cfg.param_count());
    println!("channels:    d_model = {} (paper m = 4096)", cfg.d_model);
    let dir = PathBuf::from(opt(opts, "artifacts", "artifacts"));
    match ArtifactManifest::load(&dir, opt(opts, "preset", "small")) {
        Ok(man) => {
            println!("artifacts:   {} executables in {}", man.executables.len(), dir.display());
            for name in man.executables.keys() {
                println!("  - {name}");
            }
        }
        Err(e) => println!("artifacts:   not available ({e})"),
    }
    Ok(())
}
