//! Batched evaluation service.
//!
//! A vLLM-router-style front end over the `fwd_eval` executable: clients
//! submit [`EvalRequest`]s (one token window each) and receive per-request
//! NLL. A dedicated batcher thread drains a bounded queue, packs up to
//! `batch` requests into the executable's fixed `[batch, seq]` shape
//! (padding short batches by repeating row 0 — padded rows are discarded on
//! the way out), executes, and replies through per-request channels.
//!
//! Invariants (property-tested in `rust/tests/prop_invariants.rs`):
//! - every submitted request receives exactly one response;
//! - a batch never exceeds the executable's batch size;
//! - the queue bound enforces backpressure on submitters;
//! - responses are independent of how requests were interleaved into
//!   batches (same tokens ⇒ same NLL).

use crate::coordinator::metrics::Metrics;
use crate::model::ModelConfig;
use crate::runtime::convert::literal_to_tensor;
use crate::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine};
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One evaluation request: a `seq+1`-token window (input + next-token
/// targets derive from it).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub tokens: Vec<i32>,
}

/// Per-request response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResponse {
    /// Sum of negative log-likelihood over the window.
    pub nll_sum: f64,
    /// Number of scored tokens.
    pub tokens: usize,
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bounded queue capacity (backpressure limit).
    pub queue_capacity: usize,
    /// Max time the batcher waits to fill a batch before flushing a
    /// partial one.
    pub max_batch_delay: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { queue_capacity: 256, max_batch_delay: Duration::from_millis(10) }
    }
}

enum Job {
    Eval(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>),
    Shutdown,
}

/// Handle to a running evaluation service.
pub struct EvalService {
    tx: mpsc::SyncSender<Job>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    seq: usize,
}

impl EvalService {
    /// Spawn the batcher thread.
    ///
    /// PJRT handles are `!Send` (the xla crate wraps raw pointers in `Rc`),
    /// so the batcher thread constructs its *own* [`Engine`] from the
    /// manifest — only `Send` data (manifest, host tensors, channels)
    /// crosses the thread boundary.
    pub fn start(
        manifest: ArtifactManifest,
        cfg: ModelConfig,
        host_params: Vec<crate::tensor::Tensor>,
        svc_cfg: ServiceConfig,
    ) -> Result<EvalService> {
        manifest.verify_config(&cfg)?;
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Job>(svc_cfg.queue_capacity);
        let m = metrics.clone();
        let seq = cfg.seq;

        let worker = std::thread::spawn(move || {
            let engine = match Engine::new(manifest) {
                Ok(e) => e,
                Err(err) => {
                    let msg = format!("engine init failed: {err:#}");
                    for job in rx {
                        if let Job::Eval(_, tx) = job {
                            let _ = tx.send(Err(msg.clone()));
                        }
                    }
                    return;
                }
            };
            batcher_loop(engine, cfg, host_params, rx, svc_cfg, m);
        });
        Ok(EvalService { tx, worker: Some(worker), metrics, seq })
    }

    /// Submit a request; blocks when the queue is full (backpressure).
    /// Returns a receiver for the response.
    pub fn submit(&self, req: EvalRequest) -> Result<mpsc::Receiver<Result<EvalResponse, String>>> {
        anyhow::ensure!(
            req.tokens.len() == self.seq + 1,
            "request wants {} tokens (seq+1), got {}",
            self.seq + 1,
            req.tokens.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Job::Eval(req, rtx)).context("service stopped")?;
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn eval_blocking(&self, req: EvalRequest) -> Result<EvalResponse> {
        let rx = self.submit(req)?;
        rx.recv().context("service dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Graceful shutdown: drain, stop the batcher.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for EvalService {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batcher_loop(
    engine: Engine,
    cfg: ModelConfig,
    host_params: Vec<crate::tensor::Tensor>,
    rx: mpsc::Receiver<Job>,
    svc_cfg: ServiceConfig,
    metrics: Arc<Metrics>,
) {
    let exe = match engine.load("fwd_eval") {
        Ok(e) => e,
        Err(err) => {
            // Fail every request that arrives.
            let msg = format!("fwd_eval load failed: {err:#}");
            for job in rx {
                if let Job::Eval(_, tx) = job {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
            return;
        }
    };

    let mut pending: Vec<(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>)> = Vec::new();
    let mut shutting_down = false;
    loop {
        // Fill up to a full batch or until the delay elapses.
        let deadline = std::time::Instant::now() + svc_cfg.max_batch_delay;
        while pending.len() < cfg.batch && !shutting_down {
            let timeout = deadline.saturating_duration_since(std::time::Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Job::Eval(req, tx)) => pending.push((req, tx)),
                Ok(Job::Shutdown) => shutting_down = true,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutting_down = true;
                }
            }
        }
        if pending.is_empty() {
            if shutting_down {
                return;
            }
            continue;
        }

        let real = pending.len();
        metrics.incr("service.batches", 1);
        metrics.incr("service.requests", real as u64);
        if real < cfg.batch {
            metrics.incr("service.padded_rows", (cfg.batch - real) as u64);
        }

        let t0 = std::time::Instant::now();
        let result = run_batch(&exe, &cfg, &host_params, &pending);
        metrics.record("service.batch_seconds", t0.elapsed().as_secs_f64());

        match result {
            Ok(responses) => {
                for ((_, tx), resp) in pending.drain(..).zip(responses) {
                    let _ = tx.send(Ok(resp));
                }
            }
            Err(err) => {
                let msg = format!("batch failed: {err:#}");
                for (_, tx) in pending.drain(..) {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
        }
        if shutting_down {
            return;
        }
    }
}

fn run_batch(
    exe: &crate::runtime::LoadedExec,
    cfg: &ModelConfig,
    host_params: &[crate::tensor::Tensor],
    pending: &[(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>)],
) -> Result<Vec<EvalResponse>> {
    let real = pending.len();
    // Pack rows; pad the tail by repeating the first request (discarded).
    let mut inputs_flat = Vec::with_capacity(cfg.batch * cfg.seq);
    let mut targets_flat = Vec::with_capacity(cfg.batch * cfg.seq);
    for row in 0..cfg.batch {
        let req = &pending[row.min(real - 1)].0;
        inputs_flat.extend_from_slice(&req.tokens[..cfg.seq]);
        targets_flat.extend_from_slice(&req.tokens[1..cfg.seq + 1]);
    }

    let mut args = Vec::with_capacity(host_params.len() + 2);
    for t in host_params {
        args.push(tensor_to_literal(t)?);
    }
    args.push(tokens_to_literal(&inputs_flat, cfg.batch, cfg.seq)?);
    args.push(tokens_to_literal(&targets_flat, cfg.batch, cfg.seq)?);

    let outs = exe.run(&args)?;
    let nll_rows = literal_to_tensor(&outs[0])?;
    let tok_rows = literal_to_tensor(&outs[1])?;
    Ok((0..real)
        .map(|i| EvalResponse {
            nll_sum: nll_rows.data()[i] as f64,
            tokens: tok_rows.data()[i] as usize,
        })
        .collect())
}

/// Shared lock for tests that need a single service at a time (PJRT CPU
/// clients are heavy; serializing keeps test memory bounded).
pub static TEST_SERVICE_LOCK: Mutex<()> = Mutex::new(());
