//! Batched evaluation + compressed-domain linear serving.
//!
//! A vLLM-router-style front end with two request kinds:
//!
//! - [`EvalRequest`] (one token window each) → per-request NLL. A
//!   dedicated batcher thread drains a bounded queue, packs up to `batch`
//!   requests into the `fwd_eval` executable's fixed `[batch, seq]` shape
//!   (padding short batches by repeating row 0 — padded rows are discarded
//!   on the way out), executes through PJRT, and replies through
//!   per-request channels.
//! - [`LinearRequest`] (named weight + activation batch) → `Y = X·W`,
//!   served host-side from a [`CompressedModel`]. Behind the
//!   [`ServiceConfig::infer_mode`] flag these run **in the compressed
//!   domain** — bucket-sum/gather + low-rank GEMMs straight from the
//!   `.swsc` factors, no dense weight ever materialized
//!   ([`InferMode::Compressed`], the default) — or from weights
//!   reconstructed once at load ([`InferMode::Reconstructed`], the dense
//!   oracle/baseline). With [`Batching::Enabled`] (the default) linear
//!   requests route through a [`crate::serve::BatchServer`]: a coalescer
//!   thread stacks concurrent requests into micro-batches, one `apply`
//!   per (model, weight) group — bitwise identical to the inline path
//!   because `apply` is row-independent, and free of the old caveat that
//!   a linear request could queue behind an in-flight PJRT eval batch.
//!   [`Batching::Disabled`] keeps the inline path as the bitwise oracle,
//!   mirroring `ExecBackend::SpawnPerCall` / `GemmKernel::Blocked` /
//!   `InferMode::Reconstructed`.
//! - [`ForwardRequest`] (a token window) → `[tokens, vocab]` logits from
//!   the **whole transformer stack in the compressed domain** (PR 7): a
//!   [`CompressedForward`] chains every attention/MLP linear through the
//!   factored form with no reconstruction. With batching enabled these
//!   ride the coalescer's continuous-batching scheduler (requests
//!   join/leave the in-flight batch at layer boundaries); disabled, the
//!   batcher thread runs each solo — bitwise identical either way.
//!
//! The PJRT engine is constructed lazily on the first eval request, so a
//! linear-only service (started with [`EvalService::start_with_swsc`] and
//! no artifact manifest) works without any AOT artifacts — which is also
//! what `examples/serve_compressed.rs` and `examples/serve_batched.rs`
//! demonstrate.
//!
//! Invariants:
//! - every submitted request receives exactly one response — including at
//!   shutdown: requests still queued behind the shutdown marker are
//!   answered with an explicit shutdown error, never dropped silently;
//! - a batch never exceeds the executable's batch size;
//! - the queue bound enforces backpressure on submitters (blocking
//!   `submit_linear`, or explicit `Overloaded` via
//!   [`EvalService::try_submit_linear`]);
//! - responses are independent of how requests were interleaved into
//!   batches (same tokens ⇒ same NLL; linear responses are additionally
//!   bit-identical at any `SWSC_THREADS` *and* at any coalescing — the
//!   `infer` + `serve` contracts).

use crate::coordinator::metrics::Metrics;
use crate::infer::{CompressedForward, CompressedModel, InferMode, Precision};
use crate::io::SwscFile;
use crate::model::ModelConfig;
use crate::runtime::convert::literal_to_tensor;
use crate::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine, LoadedExec};
use crate::serve::{
    AdmissionError, BatchServer, Batching, FaultConfig, ModelRegistry, QuotaConfig, ServeError,
    ServerOptions, DEFAULT_MODEL,
};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub use crate::serve::{ForwardRequest, ForwardResponse, LinearRequest, LinearResponse};

/// One evaluation request: a `seq+1`-token window (input + next-token
/// targets derive from it).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub tokens: Vec<i32>,
}

/// Per-request response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResponse {
    /// Sum of negative log-likelihood over the window.
    pub nll_sum: f64,
    /// Number of scored tokens.
    pub tokens: usize,
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bounded queue capacity (backpressure limit) — applies to the eval
    /// batcher queue and, when batching is enabled, to the linear
    /// admission queue.
    pub queue_capacity: usize,
    /// Max time the eval batcher waits to fill a batch before flushing a
    /// partial one.
    pub max_batch_delay: Duration,
    /// How linear requests are served when the service holds a
    /// [`CompressedModel`] (see [`EvalService::start_with_swsc`]).
    pub infer_mode: InferMode,
    /// Arithmetic for the compressed entries: [`Precision::F32`] (the
    /// default oracle) or [`Precision::Int8`] fused-dequant serving.
    pub precision: Precision,
    /// Micro-batch coalescing for linear requests: enabled by default,
    /// [`Batching::Disabled`] is the inline bitwise oracle.
    pub batching: Batching,
    /// Per-model admission quotas for the batched front end (PR 8).
    /// Empty (the default) means unlimited.
    pub quotas: QuotaConfig,
    /// Seeded fault injection (PR 8). Defaults to the `SWSC_FAULT_*`
    /// environment: unset means `None` — injection fully off.
    pub faults: Option<FaultConfig>,
    /// Request-scoped tracing for the batched front end (PR 9). Defaults
    /// to the `SWSC_TRACE` environment; `None` is the zero-cost off
    /// state. The inline ([`Batching::Disabled`]) path stays untraced —
    /// it is the bitwise oracle and the simplest possible code path.
    pub trace: Option<crate::obs::TraceConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            queue_capacity: 256,
            max_batch_delay: Duration::from_millis(10),
            infer_mode: InferMode::Compressed,
            precision: Precision::default(),
            batching: Batching::default(),
            quotas: QuotaConfig::default(),
            faults: FaultConfig::from_env(),
            trace: crate::obs::TraceConfig::from_env(),
        }
    }
}

enum Job {
    Eval(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>),
    Linear(LinearRequest, mpsc::Sender<Result<LinearResponse, ServeError>>),
    Forward(ForwardRequest, mpsc::Sender<Result<ForwardResponse, ServeError>>),
    Shutdown,
}

/// Handle to a running evaluation service.
pub struct EvalService {
    tx: mpsc::SyncSender<Job>,
    worker: Option<std::thread::JoinHandle<()>>,
    batch: Option<BatchServer>,
    /// The whole-model compressed forward (PR 7), when the `.swsc`
    /// container covers every parameter of the config. `None` on
    /// partial (linear-only) containers — forward requests then get an
    /// explicit error instead of a mid-request shape panic.
    forward: Option<Arc<CompressedForward>>,
    pub metrics: Arc<Metrics>,
    seq: usize,
}

impl EvalService {
    /// Spawn the batcher thread over explicit dense parameters — the
    /// original eval-only surface (no compressed model; linear requests
    /// are answered with an error).
    ///
    /// PJRT handles are `!Send` (the xla crate wraps raw pointers in `Rc`),
    /// so the batcher thread constructs its *own* [`Engine`] from the
    /// manifest — only `Send` data (manifest, host tensors, channels)
    /// crosses the thread boundary.
    pub fn start(
        manifest: ArtifactManifest,
        cfg: ModelConfig,
        host_params: Vec<Tensor>,
        svc_cfg: ServiceConfig,
    ) -> Result<EvalService> {
        manifest.verify_config(&cfg)?;
        Ok(Self::spawn(Some(manifest), cfg, host_params, None, svc_cfg))
    }

    /// Spawn the batcher over a `.swsc` container. Linear requests are
    /// served from a [`CompressedModel`] built in `svc_cfg.infer_mode` —
    /// with [`InferMode::Compressed`] the dense weights are never
    /// materialized for that surface.
    ///
    /// `manifest = Some(..)` additionally enables the PJRT eval path; the
    /// `fwd_eval` executable's contract is dense parameter literals, so
    /// the container must then cover every model parameter and compressed
    /// entries are restored host-side for that path only (the
    /// accelerator-side analog is the L1 `decode_matmul` kernel). With
    /// `manifest = None` the service is linear-only and needs no
    /// artifacts.
    pub fn start_with_swsc(
        manifest: Option<ArtifactManifest>,
        cfg: ModelConfig,
        file: &SwscFile,
        svc_cfg: ServiceConfig,
    ) -> Result<EvalService> {
        let host_params = if let Some(man) = &manifest {
            man.verify_config(&cfg)?;
            crate::eval::restore_param_tensors(file, &cfg)?
        } else {
            Vec::new()
        };
        let model = CompressedModel::from_file_with(file, svc_cfg.infer_mode, svc_cfg.precision);
        Ok(Self::spawn(manifest, cfg, host_params, Some(model), svc_cfg))
    }

    fn spawn(
        manifest: Option<ArtifactManifest>,
        cfg: ModelConfig,
        host_params: Vec<Tensor>,
        model: Option<CompressedModel>,
        svc_cfg: ServiceConfig,
    ) -> EvalService {
        let metrics = Arc::new(Metrics::new());
        let model = model.map(Arc::new);
        // Whole-model forward surface (PR 7): best-effort — a container
        // covering every parameter serves ForwardRequests; a partial
        // (linear-only) container leaves this None and forward
        // submissions get an explicit error.
        let forward = model
            .as_ref()
            .and_then(|m| CompressedForward::new(m.clone(), cfg.clone()).ok())
            .map(Arc::new);
        // Linear micro-batching front end: a BatchServer over a
        // single-model registry, sharing the service's metrics (and the
        // model's lazily packed panels, through the Arc). When the
        // forward exists it is registered under the same name, so the
        // coalescer's continuous-batching scheduler serves it too.
        let batch = match (&model, svc_cfg.batching) {
            (Some(m), Batching::Enabled(bc)) => {
                let registry = ModelRegistry::new();
                match &forward {
                    Some(f) => registry.insert_forward(DEFAULT_MODEL, f.clone()),
                    None => registry.insert(DEFAULT_MODEL, m.clone()),
                }
                Some(BatchServer::start_with_opts(
                    Arc::new(registry),
                    bc,
                    ServerOptions {
                        queue_capacity: svc_cfg.queue_capacity,
                        metrics: metrics.clone(),
                        quotas: svc_cfg.quotas.clone(),
                        faults: svc_cfg.faults.clone(),
                        trace: svc_cfg.trace.clone(),
                    },
                ))
            }
            _ => None,
        };
        let (tx, rx) = mpsc::sync_channel::<Job>(svc_cfg.queue_capacity);
        let m = metrics.clone();
        let seq = cfg.seq;
        let fwd_inline = forward.clone();
        let worker = std::thread::spawn(move || {
            batcher_loop(manifest, cfg, host_params, model, fwd_inline, rx, svc_cfg, m);
        });
        EvalService { tx, worker: Some(worker), batch, forward, metrics, seq }
    }

    /// Submit a request; blocks when the queue is full (backpressure).
    /// Returns a receiver for the response.
    pub fn submit(&self, req: EvalRequest) -> Result<mpsc::Receiver<Result<EvalResponse, String>>> {
        anyhow::ensure!(
            req.tokens.len() == self.seq + 1,
            "request wants {} tokens (seq+1), got {}",
            self.seq + 1,
            req.tokens.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Job::Eval(req, rtx)).context("service stopped")?;
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn eval_blocking(&self, req: EvalRequest) -> Result<EvalResponse> {
        let rx = self.submit(req)?;
        rx.recv().context("service dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit a linear request; blocks when the queue is full. With
    /// batching enabled this routes through the coalescer — responses are
    /// bitwise identical either way.
    pub fn submit_linear(
        &self,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>> {
        let rrx = match &self.batch {
            Some(server) => server
                .submit(DEFAULT_MODEL, req)
                .map_err(|e| anyhow::anyhow!("service stopped: {e}"))?,
            None => {
                let (rtx, rrx) = mpsc::channel();
                self.tx.send(Job::Linear(req, rtx)).context("service stopped")?;
                rrx
            }
        };
        self.metrics.incr("service.linear_requests", 1);
        Ok(rrx)
    }

    /// Non-blocking [`EvalService::submit_linear`]: a full queue is an
    /// explicit [`AdmissionError::Overloaded`] instead of a stall —
    /// load-shedding backpressure for callers that can retry or reroute.
    pub fn try_submit_linear(
        &self,
        req: LinearRequest,
    ) -> std::result::Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        let rrx = match &self.batch {
            Some(server) => server.try_submit(DEFAULT_MODEL, req)?,
            None => {
                let (rtx, rrx) = mpsc::channel();
                match self.tx.try_send(Job::Linear(req, rtx)) {
                    Ok(()) => rrx,
                    Err(mpsc::TrySendError::Full(_)) => return Err(AdmissionError::Overloaded),
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        return Err(AdmissionError::ShuttingDown)
                    }
                }
            }
        };
        self.metrics.incr("service.linear_requests", 1);
        Ok(rrx)
    }

    /// Submit a linear request and wait.
    pub fn linear_blocking(&self, req: LinearRequest) -> Result<LinearResponse> {
        let rx = self.submit_linear(req)?;
        rx.recv().context("service dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Whether the service can answer [`ForwardRequest`]s (the `.swsc`
    /// container covered every parameter of the model config).
    pub fn has_forward(&self) -> bool {
        self.forward.is_some()
    }

    /// Chrome trace-event JSON from the batched front end's trace ring
    /// (PR 9). `None` unless both batching and tracing are enabled.
    pub fn dump_trace(&self) -> Option<String> {
        self.batch.as_ref().and_then(|s| s.dump_trace())
    }

    /// Submit a whole-model forward request (PR 7); blocks when the
    /// queue is full. With batching enabled this routes through the
    /// coalescer's continuous-batching scheduler — responses are bitwise
    /// identical to the inline solo path either way (layer-boundary
    /// re-forming is pure scheduling; see `crate::infer::CompressedForward`).
    pub fn submit_forward(
        &self,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>> {
        anyhow::ensure!(
            self.forward.is_some(),
            "forward serving disabled: the .swsc container does not cover every model \
             parameter (linear requests only)"
        );
        let rrx = match &self.batch {
            Some(server) => server
                .submit_forward(DEFAULT_MODEL, req)
                .map_err(|e| anyhow::anyhow!("service stopped: {e}"))?,
            None => {
                let (rtx, rrx) = mpsc::channel();
                self.tx.send(Job::Forward(req, rtx)).context("service stopped")?;
                rrx
            }
        };
        self.metrics.incr("service.forward_requests", 1);
        Ok(rrx)
    }

    /// Non-blocking [`EvalService::submit_forward`]: a full queue is an
    /// explicit [`AdmissionError::Overloaded`].
    pub fn try_submit_forward(
        &self,
        req: ForwardRequest,
    ) -> std::result::Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        if self.forward.is_none() {
            return Err(AdmissionError::ShuttingDown);
        }
        let rrx = match &self.batch {
            Some(server) => server.try_submit_forward(DEFAULT_MODEL, req)?,
            None => {
                let (rtx, rrx) = mpsc::channel();
                match self.tx.try_send(Job::Forward(req, rtx)) {
                    Ok(()) => rrx,
                    Err(mpsc::TrySendError::Full(_)) => return Err(AdmissionError::Overloaded),
                    Err(mpsc::TrySendError::Disconnected(_)) => {
                        return Err(AdmissionError::ShuttingDown)
                    }
                }
            }
        };
        self.metrics.incr("service.forward_requests", 1);
        Ok(rrx)
    }

    /// Submit a forward request and wait for its `[tokens, vocab]` logits.
    pub fn forward_blocking(&self, req: ForwardRequest) -> Result<ForwardResponse> {
        let rx = self.submit_forward(req)?;
        rx.recv().context("service dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Signal shutdown without joining: the linear front end stops
    /// admitting (new submissions get [`AdmissionError::ShuttingDown`])
    /// and the eval batcher is woken with a shutdown marker. Requests
    /// already admitted are still served; anything behind the marker gets
    /// an explicit shutdown error. [`EvalService::shutdown`] (or drop)
    /// still joins the workers.
    pub fn begin_shutdown(&self) {
        if let Some(server) = &self.batch {
            server.begin_shutdown();
        }
        let _ = self.tx.send(Job::Shutdown);
    }

    /// Graceful shutdown: serve everything admitted, answer everything
    /// queued behind the marker with an explicit error, join the workers.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        if let Some(server) = self.batch.take() {
            server.shutdown();
        }
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for EvalService {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Lazily initialize the PJRT engine + `fwd_eval` — only ever on the
/// first eval request, so linear-only services never touch PJRT.
fn init_fwd_eval(manifest: &Option<ArtifactManifest>) -> Result<Arc<LoadedExec>, String> {
    let Some(man) = manifest else {
        return Err(
            "eval serving disabled: service started without an artifact manifest \
             (linear requests only)"
                .to_string(),
        );
    };
    Engine::new(man.clone())
        .and_then(|e| e.load("fwd_eval"))
        .map_err(|e| format!("fwd_eval init failed: {e:#}"))
}

/// Run `f` with the same panic containment the coalescer applies: a
/// panic becomes [`ServeError::Panicked`] (message preserved for
/// `&str`/`String` payloads), an ordinary error [`ServeError::Failed`].
fn contain_inline<T>(what: &str, f: impl FnOnce() -> Result<T>) -> std::result::Result<T, ServeError> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(ServeError::Failed(format!("{what} failed: {e:#}"))),
        Err(payload) => Err(ServeError::Panicked {
            message: crate::exec::panic_message(payload.as_ref())
                .unwrap_or("opaque panic payload")
                .to_string(),
        }),
    }
}

/// Mirror the coalescer's error accounting on the inline paths, so the
/// `serve.*` counters mean the same thing in both batching modes.
fn note_serve_error(metrics: &Metrics, err: &ServeError) {
    metrics.incr("serve.errors", 1);
    match err {
        ServeError::Panicked { .. } => metrics.incr("serve.panics", 1),
        ServeError::DeadlineExceeded => metrics.incr("serve.deadline_miss", 1),
        _ => {}
    }
}

fn serve_linear(
    model: &Option<Arc<CompressedModel>>,
    metrics: &Metrics,
    req: LinearRequest,
    tx: mpsc::Sender<Result<LinearResponse, ServeError>>,
) {
    let t0 = std::time::Instant::now();
    let resp = if req.expired() {
        Err(ServeError::DeadlineExceeded)
    } else {
        match model {
            None => Err(ServeError::Failed(
                "no compressed model loaded — start the service with start_with_swsc".to_string(),
            )),
            Some(m) => {
                let what = format!("linear `{}`", req.name);
                contain_inline(&what, || m.apply(&req.name, &req.x))
                    .map(|y| LinearResponse { y })
            }
        }
    };
    if let Err(e) = &resp {
        note_serve_error(metrics, e);
    }
    metrics.record("service.linear_seconds", t0.elapsed().as_secs_f64());
    let _ = tx.send(resp);
}

/// The inline (batching-disabled) forward path — the solo bitwise oracle
/// the coalescer's continuous-batching scheduler is measured against.
fn serve_forward(
    forward: &Option<Arc<CompressedForward>>,
    metrics: &Metrics,
    req: ForwardRequest,
    tx: mpsc::Sender<Result<ForwardResponse, ServeError>>,
) {
    let t0 = std::time::Instant::now();
    let resp = if req.expired() {
        Err(ServeError::DeadlineExceeded)
    } else {
        match forward {
            None => Err(ServeError::Failed(
                "forward serving disabled: the .swsc container does not cover every \
                 model parameter (linear requests only)"
                    .to_string(),
            )),
            Some(f) => contain_inline("forward", || f.forward(&req.tokens))
                .map(|logits| ForwardResponse { logits }),
        }
    };
    if let Err(e) = &resp {
        note_serve_error(metrics, e);
    }
    metrics.record("service.forward_seconds", t0.elapsed().as_secs_f64());
    let _ = tx.send(resp);
}

const SHUTDOWN_MSG: &str =
    "service shutting down — request was queued behind shutdown and not served";

/// ISSUE 5 satellite: every job still queued when the shutdown marker is
/// processed gets an explicit error response. Before this, the batcher
/// simply returned and the queued response senders were dropped silently.
fn drain_on_shutdown(rx: &mpsc::Receiver<Job>, metrics: &Metrics) {
    while let Ok(job) = rx.try_recv() {
        match job {
            Job::Eval(_, tx) => {
                metrics.incr("service.drained_on_shutdown", 1);
                let _ = tx.send(Err(SHUTDOWN_MSG.to_string()));
            }
            Job::Linear(_, tx) => {
                metrics.incr("service.drained_on_shutdown", 1);
                let _ = tx.send(Err(ServeError::ShuttingDown));
            }
            Job::Forward(_, tx) => {
                metrics.incr("service.drained_on_shutdown", 1);
                let _ = tx.send(Err(ServeError::ShuttingDown));
            }
            Job::Shutdown => {}
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    manifest: Option<ArtifactManifest>,
    cfg: ModelConfig,
    host_params: Vec<Tensor>,
    model: Option<Arc<CompressedModel>>,
    forward: Option<Arc<CompressedForward>>,
    rx: mpsc::Receiver<Job>,
    svc_cfg: ServiceConfig,
    metrics: Arc<Metrics>,
) {
    // Lazy `fwd_eval`: Option<Result> caches either the handle or the
    // init error (replayed to every later eval request).
    let mut exe: Option<Result<Arc<LoadedExec>, String>> = None;
    let mut pending: Vec<(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>)> = Vec::new();
    let mut shutting_down = false;
    loop {
        // Fill up to a full eval batch or until the delay elapses. Linear
        // requests (the batching-disabled path) are served inline — they
        // never wait on the batch clock.
        let deadline = std::time::Instant::now() + svc_cfg.max_batch_delay;
        while pending.len() < cfg.batch && !shutting_down {
            let timeout = deadline.saturating_duration_since(std::time::Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Job::Eval(req, tx)) => pending.push((req, tx)),
                Ok(Job::Linear(req, tx)) => serve_linear(&model, &metrics, req, tx),
                Ok(Job::Forward(req, tx)) => serve_forward(&forward, &metrics, req, tx),
                Ok(Job::Shutdown) => shutting_down = true,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutting_down = true;
                }
            }
        }
        if pending.is_empty() {
            if shutting_down {
                drain_on_shutdown(&rx, &metrics);
                return;
            }
            continue;
        }

        let real = pending.len();
        metrics.incr("service.batches", 1);
        metrics.incr("service.requests", real as u64);
        if real < cfg.batch {
            metrics.incr("service.padded_rows", (cfg.batch - real) as u64);
        }

        let exe_state = exe.get_or_insert_with(|| init_fwd_eval(&manifest));
        match exe_state {
            Err(msg) => {
                let msg = msg.clone();
                for (_, tx) in pending.drain(..) {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
            Ok(loaded) => {
                let t0 = std::time::Instant::now();
                let result = run_batch(loaded.as_ref(), &cfg, &host_params, &pending);
                metrics.record("service.batch_seconds", t0.elapsed().as_secs_f64());
                match result {
                    Ok(responses) => {
                        for ((_, tx), resp) in pending.drain(..).zip(responses) {
                            let _ = tx.send(Ok(resp));
                        }
                    }
                    Err(err) => {
                        let msg = format!("batch failed: {err:#}");
                        for (_, tx) in pending.drain(..) {
                            let _ = tx.send(Err(msg.clone()));
                        }
                    }
                }
            }
        }
        if shutting_down {
            drain_on_shutdown(&rx, &metrics);
            return;
        }
    }
}

fn run_batch(
    exe: &LoadedExec,
    cfg: &ModelConfig,
    host_params: &[Tensor],
    pending: &[(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>)],
) -> Result<Vec<EvalResponse>> {
    let real = pending.len();
    // Pack rows; pad the tail by repeating the first request (discarded).
    let mut inputs_flat = Vec::with_capacity(cfg.batch * cfg.seq);
    let mut targets_flat = Vec::with_capacity(cfg.batch * cfg.seq);
    for row in 0..cfg.batch {
        let req = &pending[row.min(real - 1)].0;
        inputs_flat.extend_from_slice(&req.tokens[..cfg.seq]);
        targets_flat.extend_from_slice(&req.tokens[1..cfg.seq + 1]);
    }

    let mut args = Vec::with_capacity(host_params.len() + 2);
    for t in host_params {
        args.push(tensor_to_literal(t)?);
    }
    args.push(tokens_to_literal(&inputs_flat, cfg.batch, cfg.seq)?);
    args.push(tokens_to_literal(&targets_flat, cfg.batch, cfg.seq)?);

    let outs = exe.run(&args)?;
    let nll_rows = literal_to_tensor(&outs[0])?;
    let tok_rows = literal_to_tensor(&outs[1])?;
    Ok((0..real)
        .map(|i| EvalResponse {
            nll_sum: nll_rows.data()[i] as f64,
            tokens: tok_rows.data()[i] as usize,
        })
        .collect())
}

/// Shared lock for tests that need a single service at a time (PJRT CPU
/// clients are heavy; serializing keeps test memory bounded).
pub static TEST_SERVICE_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::rng::Rng;

    fn tiny_model() -> Arc<CompressedModel> {
        let mut rng = Rng::new(90);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[16, 16], &mut rng), &SwscConfig::new(2, 1)),
        );
        Arc::new(CompressedModel::from_file(&file, InferMode::Compressed))
    }

    /// Deterministic drain-on-shutdown through the batcher loop itself:
    /// jobs ahead of the marker are served, jobs behind it — a linear
    /// and an eval request — get the explicit shutdown error. Runs the
    /// loop on this thread, so there is no race to construct.
    #[test]
    fn batcher_drains_queue_on_shutdown_with_explicit_errors() {
        let cfg = ModelConfig::tiny();
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Job>(16);
        let (t1, r1) = mpsc::channel();
        let (t2, r2) = mpsc::channel();
        let (t3, r3) = mpsc::channel();
        let (t4, r4) = mpsc::channel();
        let served = LinearRequest::new("w", Tensor::zeros(&[1, 16]));
        let queued = LinearRequest::new("w", Tensor::zeros(&[1, 16]));
        tx.send(Job::Linear(served, t1)).unwrap();
        tx.send(Job::Shutdown).unwrap();
        tx.send(Job::Linear(queued, t2)).unwrap();
        tx.send(Job::Eval(EvalRequest { tokens: vec![1; cfg.seq + 1] }, t3)).unwrap();
        tx.send(Job::Forward(ForwardRequest::new(vec![1, 2]), t4)).unwrap();
        drop(tx);
        batcher_loop(
            None,
            cfg,
            Vec::new(),
            Some(tiny_model()),
            None,
            rx,
            ServiceConfig::default(),
            metrics.clone(),
        );
        assert!(r1.recv().unwrap().is_ok(), "job ahead of the marker must be served");
        assert_eq!(r2.recv().unwrap().unwrap_err(), ServeError::ShuttingDown);
        assert!(r3.recv().unwrap().unwrap_err().contains("shutting down"));
        assert_eq!(r4.recv().unwrap().unwrap_err(), ServeError::ShuttingDown);
        assert_eq!(metrics.counter("service.drained_on_shutdown"), 3);
    }
}
