//! Batched evaluation + compressed-domain linear serving.
//!
//! A vLLM-router-style front end with two request kinds:
//!
//! - [`EvalRequest`] (one token window each) → per-request NLL. A
//!   dedicated batcher thread drains a bounded queue, packs up to `batch`
//!   requests into the `fwd_eval` executable's fixed `[batch, seq]` shape
//!   (padding short batches by repeating row 0 — padded rows are discarded
//!   on the way out), executes through PJRT, and replies through
//!   per-request channels.
//! - [`LinearRequest`] (named weight + activation batch) → `Y = X·W`,
//!   served host-side from a [`CompressedModel`]. Behind the
//!   [`ServiceConfig::infer_mode`] flag these run **in the compressed
//!   domain** — bucket-sum/gather + low-rank GEMMs straight from the
//!   `.swsc` factors, no dense weight ever materialized
//!   ([`InferMode::Compressed`], the default) — or from weights
//!   reconstructed once at load ([`InferMode::Reconstructed`], the dense
//!   oracle/baseline). Linear requests are answered inline as they
//!   arrive and never wait on the batch *fill clock*; one caveat: the
//!   single batcher thread serves both kinds, so a linear request that
//!   lands while an eval batch is executing on PJRT queues behind that
//!   in-flight execution.
//!
//! The PJRT engine is constructed lazily on the first eval request, so a
//! linear-only service (started with [`EvalService::start_with_swsc`] and
//! no artifact manifest) works without any AOT artifacts — which is also
//! what `examples/serve_compressed.rs` demonstrates.
//!
//! Invariants:
//! - every submitted request receives exactly one response;
//! - a batch never exceeds the executable's batch size;
//! - the queue bound enforces backpressure on submitters;
//! - responses are independent of how requests were interleaved into
//!   batches (same tokens ⇒ same NLL; linear responses are additionally
//!   bit-identical at any `SWSC_THREADS` — the `infer` contract).

use crate::coordinator::metrics::Metrics;
use crate::infer::{CompressedModel, InferMode};
use crate::io::SwscFile;
use crate::model::ModelConfig;
use crate::runtime::convert::literal_to_tensor;
use crate::runtime::{tensor_to_literal, tokens_to_literal, ArtifactManifest, Engine, LoadedExec};
use crate::tensor::Tensor;
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One evaluation request: a `seq+1`-token window (input + next-token
/// targets derive from it).
#[derive(Debug, Clone)]
pub struct EvalRequest {
    pub tokens: Vec<i32>,
}

/// Per-request response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalResponse {
    /// Sum of negative log-likelihood over the window.
    pub nll_sum: f64,
    /// Number of scored tokens.
    pub tokens: usize,
}

/// One linear-layer request: apply the named weight to a row-major
/// activation batch (`x` is `[b, in_features]`).
#[derive(Debug, Clone)]
pub struct LinearRequest {
    pub name: String,
    pub x: Tensor,
}

/// Response to a [`LinearRequest`]: `y = x · W[name]`, `[b, out_features]`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinearResponse {
    pub y: Tensor,
}

/// Service tuning knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Bounded queue capacity (backpressure limit).
    pub queue_capacity: usize,
    /// Max time the batcher waits to fill a batch before flushing a
    /// partial one.
    pub max_batch_delay: Duration,
    /// How linear requests are served when the service holds a
    /// [`CompressedModel`] (see [`EvalService::start_with_swsc`]).
    pub infer_mode: InferMode,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            queue_capacity: 256,
            max_batch_delay: Duration::from_millis(10),
            infer_mode: InferMode::Compressed,
        }
    }
}

enum Job {
    Eval(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>),
    Linear(LinearRequest, mpsc::Sender<Result<LinearResponse, String>>),
    Shutdown,
}

/// Handle to a running evaluation service.
pub struct EvalService {
    tx: mpsc::SyncSender<Job>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    seq: usize,
}

impl EvalService {
    /// Spawn the batcher thread over explicit dense parameters — the
    /// original eval-only surface (no compressed model; linear requests
    /// are answered with an error).
    ///
    /// PJRT handles are `!Send` (the xla crate wraps raw pointers in `Rc`),
    /// so the batcher thread constructs its *own* [`Engine`] from the
    /// manifest — only `Send` data (manifest, host tensors, channels)
    /// crosses the thread boundary.
    pub fn start(
        manifest: ArtifactManifest,
        cfg: ModelConfig,
        host_params: Vec<Tensor>,
        svc_cfg: ServiceConfig,
    ) -> Result<EvalService> {
        manifest.verify_config(&cfg)?;
        Ok(Self::spawn(Some(manifest), cfg, host_params, None, svc_cfg))
    }

    /// Spawn the batcher over a `.swsc` container. Linear requests are
    /// served from a [`CompressedModel`] built in `svc_cfg.infer_mode` —
    /// with [`InferMode::Compressed`] the dense weights are never
    /// materialized for that surface.
    ///
    /// `manifest = Some(..)` additionally enables the PJRT eval path; the
    /// `fwd_eval` executable's contract is dense parameter literals, so
    /// the container must then cover every model parameter and compressed
    /// entries are restored host-side for that path only (the
    /// accelerator-side analog is the L1 `decode_matmul` kernel). With
    /// `manifest = None` the service is linear-only and needs no
    /// artifacts.
    pub fn start_with_swsc(
        manifest: Option<ArtifactManifest>,
        cfg: ModelConfig,
        file: &SwscFile,
        svc_cfg: ServiceConfig,
    ) -> Result<EvalService> {
        let host_params = if let Some(man) = &manifest {
            man.verify_config(&cfg)?;
            crate::eval::restore_param_tensors(file, &cfg)?
        } else {
            Vec::new()
        };
        let model = CompressedModel::from_file(file, svc_cfg.infer_mode);
        Ok(Self::spawn(manifest, cfg, host_params, Some(model), svc_cfg))
    }

    fn spawn(
        manifest: Option<ArtifactManifest>,
        cfg: ModelConfig,
        host_params: Vec<Tensor>,
        model: Option<CompressedModel>,
        svc_cfg: ServiceConfig,
    ) -> EvalService {
        let metrics = Arc::new(Metrics::new());
        let (tx, rx) = mpsc::sync_channel::<Job>(svc_cfg.queue_capacity);
        let m = metrics.clone();
        let seq = cfg.seq;
        let worker = std::thread::spawn(move || {
            batcher_loop(manifest, cfg, host_params, model, rx, svc_cfg, m);
        });
        EvalService { tx, worker: Some(worker), metrics, seq }
    }

    /// Submit a request; blocks when the queue is full (backpressure).
    /// Returns a receiver for the response.
    pub fn submit(&self, req: EvalRequest) -> Result<mpsc::Receiver<Result<EvalResponse, String>>> {
        anyhow::ensure!(
            req.tokens.len() == self.seq + 1,
            "request wants {} tokens (seq+1), got {}",
            self.seq + 1,
            req.tokens.len()
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Job::Eval(req, rtx)).context("service stopped")?;
        Ok(rrx)
    }

    /// Submit and wait.
    pub fn eval_blocking(&self, req: EvalRequest) -> Result<EvalResponse> {
        let rx = self.submit(req)?;
        rx.recv().context("service dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit a linear request; blocks when the queue is full.
    pub fn submit_linear(
        &self,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, String>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx.send(Job::Linear(req, rtx)).context("service stopped")?;
        Ok(rrx)
    }

    /// Submit a linear request and wait.
    pub fn linear_blocking(&self, req: LinearRequest) -> Result<LinearResponse> {
        let rx = self.submit_linear(req)?;
        rx.recv().context("service dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Graceful shutdown: drain, stop the batcher.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for EvalService {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Lazily initialize the PJRT engine + `fwd_eval` — only ever on the
/// first eval request, so linear-only services never touch PJRT.
fn init_fwd_eval(manifest: &Option<ArtifactManifest>) -> Result<Arc<LoadedExec>, String> {
    let Some(man) = manifest else {
        return Err(
            "eval serving disabled: service started without an artifact manifest \
             (linear requests only)"
                .to_string(),
        );
    };
    Engine::new(man.clone())
        .and_then(|e| e.load("fwd_eval"))
        .map_err(|e| format!("fwd_eval init failed: {e:#}"))
}

fn serve_linear(
    model: &Option<CompressedModel>,
    metrics: &Metrics,
    req: LinearRequest,
    tx: mpsc::Sender<Result<LinearResponse, String>>,
) {
    metrics.incr("service.linear_requests", 1);
    let t0 = std::time::Instant::now();
    let resp = match model {
        None => Err("no compressed model loaded — start the service with start_with_swsc"
            .to_string()),
        Some(m) => m
            .apply(&req.name, &req.x)
            .map(|y| LinearResponse { y })
            .map_err(|e| format!("linear `{}` failed: {e:#}", req.name)),
    };
    metrics.record("service.linear_seconds", t0.elapsed().as_secs_f64());
    let _ = tx.send(resp);
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    manifest: Option<ArtifactManifest>,
    cfg: ModelConfig,
    host_params: Vec<Tensor>,
    model: Option<CompressedModel>,
    rx: mpsc::Receiver<Job>,
    svc_cfg: ServiceConfig,
    metrics: Arc<Metrics>,
) {
    // Lazy `fwd_eval`: Option<Result> caches either the handle or the
    // init error (replayed to every later eval request).
    let mut exe: Option<Result<Arc<LoadedExec>, String>> = None;
    let mut pending: Vec<(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>)> = Vec::new();
    let mut shutting_down = false;
    loop {
        // Fill up to a full eval batch or until the delay elapses. Linear
        // requests are served inline — they never wait on the batch clock.
        let deadline = std::time::Instant::now() + svc_cfg.max_batch_delay;
        while pending.len() < cfg.batch && !shutting_down {
            let timeout = deadline.saturating_duration_since(std::time::Instant::now());
            match rx.recv_timeout(timeout) {
                Ok(Job::Eval(req, tx)) => pending.push((req, tx)),
                Ok(Job::Linear(req, tx)) => serve_linear(&model, &metrics, req, tx),
                Ok(Job::Shutdown) => shutting_down = true,
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    shutting_down = true;
                }
            }
        }
        if pending.is_empty() {
            if shutting_down {
                return;
            }
            continue;
        }

        let real = pending.len();
        metrics.incr("service.batches", 1);
        metrics.incr("service.requests", real as u64);
        if real < cfg.batch {
            metrics.incr("service.padded_rows", (cfg.batch - real) as u64);
        }

        let exe_state = exe.get_or_insert_with(|| init_fwd_eval(&manifest));
        match exe_state {
            Err(msg) => {
                let msg = msg.clone();
                for (_, tx) in pending.drain(..) {
                    let _ = tx.send(Err(msg.clone()));
                }
            }
            Ok(loaded) => {
                let t0 = std::time::Instant::now();
                let result = run_batch(loaded.as_ref(), &cfg, &host_params, &pending);
                metrics.record("service.batch_seconds", t0.elapsed().as_secs_f64());
                match result {
                    Ok(responses) => {
                        for ((_, tx), resp) in pending.drain(..).zip(responses) {
                            let _ = tx.send(Ok(resp));
                        }
                    }
                    Err(err) => {
                        let msg = format!("batch failed: {err:#}");
                        for (_, tx) in pending.drain(..) {
                            let _ = tx.send(Err(msg.clone()));
                        }
                    }
                }
            }
        }
        if shutting_down {
            return;
        }
    }
}

fn run_batch(
    exe: &LoadedExec,
    cfg: &ModelConfig,
    host_params: &[Tensor],
    pending: &[(EvalRequest, mpsc::Sender<Result<EvalResponse, String>>)],
) -> Result<Vec<EvalResponse>> {
    let real = pending.len();
    // Pack rows; pad the tail by repeating the first request (discarded).
    let mut inputs_flat = Vec::with_capacity(cfg.batch * cfg.seq);
    let mut targets_flat = Vec::with_capacity(cfg.batch * cfg.seq);
    for row in 0..cfg.batch {
        let req = &pending[row.min(real - 1)].0;
        inputs_flat.extend_from_slice(&req.tokens[..cfg.seq]);
        targets_flat.extend_from_slice(&req.tokens[1..cfg.seq + 1]);
    }

    let mut args = Vec::with_capacity(host_params.len() + 2);
    for t in host_params {
        args.push(tensor_to_literal(t)?);
    }
    args.push(tokens_to_literal(&inputs_flat, cfg.batch, cfg.seq)?);
    args.push(tokens_to_literal(&targets_flat, cfg.batch, cfg.seq)?);

    let outs = exe.run(&args)?;
    let nll_rows = literal_to_tensor(&outs[0])?;
    let tok_rows = literal_to_tensor(&outs[1])?;
    Ok((0..real)
        .map(|i| EvalResponse {
            nll_sum: nll_rows.data()[i] as f64,
            tokens: tok_rows.data()[i] as usize,
        })
        .collect())
}

/// Shared lock for tests that need a single service at a time (PJRT CPU
/// clients are heavy; serializing keeps test memory bounded).
pub static TEST_SERVICE_LOCK: Mutex<()> = Mutex::new(());
