//! Per-matrix compression job scheduler.
//!
//! Every matrix in a [`CompressionPlan`] is an independent job; the
//! scheduler fans them out on the deterministic executor ([`crate::exec`])
//! and merges results into a single [`SwscFile`]. Output is deterministic
//! twice over: job seeds are derived from matrix names at planning time,
//! each job lands in its plan-order slot regardless of which worker ran it,
//! and the per-matrix compression itself is bit-identical at any thread
//! count.
//!
//! On the persistent-pool backend the job fan-out and each job's inner ops
//! (matmuls, Lloyd chunks, SVD GEMMs) all share one worker pool via nested
//! submission — jobs are claimed dynamically either way, so the pool
//! migration changed no semantics here, only dispatch cost.

use crate::compress::{
    compress_matrix_traced, matrix_stats, CompressionPlan, CompressionReport, MatrixStats,
    MatrixTelemetry,
};
use crate::coordinator::metrics::Metrics;
use crate::exec::{self, ExecConfig};
use crate::io::{Checkpoint, SwscFile};
use crate::obs::prof::{time_it, ProfScope};
use anyhow::{Context, Result};
use std::sync::Arc;

/// Result of compressing a whole model.
pub struct CompressOutcome {
    pub file: SwscFile,
    pub stats: Vec<MatrixStats>,
    pub wall_seconds: f64,
    /// Quality telemetry, present when requested (PR 10): one record per
    /// compressed matrix, name-sorted. `None` costs nothing.
    pub telemetry: Option<CompressionReport>,
}

/// Compress every matrix in `plan`, spreading jobs across `workers`
/// threads. Tensors *not* named by the plan pass through as dense entries.
///
/// `workers` bounds the *total* CPU budget: the job-level fan-out takes
/// `min(workers, jobs)` threads and each job's internal `SwscConfig.exec`
/// gets the remaining `workers / fan-out` share, so `workers = 1` is fully
/// serial. With many small matrices the job-level fan-out dominates, with
/// few large ones the in-matrix fan-out does. Either way the merged file
/// is bit-identical at any worker count.
pub fn compress_model(
    ck: &Checkpoint,
    plan: &CompressionPlan,
    workers: usize,
    metrics: Option<Arc<Metrics>>,
) -> Result<CompressOutcome> {
    compress_model_traced(ck, plan, workers, metrics, None, false)
}

/// [`compress_model`] with observation hooks (PR 10): an optional parent
/// profiler scope (each job opens a per-matrix child with `kmeans` /
/// `rsvd` grandchildren — explicit parenting across the `WorkerPool`
/// task boundary) and optional quality-telemetry collection. Both are
/// observation-only: the merged file is bitwise identical whatever the
/// hooks, at any worker count.
pub fn compress_model_traced(
    ck: &Checkpoint,
    plan: &CompressionPlan,
    workers: usize,
    metrics: Option<Arc<Metrics>>,
    prof: Option<&ProfScope<'_>>,
    collect_telemetry: bool,
) -> Result<CompressOutcome> {
    let workers = workers.clamp(1, 64);
    let job_threads = workers.min(plan.len().max(1));
    // Floor split keeps total threads ≤ workers — the budget is a hard
    // bound, so a remainder core may idle (workers=8, 3 jobs → 3×2) rather
    // than oversubscribe for the whole run. Thread counts never touch
    // numerics either way.
    let inner = ExecConfig::with_threads(workers / job_threads);
    type JobOut = (crate::compress::CompressedMatrix, MatrixStats, f64, Option<MatrixTelemetry>);
    let (outcome, wall) = time_it(|| -> Result<(SwscFile, Vec<MatrixStats>, Option<CompressionReport>)> {
        // Validate up front so workers never see a bad job.
        let mut jobs = Vec::with_capacity(plan.len());
        for mp in &plan.matrices {
            let t = ck.get(&mp.name).with_context(|| format!("plan names missing tensor `{}`", mp.name))?;
            anyhow::ensure!(t.ndim() == 2, "plan matrix `{}` is not 2-D", mp.name);
            let mut cfg = mp.config.clone();
            cfg.exec = inner;
            jobs.push((mp.name.as_str(), t, cfg));
        }

        // One pre-assigned slot per plan entry: results come back in plan
        // order no matter which worker ran which job. Jobs are uneven
        // (matrix sizes vary), so use the dynamically balanced variant.
        let results: Vec<JobOut> =
            exec::map_indexed_balanced(ExecConfig::with_threads(job_threads), jobs.len(), |i| {
                let (name, tensor, cfg) = &jobs[i];
                let job_scope = crate::obs::prof::scope(prof, name);
                let mut tel = collect_telemetry
                    .then(|| MatrixTelemetry { name: name.to_string(), ..Default::default() });
                let (compressed, secs) = time_it(|| {
                    compress_matrix_traced(tensor, cfg, job_scope.as_ref(), tel.as_mut())
                });
                let stats = matrix_stats(name, tensor, &compressed);
                (compressed, stats, secs, tel)
            });

        let mut file = SwscFile::new();
        let mut stats = Vec::with_capacity(results.len());
        let mut report = collect_telemetry.then(CompressionReport::default);
        for ((name, _, _), (compressed, st, secs, tel)) in jobs.iter().zip(results) {
            if let Some(m) = &metrics {
                m.incr("compress.jobs", 1);
                m.record("compress.job_seconds", secs);
            }
            if let (Some(rep), Some(tel)) = (report.as_mut(), tel) {
                rep.matrices.push(tel);
            }
            file.compressed.insert(name.to_string(), compressed);
            stats.push(st);
        }
        stats.sort_by(|a, b| a.name.cmp(&b.name));
        if let Some(rep) = report.as_mut() {
            rep.finalize();
        }

        // Dense passthrough for everything the plan did not compress.
        for (name, t) in ck.iter() {
            if !file.compressed.contains_key(name) {
                file.dense.insert(name.to_string(), t.clone());
            }
        }
        Ok((file, stats, report))
    });
    let (file, stats, telemetry) = outcome?;
    Ok(CompressOutcome { file, stats, wall_seconds: wall, telemetry })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ProjectorSet;
    use crate::model::{init_params, ModelConfig};

    fn setup() -> (Checkpoint, CompressionPlan) {
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, 5);
        let plan =
            CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 9);
        (ck, plan)
    }

    #[test]
    fn compresses_exactly_the_planned_matrices() {
        let (ck, plan) = setup();
        let out = compress_model(&ck, &plan, 4, None).unwrap();
        assert_eq!(out.file.compressed.len(), plan.len());
        for mp in &plan.matrices {
            assert!(out.file.compressed.contains_key(&mp.name), "{} missing", mp.name);
        }
        // Everything else is dense, and nothing is both.
        assert_eq!(out.file.compressed.len() + out.file.dense.len(), ck.len());
        for name in out.file.compressed.keys() {
            assert!(!out.file.dense.contains_key(name));
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let (ck, plan) = setup();
        let a = compress_model(&ck, &plan, 1, None).unwrap();
        let b = compress_model(&ck, &plan, 8, None).unwrap();
        assert_eq!(a.file.to_bytes(), b.file.to_bytes(), "parallelism changed the result");
    }

    #[test]
    fn metrics_are_recorded() {
        let (ck, plan) = setup();
        let m = Arc::new(Metrics::new());
        compress_model(&ck, &plan, 2, Some(m.clone())).unwrap();
        assert_eq!(m.counter("compress.jobs") as usize, plan.len());
        assert_eq!(m.timing_count("compress.job_seconds"), plan.len());
    }

    #[test]
    fn stats_sorted_by_name() {
        let (ck, plan) = setup();
        let out = compress_model(&ck, &plan, 4, None).unwrap();
        let names: Vec<&str> = out.stats.iter().map(|s| s.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn traced_compress_is_bitwise_identical_and_reports() {
        let (ck, plan) = setup();
        let base = compress_model(&ck, &plan, 2, None).unwrap();
        let prof = crate::obs::prof::Profiler::new();
        {
            let root = prof.root("compress");
            let out = compress_model_traced(&ck, &plan, 2, None, Some(&root), true).unwrap();
            assert_eq!(
                base.file.to_bytes(),
                out.file.to_bytes(),
                "profiling must not move a bit"
            );
            let rep = out.telemetry.unwrap();
            assert_eq!(rep.matrices.len(), plan.len());
            let names: Vec<&str> = rep.matrices.iter().map(|m| m.name.as_str()).collect();
            let mut sorted = names.clone();
            sorted.sort();
            assert_eq!(names, sorted, "telemetry must be name-sorted");
            // Telemetry is a function of (weights, seed, config) — not of
            // the worker count or the profiler.
            let again = compress_model_traced(&ck, &plan, 8, None, None, true).unwrap();
            assert_eq!(rep.to_json(), again.telemetry.unwrap().to_json());
        }
        let phases = prof.phases();
        assert!(
            phases.keys().any(|k| k.starts_with("compress/") && k.ends_with("/kmeans")),
            "per-matrix children missing: {phases:?}"
        );
        assert!(base.telemetry.is_none(), "plain path must not collect telemetry");
    }

    #[test]
    fn missing_tensor_in_plan_errors() {
        let (ck, mut plan) = setup();
        plan.matrices[0].name = "does.not.exist".into();
        assert!(compress_model(&ck, &plan, 2, None).is_err());
    }
}
