//! Per-matrix compression job scheduler.
//!
//! Every matrix in a [`CompressionPlan`] is an independent job; the
//! scheduler runs them on a fixed worker pool (std threads + channels —
//! the vendored crate set has no rayon/tokio) and merges results into a
//! single [`SwscFile`]. Output is deterministic: job seeds are derived
//! from matrix names at planning time, and the merge sorts by name.

use crate::compress::{compress_matrix, matrix_stats, CompressionPlan, MatrixStats};
use crate::coordinator::metrics::Metrics;
use crate::io::{Checkpoint, SwscFile};
use crate::util::timer::time_it;
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::Arc;

/// Result of compressing a whole model.
pub struct CompressOutcome {
    pub file: SwscFile,
    pub stats: Vec<MatrixStats>,
    pub wall_seconds: f64,
}

/// Compress every matrix in `plan`, spreading jobs across `workers`
/// threads. Tensors *not* named by the plan pass through as dense entries.
pub fn compress_model(
    ck: &Checkpoint,
    plan: &CompressionPlan,
    workers: usize,
    metrics: Option<Arc<Metrics>>,
) -> Result<CompressOutcome> {
    let workers = workers.clamp(1, 64);
    let (outcome, wall) = time_it(|| -> Result<(SwscFile, Vec<MatrixStats>)> {
        // Job list: (name, tensor, config).
        let mut jobs = Vec::new();
        for mp in &plan.matrices {
            let t = ck.get(&mp.name).with_context(|| format!("plan names missing tensor `{}`", mp.name))?;
            anyhow::ensure!(t.ndim() == 2, "plan matrix `{}` is not 2-D", mp.name);
            jobs.push((mp.name.clone(), t.clone(), mp.config.clone()));
        }

        let (result_tx, result_rx) = mpsc::channel();
        let jobs = Arc::new(std::sync::Mutex::new(jobs));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let jobs = jobs.clone();
                let tx = result_tx.clone();
                let metrics = metrics.clone();
                scope.spawn(move || loop {
                    let job = jobs.lock().unwrap().pop();
                    let Some((name, tensor, cfg)) = job else { break };
                    let (compressed, secs) = time_it(|| compress_matrix(&tensor, &cfg));
                    if let Some(m) = &metrics {
                        m.incr("compress.jobs", 1);
                        m.record("compress.job_seconds", secs);
                    }
                    let stats = matrix_stats(&name, &tensor, &compressed);
                    // Receiver outlives the scope; ignore send error on
                    // early drop.
                    let _ = tx.send((name, compressed, stats));
                });
            }
        });
        drop(result_tx);

        let mut file = SwscFile::new();
        let mut stats = Vec::new();
        for (name, compressed, st) in result_rx {
            file.compressed.insert(name, compressed);
            stats.push(st);
        }
        stats.sort_by(|a, b| a.name.cmp(&b.name));

        // Dense passthrough for everything the plan did not compress.
        for (name, t) in ck.iter() {
            if !file.compressed.contains_key(name) {
                file.dense.insert(name.to_string(), t.clone());
            }
        }
        Ok((file, stats))
    });
    let (file, stats) = outcome?;
    Ok(CompressOutcome { file, stats, wall_seconds: wall })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::ProjectorSet;
    use crate::model::{init_params, ModelConfig};

    fn setup() -> (Checkpoint, CompressionPlan) {
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, 5);
        let plan =
            CompressionPlan::for_target_bits(&ck.shapes(), ProjectorSet::QAndK, 2.0, 0.5, 9);
        (ck, plan)
    }

    #[test]
    fn compresses_exactly_the_planned_matrices() {
        let (ck, plan) = setup();
        let out = compress_model(&ck, &plan, 4, None).unwrap();
        assert_eq!(out.file.compressed.len(), plan.len());
        for mp in &plan.matrices {
            assert!(out.file.compressed.contains_key(&mp.name), "{} missing", mp.name);
        }
        // Everything else is dense, and nothing is both.
        assert_eq!(out.file.compressed.len() + out.file.dense.len(), ck.len());
        for name in out.file.compressed.keys() {
            assert!(!out.file.dense.contains_key(name));
        }
    }

    #[test]
    fn deterministic_across_worker_counts() {
        let (ck, plan) = setup();
        let a = compress_model(&ck, &plan, 1, None).unwrap();
        let b = compress_model(&ck, &plan, 8, None).unwrap();
        assert_eq!(a.file.to_bytes(), b.file.to_bytes(), "parallelism changed the result");
    }

    #[test]
    fn metrics_are_recorded() {
        let (ck, plan) = setup();
        let m = Arc::new(Metrics::new());
        compress_model(&ck, &plan, 2, Some(m.clone())).unwrap();
        assert_eq!(m.counter("compress.jobs") as usize, plan.len());
        assert_eq!(m.timing_count("compress.job_seconds"), plan.len());
    }

    #[test]
    fn stats_sorted_by_name() {
        let (ck, plan) = setup();
        let out = compress_model(&ck, &plan, 4, None).unwrap();
        let names: Vec<&str> = out.stats.iter().map(|s| s.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn missing_tensor_in_plan_errors() {
        let (ck, mut plan) = setup();
        plan.matrices[0].name = "does.not.exist".into();
        assert!(compress_model(&ck, &plan, 2, None).is_err());
    }
}
