//! Lightweight shared metrics: counters + fixed-size value histograms.
//!
//! Until the serving layer landed, timings were stored as unbounded
//! sample `Vec`s (`util::timer::Stats`) — fine for a bench's dozens of
//! iterations, unbounded growth for a service answering millions of
//! requests. Distributions are now [`Histogram`]s: a fixed array of
//! geometric buckets (constant memory per metric, ~±5% relative
//! resolution) with exact count/sum/min/max on the side, so
//! `render()` reports p50/p95/p99 tail latency instead of a mean that
//! hides the tail. The same histogram records unit-less distributions
//! (e.g. `serve.batch_rows`, the coalescer's batch-size distribution).

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Number of geometric buckets per histogram (fixed at compile time —
/// this is the entire memory footprint of a metric).
const HIST_BUCKETS: usize = 256;
/// Lower edge of the bucketed range. Values at or below land in bucket 0.
const HIST_LO: f64 = 1e-7;
/// Upper edge of the bucketed range. Values at or above land in the last
/// bucket. The range spans 11 decades: 0.1 µs … ~3 h in seconds, or
/// 1 … 10⁴ for unit-less distributions like batch sizes.
const HIST_HI: f64 = 1e4;

/// Fixed-size log-bucketed histogram with exact count/sum/min/max.
///
/// Percentiles are bucket-midpoint estimates, clamped into the exact
/// observed `[min, max]`; with 256 buckets over 11 decades the relative
/// error is ≤ ~5.5% — plenty for serving dashboards, at constant memory.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Box<[u64; HIST_BUCKETS]>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: Box::new([0; HIST_BUCKETS]),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    fn bucket_of(v: f64) -> usize {
        if v.is_nan() || v <= HIST_LO {
            return 0; // ≤ LO (and NaN) collapse into the first bucket
        }
        if v >= HIST_HI {
            return HIST_BUCKETS - 1;
        }
        let frac = (v / HIST_LO).ln() / (HIST_HI / HIST_LO).ln();
        ((frac * HIST_BUCKETS as f64) as usize).min(HIST_BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — what a percentile reports.
    fn bucket_mid(i: usize) -> f64 {
        HIST_LO * ((HIST_HI / HIST_LO).ln() * ((i as f64 + 0.5) / HIST_BUCKETS as f64)).exp()
    }

    /// Lower edge of bucket `i` (bucket 0 absorbs everything ≤ `HIST_LO`,
    /// so its lower edge is reported as 0).
    fn bucket_lo(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            HIST_LO * ((HIST_HI / HIST_LO).ln() * (i as f64 / HIST_BUCKETS as f64)).exp()
        }
    }

    /// Upper edge of bucket `i`.
    fn bucket_hi(i: usize) -> f64 {
        HIST_LO * ((HIST_HI / HIST_LO).ln() * ((i as f64 + 1.0) / HIST_BUCKETS as f64)).exp()
    }

    /// The observations recorded into `self` after `earlier` was cloned
    /// from it — i.e. snapshot a long-lived histogram before a run, then
    /// report the run's *own* samples instead of the cumulative stream.
    ///
    /// Bucket counts, `count`, and `sum` are exact deltas. `min`/`max`
    /// are exact whenever the window moved the cumulative extreme;
    /// otherwise they are bucket-edge estimates clamped into the
    /// cumulative `[min, max]` (same ≤ ~5.5% resolution as percentiles).
    pub fn since(&self, earlier: &Histogram) -> Histogram {
        let mut out = Histogram::new();
        let count = self.count.saturating_sub(earlier.count);
        if count == 0 {
            return out; // canonical empty (min/max sentinels intact)
        }
        for i in 0..HIST_BUCKETS {
            out.buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        out.count = count;
        out.sum = self.sum - earlier.sum;
        let first = out.buckets.iter().position(|&c| c > 0).unwrap_or(0);
        let last = out.buckets.iter().rposition(|&c| c > 0).unwrap_or(HIST_BUCKETS - 1);
        out.min = if self.min < earlier.min {
            self.min
        } else {
            Self::bucket_lo(first).clamp(self.min, self.max)
        };
        out.max = if self.max > earlier.max {
            self.max
        } else {
            Self::bucket_hi(last).clamp(self.min, self.max)
        };
        out
    }

    pub fn push(&mut self, v: f64) {
        // NaN observations are recorded as 0 so the exact min/max/sum
        // side-stats stay finite: `f64::min(INFINITY, NAN)` would leave
        // `min > max` after a NaN-only stream, and `percentile`'s clamp
        // into [min, max] must never be handed an inverted range.
        let v = if v.is_nan() { 0.0 } else { v };
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact mean (tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank percentile estimate (`p` in 0..=100), clamped into
    /// the exact observed range. Empty histograms report 0.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().clamp(1.0, self.count as f64) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max()
    }
}

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    hists: Mutex<BTreeMap<String, Histogram>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Record one observation into `name`'s histogram. Timings are in
    /// seconds by convention; unit-less distributions (batch sizes) use
    /// the same mechanism.
    pub fn record(&self, name: &str, value: f64) {
        self.hists.lock().unwrap().entry(name.to_string()).or_default().push(value);
    }

    pub fn timing_mean(&self, name: &str) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.mean()).unwrap_or(0.0)
    }

    pub fn timing_count(&self, name: &str) -> usize {
        self.hists.lock().unwrap().get(name).map(|h| h.count() as usize).unwrap_or(0)
    }

    /// Percentile estimate of a recorded distribution (0 when absent).
    pub fn timing_percentile(&self, name: &str, p: f64) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.percentile(p)).unwrap_or(0.0)
    }

    pub fn timing_max(&self, name: &str) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.max()).unwrap_or(0.0)
    }

    /// Clone `name`'s current histogram (empty when absent). Pair with
    /// [`Metrics::hist_since`] to report one run's own distribution on a
    /// long-lived server whose histograms are cumulative.
    pub fn hist_snapshot(&self, name: &str) -> Histogram {
        self.hists.lock().unwrap().get(name).cloned().unwrap_or_default()
    }

    /// The observations recorded into `name` since `earlier` was
    /// snapshotted (see [`Histogram::since`]).
    pub fn hist_since(&self, name: &str, earlier: &Histogram) -> Histogram {
        self.hist_snapshot(name).since(earlier)
    }

    /// Render all metrics as a report block: counters, then every
    /// histogram with tail percentiles.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, h) in self.hists.lock().unwrap().iter() {
            out.push_str(&format!(
                "hist    {k}: n={} mean={:.6} p50={:.6} p95={:.6} p99={:.6} max={:.6}\n",
                h.count(),
                h.mean(),
                h.percentile(50.0),
                h.percentile(95.0),
                h.percentile(99.0),
                h.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::new();
        m.incr("jobs", 1);
        m.incr("jobs", 2);
        assert_eq!(m.counter("jobs"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.record("svd", 0.5);
        m.record("svd", 1.5);
        assert_eq!(m.timing_count("svd"), 2);
        assert!((m.timing_mean("svd") - 1.0).abs() < 1e-12);
        assert_eq!(m.timing_count("absent"), 0);
        assert_eq!(m.timing_percentile("absent", 95.0), 0.0);
        let r = m.render();
        assert!(r.contains("jobs = 3"));
        assert!(r.contains("svd"));
        assert!(r.contains("p95="), "render must include tail percentiles: {r}");
        assert!(r.contains("p99="));
    }

    /// Percentiles land within the documented bucket resolution on a
    /// known distribution (1 ms … 1 s, uniform).
    #[test]
    fn histogram_percentiles_are_accurate() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.push(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9, "mean is exact");
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
        for (p, want) in
            [(0.0, 1e-3), (50.0, 0.5), (95.0, 0.95), (99.0, 0.99), (100.0, 1.0)]
        {
            let got = h.percentile(p);
            assert!(
                (got - want).abs() / want < 0.12,
                "p{p}: got {got}, want ~{want} (±12%)"
            );
        }
        // Estimates never escape the exact observed range.
        assert!(h.percentile(100.0) <= h.max() && h.percentile(0.0) >= h.min());
    }

    /// Out-of-range and degenerate values stay bounded: everything lands
    /// in a bucket, memory never grows.
    #[test]
    fn histogram_handles_extremes() {
        let mut h = Histogram::new();
        h.push(0.0);
        h.push(-3.0);
        h.push(1e-12);
        h.push(1e9);
        h.push(f64::NAN); // recorded as 0 — side-stats stay finite
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), 1e9);
        assert!(h.percentile(99.0) <= 1e9);
        assert!(h.mean().is_finite(), "a NaN observation must not poison the mean");
        let empty = Histogram::new();
        assert_eq!(empty.percentile(50.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.min(), 0.0);
        assert_eq!(empty.max(), 0.0);
    }

    /// A NaN-only stream must not panic percentile's clamp into
    /// [min, max] (min/max would otherwise stay at ±infinity).
    #[test]
    fn nan_only_histogram_does_not_panic() {
        let mut h = Histogram::new();
        h.push(f64::NAN);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        let m = Metrics::new();
        m.record("rate", f64::NAN);
        assert_eq!(m.timing_percentile("rate", 95.0), 0.0);
        assert!(m.render().contains("rate"));
    }

    /// A single sample reports itself exactly at every percentile (the
    /// clamp into [min, max] collapses the bucket estimate).
    #[test]
    fn single_sample_is_exact() {
        let mut h = Histogram::new();
        h.push(0.125);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 0.125);
        }
    }

    /// Snapshot-and-delta: a second window's stats are its own, not the
    /// cumulative stream's (the loadgen double-replay bug).
    #[test]
    fn since_reports_only_the_window() {
        let m = Metrics::new();
        // First window: a slow regime.
        for _ in 0..100 {
            m.record("lat", 1.0);
        }
        let snap = m.hist_snapshot("lat");
        // Second window: fast. Cumulative p99 would still say ~1 s.
        for _ in 0..100 {
            m.record("lat", 1e-3);
        }
        let delta = m.hist_since("lat", &snap);
        assert_eq!(delta.count(), 100);
        assert!((delta.mean() - 1e-3).abs() / 1e-3 < 0.01, "sum delta is exact");
        assert!(delta.percentile(99.0) < 0.01, "p99 must not see the first window");
        assert!(delta.max() < 0.01, "max estimate must stay inside the window's bucket");
        // min moved the cumulative extreme in the window → exact.
        assert_eq!(delta.min(), 1e-3);
        // Empty window against a fresh snapshot reports the empty shape.
        let snap2 = m.hist_snapshot("lat");
        let none = m.hist_since("lat", &snap2);
        assert_eq!(none.count(), 0);
        assert_eq!(none.percentile(50.0), 0.0);
        // Absent histogram: snapshot and delta are both empty.
        assert_eq!(m.hist_snapshot("missing").count(), 0);
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.incr("n", 1);
                    m.record("t", 0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
        assert_eq!(m.timing_count("t"), 8000);
    }
}
