//! Lightweight shared metrics: counters + fixed-size value histograms.
//!
//! Until the serving layer landed, timings were stored as unbounded
//! sample `Vec`s (`obs::prof::Stats`) — fine for a bench's dozens of
//! iterations, unbounded growth for a service answering millions of
//! requests. Distributions are now [`Histogram`]s: a fixed array of
//! geometric buckets (constant memory per metric, ~±5% relative
//! resolution) with exact count/sum/min/max on the side, so
//! `render()` reports p50/p95/p99 tail latency instead of a mean that
//! hides the tail. The same histogram records unit-less distributions
//! (e.g. `serve.batch_rows`, the coalescer's batch-size distribution).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

/// Number of geometric buckets per histogram (fixed at compile time —
/// this is the entire memory footprint of a metric).
const HIST_BUCKETS: usize = 256;
/// Lower edge of the bucketed range. Values at or below land in bucket 0.
const HIST_LO: f64 = 1e-7;
/// Upper edge of the bucketed range. Values at or above land in the last
/// bucket. The range spans 11 decades: 0.1 µs … ~3 h in seconds, or
/// 1 … 10⁴ for unit-less distributions like batch sizes.
const HIST_HI: f64 = 1e4;

/// Fixed-size log-bucketed histogram with exact count/sum/min/max.
///
/// Percentiles are bucket-midpoint estimates, clamped into the exact
/// observed `[min, max]`; with 256 buckets over 11 decades the relative
/// error is ≤ ~5.5% — plenty for serving dashboards, at constant memory.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Box<[u64; HIST_BUCKETS]>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: Box::new([0; HIST_BUCKETS]),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    fn bucket_of(v: f64) -> usize {
        if v.is_nan() || v <= HIST_LO {
            return 0; // ≤ LO (and NaN) collapse into the first bucket
        }
        if v >= HIST_HI {
            return HIST_BUCKETS - 1;
        }
        let frac = (v / HIST_LO).ln() / (HIST_HI / HIST_LO).ln();
        ((frac * HIST_BUCKETS as f64) as usize).min(HIST_BUCKETS - 1)
    }

    /// Geometric midpoint of bucket `i` — what a percentile reports.
    fn bucket_mid(i: usize) -> f64 {
        HIST_LO * ((HIST_HI / HIST_LO).ln() * ((i as f64 + 0.5) / HIST_BUCKETS as f64)).exp()
    }

    /// Lower edge of bucket `i` (bucket 0 absorbs everything ≤ `HIST_LO`,
    /// so its lower edge is reported as 0).
    fn bucket_lo(i: usize) -> f64 {
        if i == 0 {
            0.0
        } else {
            HIST_LO * ((HIST_HI / HIST_LO).ln() * (i as f64 / HIST_BUCKETS as f64)).exp()
        }
    }

    /// Upper edge of bucket `i`.
    fn bucket_hi(i: usize) -> f64 {
        HIST_LO * ((HIST_HI / HIST_LO).ln() * ((i as f64 + 1.0) / HIST_BUCKETS as f64)).exp()
    }

    /// The observations recorded into `self` after `earlier` was cloned
    /// from it — i.e. snapshot a long-lived histogram before a run, then
    /// report the run's *own* samples instead of the cumulative stream.
    ///
    /// Bucket counts, `count`, and `sum` are exact deltas. `min`/`max`
    /// are exact whenever the window moved the cumulative extreme;
    /// otherwise they are bucket-edge estimates clamped into the
    /// cumulative `[min, max]` (same ≤ ~5.5% resolution as percentiles).
    pub fn since(&self, earlier: &Histogram) -> Histogram {
        let mut out = Histogram::new();
        let count = self.count.saturating_sub(earlier.count);
        if count == 0 {
            return out; // canonical empty (min/max sentinels intact)
        }
        for i in 0..HIST_BUCKETS {
            out.buckets[i] = self.buckets[i].saturating_sub(earlier.buckets[i]);
        }
        out.count = count;
        out.sum = self.sum - earlier.sum;
        let first = out.buckets.iter().position(|&c| c > 0).unwrap_or(0);
        let last = out.buckets.iter().rposition(|&c| c > 0).unwrap_or(HIST_BUCKETS - 1);
        out.min = if self.min < earlier.min {
            self.min
        } else {
            Self::bucket_lo(first).clamp(self.min, self.max)
        };
        out.max = if self.max > earlier.max {
            self.max
        } else {
            Self::bucket_hi(last).clamp(self.min, self.max)
        };
        out
    }

    pub fn push(&mut self, v: f64) {
        // NaN observations are recorded as 0 so the exact min/max/sum
        // side-stats stay finite: `f64::min(INFINITY, NAN)` would leave
        // `min > max` after a NaN-only stream, and `percentile`'s clamp
        // into [min, max] must never be handed an inverted range.
        let v = if v.is_nan() { 0.0 } else { v };
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all observations (tracked outside the buckets).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean (tracked outside the buckets).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank percentile estimate (`p` in 0..=100), clamped into
    /// the exact observed range. Empty histograms report 0.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().clamp(1.0, self.count as f64) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return Self::bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max()
    }
}

/// Thread-safe metrics registry.
///
/// Two dimensions per metric family since PR 9: the plain name-keyed
/// counters/histograms (unchanged — every pre-existing `serve.*` counter
/// keeps its exact global value), plus an optional **label** dimension
/// keyed by `(name, label)` — the serving layer labels by canonical
/// model name, so `serve.latency_seconds` etc. break down per model.
/// All four maps are `BTreeMap`s, so every renderer below iterates in
/// deterministic sorted order — stable enough for golden-text tests.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    hists: Mutex<BTreeMap<String, Histogram>>,
    labeled_counters: Mutex<BTreeMap<(String, String), u64>>,
    labeled_hists: Mutex<BTreeMap<(String, String), Histogram>>,
    /// Names written through [`Metrics::set`] — gauge semantics (the
    /// value can go down). Stored alongside the counters map so `counter`
    /// / `render` read one value space, but the Prometheus exporter must
    /// type these families `gauge`: a decreasing `counter` breaks
    /// `rate()`/`increase()` queries.
    gauge_names: Mutex<BTreeSet<String>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    /// Overwrite `name` with an absolute value — gauge semantics for
    /// sampled values (pool busy-time, worker counts) that are not
    /// increments. Rendered alongside counters, but typed `gauge` in the
    /// Prometheus exposition (the value may decrease).
    pub fn set(&self, name: &str, value: u64) {
        self.gauge_names.lock().unwrap().insert(name.to_string());
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Overwrite `name` with an absolute cumulative total — for counters
    /// accumulated elsewhere (the kernel-layer atomics in
    /// [`crate::obs::prof::counters`]) and copied into the registry at
    /// export time. Unlike [`Metrics::set`] the family stays typed
    /// `counter`: the underlying value is monotone, only the copy is an
    /// absolute store.
    pub fn counter_total(&self, name: &str, value: u64) {
        self.counters.lock().unwrap().insert(name.to_string(), value);
    }

    /// [`Metrics::counter_total`] for a `(name, label)` series.
    pub fn counter_total_with(&self, name: &str, label: &str, value: u64) {
        self.labeled_counters
            .lock()
            .unwrap()
            .insert((name.to_string(), label.to_string()), value);
    }

    /// Labeled counter increment (label = model name by convention).
    /// Independent of the global [`Metrics::incr`] stream — call both to
    /// keep the global totals intact.
    pub fn incr_with(&self, name: &str, label: &str, by: u64) {
        *self
            .labeled_counters
            .lock()
            .unwrap()
            .entry((name.to_string(), label.to_string()))
            .or_insert(0) += by;
    }

    pub fn counter_with(&self, name: &str, label: &str) -> u64 {
        self.labeled_counters
            .lock()
            .unwrap()
            .get(&(name.to_string(), label.to_string()))
            .copied()
            .unwrap_or(0)
    }

    /// Record one observation into the `(name, label)` histogram.
    pub fn record_with(&self, name: &str, label: &str, value: f64) {
        self.labeled_hists
            .lock()
            .unwrap()
            .entry((name.to_string(), label.to_string()))
            .or_default()
            .push(value);
    }

    /// Clone the `(name, label)` histogram (empty when absent).
    pub fn hist_with(&self, name: &str, label: &str) -> Histogram {
        self.labeled_hists
            .lock()
            .unwrap()
            .get(&(name.to_string(), label.to_string()))
            .cloned()
            .unwrap_or_default()
    }

    /// Labels recorded for a metric family, sorted.
    pub fn labels_of(&self, name: &str) -> Vec<String> {
        let mut out: Vec<String> = self
            .labeled_counters
            .lock()
            .unwrap()
            .keys()
            .filter(|(n, _)| n == name)
            .map(|(_, l)| l.clone())
            .collect();
        out.extend(
            self.labeled_hists
                .lock()
                .unwrap()
                .keys()
                .filter(|(n, _)| n == name)
                .map(|(_, l)| l.clone()),
        );
        out.sort();
        out.dedup();
        out
    }

    /// Record one observation into `name`'s histogram. Timings are in
    /// seconds by convention; unit-less distributions (batch sizes) use
    /// the same mechanism.
    pub fn record(&self, name: &str, value: f64) {
        self.hists.lock().unwrap().entry(name.to_string()).or_default().push(value);
    }

    pub fn timing_mean(&self, name: &str) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.mean()).unwrap_or(0.0)
    }

    pub fn timing_count(&self, name: &str) -> usize {
        self.hists.lock().unwrap().get(name).map(|h| h.count() as usize).unwrap_or(0)
    }

    /// Percentile estimate of a recorded distribution (0 when absent).
    pub fn timing_percentile(&self, name: &str, p: f64) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.percentile(p)).unwrap_or(0.0)
    }

    pub fn timing_max(&self, name: &str) -> f64 {
        self.hists.lock().unwrap().get(name).map(|h| h.max()).unwrap_or(0.0)
    }

    /// Clone `name`'s current histogram (empty when absent). Pair with
    /// [`Metrics::hist_since`] to report one run's own distribution on a
    /// long-lived server whose histograms are cumulative.
    pub fn hist_snapshot(&self, name: &str) -> Histogram {
        self.hists.lock().unwrap().get(name).cloned().unwrap_or_default()
    }

    /// The observations recorded into `name` since `earlier` was
    /// snapshotted (see [`Histogram::since`]).
    pub fn hist_since(&self, name: &str, earlier: &Histogram) -> Histogram {
        self.hist_snapshot(name).since(earlier)
    }

    /// Render all metrics as a report block: counters, then every
    /// histogram with tail percentiles, then the labeled breakdowns —
    /// each section in deterministic sorted order (`BTreeMap` iteration;
    /// labeled lines sort by `(name, label)`), so the output is stable
    /// for golden-text assertions.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let gauges = self.gauge_names.lock().unwrap().clone();
        for (k, v) in self.counters.lock().unwrap().iter() {
            let kind = if gauges.contains(k) { "gauge  " } else { "counter" };
            out.push_str(&format!("{kind} {k} = {v}\n"));
        }
        for (k, h) in self.hists.lock().unwrap().iter() {
            out.push_str(&format!("hist    {k}: {}\n", hist_line(h)));
        }
        for ((k, l), v) in self.labeled_counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k}{{{l}}} = {v}\n"));
        }
        for ((k, l), h) in self.labeled_hists.lock().unwrap().iter() {
            out.push_str(&format!("hist    {k}{{{l}}}: {}\n", hist_line(h)));
        }
        out
    }

    /// Prometheus text exposition format. Incremented names type as
    /// `counter`, [`Metrics::set`] names as `gauge`, histograms as
    /// summaries (`_count`, `_sum`, `quantile` series); labeled series
    /// carry a `model` label. Names
    /// are sanitized (`.` → `_`) and prefixed `swsc_`; output is fully
    /// deterministic: families sorted by name, the unlabeled sample
    /// first, labeled samples sorted by label.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        // Counter/gauge families: global value then per-label values.
        let counters = self.counters.lock().unwrap().clone();
        let gauges = self.gauge_names.lock().unwrap().clone();
        let labeled: BTreeMap<(String, String), u64> =
            self.labeled_counters.lock().unwrap().clone();
        let mut families: Vec<String> = counters.keys().cloned().collect();
        families.extend(labeled.keys().map(|(n, _)| n.clone()));
        families.sort();
        families.dedup();
        for name in families {
            let prom = prom_name(&name);
            let ty = if gauges.contains(&name) { "gauge" } else { "counter" };
            out.push_str(&format!("# TYPE {prom} {ty}\n"));
            if let Some(v) = counters.get(&name) {
                out.push_str(&format!("{prom} {v}\n"));
            }
            for ((n, l), v) in labeled.iter() {
                if *n == name {
                    out.push_str(&format!("{prom}{{model=\"{}\"}} {v}\n", prom_label(l)));
                }
            }
        }
        // Histogram families as summaries.
        let hists = self.hists.lock().unwrap().clone();
        let labeled: BTreeMap<(String, String), Histogram> =
            self.labeled_hists.lock().unwrap().clone();
        let mut families: Vec<String> = hists.keys().cloned().collect();
        families.extend(labeled.keys().map(|(n, _)| n.clone()));
        families.sort();
        families.dedup();
        for name in families {
            let prom = prom_name(&name);
            out.push_str(&format!("# TYPE {prom} summary\n"));
            if let Some(h) = hists.get(&name) {
                out.push_str(&prom_summary(&prom, "", h));
            }
            for ((n, l), h) in labeled.iter() {
                if *n == name {
                    let pre = format!("model=\"{}\",", prom_label(l));
                    out.push_str(&prom_summary(&prom, &pre, h));
                }
            }
        }
        out
    }

    /// JSON snapshot of every metric: `counters` / `hists` maps plus
    /// `labeled_counters` / `labeled_hists` keyed `name → label → value`.
    /// Every entry carries a `"type"` field (`counter` / `gauge` /
    /// `histogram`) agreeing with the text render and the Prometheus
    /// `# TYPE` lines, so a JSON consumer never has to re-derive the
    /// family kind from the section it appeared in. Hand-rolled (no serde
    /// in the vendored set), deterministic sorted key order, strings
    /// escaped.
    pub fn render_json(&self) -> String {
        use crate::obs::json_escape as esc;
        let gauges = self.gauge_names.lock().unwrap().clone();
        let mut out = String::from("{");
        out.push_str("\"counters\":{");
        for (i, (k, v)) in self.counters.lock().unwrap().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let ty = if gauges.contains(k) { "gauge" } else { "counter" };
            out.push_str(&format!("\"{}\":{{\"type\":\"{ty}\",\"value\":{v}}}", esc(k)));
        }
        out.push_str("},\"labeled_counters\":{");
        let labeled = self.labeled_counters.lock().unwrap().clone();
        out.push_str(&json_grouped(&labeled, "counter", |v| v.to_string()));
        out.push_str("},\"hists\":{");
        for (i, (k, h)) in self.hists.lock().unwrap().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{}\":{}", esc(k), hist_json(h)));
        }
        out.push_str("},\"labeled_hists\":{");
        let labeled = self.labeled_hists.lock().unwrap().clone();
        out.push_str(&json_grouped(&labeled, "histogram", hist_json));
        out.push_str("}}");
        out.push('\n');
        out
    }
}

/// One-line histogram summary shared by `render` lines.
fn hist_line(h: &Histogram) -> String {
    format!(
        "n={} mean={:.6} p50={:.6} p95={:.6} p99={:.6} max={:.6}",
        h.count(),
        h.mean(),
        h.percentile(50.0),
        h.percentile(95.0),
        h.percentile(99.0),
        h.max()
    )
}

/// Sanitize a dotted metric name into a Prometheus metric name.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 5);
    out.push_str("swsc_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

/// Label values only need quote/backslash escaping in the text format.
fn prom_label(l: &str) -> String {
    l.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Summary series for one (possibly labeled) histogram. `label_prefix`
/// is either empty or `model="x",`.
fn prom_summary(prom: &str, label_prefix: &str, h: &Histogram) -> String {
    let brace = |inner: &str| {
        if inner.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", inner.trim_end_matches(','))
        }
    };
    let mut out = String::new();
    out.push_str(&format!("{prom}_count{} {}\n", brace(label_prefix), h.count()));
    out.push_str(&format!("{prom}_sum{} {}\n", brace(label_prefix), h.sum()));
    for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0)] {
        out.push_str(&format!(
            "{prom}{{{}quantile=\"{q}\"}} {}\n",
            label_prefix,
            h.percentile(p)
        ));
    }
    out
}

/// Render a `(name, label) → value` map as JSON
/// `"name":{"type":"<ty>","values":{"label":V,…}}` entries (no outer
/// braces), keys sorted by `BTreeMap` order.
fn json_grouped<V>(
    map: &BTreeMap<(String, String), V>,
    ty: &str,
    render: impl Fn(&V) -> String,
) -> String {
    use crate::obs::json_escape as esc;
    let mut out = String::new();
    let mut open: Option<&str> = None;
    for ((name, label), v) in map.iter() {
        if open != Some(name.as_str()) {
            if open.is_some() {
                out.push_str("}},");
            }
            out.push_str(&format!("\"{}\":{{\"type\":\"{ty}\",\"values\":{{", esc(name)));
            open = Some(name.as_str());
        } else {
            out.push(',');
        }
        out.push_str(&format!("\"{}\":{}", esc(label), render(v)));
    }
    if open.is_some() {
        out.push_str("}}");
    }
    out
}

/// JSON object for one histogram (exact count/mean/min/max, estimated
/// percentiles), typed like the counter/gauge entries.
fn hist_json(h: &Histogram) -> String {
    format!(
        "{{\"type\":\"histogram\",\"count\":{},\"mean\":{},\"min\":{},\"max\":{},\
         \"p50\":{},\"p95\":{},\"p99\":{}}}",
        h.count(),
        h.mean(),
        h.min(),
        h.max(),
        h.percentile(50.0),
        h.percentile(95.0),
        h.percentile(99.0)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_histograms() {
        let m = Metrics::new();
        m.incr("jobs", 1);
        m.incr("jobs", 2);
        assert_eq!(m.counter("jobs"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.record("svd", 0.5);
        m.record("svd", 1.5);
        assert_eq!(m.timing_count("svd"), 2);
        assert!((m.timing_mean("svd") - 1.0).abs() < 1e-12);
        assert_eq!(m.timing_count("absent"), 0);
        assert_eq!(m.timing_percentile("absent", 95.0), 0.0);
        let r = m.render();
        assert!(r.contains("jobs = 3"));
        assert!(r.contains("svd"));
        assert!(r.contains("p95="), "render must include tail percentiles: {r}");
        assert!(r.contains("p99="));
    }

    /// Percentiles land within the documented bucket resolution on a
    /// known distribution (1 ms … 1 s, uniform).
    #[test]
    fn histogram_percentiles_are_accurate() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.push(i as f64 * 1e-3);
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 0.5005).abs() < 1e-9, "mean is exact");
        assert_eq!(h.min(), 1e-3);
        assert_eq!(h.max(), 1.0);
        for (p, want) in
            [(0.0, 1e-3), (50.0, 0.5), (95.0, 0.95), (99.0, 0.99), (100.0, 1.0)]
        {
            let got = h.percentile(p);
            assert!(
                (got - want).abs() / want < 0.12,
                "p{p}: got {got}, want ~{want} (±12%)"
            );
        }
        // Estimates never escape the exact observed range.
        assert!(h.percentile(100.0) <= h.max() && h.percentile(0.0) >= h.min());
    }

    /// Out-of-range and degenerate values stay bounded: everything lands
    /// in a bucket, memory never grows.
    #[test]
    fn histogram_handles_extremes() {
        let mut h = Histogram::new();
        h.push(0.0);
        h.push(-3.0);
        h.push(1e-12);
        h.push(1e9);
        h.push(f64::NAN); // recorded as 0 — side-stats stay finite
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), 1e9);
        assert!(h.percentile(99.0) <= 1e9);
        assert!(h.mean().is_finite(), "a NaN observation must not poison the mean");
        let empty = Histogram::new();
        assert_eq!(empty.percentile(50.0), 0.0);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.min(), 0.0);
        assert_eq!(empty.max(), 0.0);
    }

    /// A NaN-only stream must not panic percentile's clamp into
    /// [min, max] (min/max would otherwise stay at ±infinity).
    #[test]
    fn nan_only_histogram_does_not_panic() {
        let mut h = Histogram::new();
        h.push(f64::NAN);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
        let m = Metrics::new();
        m.record("rate", f64::NAN);
        assert_eq!(m.timing_percentile("rate", 95.0), 0.0);
        assert!(m.render().contains("rate"));
    }

    /// A single sample reports itself exactly at every percentile (the
    /// clamp into [min, max] collapses the bucket estimate).
    #[test]
    fn single_sample_is_exact() {
        let mut h = Histogram::new();
        h.push(0.125);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(h.percentile(p), 0.125);
        }
    }

    /// Snapshot-and-delta: a second window's stats are its own, not the
    /// cumulative stream's (the loadgen double-replay bug).
    #[test]
    fn since_reports_only_the_window() {
        let m = Metrics::new();
        // First window: a slow regime.
        for _ in 0..100 {
            m.record("lat", 1.0);
        }
        let snap = m.hist_snapshot("lat");
        // Second window: fast. Cumulative p99 would still say ~1 s.
        for _ in 0..100 {
            m.record("lat", 1e-3);
        }
        let delta = m.hist_since("lat", &snap);
        assert_eq!(delta.count(), 100);
        assert!((delta.mean() - 1e-3).abs() / 1e-3 < 0.01, "sum delta is exact");
        assert!(delta.percentile(99.0) < 0.01, "p99 must not see the first window");
        assert!(delta.max() < 0.01, "max estimate must stay inside the window's bucket");
        // min moved the cumulative extreme in the window → exact.
        assert_eq!(delta.min(), 1e-3);
        // Empty window against a fresh snapshot reports the empty shape.
        let snap2 = m.hist_snapshot("lat");
        let none = m.hist_since("lat", &snap2);
        assert_eq!(none.count(), 0);
        assert_eq!(none.percentile(50.0), 0.0);
        // Absent histogram: snapshot and delta are both empty.
        assert_eq!(m.hist_snapshot("missing").count(), 0);
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.incr("n", 1);
                    m.record("t", 0.001);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
        assert_eq!(m.timing_count("t"), 8000);
    }

    /// Histogram edge values stay bounded and reportable: exact zero,
    /// `u64::MAX` as f64 (far beyond the bucketed range), and an
    /// empty-since-snapshot window must all render finite numbers.
    #[test]
    fn histogram_edge_values() {
        let mut h = Histogram::new();
        h.push(0.0);
        assert_eq!(h.count(), 1);
        assert_eq!((h.min(), h.max()), (0.0, 0.0));
        for p in [0.0, 50.0, 100.0] {
            assert_eq!(h.percentile(p), 0.0, "zero-only stream reports 0 at p{p}");
        }

        let big = u64::MAX as f64;
        h.push(big);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), big);
        assert_eq!(h.sum(), big);
        assert!(h.percentile(100.0) <= big, "estimate must clamp into the observed range");
        assert!(h.mean().is_finite());

        // Empty delta window: canonical empty shape, no inverted range.
        let snap = h.clone();
        let none = h.since(&snap);
        assert_eq!(none.count(), 0);
        assert_eq!(none.sum(), 0.0);
        assert_eq!((none.min(), none.max()), (0.0, 0.0));
        assert_eq!(none.percentile(95.0), 0.0);
    }

    #[test]
    fn labeled_counters_and_hists() {
        let m = Metrics::new();
        m.incr("serve.panics", 1);
        m.incr_with("serve.panics", "prod", 1);
        m.incr_with("serve.panics", "canary", 2);
        assert_eq!(m.counter("serve.panics"), 1, "global stream untouched by labels");
        assert_eq!(m.counter_with("serve.panics", "prod"), 1);
        assert_eq!(m.counter_with("serve.panics", "canary"), 2);
        assert_eq!(m.counter_with("serve.panics", "absent"), 0);
        m.record_with("serve.latency_seconds", "prod", 0.25);
        m.record_with("serve.latency_seconds", "prod", 0.75);
        assert_eq!(m.hist_with("serve.latency_seconds", "prod").count(), 2);
        assert_eq!(m.hist_with("serve.latency_seconds", "nope").count(), 0);
        assert_eq!(m.labels_of("serve.panics"), vec!["canary".to_string(), "prod".to_string()]);
        m.set("exec.pool_workers", 4);
        m.set("exec.pool_workers", 3);
        assert_eq!(m.counter("exec.pool_workers"), 3, "set is overwrite, not add");
        let r = m.render();
        assert!(r.contains("serve.panics{canary} = 2"), "labeled render line: {r}");
        assert!(r.contains("serve.latency_seconds{prod}:"));
        // set() names carry gauge semantics end to end: the text render
        // marks them and the Prometheus exposition types them `gauge`
        // (a decreasing `counter` would break rate()/increase()).
        assert!(r.contains("gauge   exec.pool_workers = 3"), "render must mark gauges: {r}");
        let prom = m.render_prometheus();
        assert!(
            prom.contains("# TYPE swsc_exec_pool_workers gauge\nswsc_exec_pool_workers 3\n"),
            "set() families must type as gauge: {prom}"
        );
        assert!(
            prom.contains("# TYPE swsc_serve_panics counter\n"),
            "incremented families must stay counters: {prom}"
        );
    }

    /// Golden text: the exporters emit exactly this, in exactly this
    /// order — per-model labels included — so dashboards and CI line
    /// parsers can rely on the shape.
    #[test]
    fn exporters_are_deterministic_and_sorted() {
        let m = Metrics::new();
        m.incr("serve.requests", 7);
        m.incr_with("serve.quota_rejected", "prod", 3);
        m.record("serve.latency_seconds", 0.5);
        m.record_with("serve.latency_seconds", "prod", 0.5);

        let prom = m.render_prometheus();
        let want_prom = "# TYPE swsc_serve_quota_rejected counter\n\
                         swsc_serve_quota_rejected{model=\"prod\"} 3\n\
                         # TYPE swsc_serve_requests counter\n\
                         swsc_serve_requests 7\n\
                         # TYPE swsc_serve_latency_seconds summary\n\
                         swsc_serve_latency_seconds_count 1\n\
                         swsc_serve_latency_seconds_sum 0.5\n\
                         swsc_serve_latency_seconds{quantile=\"0.5\"} 0.5\n\
                         swsc_serve_latency_seconds{quantile=\"0.95\"} 0.5\n\
                         swsc_serve_latency_seconds{quantile=\"0.99\"} 0.5\n\
                         swsc_serve_latency_seconds_count{model=\"prod\"} 1\n\
                         swsc_serve_latency_seconds_sum{model=\"prod\"} 0.5\n\
                         swsc_serve_latency_seconds{model=\"prod\",quantile=\"0.5\"} 0.5\n\
                         swsc_serve_latency_seconds{model=\"prod\",quantile=\"0.95\"} 0.5\n\
                         swsc_serve_latency_seconds{model=\"prod\",quantile=\"0.99\"} 0.5\n";
        assert_eq!(prom, want_prom);
        assert_eq!(prom, m.render_prometheus(), "repeated renders must be identical");

        let json = m.render_json();
        assert_eq!(json, m.render_json());
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
        assert!(json.contains("\"serve.requests\":{\"type\":\"counter\",\"value\":7}"));
        assert!(json.contains(
            "\"serve.quota_rejected\":{\"type\":\"counter\",\"values\":{\"prod\":3}}"
        ));
        assert!(json.contains("\"type\":\"histogram\",\"count\":1"));
        // Structurally sound: balanced braces outside strings.
        let (mut depth, mut in_str, mut esc) = (0i64, false, false);
        for c in json.chars() {
            if esc {
                esc = false;
                continue;
            }
            match c {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        assert_eq!(depth, 0, "unbalanced JSON export: {json}");
    }

    /// All three renders must agree on every family's type: a name that
    /// is a gauge in the text render must be a gauge in the Prometheus
    /// `# TYPE` line and carry `"type":"gauge"` in the JSON snapshot.
    #[test]
    fn renders_agree_on_metric_type() {
        let m = Metrics::new();
        m.incr("pipeline.jobs", 2);
        m.set("exec.pool_workers", 3);
        m.counter_total("obs.trace_dropped", 11);
        m.counter_total_with("gemm.calls", "rows/large", 5);
        m.record("compress.job_seconds", 0.25);
        m.record_with("compress.job_seconds", "tiny", 0.25);

        let text = m.render();
        assert!(text.contains("counter pipeline.jobs = 2"), "{text}");
        assert!(text.contains("gauge   exec.pool_workers = 3"), "{text}");
        assert!(text.contains("counter obs.trace_dropped = 11"), "{text}");
        assert!(text.contains("hist    compress.job_seconds:"), "{text}");

        let prom = m.render_prometheus();
        assert!(prom.contains("# TYPE swsc_pipeline_jobs counter\n"), "{prom}");
        assert!(prom.contains("# TYPE swsc_exec_pool_workers gauge\n"), "{prom}");
        assert!(
            prom.contains("# TYPE swsc_obs_trace_dropped counter\n"),
            "counter_total must stay counter-typed: {prom}"
        );
        assert!(prom.contains("# TYPE swsc_gemm_calls counter\n"), "{prom}");
        assert!(prom.contains("# TYPE swsc_compress_job_seconds summary\n"), "{prom}");

        let json = m.render_json();
        assert!(json.contains("\"pipeline.jobs\":{\"type\":\"counter\",\"value\":2}"), "{json}");
        assert!(json.contains("\"exec.pool_workers\":{\"type\":\"gauge\",\"value\":3}"), "{json}");
        assert!(json.contains("\"obs.trace_dropped\":{\"type\":\"counter\",\"value\":11}"), "{json}");
        assert!(
            json.contains("\"gemm.calls\":{\"type\":\"counter\",\"values\":{\"rows/large\":5}}"),
            "{json}"
        );
        assert!(
            json.contains("\"compress.job_seconds\":{\"type\":\"histogram\",\"count\":1"),
            "plain hists carry the type field: {json}"
        );
        assert!(
            json.contains(
                "\"compress.job_seconds\":{\"type\":\"histogram\",\"values\":{\"tiny\":{\"type\":\"histogram\",\"count\":1"
            ),
            "labeled hists carry the type field at both levels: {json}"
        );
    }
}
