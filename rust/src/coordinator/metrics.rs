//! Lightweight shared metrics (counters + timing stats).

use crate::util::timer::Stats;
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Thread-safe metrics registry.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: Mutex<BTreeMap<String, u64>>,
    timings: Mutex<BTreeMap<String, Stats>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn incr(&self, name: &str, by: u64) {
        *self.counters.lock().unwrap().entry(name.to_string()).or_insert(0) += by;
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn record(&self, name: &str, seconds: f64) {
        self.timings.lock().unwrap().entry(name.to_string()).or_default().push(seconds);
    }

    pub fn timing_mean(&self, name: &str) -> f64 {
        self.timings.lock().unwrap().get(name).map(|s| s.mean()).unwrap_or(0.0)
    }

    pub fn timing_count(&self, name: &str) -> usize {
        self.timings.lock().unwrap().get(name).map(|s| s.count()).unwrap_or(0)
    }

    /// Render all metrics as a report block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("counter {k} = {v}\n"));
        }
        for (k, s) in self.timings.lock().unwrap().iter() {
            out.push_str(&format!(
                "timing  {k}: n={} mean={:.6}s p50={:.6}s max={:.6}s\n",
                s.count(),
                s.mean(),
                s.percentile(50.0),
                s.max()
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_timings() {
        let m = Metrics::new();
        m.incr("jobs", 1);
        m.incr("jobs", 2);
        assert_eq!(m.counter("jobs"), 3);
        assert_eq!(m.counter("missing"), 0);
        m.record("svd", 0.5);
        m.record("svd", 1.5);
        assert_eq!(m.timing_count("svd"), 2);
        assert!((m.timing_mean("svd") - 1.0).abs() < 1e-12);
        let r = m.render();
        assert!(r.contains("jobs = 3"));
        assert!(r.contains("svd"));
    }

    #[test]
    fn thread_safety() {
        let m = std::sync::Arc::new(Metrics::new());
        let mut handles = Vec::new();
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.incr("n", 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.counter("n"), 8000);
    }
}
