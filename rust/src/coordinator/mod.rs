//! The L3 coordinator — the systems contribution wrapped around the
//! algorithm.
//!
//! Two services:
//!
//! - [`scheduler`]: fans per-matrix SWSC/RTN compression jobs across a
//!   worker pool. Each job is independent (cluster → mean → error SVD →
//!   pack), so the pool scales to the layer count; results are merged
//!   deterministically regardless of completion order.
//! - [`service`]: a batched evaluation service in the vLLM-router mould —
//!   clients submit token windows, a batcher thread assembles fixed-shape
//!   batches (padding partial batches), executes `fwd_eval` through PJRT,
//!   and returns per-request NLL. Bounded queue = backpressure. Since the
//!   infer layer it also serves [`LinearRequest`]s straight from a
//!   `.swsc` container — compressed-domain matmuls with no dense weight
//!   materialization, behind the `ServiceConfig::infer_mode` flag — and
//!   since the serving layer ([`crate::serve`]) those route through a
//!   micro-batch coalescer behind `ServiceConfig::batching` (bitwise
//!   identical to inline serving; `Disabled` is the oracle). Since PR 7
//!   it also serves whole-model [`service::ForwardRequest`]s from a
//!   [`crate::infer::CompressedForward`] — the full transformer stack in
//!   the compressed domain, continuous-batched at layer boundaries when
//!   batching is enabled, with the inline solo path as the bitwise
//!   oracle.
//!
//! [`metrics`] carries counters and fixed-size latency histograms
//! (p50/p95/p99) for all of it.

pub mod metrics;
pub mod scheduler;
pub mod service;

pub use metrics::{Histogram, Metrics};
pub use scheduler::{compress_model, compress_model_traced, CompressOutcome};
pub use service::{
    EvalRequest, EvalResponse, EvalService, ForwardRequest, ForwardResponse, LinearRequest,
    LinearResponse, ServiceConfig,
};
