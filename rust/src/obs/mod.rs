//! Observation-only tracing for the serving stack (PR 9).
//!
//! Every admitted request carries a **trace id** — the admission-order
//! `u64` the [`crate::serve::AdmissionQueue`] already assigns (and the
//! fault injector already keys on), so span structure inherits the same
//! determinism story as the chaos schedule: ids are a pure function of
//! submission order, independent of thread count and wall clock.
//!
//! The serving path records into a [`TraceSink`]: a bounded, lock-cheap
//! ring buffer of [`TraceRecord`]s. Two record shapes:
//!
//! - **Spans** ([`SpanKind`]) — an interval with a start and duration:
//!   queue wait (admission → batch pick), one span per (model, weight)
//!   group `apply`, one per forward layer-step, one per batch pick on
//!   the server track (trace id 0).
//! - **Events** ([`EventKind`]) — instants: admission, rejections,
//!   deadline evictions, retries, contained panics, injected faults,
//!   shutdown drains.
//!
//! ## The observation-only invariant
//!
//! Tracing is pure observation — it must never move a bit:
//!
//! - Nothing on the bit-producing path ever *reads* the sink or branches
//!   on a recorded value; records are write-only from serving code, and
//!   durations are measured around compute, never fed into it.
//! - When tracing is off (the default), the hot paths carry an
//!   `Option<Arc<TraceSink>>` that stays `None` — the entire cost is one
//!   pointer test per site, and the labels/details are not even
//!   formatted (the same zero-cost-off pattern as
//!   [`crate::serve::FaultInjector`]).
//! - The ring buffer is bounded: past `capacity` records the oldest are
//!   dropped (counted in [`TraceSink::dropped`]), so a long-lived server
//!   holds constant trace memory.
//!
//! Traced and untraced serving are therefore **bitwise identical** at
//! any `SWSC_THREADS` — pinned by `tests/obs_trace.rs` — and for a fixed
//! fault seed and a sequential schedule the span/event *structure* (ids,
//! kinds, labels — not durations) is identical across runs.
//!
//! ## Export
//!
//! [`TraceSink::to_chrome_json`] renders the ring as a Chrome
//! trace-event JSON array (`ph: "X"` complete spans + `ph: "i"` instant
//! events, one `tid` per trace id) loadable in Perfetto / `chrome://
//! tracing` — a stall is a visible gap on a request's track. The `swsc
//! trace` CLI subcommand and [`crate::serve::BatchServer::dump_trace`]
//! both produce this format.

pub mod prof;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Default ring capacity (records). ~64k records comfortably covers a
/// loadgen run; a saturated server wraps (dropping the oldest) instead
/// of growing.
pub const DEFAULT_TRACE_CAPACITY: usize = 65_536;

/// Trace id for records that belong to no admitted request — e.g. a
/// submitter's retry attempts, which fire after admission failed and so
/// never received an id. Renders as its own Chrome track instead of
/// landing on the server-scope track (trace id 0) or a real request's.
pub const NO_REQUEST_ID: u64 = u64::MAX;

/// Configuration for a [`TraceSink`]. Constructed explicitly or from the
/// environment (`SWSC_TRACE=1`, optional `SWSC_TRACE_CAPACITY=N`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceConfig {
    /// Ring capacity in records; 0 is clamped to 1.
    pub capacity: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig { capacity: DEFAULT_TRACE_CAPACITY }
    }
}

impl TraceConfig {
    /// Read the env gate: `Some` when `SWSC_TRACE` is set to anything but
    /// `0`/empty, with `SWSC_TRACE_CAPACITY` overriding the ring size.
    pub fn from_env() -> Option<TraceConfig> {
        Self::from_lookup(|k| std::env::var(k).ok())
    }

    /// [`TraceConfig::from_env`] against an arbitrary lookup (testable).
    pub fn from_lookup(lookup: impl Fn(&str) -> Option<String>) -> Option<TraceConfig> {
        let on = lookup("SWSC_TRACE").map(|v| {
            let v = v.trim().to_string();
            !v.is_empty() && v != "0"
        })?;
        if !on {
            return None;
        }
        let capacity = lookup("SWSC_TRACE_CAPACITY")
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or(DEFAULT_TRACE_CAPACITY);
        Some(TraceConfig { capacity })
    }
}

/// What interval a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// Admission → batch pick, per request.
    QueueWait,
    /// One coalescer drain cycle (server track, trace id 0).
    BatchPick,
    /// One stacked (model, weight)-group `apply`, recorded per member
    /// request.
    GroupApply,
    /// One forward layer-step cohort, recorded per member request.
    LayerStep,
    /// One pipeline-profiler scope ([`prof`], PR 10): `detail` carries the
    /// `/`-joined phase path (e.g. `compress/attn.wq/rsvd`), which the
    /// Chrome export uses as the event *name* so Perfetto shows the phase
    /// tree, not a wall of identical "phase" blocks.
    Phase,
}

impl SpanKind {
    pub fn label(self) -> &'static str {
        match self {
            SpanKind::QueueWait => "queue_wait",
            SpanKind::BatchPick => "batch_pick",
            SpanKind::GroupApply => "group_apply",
            SpanKind::LayerStep => "layer_step",
            SpanKind::Phase => "phase",
        }
    }
}

/// What instant an event marks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// Request admitted (id assigned, queue slot taken).
    Admitted,
    /// Request rejected at admission (detail: overloaded / quota /
    /// shutting down / injected).
    Rejected,
    /// Deadline expired (detail says where: admission / pick / layer).
    DeadlineEvicted,
    /// One retry attempt spent by a retrying submitter.
    Retry,
    /// A contained panic answered this request.
    Panic,
    /// The fault injector fired (detail: panic / delay / reject).
    FaultInjected,
    /// Request drained unserved at shutdown.
    Drained,
}

impl EventKind {
    pub fn label(self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::Rejected => "rejected",
            EventKind::DeadlineEvicted => "deadline_evicted",
            EventKind::Retry => "retry",
            EventKind::Panic => "panic",
            EventKind::FaultInjected => "fault_injected",
            EventKind::Drained => "drained",
        }
    }
}

/// Span-or-event payload of a [`TraceRecord`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceData {
    Span { kind: SpanKind, dur: Duration },
    Event { kind: EventKind },
}

/// One recorded observation: who (`trace`, `model`), what (`data`,
/// `detail`), when (`ts`, relative to the sink's epoch).
#[derive(Debug, Clone)]
pub struct TraceRecord {
    /// Monotone record sequence number (survives ring wrap).
    pub seq: u64,
    /// Request trace id; 0 is the server-scope track.
    pub trace: u64,
    pub model: String,
    /// Free-form label: weight name, layer number, rejection reason…
    pub detail: String,
    /// Record time relative to the sink epoch (span start for spans).
    pub ts: Duration,
    pub data: TraceData,
}

impl TraceRecord {
    /// The duration-free shape of this record — what the determinism
    /// tests compare across runs.
    pub fn structure(&self) -> String {
        let kind = match &self.data {
            TraceData::Span { kind, .. } => kind.label(),
            TraceData::Event { kind } => kind.label(),
        };
        format!("{}:{}:{}:{}", self.trace, kind, self.model, self.detail)
    }
}

/// Bounded ring buffer of [`TraceRecord`]s behind one short-critical-
/// section mutex (push = one `VecDeque` rotate; no allocation once the
/// ring is warm beyond the record's own strings).
#[derive(Debug)]
pub struct TraceSink {
    epoch: Instant,
    capacity: usize,
    seq: AtomicU64,
    dropped: AtomicU64,
    ring: Mutex<VecDeque<TraceRecord>>,
}

impl TraceSink {
    pub fn new(cfg: TraceConfig) -> TraceSink {
        let capacity = cfg.capacity.max(1);
        TraceSink {
            epoch: Instant::now(),
            capacity,
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::with_capacity(capacity.min(4096))),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Record a span that started at `start` and is ending now.
    pub fn span(
        &self,
        kind: SpanKind,
        trace: u64,
        model: impl Into<String>,
        detail: impl Into<String>,
        start: Instant,
    ) {
        let dur = start.elapsed();
        let ts = start.saturating_duration_since(self.epoch);
        self.push(TraceRecord {
            seq: 0,
            trace,
            model: model.into(),
            detail: detail.into(),
            ts,
            data: TraceData::Span { kind, dur },
        });
    }

    /// Record an instant event happening now.
    pub fn event(
        &self,
        kind: EventKind,
        trace: u64,
        model: impl Into<String>,
        detail: impl Into<String>,
    ) {
        let ts = self.epoch.elapsed();
        self.push(TraceRecord {
            seq: 0,
            trace,
            model: model.into(),
            detail: detail.into(),
            ts,
            data: TraceData::Event { kind },
        });
    }

    fn push(&self, mut rec: TraceRecord) {
        rec.seq = self.seq.fetch_add(1, Ordering::Relaxed);
        // Evict and push under one lock hold: releasing between the two
        // would let a concurrent push overfill the ring past `capacity`,
        // after which an `==` fullness check never fires again and the
        // "bounded drop-oldest" invariant is gone. `>=` keeps the bound
        // self-healing either way; the counter bump is a relaxed atomic,
        // cheap enough to keep inside the critical section.
        let mut ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        while ring.len() >= self.capacity {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(rec);
    }

    /// Records currently held (oldest first; at most `capacity`).
    pub fn records(&self) -> Vec<TraceRecord> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).is_empty()
    }

    /// Records evicted by ring wrap since creation (0 ⇒ the trace is
    /// complete).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn clear(&self) {
        self.ring.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }

    /// The duration-free span/event structure, sorted by (trace id,
    /// record sequence): what must be identical across runs for a pinned
    /// fault seed and a sequential schedule.
    pub fn structure(&self) -> Vec<String> {
        let ring = self.ring.lock().unwrap_or_else(|e| e.into_inner());
        let mut keyed: Vec<(u64, u64, String)> =
            ring.iter().map(|r| (r.trace, r.seq, r.structure())).collect();
        drop(ring);
        keyed.sort();
        keyed.into_iter().map(|(_, _, s)| s).collect()
    }

    /// Render the ring as a Chrome trace-event JSON array (the
    /// `chrome://tracing` / Perfetto "JSON array format"): spans as
    /// `ph:"X"` complete events, events as `ph:"i"` instants, one `tid`
    /// per trace id (tid 0 = the server track; tid [`NO_REQUEST_ID`] =
    /// records tied to no admitted request). Timestamps/durations in
    /// microseconds. Deterministically ordered by record sequence.
    pub fn to_chrome_json(&self) -> String {
        let records = self.records();
        let mut out = String::with_capacity(128 * records.len() + 2);
        out.push('[');
        for (i, r) in records.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str("\n  ");
            let ts = r.ts.as_secs_f64() * 1e6;
            match &r.data {
                TraceData::Span { kind, dur } => {
                    // Phase spans name themselves by their profiler path —
                    // that's what makes the Perfetto view a readable tree.
                    let name = match kind {
                        SpanKind::Phase => json_escape(&r.detail),
                        _ => kind.label().to_string(),
                    };
                    out.push_str(&format!(
                        "{{\"name\":\"{}\",\"cat\":\"span\",\"ph\":\"X\",\"ts\":{:.3},\
                         \"dur\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"model\":\"{}\",\
                         \"detail\":\"{}\",\"seq\":{}}}}}",
                        name,
                        ts,
                        dur.as_secs_f64() * 1e6,
                        r.trace,
                        json_escape(&r.model),
                        json_escape(&r.detail),
                        r.seq,
                    ));
                }
                TraceData::Event { kind } => {
                    out.push_str(&format!(
                        "{{\"name\":\"{}\",\"cat\":\"event\",\"ph\":\"i\",\"s\":\"t\",\
                         \"ts\":{:.3},\"pid\":1,\"tid\":{},\"args\":{{\"model\":\"{}\",\
                         \"detail\":\"{}\",\"seq\":{}}}}}",
                        kind.label(),
                        ts,
                        r.trace,
                        json_escape(&r.model),
                        json_escape(&r.detail),
                        r.seq,
                    ));
                }
            }
        }
        out.push_str("\n]\n");
        out
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars) —
/// enough for metric/model/weight names and error messages; the vendored
/// crate set has no serde.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sink(cap: usize) -> TraceSink {
        TraceSink::new(TraceConfig { capacity: cap })
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = sink(4);
        for i in 0..10u64 {
            t.event(EventKind::Admitted, i, "m", "");
        }
        assert_eq!(t.len(), 4, "ring must cap at capacity");
        assert_eq!(t.dropped(), 6);
        // Oldest evicted first: the survivors are the last four ids.
        let traces: Vec<u64> = t.records().iter().map(|r| r.trace).collect();
        assert_eq!(traces, vec![6, 7, 8, 9]);
        // seq keeps counting across the wrap.
        assert_eq!(t.records().last().unwrap().seq, 9);
        t.clear();
        assert!(t.is_empty());
    }

    /// Racing pushers (admission threads vs the coalescer) must never
    /// overfill the ring: eviction and push happen under one lock hold,
    /// so `len` can never exceed `capacity` — the regression that made
    /// the `==` fullness check dead and the ring unbounded.
    #[test]
    fn concurrent_pushes_keep_ring_bounded() {
        let t = std::sync::Arc::new(sink(8));
        let mut handles = Vec::new();
        for thread in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..500u64 {
                    t.event(EventKind::Admitted, thread * 1000 + i, "m", "");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(t.len(), 8, "ring must sit exactly at capacity after overflow");
        assert_eq!(t.dropped(), 4 * 500 - 8, "every push past capacity evicts exactly one");
    }

    #[test]
    fn config_env_gate() {
        assert_eq!(TraceConfig::from_lookup(|_| None), None);
        assert_eq!(
            TraceConfig::from_lookup(|k| (k == "SWSC_TRACE").then(|| "0".into())),
            None
        );
        assert_eq!(
            TraceConfig::from_lookup(|k| (k == "SWSC_TRACE").then(|| "1".into())),
            Some(TraceConfig::default())
        );
        let cfg = TraceConfig::from_lookup(|k| match k {
            "SWSC_TRACE" => Some("1".into()),
            "SWSC_TRACE_CAPACITY" => Some("128".into()),
            _ => None,
        });
        assert_eq!(cfg, Some(TraceConfig { capacity: 128 }));
    }

    #[test]
    fn chrome_export_shape() {
        let t = sink(16);
        let start = Instant::now();
        t.event(EventKind::Admitted, 7, "prod", "");
        t.span(SpanKind::QueueWait, 7, "prod", "", start);
        t.span(SpanKind::GroupApply, 7, "prod", "attn.\"wq\"", start);
        let json = t.to_chrome_json();
        assert!(json.starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"ph\":\"X\""), "spans must be complete events");
        assert!(json.contains("\"ph\":\"i\""), "events must be instants");
        assert!(json.contains("\"tid\":7"));
        assert!(json.contains("attn.\\\"wq\\\""), "details must be escaped: {json}");
        // Balanced braces/brackets outside strings ⇒ structurally sound.
        let (mut depth, mut in_str, mut esc) = (0i64, false, false);
        for c in json.chars() {
            if esc {
                esc = false;
                continue;
            }
            match c {
                '\\' if in_str => esc = true,
                '"' => in_str = !in_str,
                '{' | '[' if !in_str => depth += 1,
                '}' | ']' if !in_str => depth -= 1,
                _ => {}
            }
            assert!(depth >= 0, "unbalanced close in {json}");
        }
        assert_eq!(depth, 0, "unbalanced export: {json}");
        assert!(!in_str);
    }

    #[test]
    fn structure_is_duration_free_and_trace_sorted() {
        let t = sink(16);
        let start = Instant::now();
        t.event(EventKind::Admitted, 2, "b", "");
        t.span(SpanKind::QueueWait, 1, "a", "", start);
        std::thread::sleep(Duration::from_millis(1));
        t.span(SpanKind::QueueWait, 1, "a", "", start);
        let s = t.structure();
        // Sorted by trace id first; the two differently-timed spans have
        // the same structure line.
        assert_eq!(
            s,
            vec![
                "1:queue_wait:a:".to_string(),
                "1:queue_wait:a:".to_string(),
                "2:admitted:b:".to_string(),
            ]
        );
    }

    #[test]
    fn json_escape_covers_controls() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
        assert_eq!(json_escape("plain"), "plain");
    }
}
