//! Pipeline profiler + kernel counters (PR 10).
//!
//! PR 9 made the serving stack observable; this module does the same for
//! everything below and before it: the offline compress pipeline
//! (k-means → randomized SVD → quantize → serialize), the bench/eval
//! harness, and the kernel layer (packed GEMM, bucket sums, panel cache,
//! [`crate::exec::WorkerPool`]).
//!
//! ## Phase profiler
//!
//! A [`Profiler`] aggregates scoped timers into a call tree keyed by
//! `/`-joined phase paths (`compress/attn.wq/rsvd`). Scopes are RAII
//! guards ([`ProfScope`]): entering a phase creates one, dropping it
//! records `(count += 1, total_ns += elapsed)` under its path *and*
//! pushes a [`SpanKind::Phase`] span into the profiler's embedded
//! [`TraceSink`], so [`Profiler::to_chrome_json`] reuses the PR 9 export
//! machinery verbatim and pipeline runs load in Perfetto next to serving
//! traces.
//!
//! Parenting is **explicit**: `parent.child("rsvd")` — not ambient
//! thread-local nesting — because pipeline phases cross
//! [`crate::exec::WorkerPool`] task boundaries (the per-matrix jobs run
//! on pool workers; a thread-local stack would misattribute them).
//! `&ProfScope` is `Sync`, so a parent scope can be borrowed by every
//! worker closure and each job opens its own child.
//!
//! ## The observation-only invariant
//!
//! Same contract as [`TraceSink`][crate::obs::TraceSink]: profiling must
//! never move a bit. Compressed `.swsc` bytes, the golden fixture, and
//! served output are identical with `SWSC_PROF` on or off, at any
//! `SWSC_THREADS` — pinned by `tests/obs_prof.rs`. The mechanism is the
//! same zero-cost-off pattern: call sites carry `Option<&ProfScope>`
//! that stays `None` when profiling is off (one pointer test, no
//! formatting), and nothing on the bit-producing path ever *reads* a
//! recorded value. Timings are nondeterministic; the phase *tree*
//! (paths and counts) is a pure function of (weights, config), and the
//! quality telemetry in [`crate::compress::CompressionReport`] is a pure
//! function of (weights, seed, config).
//!
//! ## Kernel counters
//!
//! [`counters`] holds process-global relaxed atomics bumped by the hot
//! kernels: GEMM calls + FLOPs by (entry point, shape class), panel-pack
//! builds vs cache reuses in [`crate::infer::CompressedLinear`],
//! bucket-sum chunk counts, and `WorkerPool` tasks / steal-misses. They
//! are always on (a relaxed `fetch_add` next to a GEMM inner loop is
//! noise) and observation-only by construction — nothing reads them back
//! into compute. [`counters::export_kernel_counters`] copies a snapshot
//! into a [`crate::coordinator::Metrics`] registry so they ride the
//! text / Prometheus / JSON exporters.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::{SpanKind, TraceConfig, TraceSink, DEFAULT_TRACE_CAPACITY};

/// Configuration for pipeline profiling. Constructed explicitly or from
/// the environment (`SWSC_PROF=1`, optional `SWSC_PROF_OUT=path` to also
/// write the Chrome trace-event JSON).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct ProfConfig {
    /// Where to write the Chrome trace-event JSON, if anywhere.
    pub chrome_out: Option<String>,
}

impl ProfConfig {
    /// Read the env gate: `Some` when `SWSC_PROF` is set to anything but
    /// `0`/empty, with `SWSC_PROF_OUT` naming an optional Chrome-JSON
    /// output path.
    pub fn from_env() -> Option<ProfConfig> {
        Self::from_lookup(|k| std::env::var(k).ok())
    }

    /// [`ProfConfig::from_env`] against an arbitrary lookup (testable).
    pub fn from_lookup(lookup: impl Fn(&str) -> Option<String>) -> Option<ProfConfig> {
        let on = lookup("SWSC_PROF").map(|v| {
            let v = v.trim().to_string();
            !v.is_empty() && v != "0"
        })?;
        if !on {
            return None;
        }
        let chrome_out =
            lookup("SWSC_PROF_OUT").map(|v| v.trim().to_string()).filter(|v| !v.is_empty());
        Some(ProfConfig { chrome_out })
    }
}

/// Aggregated statistics for one phase path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseStat {
    /// Times the phase was entered (or the synthetic count from
    /// [`Profiler::add`], e.g. k-means iterations).
    pub count: u64,
    /// Total wall time across all entries, nanoseconds.
    pub total_ns: u64,
}

impl PhaseStat {
    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_ns / self.count
        }
    }
}

/// Hierarchical phase profiler: a path-keyed stat map plus an embedded
/// [`TraceSink`] for the Chrome export. Shared by reference across
/// worker threads (all interior mutability is a short-critical-section
/// mutex / the sink's own ring lock).
#[derive(Debug)]
pub struct Profiler {
    stats: Mutex<BTreeMap<String, PhaseStat>>,
    sink: TraceSink,
}

impl Default for Profiler {
    fn default() -> Self {
        Self::new()
    }
}

impl Profiler {
    pub fn new() -> Profiler {
        Profiler::with_capacity(DEFAULT_TRACE_CAPACITY)
    }

    /// A profiler whose span ring holds at most `capacity` records (the
    /// aggregated stat map is unbounded but one entry per distinct path).
    pub fn with_capacity(capacity: usize) -> Profiler {
        Profiler {
            stats: Mutex::new(BTreeMap::new()),
            sink: TraceSink::new(TraceConfig { capacity }),
        }
    }

    /// Open a top-level scope. Nested phases come from
    /// [`ProfScope::child`].
    pub fn root(&self, name: &str) -> ProfScope<'_> {
        ProfScope { prof: self, path: name.to_string(), start: Instant::now() }
    }

    /// Fold `count` occurrences totalling `total_ns` into `path` without
    /// a live scope — for synthetic aggregate nodes like
    /// `…/kmeans/iters`, where the iteration count is known but the
    /// per-iteration boundaries are inside a callee.
    pub fn add(&self, path: &str, count: u64, total_ns: u64) {
        let mut stats = self.stats.lock().unwrap_or_else(|e| e.into_inner());
        let s = stats.entry(path.to_string()).or_default();
        s.count += count;
        s.total_ns += total_ns;
    }

    /// Snapshot of the aggregated phase tree, sorted by path (parents
    /// sort before their children).
    pub fn phases(&self) -> BTreeMap<String, PhaseStat> {
        self.stats.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// The embedded span sink (per-occurrence records; ring-bounded).
    pub fn sink(&self) -> &TraceSink {
        &self.sink
    }

    /// Sorted text tree: one line per path, indented by depth, with
    /// count / total / mean. Never panics; an empty profile renders a
    /// placeholder line.
    pub fn render_text(&self) -> String {
        let stats = self.phases();
        if stats.is_empty() {
            return "(no phases recorded)\n".to_string();
        }
        let mut out = String::new();
        out.push_str(&format!(
            "{:<48} {:>8} {:>12} {:>12}\n",
            "phase", "count", "total_ms", "mean_ms"
        ));
        for (path, s) in stats.iter() {
            let depth = path.matches('/').count();
            let name = path.rsplit('/').next().unwrap_or(path);
            let label = format!("{}{}", "  ".repeat(depth), name);
            out.push_str(&format!(
                "{:<48} {:>8} {:>12.3} {:>12.3}\n",
                label,
                s.count,
                s.total_ns as f64 / 1e6,
                s.mean_ns() as f64 / 1e6,
            ));
        }
        out
    }

    /// JSON snapshot of the aggregated tree:
    /// `{"phases":{"<path>":{"count":N,"total_ns":N},…}}` — sorted keys,
    /// hand-rolled like every exporter in this crate.
    pub fn render_json(&self) -> String {
        use super::json_escape as esc;
        let stats = self.phases();
        let mut out = String::from("{\"phases\":{");
        for (i, (path, s)) in stats.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\"{}\":{{\"count\":{},\"total_ns\":{}}}",
                esc(path),
                s.count,
                s.total_ns
            ));
        }
        out.push_str("}}");
        out.push('\n');
        out
    }

    /// Chrome trace-event JSON of every recorded scope occurrence —
    /// loads in Perfetto, one track per worker lane. Delegates to the
    /// PR 9 [`TraceSink::to_chrome_json`] machinery.
    pub fn to_chrome_json(&self) -> String {
        self.sink.to_chrome_json()
    }
}

/// RAII guard for one phase occurrence. Dropping it records the elapsed
/// time into the profiler's stat map and span ring.
#[derive(Debug)]
pub struct ProfScope<'p> {
    prof: &'p Profiler,
    path: String,
    start: Instant,
}

impl<'p> ProfScope<'p> {
    /// Open a nested scope `self.path + "/" + name`. Explicit parenting
    /// lets a scope cross a [`crate::exec::WorkerPool`] task boundary:
    /// borrow the parent in the worker closure and open the child there.
    pub fn child(&self, name: &str) -> ProfScope<'p> {
        ProfScope {
            prof: self.prof,
            path: format!("{}/{}", self.path, name),
            start: Instant::now(),
        }
    }

    /// The profiler this scope records into — for [`Profiler::add`]
    /// calls relative to the current position in the tree.
    pub fn profiler(&self) -> &'p Profiler {
        self.prof
    }

    /// The `/`-joined phase path of this scope.
    pub fn path(&self) -> &str {
        &self.path
    }
}

impl Drop for ProfScope<'_> {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.prof.add(&self.path, 1, ns);
        self.prof.sink.span(SpanKind::Phase, lane(), "pipeline", self.path.clone(), self.start);
    }
}

/// Open a child scope under an optional parent — the zero-cost-off
/// helper every instrumented call site uses: `None` in ⇒ `None` out,
/// one pointer test, nothing formatted.
pub fn scope<'p>(parent: Option<&ProfScope<'p>>, name: &str) -> Option<ProfScope<'p>> {
    parent.map(|p| p.child(name))
}

/// Stable per-thread lane id for the Chrome export (`tid`): worker
/// threads get distinct tracks, and the id is assigned lazily on first
/// use so unprofiled threads never take one. Purely cosmetic — the
/// aggregated tree ignores lanes entirely.
fn lane() -> u64 {
    static NEXT_LANE: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static LANE: std::cell::Cell<u64> = std::cell::Cell::new(0);
    }
    LANE.with(|l| {
        if l.get() == 0 {
            l.set(NEXT_LANE.fetch_add(1, Ordering::Relaxed));
        }
        l.get()
    })
}

/// Measure the wallclock time of `f`, returning `(result, seconds)`.
/// (Folded in from the old `util/timer` module — this is the one timing
/// utility in the crate.)
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A simple running statistics accumulator (count / mean / min / max /
/// percentiles via stored samples) — sized for bench iteration counts,
/// not serving traffic (the serving side uses the bounded
/// [`crate::coordinator::Histogram`]).
#[derive(Debug, Default, Clone)]
pub struct Stats {
    samples: Vec<f64>,
}

impl Stats {
    pub fn new() -> Self {
        Stats { samples: Vec::new() }
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.sum() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Percentile via nearest-rank on a sorted copy (fine for bench sizes).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }
}

pub mod counters {
    //! Process-global kernel work counters: always-on relaxed atomics,
    //! write-only from kernel code, snapshot + exported on demand.
    //!
    //! Living here (not in `tensor`/`exec`/`infer`) keeps the dependency
    //! arrow pointing one way — kernels call *into* obs, obs reads
    //! nothing from them — and gives the exporters one place to sweep.

    use std::sync::atomic::{AtomicU64, Ordering};

    /// Which GEMM entry point a call came through.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum GemmEntry {
        /// `gemm_rows`: unpacked-A row range against a packed B.
        Rows = 0,
        /// `gemm_rows_prepacked`: packed A against packed B.
        RowsPrepacked = 1,
        /// `gemm_rows_q`: f32 rows against a quantized packed B.
        RowsQ = 2,
        /// `gemm_rows_q_prepacked`: packed A against a quantized packed B.
        RowsQPrepacked = 3,
    }

    pub const GEMM_ENTRY_NAMES: [&str; 4] =
        ["rows", "rows_prepacked", "rows_q", "rows_q_prepacked"];
    pub const SHAPE_CLASS_NAMES: [&str; 3] = ["small", "medium", "large"];
    /// Cells in the (entry × shape-class) GEMM grid.
    pub const GEMM_CELLS: usize = 12;

    // `static [AtomicU64; N]` needs a const element to repeat; the
    // interior-mutability lint fires on any `const` atomic even though
    // each array slot gets its own instance.
    #[allow(clippy::declare_interior_mutable_const)]
    const ZERO: AtomicU64 = AtomicU64::new(0);
    static GEMM_CALLS: [AtomicU64; GEMM_CELLS] = [ZERO; GEMM_CELLS];
    static GEMM_FLOPS: [AtomicU64; GEMM_CELLS] = [ZERO; GEMM_CELLS];
    static POOL_TASKS: AtomicU64 = AtomicU64::new(0);
    static POOL_STEAL_MISSES: AtomicU64 = AtomicU64::new(0);
    static PANEL_BUILDS: AtomicU64 = AtomicU64::new(0);
    static PANEL_REUSES: AtomicU64 = AtomicU64::new(0);
    static BUCKET_CALLS: AtomicU64 = AtomicU64::new(0);
    static BUCKET_CHUNKS: AtomicU64 = AtomicU64::new(0);

    /// Shape class by the largest dimension: `small` < 128 ≤ `medium`
    /// < 512 ≤ `large`. Coarse on purpose — the point is separating
    /// centroid-sized panels from full-weight panels, not a histogram.
    fn shape_class(rows: usize, k: usize, n: usize) -> usize {
        let d = rows.max(k).max(n);
        if d < 128 {
            0
        } else if d < 512 {
            1
        } else {
            2
        }
    }

    /// Count one GEMM call of `rows × k × n` through `entry`
    /// (FLOPs = 2·rows·k·n).
    pub fn gemm_call(entry: GemmEntry, rows: usize, k: usize, n: usize) {
        let idx = entry as usize * 3 + shape_class(rows, k, n);
        let flops = 2u64
            .saturating_mul(rows as u64)
            .saturating_mul(k as u64)
            .saturating_mul(n as u64);
        GEMM_CALLS[idx].fetch_add(1, Ordering::Relaxed);
        GEMM_FLOPS[idx].fetch_add(flops, Ordering::Relaxed);
    }

    /// Count `n` tasks executed by a `WorkerPool` dispatch (claimed
    /// indices, whichever thread ran them).
    pub fn pool_tasks(n: u64) {
        POOL_TASKS.fetch_add(n, Ordering::Relaxed);
    }

    /// Count one worker wakeup that found no job to claim.
    pub fn pool_steal_miss() {
        POOL_STEAL_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one panel pack actually built (OnceLock cold path).
    pub fn panel_build() {
        PANEL_BUILDS.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one panel served from the cache (OnceLock warm path).
    pub fn panel_reuse() {
        PANEL_REUSES.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one bucket-sum call that processed `chunks` column chunks.
    pub fn bucket_call(chunks: u64) {
        BUCKET_CALLS.fetch_add(1, Ordering::Relaxed);
        BUCKET_CHUNKS.fetch_add(chunks, Ordering::Relaxed);
    }

    /// Point-in-time copy of every kernel counter.
    #[derive(Debug, Clone, Default, PartialEq, Eq)]
    pub struct KernelCounters {
        pub gemm_calls: [u64; GEMM_CELLS],
        pub gemm_flops: [u64; GEMM_CELLS],
        pub pool_tasks: u64,
        pub pool_steal_misses: u64,
        pub panel_builds: u64,
        pub panel_reuses: u64,
        pub bucket_calls: u64,
        pub bucket_chunks: u64,
    }

    impl KernelCounters {
        /// The non-empty GEMM grid cells as
        /// `("entry/class", calls, flops)` rows, grid order.
        pub fn gemm_cells(&self) -> Vec<(String, u64, u64)> {
            let mut out = Vec::new();
            for (i, name) in GEMM_ENTRY_NAMES.iter().enumerate() {
                for (j, class) in SHAPE_CLASS_NAMES.iter().enumerate() {
                    let idx = i * 3 + j;
                    if self.gemm_calls[idx] > 0 {
                        out.push((
                            format!("{name}/{class}"),
                            self.gemm_calls[idx],
                            self.gemm_flops[idx],
                        ));
                    }
                }
            }
            out
        }
    }

    pub fn snapshot() -> KernelCounters {
        let mut s = KernelCounters::default();
        for i in 0..GEMM_CELLS {
            s.gemm_calls[i] = GEMM_CALLS[i].load(Ordering::Relaxed);
            s.gemm_flops[i] = GEMM_FLOPS[i].load(Ordering::Relaxed);
        }
        s.pool_tasks = POOL_TASKS.load(Ordering::Relaxed);
        s.pool_steal_misses = POOL_STEAL_MISSES.load(Ordering::Relaxed);
        s.panel_builds = PANEL_BUILDS.load(Ordering::Relaxed);
        s.panel_reuses = PANEL_REUSES.load(Ordering::Relaxed);
        s.bucket_calls = BUCKET_CALLS.load(Ordering::Relaxed);
        s.bucket_chunks = BUCKET_CHUNKS.load(Ordering::Relaxed);
        s
    }

    /// Copy the current counter totals into a metrics registry as
    /// counter-typed absolute series, so they ride the text /
    /// Prometheus / JSON exporters. Called explicitly at export time
    /// (never from inside a render — the exporters' golden tests pin
    /// exact output, and these globals move under parallel tests).
    pub fn export_kernel_counters(m: &crate::coordinator::Metrics) {
        let s = snapshot();
        for (label, calls, flops) in s.gemm_cells() {
            m.counter_total_with("gemm.calls", &label, calls);
            m.counter_total_with("gemm.flops", &label, flops);
        }
        m.counter_total("exec.pool_tasks", s.pool_tasks);
        m.counter_total("exec.pool_steal_misses", s.pool_steal_misses);
        m.counter_total("infer.panel_builds", s.panel_builds);
        m.counter_total("infer.panel_reuses", s.panel_reuses);
        m.counter_total("infer.bucket_calls", s.bucket_calls);
        m.counter_total("infer.bucket_chunks", s.bucket_chunks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_env_gate_mirrors_trace() {
        assert_eq!(ProfConfig::from_lookup(|_| None), None);
        assert_eq!(ProfConfig::from_lookup(|k| (k == "SWSC_PROF").then(|| "0".into())), None);
        assert_eq!(ProfConfig::from_lookup(|k| (k == "SWSC_PROF").then(|| " ".into())), None);
        assert_eq!(
            ProfConfig::from_lookup(|k| (k == "SWSC_PROF").then(|| "1".into())),
            Some(ProfConfig { chrome_out: None })
        );
        let cfg = ProfConfig::from_lookup(|k| match k {
            "SWSC_PROF" => Some("1".into()),
            "SWSC_PROF_OUT" => Some("out.json".into()),
            _ => None,
        });
        assert_eq!(cfg, Some(ProfConfig { chrome_out: Some("out.json".into()) }));
    }

    #[test]
    fn scopes_aggregate_into_a_path_tree() {
        let p = Profiler::new();
        {
            let root = p.root("compress");
            {
                let m = root.child("attn.wq");
                let _r = m.child("rsvd");
            }
            {
                let m = root.child("attn.wq");
                let _q = m.child("quant");
            }
        }
        let phases = p.phases();
        assert_eq!(phases["compress"].count, 1);
        assert_eq!(phases["compress/attn.wq"].count, 2);
        assert_eq!(phases["compress/attn.wq/rsvd"].count, 1);
        assert_eq!(phases["compress/attn.wq/quant"].count, 1);
        // BTreeMap order puts parents before children.
        let keys: Vec<&str> = phases.keys().map(|s| s.as_str()).collect();
        assert_eq!(
            keys,
            vec![
                "compress",
                "compress/attn.wq",
                "compress/attn.wq/quant",
                "compress/attn.wq/rsvd"
            ]
        );
        // Every occurrence also landed in the span ring.
        assert_eq!(p.sink().len(), 5);
    }

    #[test]
    fn add_folds_synthetic_counts() {
        let p = Profiler::new();
        p.add("compress/m/kmeans/iters", 7, 700);
        p.add("compress/m/kmeans/iters", 3, 300);
        let s = p.phases()["compress/m/kmeans/iters"];
        assert_eq!(s.count, 10);
        assert_eq!(s.total_ns, 1000);
        assert_eq!(s.mean_ns(), 100);
    }

    #[test]
    fn renders_never_panic_on_empty() {
        let p = Profiler::new();
        assert_eq!(p.render_text(), "(no phases recorded)\n");
        assert_eq!(p.render_json(), "{\"phases\":{}}\n");
        assert!(p.to_chrome_json().starts_with('['));
    }

    #[test]
    fn text_render_indents_by_depth() {
        let p = Profiler::new();
        p.add("compress", 1, 2_000_000);
        p.add("compress/w", 1, 1_000_000);
        let text = p.render_text();
        assert!(text.contains("\ncompress "), "root at column 0: {text}");
        assert!(text.contains("\n  w "), "child indented under parent: {text}");
    }

    #[test]
    fn chrome_export_names_spans_by_path() {
        let p = Profiler::new();
        {
            let root = p.root("compress");
            let _c = root.child("serialize");
        }
        let json = p.to_chrome_json();
        assert!(json.contains("\"name\":\"compress/serialize\""), "{json}");
        assert!(json.contains("\"name\":\"compress\""), "{json}");
        assert!(json.contains("\"ph\":\"X\""));
    }

    #[test]
    fn scope_helper_is_none_propagating() {
        assert!(scope(None, "anything").is_none());
        let p = Profiler::new();
        let root = p.root("r");
        let child = scope(Some(&root), "c");
        assert_eq!(child.as_ref().unwrap().path(), "r/c");
    }

    #[test]
    fn kernel_counters_accumulate_and_label() {
        use counters::*;
        let before = snapshot();
        gemm_call(GemmEntry::Rows, 4, 8, 16); // all dims < 128 → small
        gemm_call(GemmEntry::RowsQPrepacked, 64, 64, 1024); // max ≥ 512 → large
        pool_tasks(3);
        pool_steal_miss();
        panel_build();
        panel_reuse();
        bucket_call(5);
        let after = snapshot();
        // Globals are shared across parallel tests: assert deltas, not totals.
        assert!(after.pool_tasks >= before.pool_tasks + 3);
        assert!(after.pool_steal_misses >= before.pool_steal_misses + 1);
        assert!(after.panel_builds >= before.panel_builds + 1);
        assert!(after.panel_reuses >= before.panel_reuses + 1);
        assert!(after.bucket_calls >= before.bucket_calls + 1);
        assert!(after.bucket_chunks >= before.bucket_chunks + 5);
        // The instrumented kernels also bump the GEMM cells from other
        // tests' real GEMMs, so these too are lower bounds.
        assert!(after.gemm_calls[0] >= before.gemm_calls[0] + 1, "rows/small cell");
        assert!(
            after.gemm_flops[0] >= before.gemm_flops[0] + 2 * 4 * 8 * 16,
            "flops = 2·m·k·n"
        );
        assert!(after.gemm_calls[11] >= before.gemm_calls[11] + 1, "rows_q_prepacked/large");
        let labels: Vec<String> = after.gemm_cells().into_iter().map(|(l, _, _)| l).collect();
        assert!(labels.contains(&"rows/small".to_string()), "{labels:?}");
        assert!(labels.contains(&"rows_q_prepacked/large".to_string()), "{labels:?}");
    }

    #[test]
    fn export_rides_the_metrics_exporters() {
        use crate::coordinator::Metrics;
        counters::gemm_call(counters::GemmEntry::Rows, 2, 2, 2);
        let m = Metrics::new();
        counters::export_kernel_counters(&m);
        let prom = m.render_prometheus();
        assert!(prom.contains("# TYPE swsc_gemm_calls counter\n"), "{prom}");
        assert!(prom.contains("swsc_gemm_calls{model=\"rows/small\"}"), "{prom}");
        assert!(prom.contains("# TYPE swsc_exec_pool_tasks counter\n"), "{prom}");
        let json = m.render_json();
        assert!(json.contains("\"gemm.calls\":{\"type\":\"counter\",\"values\":{"), "{json}");
        assert!(json.contains("\"infer.panel_builds\":{\"type\":\"counter\",\"value\":"), "{json}");
    }

    // --- ported verbatim from the old util/timer module ---

    #[test]
    fn stats_basics() {
        let mut s = Stats::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.percentile(50.0) - 2.0).abs() <= 1.0);
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 42);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
