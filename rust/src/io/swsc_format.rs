//! The `.swsc` packed compressed-model container.
//!
//! This is the paper's storage story made concrete: per compressed matrix
//! we store the bit-packed label list, fp16-encoded centroid columns, and
//! fp16-encoded low-rank factors. Uncompressed tensors (everything not in
//! the plan — V projectors, MLPs, embeddings) ride along as fp32 so a
//! single file restores a runnable model.
//!
//! Layout (little-endian):
//! ```text
//! magic "SWSC" | u32 version
//! u32 n_compressed
//!   per entry: name | u32 m | u32 n | u32 k | u32 r
//!              | packed labels (ceil(log2 k) bits each)
//!              | centroids fp16 (m·k) | A fp16 (m·r) | B fp16 (r·n)
//! u32 n_dense
//!   per entry: name | u32 ndim | u64 dims... | f32 payload
//! u32 n_quantized                                   (version ≥ 2)
//!   per entry: name | u32 m | u32 n | u32 k | u32 r | u32 group
//!              | packed labels (ceil(log2 k) bits each)
//!              | per payload R (m×k), A (m×r), B (r×n):
//!                  u8 codes | f32 scales (⌈rows/group⌉·cols)
//!                           | f32 zeros  (⌈rows/group⌉·cols)
//! trailer crc32
//! ```
//! fp16 here is real IEEE half-precision encode/decode (not just
//! accounting), so the on-disk size *is* the avg-bits story. The
//! version-2 quantized section (PR 6) stores double-compressed entries:
//! grouped int8 payloads that the serving engine packs straight into
//! fused-dequant GEMM panels, never expanding to f32. Version-1 files
//! simply lack the section; files declaring a version newer than
//! [`VERSION`] are rejected with a "needs a newer reader" error rather
//! than a confusing parse failure further in.

use crate::compress::{CompressedMatrix, QuantizedMatrix};
use crate::io::{bitpack, crc32};
use crate::quant::QuantizedTensor;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SWSC";
const VERSION: u32 = 2;

/// A compressed model file: compressed matrices, dense passthrough, and
/// (version ≥ 2) double-compressed quantized matrices. A name should
/// appear in only one of the three maps.
#[derive(Debug, Clone, Default)]
pub struct SwscFile {
    pub compressed: BTreeMap<String, CompressedMatrix>,
    pub dense: BTreeMap<String, Tensor>,
    pub quantized: BTreeMap<String, QuantizedMatrix>,
}

impl SwscFile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Restore a full named-tensor map: compressed entries are
    /// reconstructed (`W' + A·B`), quantized entries dequantize then
    /// reconstruct, dense entries pass through.
    pub fn restore_all(&self) -> BTreeMap<String, Tensor> {
        let mut out = BTreeMap::new();
        for (name, c) in &self.compressed {
            out.insert(name.clone(), c.reconstruct());
        }
        for (name, q) in &self.quantized {
            out.insert(name.clone(), q.dequantize().reconstruct());
        }
        for (name, t) in &self.dense {
            out.insert(name.clone(), t.clone());
        }
        out
    }

    /// Total on-disk payload bytes of the compressed entries.
    pub fn compressed_payload_bytes(&self) -> usize {
        self.compressed.values().map(|c| (c.bits().total_bits as usize).div_ceil(8)).sum()
    }

    /// Total on-disk payload bytes of the quantized entries (int8 codes,
    /// group metadata, packed labels).
    pub fn quantized_payload_bytes(&self) -> usize {
        self.quantized.values().map(|q| (q.bits().total_bits as usize).div_ceil(8)).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        body.extend_from_slice(&VERSION.to_le_bytes());

        body.extend_from_slice(&(self.compressed.len() as u32).to_le_bytes());
        for (name, c) in &self.compressed {
            write_name(&mut body, name);
            let (m, n) = c.shape;
            let (k, r) = (c.k(), c.rank());
            for v in [m as u32, n as u32, k as u32, r as u32] {
                body.extend_from_slice(&v.to_le_bytes());
            }
            let label_bits = ceil_log2(k).max(1);
            let packed = bitpack::pack_u32(&c.labels, label_bits);
            body.extend_from_slice(&(packed.len() as u64).to_le_bytes());
            body.extend_from_slice(&packed);
            write_f16(&mut body, c.centroids.data());
            write_f16(&mut body, c.factor_a.data());
            write_f16(&mut body, c.factor_b.data());
        }

        body.extend_from_slice(&(self.dense.len() as u32).to_le_bytes());
        for (name, t) in &self.dense {
            write_name(&mut body, name);
            body.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
            for &d in t.shape() {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &v in t.data() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }

        body.extend_from_slice(&(self.quantized.len() as u32).to_le_bytes());
        for (name, q) in &self.quantized {
            write_name(&mut body, name);
            let (m, n) = q.shape;
            let (k, r) = (q.k(), q.rank());
            for v in [m as u32, n as u32, k as u32, r as u32, q.group() as u32] {
                body.extend_from_slice(&v.to_le_bytes());
            }
            let label_bits = ceil_log2(k).max(1);
            let packed = bitpack::pack_u32(&q.labels, label_bits);
            body.extend_from_slice(&(packed.len() as u64).to_le_bytes());
            body.extend_from_slice(&packed);
            for qt in [&q.centroids, &q.factor_a, &q.factor_b] {
                body.extend_from_slice(qt.data());
                for &s in qt.scales() {
                    body.extend_from_slice(&s.to_le_bytes());
                }
                for &z in qt.zeros() {
                    body.extend_from_slice(&z.to_le_bytes());
                }
            }
        }

        let mut out = Vec::with_capacity(body.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<SwscFile> {
        if data.len() < 12 || &data[..4] != MAGIC {
            bail!("not a SWSC container (bad magic)");
        }
        let body = &data[4..data.len() - 4];
        let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
        if crc32(body) != stored {
            bail!("SWSC container CRC mismatch");
        }
        let mut cur = body;
        let version = read_u32(&mut cur)?;
        if version > VERSION {
            bail!(
                "SWSC container declares version {version} but this reader supports up to \
                 {VERSION} — the file needs a newer reader"
            );
        }
        if version == 0 {
            bail!("unsupported SWSC version 0");
        }

        let mut file = SwscFile::new();
        let n_comp = read_u32(&mut cur)? as usize;
        for _ in 0..n_comp {
            let name = read_name(&mut cur)?;
            let m = read_u32(&mut cur)? as usize;
            let n = read_u32(&mut cur)? as usize;
            let k = read_u32(&mut cur)? as usize;
            let r = read_u32(&mut cur)? as usize;
            // Header invariants first, so a corrupted header fails with a
            // clear error instead of a later panic (or absurd allocation)
            // in reconstruction/inference code that trusts the shapes.
            if n > 0 && k == 0 {
                bail!("matrix `{name}`: {n} channels but zero clusters");
            }
            if r > m.min(n) {
                bail!("matrix `{name}`: rank {r} exceeds min(m, n) = {}", m.min(n));
            }
            let label_bits = ceil_log2(k).max(1);
            let packed_len = read_u64(&mut cur)? as usize;
            let want_packed = (n * label_bits as usize).div_ceil(8);
            if packed_len != want_packed {
                bail!("matrix `{name}`: packed label section {packed_len} B != {want_packed}");
            }
            let packed = take(&mut cur, packed_len)?;
            let labels = bitpack::unpack_u32(packed, n, label_bits);
            if labels.iter().any(|&l| l as usize >= k) {
                bail!("matrix `{name}`: label out of range (k = {k})");
            }
            let centroids = Tensor::from_vec(&[m, k], read_f16(&mut cur, elems(&name, m, k)?)?);
            let factor_a = Tensor::from_vec(&[m, r], read_f16(&mut cur, elems(&name, m, r)?)?);
            let factor_b = Tensor::from_vec(&[r, n], read_f16(&mut cur, elems(&name, r, n)?)?);
            file.compressed.insert(
                name,
                CompressedMatrix { shape: (m, n), labels, centroids, factor_a, factor_b },
            );
        }

        let n_dense = read_u32(&mut cur)? as usize;
        for _ in 0..n_dense {
            let name = read_name(&mut cur)?;
            let ndim = read_u32(&mut cur)? as usize;
            if ndim > 8 {
                bail!("tensor `{name}`: implausible rank {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut cur)? as usize);
            }
            let count = shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d));
            let count = match count {
                Some(c) => c,
                None => bail!("tensor `{name}`: shape {shape:?} overflows"),
            };
            let bytes = count
                .checked_mul(4)
                .with_context(|| format!("tensor `{name}`: payload size overflows"))?;
            let raw = take(&mut cur, bytes)?;
            let mut vals = Vec::with_capacity(count);
            for c in raw.chunks_exact(4) {
                vals.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            file.dense.insert(name, Tensor::from_vec(&shape, vals));
        }

        // Version ≥ 2: the double-compressed (grouped int8) section.
        if version >= 2 {
            let n_quant = read_u32(&mut cur)? as usize;
            for _ in 0..n_quant {
                let name = read_name(&mut cur)?;
                let m = read_u32(&mut cur)? as usize;
                let n = read_u32(&mut cur)? as usize;
                let k = read_u32(&mut cur)? as usize;
                let r = read_u32(&mut cur)? as usize;
                let group = read_u32(&mut cur)? as usize;
                if n > 0 && k == 0 {
                    bail!("matrix `{name}`: {n} channels but zero clusters");
                }
                if r > m.min(n) {
                    bail!("matrix `{name}`: rank {r} exceeds min(m, n) = {}", m.min(n));
                }
                if group == 0 {
                    bail!("matrix `{name}`: quantization group must be positive");
                }
                let label_bits = ceil_log2(k).max(1);
                let packed_len = read_u64(&mut cur)? as usize;
                let want_packed = (n * label_bits as usize).div_ceil(8);
                if packed_len != want_packed {
                    bail!("matrix `{name}`: packed label section {packed_len} B != {want_packed}");
                }
                let packed = take(&mut cur, packed_len)?;
                let labels = bitpack::unpack_u32(packed, n, label_bits);
                if labels.iter().any(|&l| l as usize >= k) {
                    bail!("matrix `{name}`: label out of range (k = {k})");
                }
                let centroids = read_quantized(&mut cur, &name, m, k, group)?;
                let factor_a = read_quantized(&mut cur, &name, m, r, group)?;
                let factor_b = read_quantized(&mut cur, &name, r, n, group)?;
                file.quantized.insert(
                    name,
                    QuantizedMatrix { shape: (m, n), labels, centroids, factor_a, factor_b },
                );
            }
        }
        Ok(file)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::File::create(path)
            .with_context(|| format!("create {path:?}"))?
            .write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<SwscFile> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut data)?;
        Self::from_bytes(&data)
    }
}

// --- fp16 encode/decode -------------------------------------------------

/// f32 → IEEE 754 half (round-to-nearest-even), as u16 bits.
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal half.
        let half_exp = (unbiased + 15) as u32;
        let mut half_mant = mant >> 13;
        // Round to nearest even on the dropped 13 bits.
        let rem = mant & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
            half_mant += 1;
            if half_mant == 0x400 {
                return sign | (((half_exp + 1) as u16) << 10);
            }
        }
        return sign | ((half_exp as u16) << 10) | half_mant as u16;
    }
    if unbiased >= -24 {
        // Subnormal half: value = half_mant · 2⁻²⁴, so
        // half_mant = round(1.mant · 2^(unbiased+24)) = full >> (−unbiased−1).
        let shift = (-unbiased - 1) as u32; // 14..=23
        let full = mant | 0x80_0000;
        let mut half_mant = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half_point = 1u32 << (shift - 1);
        if rem > half_point || (rem == half_point && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }
    sign // underflow -> ±0
}

/// IEEE 754 half bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((112 + e + 1) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

fn write_f16(out: &mut Vec<u8>, vals: &[f32]) {
    for &v in vals {
        out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
    }
}

fn read_f16(cur: &mut &[u8], count: usize) -> Result<Vec<f32>> {
    let bytes = count.checked_mul(2).context("f16 payload size overflows")?;
    let raw = take(cur, bytes)?;
    Ok(raw.chunks_exact(2).map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()))).collect())
}

/// Checked element count for a 2-D payload read off the wire — corrupted
/// headers must surface as `Err`, not as an overflowed allocation.
fn elems(name: &str, a: usize, b: usize) -> Result<usize> {
    a.checked_mul(b).with_context(|| format!("matrix `{name}`: payload shape {a}×{b} overflows"))
}

fn read_f32s(cur: &mut &[u8], count: usize) -> Result<Vec<f32>> {
    let bytes = count.checked_mul(4).context("f32 payload size overflows")?;
    let raw = take(cur, bytes)?;
    Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// One grouped-int8 payload off the wire: u8 codes, then f32 scales and
/// zeros (`⌈rows/group⌉ × cols` each). Geometry re-validated by
/// [`QuantizedTensor::from_parts`] — `Err`, never a panic.
fn read_quantized(
    cur: &mut &[u8],
    name: &str,
    rows: usize,
    cols: usize,
    group: usize,
) -> Result<QuantizedTensor> {
    let count = elems(name, rows, cols)?;
    let codes = take(cur, count)?.to_vec();
    let mcount = elems(name, rows.div_ceil(group), cols)?;
    let scales = read_f32s(cur, mcount)?;
    let zeros = read_f32s(cur, mcount)?;
    QuantizedTensor::from_parts(rows, cols, group, codes, scales, zeros)
        .with_context(|| format!("matrix `{name}`: quantized payload"))
}

fn write_name(out: &mut Vec<u8>, name: &str) {
    out.extend_from_slice(&(name.len() as u32).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
}

fn read_name(cur: &mut &[u8]) -> Result<String> {
    let len = read_u32(cur)? as usize;
    Ok(std::str::from_utf8(take(cur, len)?).context("name not utf-8")?.to_string())
}

fn take<'a>(cur: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if cur.len() < n {
        bail!("truncated SWSC container");
    }
    let (head, rest) = cur.split_at(n);
    *cur = rest;
    Ok(head)
}

fn read_u32(cur: &mut &[u8]) -> Result<u32> {
    Ok(u32::from_le_bytes(take(cur, 4)?.try_into().unwrap()))
}

fn read_u64(cur: &mut &[u8]) -> Result<u64> {
    Ok(u64::from_le_bytes(take(cur, 8)?.try_into().unwrap()))
}

fn ceil_log2(k: usize) -> u32 {
    if k <= 1 {
        0
    } else {
        (usize::BITS - (k - 1).leading_zeros()).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::rng::Rng;

    #[test]
    fn f16_round_trip_representable() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, -65504.0, 1.5, 0.099975586] {
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(r, v, "{v} -> {r}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        let mut rng = Rng::new(131);
        for _ in 0..10_000 {
            let v = rng.normal_f32(0.0, 10.0);
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!((r - v).abs() <= v.abs() * 1e-3 + 1e-7, "{v} -> {r}");
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY); // overflow
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0); // underflow
        // Subnormal round trip.
        let sub = 3.0e-6f32;
        let r = f16_bits_to_f32(f32_to_f16_bits(sub));
        assert!((r - sub).abs() < 1e-6);
    }

    #[test]
    fn container_round_trip() {
        let mut rng = Rng::new(132);
        let w = Tensor::randn(&[32, 32], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(4, 3));
        let mut file = SwscFile::new();
        file.compressed.insert("layers.0.attn.wq".into(), c.clone());
        file.dense.insert("embed.tok".into(), Tensor::randn(&[16, 8], &mut rng));

        let restored = SwscFile::from_bytes(&file.to_bytes()).unwrap();
        assert_eq!(restored.compressed.len(), 1);
        assert_eq!(restored.dense.len(), 1);
        let rc = &restored.compressed["layers.0.attn.wq"];
        assert_eq!(rc.labels, c.labels);
        assert_eq!(rc.shape, c.shape);
        // fp16 quantization of payloads: close but not exact.
        let orig_rec = c.reconstruct();
        let rest_rec = rc.reconstruct();
        assert!(orig_rec.mse(&rest_rec) < 1e-5, "mse {}", orig_rec.mse(&rest_rec));
        assert_eq!(restored.dense["embed.tok"], file.dense["embed.tok"]);
    }

    #[test]
    fn on_disk_size_matches_avg_bits_accounting() {
        let mut rng = Rng::new(133);
        let m = 128;
        let w = Tensor::randn(&[m, m], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(16, 8));
        let accounted_bits = c.bits().total_bits as f64;
        let mut file = SwscFile::new();
        file.compressed.insert("w".into(), c);
        let bytes = file.to_bytes().len() as f64 * 8.0;
        // Allow header overhead but the payload must dominate.
        assert!(bytes >= accounted_bits);
        assert!(bytes < accounted_bits * 1.05 + 1024.0, "container too fat: {bytes} vs {accounted_bits}");
    }

    #[test]
    fn corruption_detected() {
        let mut file = SwscFile::new();
        file.dense.insert("t".into(), Tensor::full(&[4], 2.0));
        let mut bytes = file.to_bytes();
        let mid = bytes.len() - 10;
        bytes[mid] ^= 1;
        assert!(SwscFile::from_bytes(&bytes).is_err());
    }

    // --- corrupted-but-CRC-valid payloads (the load-time validation the
    // CRC cannot provide: a hostile or buggy *writer* produces a
    // consistent checksum over nonsense) ------------------------------

    /// Recompute the trailer CRC so a surgical corruption reaches the
    /// semantic validation instead of the checksum gate.
    fn recrc(bytes: &mut [u8]) {
        let end = bytes.len() - 4;
        let crc = crate::io::crc32(&bytes[4..end]);
        bytes[end..].copy_from_slice(&crc.to_le_bytes());
    }

    /// One-compressed-entry container with a known layout, k = 5 so the
    /// 3-bit label field has out-of-range codes (5, 6, 7) available.
    fn one_entry_bytes() -> (Vec<u8>, usize) {
        let mut rng = Rng::new(135);
        let w = Tensor::randn(&[24, 24], &mut rng);
        let mut file = SwscFile::new();
        file.compressed.insert("w".into(), compress_matrix(&w, &SwscConfig::new(5, 2)));
        let bytes = file.to_bytes();
        // magic(4) version(4) n_comp(4) name_len(4) name(1) → m n k r ...
        let header_off = 4 + 4 + 4 + 4 + 1;
        (bytes, header_off)
    }

    fn patch_u32(bytes: &mut [u8], off: usize, v: u32) {
        bytes[off..off + 4].copy_from_slice(&v.to_le_bytes());
    }

    #[test]
    fn valid_one_entry_container_loads() {
        let (bytes, _) = one_entry_bytes();
        let f = SwscFile::from_bytes(&bytes).unwrap();
        assert_eq!(f.compressed["w"].k(), 5);
    }

    #[test]
    fn label_out_of_range_rejected_not_panicked() {
        let (mut bytes, header_off) = one_entry_bytes();
        // Packed labels start after m,n,k,r (16 B) + packed_len (8 B).
        let packed_off = header_off + 16 + 8;
        bytes[packed_off] = 0xFF; // 3-bit codes 7,7,… ≥ k = 5
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("label out of range"), "{err}");
    }

    #[test]
    fn zero_clusters_with_channels_rejected() {
        let (mut bytes, header_off) = one_entry_bytes();
        patch_u32(&mut bytes, header_off + 8, 0); // k = 0
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("zero clusters"), "{err}");
    }

    #[test]
    fn rank_beyond_dims_rejected() {
        let (mut bytes, header_off) = one_entry_bytes();
        patch_u32(&mut bytes, header_off + 12, 10_000); // r ≫ min(m, n)
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("rank"), "{err}");
    }

    #[test]
    fn label_section_length_mismatch_rejected() {
        let (mut bytes, header_off) = one_entry_bytes();
        // k = 4 shrinks label_bits 3 → 2, so the stored packed_len no
        // longer matches the header — caught before any label decodes.
        patch_u32(&mut bytes, header_off + 8, 4);
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("packed label section"), "{err}");
    }

    #[test]
    fn truncated_payload_rejected() {
        let (bytes, _) = one_entry_bytes();
        // Drop the tail of the centroid payload (and the trailer), then
        // re-trailer so the CRC is consistent with the truncated body.
        let mut cut = bytes[..bytes.len() - 40].to_vec();
        let body_end = cut.len();
        cut.extend_from_slice(&[0u8; 4]);
        let crc = crate::io::crc32(&cut[4..body_end]);
        let end = cut.len() - 4;
        cut[end..].copy_from_slice(&crc.to_le_bytes());
        let err = SwscFile::from_bytes(&cut).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn absurd_dense_dims_rejected_without_allocation() {
        // Hand-build a container whose dense entry claims a shape whose
        // product overflows usize — must fail via checked arithmetic, not
        // by attempting the allocation.
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes()); // version
        body.extend_from_slice(&0u32.to_le_bytes()); // n_compressed
        body.extend_from_slice(&1u32.to_le_bytes()); // n_dense
        body.extend_from_slice(&1u32.to_le_bytes()); // name len
        body.push(b't');
        body.extend_from_slice(&2u32.to_le_bytes()); // ndim
        body.extend_from_slice(&u64::MAX.to_le_bytes());
        body.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SWSC");
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&crate::io::crc32(&body).to_le_bytes());
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");

        // Rank > 8 is rejected as implausible before any dim reads.
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b't');
        body.extend_from_slice(&99u32.to_le_bytes()); // ndim = 99
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SWSC");
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&crate::io::crc32(&body).to_le_bytes());
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("implausible rank"), "{err}");
    }

    #[test]
    fn restore_all_merges_both_kinds() {
        let mut rng = Rng::new(134);
        let w = Tensor::randn(&[16, 16], &mut rng);
        let mut file = SwscFile::new();
        file.compressed.insert("wq".into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
        file.dense.insert("wv".into(), w.clone());
        let all = file.restore_all();
        assert_eq!(all.len(), 2);
        assert_eq!(all["wv"], w);
        assert_eq!(all["wq"].shape(), w.shape());
    }

    // --- version-2 quantized section ----------------------------------

    use crate::quant::QuantConfig;

    fn quantized_file(group: usize) -> SwscFile {
        let mut rng = Rng::new(136);
        let w = Tensor::randn(&[24, 24], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(5, 2));
        let mut file = SwscFile::new();
        file.quantized.insert("w".into(), c.quantize(&QuantConfig { group }));
        file
    }

    #[test]
    fn quantized_round_trip_is_bitwise() {
        for group in [1usize, 7, 24, 64] {
            let file = quantized_file(group);
            let restored = SwscFile::from_bytes(&file.to_bytes()).unwrap();
            assert_eq!(restored.quantized.len(), 1);
            let (orig, back) = (&file.quantized["w"], &restored.quantized["w"]);
            // u8 codes and f32 LE metadata are exact on the wire: the
            // round trip is bit-identical, so the fused serving path
            // computes identical results before and after save/load.
            assert_eq!(back, orig, "group {group}");
            assert_eq!(back.group(), group);
        }
    }

    #[test]
    fn quantized_restore_all_reconstructs() {
        let file = quantized_file(8);
        let all = file.restore_all();
        assert_eq!(all["w"].shape(), &[24, 24]);
        let payload = file.quantized_payload_bytes();
        assert!(payload > 0);
        // int8 + metadata at group 8 ≈ 9 + 8/... bits/elem — below fp16.
        let fp16 = file.quantized["w"].dequantize().bits().total_bits as usize / 8;
        assert!(payload < fp16, "quantized {payload} B !< fp16 {fp16} B");
    }

    #[test]
    fn newer_version_needs_newer_reader() {
        let file = quantized_file(8);
        let mut bytes = file.to_bytes();
        patch_u32(&mut bytes, 4, VERSION + 1);
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("needs a newer reader"), "{err}");
        assert!(err.contains(&format!("version {}", VERSION + 1)), "{err}");

        // Version 0 is still plain unsupported, not "newer".
        let mut bytes = file.to_bytes();
        patch_u32(&mut bytes, 4, 0);
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("unsupported SWSC version 0"), "{err}");
    }

    #[test]
    fn version_1_files_without_quantized_section_load() {
        // A v1 container is today's layout minus the trailing
        // n_quantized word: strip it, stamp version 1, re-trailer.
        let mut rng = Rng::new(137);
        let w = Tensor::randn(&[16, 16], &mut rng);
        let mut file = SwscFile::new();
        file.compressed.insert("wq".into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
        let v2 = file.to_bytes();
        let mut v1 = v2[..v2.len() - 8].to_vec(); // drop n_quantized + crc
        patch_u32(&mut v1, 4, 1);
        let crc = crate::io::crc32(&v1[4..]);
        v1.extend_from_slice(&crc.to_le_bytes());
        let restored = SwscFile::from_bytes(&v1).unwrap();
        assert_eq!(restored.compressed.len(), 1);
        assert!(restored.quantized.is_empty());
    }

    /// One-quantized-entry container offsets: magic(4) version(4)
    /// n_comp(4) n_dense(4) n_quant(4) name_len(4) name(1) → m n k r group.
    fn one_quantized_entry_bytes() -> (Vec<u8>, usize) {
        let bytes = quantized_file(8).to_bytes();
        (bytes, 4 + 4 + 4 + 4 + 4 + 4 + 1)
    }

    #[test]
    fn quantized_zero_group_rejected() {
        let (mut bytes, header_off) = one_quantized_entry_bytes();
        patch_u32(&mut bytes, header_off + 16, 0); // group = 0
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("quantization group"), "{err}");
    }

    #[test]
    fn quantized_label_out_of_range_rejected() {
        let (mut bytes, header_off) = one_quantized_entry_bytes();
        // Packed labels start after m,n,k,r,group (20 B) + packed_len (8 B).
        bytes[header_off + 28] = 0xFF; // 3-bit codes 7,7,… ≥ k = 5
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("label out of range"), "{err}");
    }

    #[test]
    fn quantized_rank_and_cluster_headers_validated() {
        let (mut bytes, header_off) = one_quantized_entry_bytes();
        patch_u32(&mut bytes, header_off + 12, 10_000); // r ≫ min(m, n)
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("rank"), "{err}");

        let (mut bytes, header_off) = one_quantized_entry_bytes();
        patch_u32(&mut bytes, header_off + 8, 0); // k = 0 with n > 0
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("zero clusters"), "{err}");
    }

    #[test]
    fn quantized_truncated_payload_rejected() {
        let (bytes, _) = one_quantized_entry_bytes();
        let mut cut = bytes[..bytes.len() - 20].to_vec();
        let body_end = cut.len();
        cut.extend_from_slice(&[0u8; 4]);
        let crc = crate::io::crc32(&cut[4..body_end]);
        let end = cut.len() - 4;
        cut[end..].copy_from_slice(&crc.to_le_bytes());
        let err = SwscFile::from_bytes(&cut).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }
}
