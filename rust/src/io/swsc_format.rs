//! The `.swsc` packed compressed-model container.
//!
//! This is the paper's storage story made concrete: per compressed matrix
//! we store the bit-packed label list, fp16-encoded centroid columns, and
//! fp16-encoded low-rank factors. Uncompressed tensors (everything not in
//! the plan — V projectors, MLPs, embeddings) ride along as fp32 so a
//! single file restores a runnable model.
//!
//! Layout (little-endian):
//! ```text
//! magic "SWSC" | u32 version
//! u32 n_compressed
//!   per entry: name | u32 m | u32 n | u32 k | u32 r
//!              | packed labels (ceil(log2 k) bits each)
//!              | centroids fp16 (m·k) | A fp16 (m·r) | B fp16 (r·n)
//! u32 n_dense
//!   per entry: name | u32 ndim | u64 dims... | f32 payload
//! trailer crc32
//! ```
//! fp16 here is real IEEE half-precision encode/decode (not just
//! accounting), so the on-disk size *is* the avg-bits story.

use crate::compress::CompressedMatrix;
use crate::io::{bitpack, crc32};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SWSC";
const VERSION: u32 = 1;

/// A compressed model file: compressed matrices + dense passthrough.
#[derive(Debug, Clone, Default)]
pub struct SwscFile {
    pub compressed: BTreeMap<String, CompressedMatrix>,
    pub dense: BTreeMap<String, Tensor>,
}

impl SwscFile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Restore a full named-tensor map: compressed entries are
    /// reconstructed (`W' + A·B`), dense entries pass through.
    pub fn restore_all(&self) -> BTreeMap<String, Tensor> {
        let mut out = BTreeMap::new();
        for (name, c) in &self.compressed {
            out.insert(name.clone(), c.reconstruct());
        }
        for (name, t) in &self.dense {
            out.insert(name.clone(), t.clone());
        }
        out
    }

    /// Total on-disk payload bytes of the compressed entries.
    pub fn compressed_payload_bytes(&self) -> usize {
        self.compressed.values().map(|c| (c.bits().total_bits as usize).div_ceil(8)).sum()
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        body.extend_from_slice(&VERSION.to_le_bytes());

        body.extend_from_slice(&(self.compressed.len() as u32).to_le_bytes());
        for (name, c) in &self.compressed {
            write_name(&mut body, name);
            let (m, n) = c.shape;
            let (k, r) = (c.k(), c.rank());
            for v in [m as u32, n as u32, k as u32, r as u32] {
                body.extend_from_slice(&v.to_le_bytes());
            }
            let label_bits = ceil_log2(k).max(1);
            let packed = bitpack::pack_u32(&c.labels, label_bits);
            body.extend_from_slice(&(packed.len() as u64).to_le_bytes());
            body.extend_from_slice(&packed);
            write_f16(&mut body, c.centroids.data());
            write_f16(&mut body, c.factor_a.data());
            write_f16(&mut body, c.factor_b.data());
        }

        body.extend_from_slice(&(self.dense.len() as u32).to_le_bytes());
        for (name, t) in &self.dense {
            write_name(&mut body, name);
            body.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
            for &d in t.shape() {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for &v in t.data() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }

        let mut out = Vec::with_capacity(body.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out
    }

    pub fn from_bytes(data: &[u8]) -> Result<SwscFile> {
        if data.len() < 12 || &data[..4] != MAGIC {
            bail!("not a SWSC container (bad magic)");
        }
        let body = &data[4..data.len() - 4];
        let stored = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
        if crc32(body) != stored {
            bail!("SWSC container CRC mismatch");
        }
        let mut cur = body;
        let version = read_u32(&mut cur)?;
        if version != VERSION {
            bail!("unsupported SWSC version {version}");
        }

        let mut file = SwscFile::new();
        let n_comp = read_u32(&mut cur)? as usize;
        for _ in 0..n_comp {
            let name = read_name(&mut cur)?;
            let m = read_u32(&mut cur)? as usize;
            let n = read_u32(&mut cur)? as usize;
            let k = read_u32(&mut cur)? as usize;
            let r = read_u32(&mut cur)? as usize;
            // Header invariants first, so a corrupted header fails with a
            // clear error instead of a later panic (or absurd allocation)
            // in reconstruction/inference code that trusts the shapes.
            if n > 0 && k == 0 {
                bail!("matrix `{name}`: {n} channels but zero clusters");
            }
            if r > m.min(n) {
                bail!("matrix `{name}`: rank {r} exceeds min(m, n) = {}", m.min(n));
            }
            let label_bits = ceil_log2(k).max(1);
            let packed_len = read_u64(&mut cur)? as usize;
            let want_packed = (n * label_bits as usize).div_ceil(8);
            if packed_len != want_packed {
                bail!("matrix `{name}`: packed label section {packed_len} B != {want_packed}");
            }
            let packed = take(&mut cur, packed_len)?;
            let labels = bitpack::unpack_u32(packed, n, label_bits);
            if labels.iter().any(|&l| l as usize >= k) {
                bail!("matrix `{name}`: label out of range (k = {k})");
            }
            let centroids = Tensor::from_vec(&[m, k], read_f16(&mut cur, elems(&name, m, k)?)?);
            let factor_a = Tensor::from_vec(&[m, r], read_f16(&mut cur, elems(&name, m, r)?)?);
            let factor_b = Tensor::from_vec(&[r, n], read_f16(&mut cur, elems(&name, r, n)?)?);
            file.compressed.insert(
                name,
                CompressedMatrix { shape: (m, n), labels, centroids, factor_a, factor_b },
            );
        }

        let n_dense = read_u32(&mut cur)? as usize;
        for _ in 0..n_dense {
            let name = read_name(&mut cur)?;
            let ndim = read_u32(&mut cur)? as usize;
            if ndim > 8 {
                bail!("tensor `{name}`: implausible rank {ndim}");
            }
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut cur)? as usize);
            }
            let count = shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d));
            let count = match count {
                Some(c) => c,
                None => bail!("tensor `{name}`: shape {shape:?} overflows"),
            };
            let bytes = count
                .checked_mul(4)
                .with_context(|| format!("tensor `{name}`: payload size overflows"))?;
            let raw = take(&mut cur, bytes)?;
            let mut vals = Vec::with_capacity(count);
            for c in raw.chunks_exact(4) {
                vals.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            file.dense.insert(name, Tensor::from_vec(&shape, vals));
        }
        Ok(file)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::File::create(path)
            .with_context(|| format!("create {path:?}"))?
            .write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<SwscFile> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut data)?;
        Self::from_bytes(&data)
    }
}

// --- fp16 encode/decode -------------------------------------------------

/// f32 → IEEE 754 half (round-to-nearest-even), as u16 bits.
pub fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let mant = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal half.
        let half_exp = (unbiased + 15) as u32;
        let mut half_mant = mant >> 13;
        // Round to nearest even on the dropped 13 bits.
        let rem = mant & 0x1FFF;
        if rem > 0x1000 || (rem == 0x1000 && (half_mant & 1) == 1) {
            half_mant += 1;
            if half_mant == 0x400 {
                return sign | (((half_exp + 1) as u16) << 10);
            }
        }
        return sign | ((half_exp as u16) << 10) | half_mant as u16;
    }
    if unbiased >= -24 {
        // Subnormal half: value = half_mant · 2⁻²⁴, so
        // half_mant = round(1.mant · 2^(unbiased+24)) = full >> (−unbiased−1).
        let shift = (-unbiased - 1) as u32; // 14..=23
        let full = mant | 0x80_0000;
        let mut half_mant = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half_point = 1u32 << (shift - 1);
        if rem > half_point || (rem == half_point && (half_mant & 1) == 1) {
            half_mant += 1;
        }
        return sign | half_mant as u16;
    }
    sign // underflow -> ±0
}

/// IEEE 754 half bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0x1F {
        sign | 0x7F80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = 0i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3FF;
            sign | (((112 + e + 1) as u32) << 23) | (m << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

fn write_f16(out: &mut Vec<u8>, vals: &[f32]) {
    for &v in vals {
        out.extend_from_slice(&f32_to_f16_bits(v).to_le_bytes());
    }
}

fn read_f16(cur: &mut &[u8], count: usize) -> Result<Vec<f32>> {
    let bytes = count.checked_mul(2).context("f16 payload size overflows")?;
    let raw = take(cur, bytes)?;
    Ok(raw.chunks_exact(2).map(|c| f16_bits_to_f32(u16::from_le_bytes(c.try_into().unwrap()))).collect())
}

/// Checked element count for a 2-D payload read off the wire — corrupted
/// headers must surface as `Err`, not as an overflowed allocation.
fn elems(name: &str, a: usize, b: usize) -> Result<usize> {
    a.checked_mul(b).with_context(|| format!("matrix `{name}`: payload shape {a}×{b} overflows"))
}

fn write_name(out: &mut Vec<u8>, name: &str) {
    out.extend_from_slice(&(name.len() as u32).to_le_bytes());
    out.extend_from_slice(name.as_bytes());
}

fn read_name(cur: &mut &[u8]) -> Result<String> {
    let len = read_u32(cur)? as usize;
    Ok(std::str::from_utf8(take(cur, len)?).context("name not utf-8")?.to_string())
}

fn take<'a>(cur: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if cur.len() < n {
        bail!("truncated SWSC container");
    }
    let (head, rest) = cur.split_at(n);
    *cur = rest;
    Ok(head)
}

fn read_u32(cur: &mut &[u8]) -> Result<u32> {
    Ok(u32::from_le_bytes(take(cur, 4)?.try_into().unwrap()))
}

fn read_u64(cur: &mut &[u8]) -> Result<u64> {
    Ok(u64::from_le_bytes(take(cur, 8)?.try_into().unwrap()))
}

fn ceil_log2(k: usize) -> u32 {
    if k <= 1 {
        0
    } else {
        (usize::BITS - (k - 1).leading_zeros()).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::util::rng::Rng;

    #[test]
    fn f16_round_trip_representable() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, -65504.0, 1.5, 0.099975586] {
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(r, v, "{v} -> {r}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        let mut rng = Rng::new(131);
        for _ in 0..10_000 {
            let v = rng.normal_f32(0.0, 10.0);
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            assert!((r - v).abs() <= v.abs() * 1e-3 + 1e-7, "{v} -> {r}");
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY); // overflow
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-12)), 0.0); // underflow
        // Subnormal round trip.
        let sub = 3.0e-6f32;
        let r = f16_bits_to_f32(f32_to_f16_bits(sub));
        assert!((r - sub).abs() < 1e-6);
    }

    #[test]
    fn container_round_trip() {
        let mut rng = Rng::new(132);
        let w = Tensor::randn(&[32, 32], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(4, 3));
        let mut file = SwscFile::new();
        file.compressed.insert("layers.0.attn.wq".into(), c.clone());
        file.dense.insert("embed.tok".into(), Tensor::randn(&[16, 8], &mut rng));

        let restored = SwscFile::from_bytes(&file.to_bytes()).unwrap();
        assert_eq!(restored.compressed.len(), 1);
        assert_eq!(restored.dense.len(), 1);
        let rc = &restored.compressed["layers.0.attn.wq"];
        assert_eq!(rc.labels, c.labels);
        assert_eq!(rc.shape, c.shape);
        // fp16 quantization of payloads: close but not exact.
        let orig_rec = c.reconstruct();
        let rest_rec = rc.reconstruct();
        assert!(orig_rec.mse(&rest_rec) < 1e-5, "mse {}", orig_rec.mse(&rest_rec));
        assert_eq!(restored.dense["embed.tok"], file.dense["embed.tok"]);
    }

    #[test]
    fn on_disk_size_matches_avg_bits_accounting() {
        let mut rng = Rng::new(133);
        let m = 128;
        let w = Tensor::randn(&[m, m], &mut rng);
        let c = compress_matrix(&w, &SwscConfig::new(16, 8));
        let accounted_bits = c.bits().total_bits as f64;
        let mut file = SwscFile::new();
        file.compressed.insert("w".into(), c);
        let bytes = file.to_bytes().len() as f64 * 8.0;
        // Allow header overhead but the payload must dominate.
        assert!(bytes >= accounted_bits);
        assert!(bytes < accounted_bits * 1.05 + 1024.0, "container too fat: {bytes} vs {accounted_bits}");
    }

    #[test]
    fn corruption_detected() {
        let mut file = SwscFile::new();
        file.dense.insert("t".into(), Tensor::full(&[4], 2.0));
        let mut bytes = file.to_bytes();
        let mid = bytes.len() - 10;
        bytes[mid] ^= 1;
        assert!(SwscFile::from_bytes(&bytes).is_err());
    }

    // --- corrupted-but-CRC-valid payloads (the load-time validation the
    // CRC cannot provide: a hostile or buggy *writer* produces a
    // consistent checksum over nonsense) ------------------------------

    /// Recompute the trailer CRC so a surgical corruption reaches the
    /// semantic validation instead of the checksum gate.
    fn recrc(bytes: &mut [u8]) {
        let end = bytes.len() - 4;
        let crc = crate::io::crc32(&bytes[4..end]);
        bytes[end..].copy_from_slice(&crc.to_le_bytes());
    }

    /// One-compressed-entry container with a known layout, k = 5 so the
    /// 3-bit label field has out-of-range codes (5, 6, 7) available.
    fn one_entry_bytes() -> (Vec<u8>, usize) {
        let mut rng = Rng::new(135);
        let w = Tensor::randn(&[24, 24], &mut rng);
        let mut file = SwscFile::new();
        file.compressed.insert("w".into(), compress_matrix(&w, &SwscConfig::new(5, 2)));
        let bytes = file.to_bytes();
        // magic(4) version(4) n_comp(4) name_len(4) name(1) → m n k r ...
        let header_off = 4 + 4 + 4 + 4 + 1;
        (bytes, header_off)
    }

    fn patch_u32(bytes: &mut [u8], off: usize, v: u32) {
        bytes[off..off + 4].copy_from_slice(&v.to_le_bytes());
    }

    #[test]
    fn valid_one_entry_container_loads() {
        let (bytes, _) = one_entry_bytes();
        let f = SwscFile::from_bytes(&bytes).unwrap();
        assert_eq!(f.compressed["w"].k(), 5);
    }

    #[test]
    fn label_out_of_range_rejected_not_panicked() {
        let (mut bytes, header_off) = one_entry_bytes();
        // Packed labels start after m,n,k,r (16 B) + packed_len (8 B).
        let packed_off = header_off + 16 + 8;
        bytes[packed_off] = 0xFF; // 3-bit codes 7,7,… ≥ k = 5
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("label out of range"), "{err}");
    }

    #[test]
    fn zero_clusters_with_channels_rejected() {
        let (mut bytes, header_off) = one_entry_bytes();
        patch_u32(&mut bytes, header_off + 8, 0); // k = 0
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("zero clusters"), "{err}");
    }

    #[test]
    fn rank_beyond_dims_rejected() {
        let (mut bytes, header_off) = one_entry_bytes();
        patch_u32(&mut bytes, header_off + 12, 10_000); // r ≫ min(m, n)
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("rank"), "{err}");
    }

    #[test]
    fn label_section_length_mismatch_rejected() {
        let (mut bytes, header_off) = one_entry_bytes();
        // k = 4 shrinks label_bits 3 → 2, so the stored packed_len no
        // longer matches the header — caught before any label decodes.
        patch_u32(&mut bytes, header_off + 8, 4);
        recrc(&mut bytes);
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("packed label section"), "{err}");
    }

    #[test]
    fn truncated_payload_rejected() {
        let (bytes, _) = one_entry_bytes();
        // Drop the tail of the centroid payload (and the trailer), then
        // re-trailer so the CRC is consistent with the truncated body.
        let mut cut = bytes[..bytes.len() - 40].to_vec();
        let body_end = cut.len();
        cut.extend_from_slice(&[0u8; 4]);
        let crc = crate::io::crc32(&cut[4..body_end]);
        let end = cut.len() - 4;
        cut[end..].copy_from_slice(&crc.to_le_bytes());
        let err = SwscFile::from_bytes(&cut).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn absurd_dense_dims_rejected_without_allocation() {
        // Hand-build a container whose dense entry claims a shape whose
        // product overflows usize — must fail via checked arithmetic, not
        // by attempting the allocation.
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes()); // version
        body.extend_from_slice(&0u32.to_le_bytes()); // n_compressed
        body.extend_from_slice(&1u32.to_le_bytes()); // n_dense
        body.extend_from_slice(&1u32.to_le_bytes()); // name len
        body.push(b't');
        body.extend_from_slice(&2u32.to_le_bytes()); // ndim
        body.extend_from_slice(&u64::MAX.to_le_bytes());
        body.extend_from_slice(&u64::MAX.to_le_bytes());
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SWSC");
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&crate::io::crc32(&body).to_le_bytes());
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");

        // Rank > 8 is rejected as implausible before any dim reads.
        let mut body = Vec::new();
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&0u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.extend_from_slice(&1u32.to_le_bytes());
        body.push(b't');
        body.extend_from_slice(&99u32.to_le_bytes()); // ndim = 99
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"SWSC");
        bytes.extend_from_slice(&body);
        bytes.extend_from_slice(&crate::io::crc32(&body).to_le_bytes());
        let err = SwscFile::from_bytes(&bytes).unwrap_err().to_string();
        assert!(err.contains("implausible rank"), "{err}");
    }

    #[test]
    fn restore_all_merges_both_kinds() {
        let mut rng = Rng::new(134);
        let w = Tensor::randn(&[16, 16], &mut rng);
        let mut file = SwscFile::new();
        file.compressed.insert("wq".into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
        file.dense.insert("wv".into(), w.clone());
        let all = file.restore_all();
        assert_eq!(all.len(), 2);
        assert_eq!(all["wv"], w);
        assert_eq!(all["wq"].shape(), w.shape());
    }
}
