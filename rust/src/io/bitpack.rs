//! Bit-packing for the cluster label list.
//!
//! The paper stores one `⌈log2 k⌉`-bit label per channel; packing them
//! tightly is where the label storage term in the avg-bits accounting comes
//! from. LSB-first within each byte, values must fit in `bits`.

/// Pack `values` at `bits` bits each (1..=32), LSB-first.
pub fn pack_u32(values: &[u32], bits: u32) -> Vec<u8> {
    assert!((1..=32).contains(&bits), "bits out of range: {bits}");
    let total_bits = values.len() * bits as usize;
    let mut out = vec![0u8; total_bits.div_ceil(8)];
    let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
    let mut bitpos = 0usize;
    for &v in values {
        // Unconditional: a release build that silently masked an
        // oversized label would round-trip it as a *different valid
        // label* — a wrong cluster served with no error anywhere.
        assert!(v <= mask, "value {v} does not fit in {bits} bits");
        let v = v as u64;
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let span = (v << off) as u128;
        // Write up to 5 bytes.
        let mut s = span;
        let mut b = byte;
        while s != 0 {
            out[b] |= (s & 0xFF) as u8;
            s >>= 8;
            b += 1;
        }
        bitpos += bits as usize;
    }
    out
}

/// Unpack `count` values at `bits` bits each from `data`.
pub fn unpack_u32(data: &[u8], count: usize, bits: u32) -> Vec<u32> {
    assert!((1..=32).contains(&bits));
    let mask = if bits == 32 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut out = Vec::with_capacity(count);
    let mut bitpos = 0usize;
    for _ in 0..count {
        let byte = bitpos / 8;
        let off = bitpos % 8;
        let mut chunk = 0u64;
        for i in 0..((bits as usize + off).div_ceil(8)) {
            if byte + i < data.len() {
                chunk |= (data[byte + i] as u64) << (8 * i);
            }
        }
        out.push(((chunk >> off) & mask) as u32);
        bitpos += bits as usize;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_trip_various_widths() {
        prop::check(
            "bitpack round trip",
            111,
            64,
            |r| {
                // Full width range, including the bits == 32 mask edge
                // (where `1u32 << bits` would overflow — the mask must
                // come from the u64 domain or the MAX special case).
                let bits = 1 + r.below(32) as u32;
                let n = r.below(200);
                let mask = (1u64 << bits) - 1;
                let vals: Vec<u32> = (0..n).map(|_| (r.next_u64() & mask) as u32).collect();
                (vals, bits)
            },
            |(vals, bits)| {
                let packed = pack_u32(vals, *bits);
                let got = unpack_u32(&packed, vals.len(), *bits);
                if &got == vals { Ok(()) } else { Err(format!("{got:?} != {vals:?}")) }
            },
        );
    }

    /// The out-of-range guard is unconditional (not `debug_assert!`):
    /// in release builds a masked oversized value would round-trip as a
    /// different valid label, serving the wrong cluster silently.
    #[test]
    #[should_panic(expected = "does not fit")]
    fn oversized_value_panics_in_all_builds() {
        pack_u32(&[16], 4);
    }

    /// Full-width edge: 32-bit values round-trip with no masking at all.
    #[test]
    fn bits_32_round_trips_max_values() {
        let vals = vec![u32::MAX, 0, 0x8000_0001, 0xDEAD_BEEF];
        let packed = pack_u32(&vals, 32);
        assert_eq!(packed.len(), 16);
        assert_eq!(unpack_u32(&packed, vals.len(), 32), vals);
    }

    #[test]
    fn packed_size_is_tight() {
        let vals = vec![1u32; 100];
        assert_eq!(pack_u32(&vals, 1).len(), 13); // ceil(100/8)
        assert_eq!(pack_u32(&vals, 7).len(), 88); // ceil(700/8)
    }

    #[test]
    fn empty_input() {
        assert!(pack_u32(&[], 4).is_empty());
        assert!(unpack_u32(&[], 0, 4).is_empty());
    }

    #[test]
    fn known_pattern() {
        // 4-bit values 0xA, 0xB -> byte 0xBA (LSB-first).
        assert_eq!(pack_u32(&[0xA, 0xB], 4), vec![0xBA]);
        assert_eq!(unpack_u32(&[0xBA], 2, 4), vec![0xA, 0xB]);
    }
}
