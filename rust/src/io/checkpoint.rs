//! Named-tensor checkpoint format.
//!
//! Layout (little-endian):
//! ```text
//! magic "SWCK" | u32 version | u32 tensor_count
//! per tensor: u32 name_len | name bytes | u32 ndim | u64 dims...
//!             | u64 payload_len | f32 payload...
//! trailer: u32 crc32 over everything after the magic
//! ```
//! Deterministic: tensors are written sorted by name.

use crate::io::crc32;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SWCK";
const VERSION: u32 = 1;

/// An in-memory named-tensor map with binary (de)serialization.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    tensors: BTreeMap<String, Tensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Checkpoint { tensors: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.tensors.get(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.tensors.remove(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.tensors.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Name + shape list (drives `CompressionPlan`).
    pub fn shapes(&self) -> Vec<(String, Vec<usize>)> {
        self.tensors.iter().map(|(k, v)| (k.clone(), v.shape().to_vec())).collect()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut body = Vec::new();
        body.extend_from_slice(&VERSION.to_le_bytes());
        body.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            body.extend_from_slice(&(name.len() as u32).to_le_bytes());
            body.extend_from_slice(name.as_bytes());
            body.extend_from_slice(&(t.ndim() as u32).to_le_bytes());
            for &d in t.shape() {
                body.extend_from_slice(&(d as u64).to_le_bytes());
            }
            body.extend_from_slice(&(t.len() as u64 * 4).to_le_bytes());
            for &v in t.data() {
                body.extend_from_slice(&v.to_le_bytes());
            }
        }
        let mut out = Vec::with_capacity(body.len() + 8);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc32(&body).to_le_bytes());
        out
    }

    /// Deserialize from bytes, verifying magic + CRC.
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.len() < 12 || &data[..4] != MAGIC {
            bail!("not a SWCK checkpoint (bad magic)");
        }
        let body = &data[4..data.len() - 4];
        let stored_crc = u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            bail!("checkpoint CRC mismatch — file corrupted");
        }
        let mut cur = body;
        let version = read_u32(&mut cur)?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = read_u32(&mut cur)? as usize;
        let mut ck = Checkpoint::new();
        for _ in 0..count {
            let name_len = read_u32(&mut cur)? as usize;
            let name = std::str::from_utf8(take(&mut cur, name_len)?)
                .context("tensor name not utf-8")?
                .to_string();
            let ndim = read_u32(&mut cur)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut cur)? as usize);
            }
            let payload_len = read_u64(&mut cur)? as usize;
            let raw = take(&mut cur, payload_len)?;
            let n = payload_len / 4;
            if n != shape.iter().product::<usize>() {
                bail!("tensor `{name}`: payload/shape mismatch");
            }
            let mut vals = Vec::with_capacity(n);
            for c in raw.chunks_exact(4) {
                vals.push(f32::from_le_bytes(c.try_into().unwrap()));
            }
            ck.insert(&name, Tensor::from_vec(&shape, vals));
        }
        Ok(ck)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut data = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("open {path:?}"))?
            .read_to_end(&mut data)?;
        Self::from_bytes(&data)
    }
}

fn take<'a>(cur: &mut &'a [u8], n: usize) -> Result<&'a [u8]> {
    if cur.len() < n {
        bail!("truncated checkpoint");
    }
    let (head, rest) = cur.split_at(n);
    *cur = rest;
    Ok(head)
}

fn read_u32(cur: &mut &[u8]) -> Result<u32> {
    Ok(u32::from_le_bytes(take(cur, 4)?.try_into().unwrap()))
}

fn read_u64(cur: &mut &[u8]) -> Result<u64> {
    Ok(u64::from_le_bytes(take(cur, 8)?.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn round_trip_in_memory() {
        let mut rng = Rng::new(121);
        let mut ck = Checkpoint::new();
        ck.insert("w1", Tensor::randn(&[4, 6], &mut rng));
        ck.insert("b1", Tensor::randn(&[6], &mut rng));
        let restored = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(restored.len(), 2);
        assert_eq!(restored.get("w1"), ck.get("w1"));
        assert_eq!(restored.get("b1"), ck.get("b1"));
    }

    #[test]
    fn round_trip_on_disk() {
        let dir = std::env::temp_dir().join("swsc_ck_test");
        let path = dir.join("model.swck");
        let mut rng = Rng::new(122);
        let mut ck = Checkpoint::new();
        ck.insert("layers.0.attn.wq", Tensor::randn(&[8, 8], &mut rng));
        ck.save(&path).unwrap();
        let restored = Checkpoint::load(&path).unwrap();
        assert_eq!(restored.get("layers.0.attn.wq"), ck.get("layers.0.attn.wq"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_is_detected() {
        let mut ck = Checkpoint::new();
        ck.insert("t", Tensor::full(&[2, 2], 1.0));
        let mut bytes = ck.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(Checkpoint::from_bytes(b"NOPE00000000").is_err());
        assert!(Checkpoint::from_bytes(b"").is_err());
    }

    #[test]
    fn serialization_is_deterministic_and_sorted() {
        let mut a = Checkpoint::new();
        a.insert("zz", Tensor::full(&[1], 1.0));
        a.insert("aa", Tensor::full(&[1], 2.0));
        let mut b = Checkpoint::new();
        b.insert("aa", Tensor::full(&[1], 2.0));
        b.insert("zz", Tensor::full(&[1], 1.0));
        assert_eq!(a.to_bytes(), b.to_bytes());
        assert_eq!(a.names().collect::<Vec<_>>(), vec!["aa", "zz"]);
    }
}
