//! On-disk formats: named-tensor checkpoints, the packed SWSC container,
//! and the bit-packing primitives the label list uses.
//!
//! Both formats are custom little-endian binary with magic + version +
//! CRC32 over the payload — no serde in the vendored crate set, and the
//! formats are simple enough that hand-rolled is clearer anyway.

pub mod bitpack;
pub mod checkpoint;
pub mod swsc_format;

pub use bitpack::{pack_u32, unpack_u32};
pub use checkpoint::Checkpoint;
pub use swsc_format::SwscFile;

/// CRC32 (IEEE) for payload integrity checks — small table-driven impl.
pub fn crc32(data: &[u8]) -> u32 {
    const POLY: u32 = 0xEDB88320;
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { POLY ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = !0u32;
    for &b in data {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    !crc
}

#[cfg(test)]
mod tests {
    #[test]
    fn crc32_known_vectors() {
        // Standard test vector.
        assert_eq!(super::crc32(b"123456789"), 0xCBF43926);
        assert_eq!(super::crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_flip() {
        let a = super::crc32(b"hello world");
        let b = super::crc32(b"hello worle");
        assert_ne!(a, b);
    }
}
