//! Seeded, deterministic fault injection for the serving stack.
//!
//! Chaos testing only earns its keep when failures are *reproducible*:
//! every injection decision here is a pure function of
//! `(seed, request-id, salt)` — independent of wall clock, thread count,
//! and scheduling — so a failing chaos run replays exactly from its seed,
//! and a test can predict which request ids will be poisoned before
//! submitting them.
//!
//! Three failure modes, each with an independent rate in `[0, 1]`:
//!
//! - **panics** — the coalescer fires a *real* `panic!` (scoped to the
//!   poisoned request, under the same `catch_unwind` containment that
//!   guards genuine panics) when the request is picked into a batch; for
//!   forward requests the panic fires at a deterministic layer boundary.
//! - **latency** — an artificial [`FaultConfig::delay`] sleep before the
//!   request executes.
//! - **admission failures** — [`super::AdmissionQueue`] rejects the
//!   request with `Overloaded` as if the queue were full.
//!
//! Injection is **off by default and zero-cost when off**: nothing
//! constructs a [`FaultInjector`] unless a [`FaultConfig`] with a nonzero
//! rate is supplied ([`crate::coordinator::ServiceConfig::faults`] /
//! [`super::ServerOptions::faults`]) or the `SWSC_FAULT_*` environment
//! variables enable one ([`FaultConfig::from_env`]); the hot paths hold an
//! `Option<Arc<FaultInjector>>` that stays `None`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Environment variables read by [`FaultConfig::from_env`].
pub const ENV_SEED: &str = "SWSC_FAULT_SEED";
pub const ENV_PANIC_RATE: &str = "SWSC_FAULT_PANIC_RATE";
pub const ENV_DELAY_RATE: &str = "SWSC_FAULT_DELAY_RATE";
pub const ENV_DELAY_US: &str = "SWSC_FAULT_DELAY_US";
pub const ENV_REJECT_RATE: &str = "SWSC_FAULT_REJECT_RATE";

/// Configuration for deterministic fault injection. All rates are
/// probabilities in `[0, 1]`, evaluated per request id.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Seed for the injection hash; same seed → same decisions.
    pub seed: u64,
    /// Fraction of requests that panic during execution.
    pub panic_rate: f64,
    /// Fraction of requests delayed by [`FaultConfig::delay`].
    pub delay_rate: f64,
    /// Artificial latency added to delayed requests.
    pub delay: Duration,
    /// Fraction of requests rejected at admission (as `Overloaded`).
    pub reject_rate: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0xFA17,
            panic_rate: 0.0,
            delay_rate: 0.0,
            delay: Duration::from_millis(1),
            reject_rate: 0.0,
        }
    }
}

impl FaultConfig {
    /// Whether any failure mode has a nonzero rate.
    pub fn enabled(&self) -> bool {
        self.panic_rate > 0.0 || self.delay_rate > 0.0 || self.reject_rate > 0.0
    }

    /// Read `SWSC_FAULT_*` from the process environment. Returns `Some`
    /// only if at least one rate is nonzero — so merely setting
    /// `SWSC_FAULT_SEED` does not switch injection on.
    pub fn from_env() -> Option<FaultConfig> {
        Self::from_lookup(|k| std::env::var(k).ok())
    }

    /// [`FaultConfig::from_env`] over an arbitrary lookup (testable
    /// without mutating process-global environment state).
    pub fn from_lookup(lookup: impl Fn(&str) -> Option<String>) -> Option<FaultConfig> {
        let mut cfg = FaultConfig::default();
        if let Some(v) = lookup(ENV_SEED).and_then(|v| v.trim().parse::<u64>().ok()) {
            cfg.seed = v;
        }
        if let Some(v) = lookup(ENV_PANIC_RATE).and_then(|v| v.trim().parse::<f64>().ok()) {
            cfg.panic_rate = v.clamp(0.0, 1.0);
        }
        if let Some(v) = lookup(ENV_DELAY_RATE).and_then(|v| v.trim().parse::<f64>().ok()) {
            cfg.delay_rate = v.clamp(0.0, 1.0);
        }
        if let Some(v) = lookup(ENV_DELAY_US).and_then(|v| v.trim().parse::<u64>().ok()) {
            cfg.delay = Duration::from_micros(v);
        }
        if let Some(v) = lookup(ENV_REJECT_RATE).and_then(|v| v.trim().parse::<f64>().ok()) {
            cfg.reject_rate = v.clamp(0.0, 1.0);
        }
        if cfg.enabled() {
            Some(cfg)
        } else {
            None
        }
    }
}

/// Counts of faults actually fired (not merely decided), for reports.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub panics: u64,
    pub delays: u64,
    pub rejections: u64,
}

/// Deterministic fault oracle: decision methods are pure functions of
/// `(seed, request-id)` and may be called any number of times; the
/// `record_*` methods count faults actually fired at the injection site.
#[derive(Debug)]
pub struct FaultInjector {
    cfg: FaultConfig,
    panics: AtomicU64,
    delays: AtomicU64,
    rejections: AtomicU64,
}

/// Distinct salts keep the three failure modes' decisions independent.
const SALT_PANIC: u64 = 0x50_41_4E_49;
const SALT_DELAY: u64 = 0x44_45_4C_41;
const SALT_REJECT: u64 = 0x52_45_4A_43;
const SALT_LAYER: u64 = 0x4C_41_59_52;

impl FaultInjector {
    pub fn new(cfg: FaultConfig) -> FaultInjector {
        FaultInjector {
            cfg,
            panics: AtomicU64::new(0),
            delays: AtomicU64::new(0),
            rejections: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// splitmix64-style mix of (seed, id, salt) mapped to `[0, 1)`.
    fn uniform(&self, id: u64, salt: u64) -> f64 {
        let mut z = self
            .cfg
            .seed
            .wrapping_add(id.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .wrapping_add(salt.wrapping_mul(0xBF58_476D_1CE4_E5B9));
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Whether this request id is fated to panic during execution.
    pub fn injects_panic(&self, id: u64) -> bool {
        self.cfg.panic_rate > 0.0 && self.uniform(id, SALT_PANIC) < self.cfg.panic_rate
    }

    /// For a forward request fated to panic: the layer boundary (in
    /// `[0, n_layers)`) at which the panic fires.
    pub fn panic_layer(&self, id: u64, n_layers: usize) -> usize {
        if n_layers <= 1 {
            return 0;
        }
        (self.uniform(id, SALT_LAYER) * n_layers as f64) as usize % n_layers
    }

    /// Artificial latency for this request id, if any.
    pub fn injects_delay(&self, id: u64) -> Option<Duration> {
        if self.cfg.delay_rate > 0.0 && self.uniform(id, SALT_DELAY) < self.cfg.delay_rate {
            Some(self.cfg.delay)
        } else {
            None
        }
    }

    /// Whether admission rejects this request id as `Overloaded`.
    pub fn injects_rejection(&self, id: u64) -> bool {
        self.cfg.reject_rate > 0.0 && self.uniform(id, SALT_REJECT) < self.cfg.reject_rate
    }

    pub fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_delay(&self) {
        self.delays.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejection(&self) {
        self.rejections.fetch_add(1, Ordering::Relaxed);
    }

    /// Faults actually fired so far.
    pub fn counts(&self) -> FaultCounts {
        FaultCounts {
            panics: self.panics.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            rejections: self.rejections.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_by_seed_and_id() {
        let cfg = FaultConfig { seed: 42, panic_rate: 0.3, reject_rate: 0.2, ..Default::default() };
        let a = FaultInjector::new(cfg.clone());
        let b = FaultInjector::new(cfg);
        for id in 0..256 {
            assert_eq!(a.injects_panic(id), b.injects_panic(id));
            assert_eq!(a.injects_rejection(id), b.injects_rejection(id));
            assert_eq!(a.panic_layer(id, 7), b.panic_layer(id, 7));
            assert!(a.panic_layer(id, 7) < 7);
        }
        // Different seeds disagree somewhere over a few hundred ids.
        let c = FaultInjector::new(FaultConfig {
            seed: 43,
            panic_rate: 0.3,
            ..Default::default()
        });
        assert!((0..256).any(|id| a.injects_panic(id) != c.injects_panic(id)));
    }

    #[test]
    fn rates_bound_the_observed_fraction_loosely() {
        let inj = FaultInjector::new(FaultConfig {
            seed: 7,
            panic_rate: 0.25,
            ..Default::default()
        });
        let hits = (0..4096).filter(|&id| inj.injects_panic(id)).count();
        // Loose two-sided bound: 0.25 ± 0.08 over 4096 draws.
        assert!((700..=1350).contains(&hits), "hits = {hits}");
    }

    #[test]
    fn zero_rates_never_fire_and_env_stays_off() {
        let inj = FaultInjector::new(FaultConfig::default());
        assert!((0..1024).all(|id| !inj.injects_panic(id)
            && !inj.injects_rejection(id)
            && inj.injects_delay(id).is_none()));
        // Seed alone does not enable injection.
        assert!(FaultConfig::from_lookup(|k| {
            (k == ENV_SEED).then(|| "9".to_string())
        })
        .is_none());
        let cfg = FaultConfig::from_lookup(|k| match k {
            ENV_SEED => Some("9".into()),
            ENV_PANIC_RATE => Some("0.5".into()),
            ENV_DELAY_US => Some("250".into()),
            _ => None,
        })
        .expect("nonzero rate enables injection");
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.panic_rate, 0.5);
        assert_eq!(cfg.delay, Duration::from_micros(250));
    }

    #[test]
    fn counts_track_fired_faults() {
        let inj = FaultInjector::new(FaultConfig::default());
        inj.record_panic();
        inj.record_panic();
        inj.record_delay();
        inj.record_rejection();
        assert_eq!(inj.counts(), FaultCounts { panics: 2, delays: 1, rejections: 1 });
    }
}
