//! The assembled serving front end: an admission queue feeding a
//! coalescer thread over a model registry.

use super::coalescer::{BatchConfig, Coalescer};
use super::queue::{AdmissionError, AdmissionQueue};
use super::registry::ModelRegistry;
use super::{ForwardRequest, ForwardResponse, LinearRequest, LinearResponse};
use crate::coordinator::metrics::Metrics;
use anyhow::Context;
use std::sync::{mpsc, Arc};

/// Registry key used when a server fronts exactly one model (the
/// `coordinator::EvalService` integration registers its `.swsc` model
/// under this name).
pub const DEFAULT_MODEL: &str = "default";

/// Default admission-queue depth for [`BatchServer::start`].
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// A running batched serving instance: submissions go through the bounded
/// [`AdmissionQueue`], a dedicated coalescer thread stacks them into
/// micro-batches, and responses come back on per-request channels —
/// bitwise identical to serving each request alone (see the module docs
/// of [`crate::serve`]).
pub struct BatchServer {
    queue: AdmissionQueue,
    registry: Arc<ModelRegistry>,
    metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl BatchServer {
    /// Start with a private metrics registry and the default queue depth.
    pub fn start(registry: Arc<ModelRegistry>, cfg: BatchConfig) -> BatchServer {
        Self::start_with(registry, cfg, DEFAULT_QUEUE_CAPACITY, Arc::new(Metrics::new()))
    }

    /// Full-control constructor: explicit admission-queue depth and a
    /// shared metrics registry (the `EvalService` integration passes its
    /// own, so one `render()` covers both surfaces).
    pub fn start_with(
        registry: Arc<ModelRegistry>,
        cfg: BatchConfig,
        queue_capacity: usize,
        metrics: Arc<Metrics>,
    ) -> BatchServer {
        let (queue, rx) = AdmissionQueue::bounded(queue_capacity);
        let coalescer = Coalescer::new(registry.clone(), cfg, metrics.clone());
        let worker = std::thread::spawn(move || coalescer.run(rx));
        BatchServer { queue, registry, metrics, worker: Some(worker) }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The admission queue (introspection: `depth()`, `capacity()`).
    pub fn queue(&self) -> &AdmissionQueue {
        &self.queue
    }

    /// Blocking admission: waits for queue space (backpressure stalls the
    /// submitter). Returns the receiver the response arrives on.
    pub fn submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, String>>, AdmissionError> {
        self.queue.submit(model, req)
    }

    /// Non-blocking admission: [`AdmissionError::Overloaded`] when the
    /// queue is at capacity — explicit backpressure instead of buffering.
    pub fn try_submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, String>>, AdmissionError> {
        match self.queue.try_submit(model, req) {
            Err(AdmissionError::Overloaded) => {
                self.metrics.incr("serve.rejected_overloaded", 1);
                Err(AdmissionError::Overloaded)
            }
            other => other,
        }
    }

    /// Blocking admission of a whole-model forward request (PR 7): the
    /// coalescer's continuous-batching scheduler steps it through the
    /// registered [`crate::infer::CompressedForward`] layer by layer,
    /// re-forming the in-flight batch at every layer boundary — bitwise
    /// identical to solo execution at any scheduling.
    pub fn submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, String>>, AdmissionError> {
        self.queue.submit_forward(model, req)
    }

    /// Non-blocking [`BatchServer::submit_forward`]: a full admission
    /// queue is an explicit [`AdmissionError::Overloaded`].
    pub fn try_submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, String>>, AdmissionError> {
        match self.queue.try_submit_forward(model, req) {
            Err(AdmissionError::Overloaded) => {
                self.metrics.incr("serve.rejected_overloaded", 1);
                Err(AdmissionError::Overloaded)
            }
            other => other,
        }
    }

    /// Submit a forward request and wait for its logits.
    pub fn submit_forward_blocking(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> anyhow::Result<ForwardResponse> {
        let rx = self.submit_forward(model, req).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv().context("server dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Submit and wait — convenience mirroring
    /// `EvalService::linear_blocking`.
    pub fn submit_blocking(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> anyhow::Result<LinearResponse> {
        let rx = self.submit(model, req).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv().context("server dropped response")?.map_err(|e| anyhow::anyhow!(e))
    }

    /// Reject new admissions and wake the coalescer; does not join.
    /// Everything admitted before this call is still served; anything
    /// racing in behind the marker gets an explicit shutdown error.
    pub fn begin_shutdown(&self) {
        self.queue.begin_shutdown();
    }

    /// Graceful shutdown: stop admitting, serve what was admitted, answer
    /// the rest with explicit errors, join the coalescer.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.begin_shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.stop();
    }
}
