//! The assembled serving front end: an admission queue feeding a
//! coalescer thread over a model registry.

use super::coalescer::{BatchConfig, Coalescer};
use super::fault::{FaultConfig, FaultInjector};
use super::queue::{AdmissionError, AdmissionQueue, QueueOptions, QuotaConfig};
use super::registry::ModelRegistry;
use super::{ForwardRequest, ForwardResponse, LinearRequest, LinearResponse, ServeError};
use crate::coordinator::metrics::Metrics;
use crate::infer::{CompressedForward, InferMode};
use crate::io::SwscFile;
use crate::model::ModelConfig;
use crate::obs::{EventKind, TraceConfig, TraceSink, NO_REQUEST_ID};
use anyhow::Context;
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Registry key used when a server fronts exactly one model (the
/// `coordinator::EvalService` integration registers its `.swsc` model
/// under this name).
pub const DEFAULT_MODEL: &str = "default";

/// Default admission-queue depth for [`BatchServer::start`].
pub const DEFAULT_QUEUE_CAPACITY: usize = 256;

/// Assembly knobs beyond the [`BatchConfig`] itself (PR 8). `Default`
/// reads the `SWSC_FAULT_*` environment for an injection config — unset
/// (the production state) means `faults: None` and the injection hooks
/// compile down to a skipped `Option` check.
pub struct ServerOptions {
    /// Admission-queue depth (bounds queued, not in-flight, work).
    pub queue_capacity: usize,
    /// Shared metrics registry; pass the coordinator's so one `render()`
    /// covers both surfaces.
    pub metrics: Arc<Metrics>,
    /// Per-model admission quotas (empty = unlimited).
    pub quotas: QuotaConfig,
    /// Seeded fault injection for chaos testing; `None` is the zero-cost
    /// production default.
    pub faults: Option<FaultConfig>,
    /// Request-scoped tracing (PR 9); `None` (the default unless
    /// `SWSC_TRACE` is set) is the zero-cost production state — tracing
    /// is pure observation either way, traced and untraced serving are
    /// bitwise identical.
    pub trace: Option<TraceConfig>,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            queue_capacity: DEFAULT_QUEUE_CAPACITY,
            metrics: Arc::new(Metrics::new()),
            quotas: QuotaConfig::default(),
            faults: FaultConfig::from_env(),
            trace: TraceConfig::from_env(),
        }
    }
}

/// Bounded retry-with-backoff for transient admission failures
/// ([`AdmissionError::Overloaded`], [`AdmissionError::QuotaExceeded`]).
/// [`AdmissionError::ShuttingDown`] is never retried — the condition is
/// terminal. The backoff doubles per attempt, capped at `max_backoff`,
/// and is skipped once the request's own deadline has expired (the next
/// attempt then resolves immediately with
/// [`ServeError::DeadlineExceeded`] instead of sleeping past it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total admission attempts (clamped to ≥ 1; 1 = no retries).
    pub attempts: u32,
    /// Sleep before the first retry; doubles each further retry.
    pub backoff: Duration,
    /// Upper bound on any single backoff sleep.
    pub max_backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 3,
            backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(50),
        }
    }
}

impl RetryPolicy {
    /// No retries at all — a single attempt.
    pub fn none() -> RetryPolicy {
        RetryPolicy { attempts: 1, ..RetryPolicy::default() }
    }

    /// Backoff before retry number `retry` (0-based): doubling, capped.
    fn delay(&self, retry: u32) -> Duration {
        let factor = 1u32 << retry.min(16);
        self.backoff.saturating_mul(factor).min(self.max_backoff)
    }

    fn retryable(err: AdmissionError) -> bool {
        matches!(err, AdmissionError::Overloaded | AdmissionError::QuotaExceeded)
    }
}

/// A running batched serving instance: submissions go through the bounded
/// [`AdmissionQueue`], a dedicated coalescer thread stacks them into
/// micro-batches, and responses come back on per-request channels —
/// bitwise identical to serving each request alone (see the module docs
/// of [`crate::serve`]).
pub struct BatchServer {
    queue: AdmissionQueue,
    registry: Arc<ModelRegistry>,
    metrics: Arc<Metrics>,
    trace: Option<Arc<TraceSink>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl BatchServer {
    /// Start with default [`ServerOptions`] (private metrics, default
    /// queue depth, no quotas, env-gated fault injection).
    pub fn start(registry: Arc<ModelRegistry>, cfg: BatchConfig) -> BatchServer {
        Self::start_with_opts(registry, cfg, ServerOptions::default())
    }

    /// [`BatchServer::start`] with an explicit queue depth and a shared
    /// metrics registry (the `EvalService` integration passes its own, so
    /// one `render()` covers both surfaces).
    pub fn start_with(
        registry: Arc<ModelRegistry>,
        cfg: BatchConfig,
        queue_capacity: usize,
        metrics: Arc<Metrics>,
    ) -> BatchServer {
        Self::start_with_opts(
            registry,
            cfg,
            ServerOptions { queue_capacity, metrics, ..ServerOptions::default() },
        )
    }

    /// Full-control constructor (PR 8): quotas and fault injection ride
    /// along. One [`FaultInjector`] instance is shared by the admission
    /// side (rejections) and the coalescer (panics, delays), so one seed
    /// determines the whole fault schedule.
    pub fn start_with_opts(
        registry: Arc<ModelRegistry>,
        cfg: BatchConfig,
        opts: ServerOptions,
    ) -> BatchServer {
        let ServerOptions { queue_capacity, metrics, quotas, faults, trace } = opts;
        let faults = faults.filter(FaultConfig::enabled).map(|f| Arc::new(FaultInjector::new(f)));
        // One sink is shared by the admission side (events) and the
        // coalescer (spans), so one export covers the whole request path.
        let trace = trace.map(|t| Arc::new(TraceSink::new(t)));
        let (queue, rx) = AdmissionQueue::bounded_with(
            queue_capacity,
            QueueOptions {
                quotas,
                faults: faults.clone(),
                metrics: Some(metrics.clone()),
                trace: trace.clone(),
            },
        );
        let coalescer = Coalescer::with_observers(
            registry.clone(),
            cfg,
            metrics.clone(),
            faults,
            trace.clone(),
        );
        let worker = std::thread::spawn(move || coalescer.run(rx));
        BatchServer { queue, registry, metrics, trace, worker: Some(worker) }
    }

    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// The admission queue (introspection: `depth()`, `capacity()`).
    pub fn queue(&self) -> &AdmissionQueue {
        &self.queue
    }

    /// The trace sink, when tracing was enabled at start (PR 9).
    pub fn trace_sink(&self) -> Option<&Arc<TraceSink>> {
        self.trace.as_ref()
    }

    /// Export everything the trace ring currently holds as Chrome
    /// trace-event JSON (loadable in Perfetto / `chrome://tracing`).
    /// `None` when tracing is disabled.
    pub fn dump_trace(&self) -> Option<String> {
        self.trace.as_ref().map(|t| t.to_chrome_json())
    }

    /// Atomic model hot-swap (PR 8): build and validate the replacement
    /// `.swsc` outside the registry lock, then flip the name. In-flight
    /// requests finish against the `Arc` they resolved; new admissions see
    /// the new model. `Err` leaves the old model serving untouched.
    pub fn replace_forward_file(
        &self,
        name: &str,
        file: &SwscFile,
        cfg: ModelConfig,
        mode: InferMode,
    ) -> anyhow::Result<Arc<CompressedForward>> {
        let fwd = self.registry.replace_forward_file(name, file, cfg, mode)?;
        self.metrics.incr("serve.swaps", 1);
        Ok(fwd)
    }

    /// Blocking admission: waits for queue space (backpressure stalls the
    /// submitter). Returns the receiver the response arrives on.
    pub fn submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        self.queue.submit(model, req)
    }

    /// Non-blocking admission: [`AdmissionError::Overloaded`] when the
    /// queue is at capacity — explicit backpressure instead of buffering.
    pub fn try_submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        match self.queue.try_submit(model, req) {
            Err(AdmissionError::Overloaded) => {
                self.metrics.incr("serve.rejected_overloaded", 1);
                Err(AdmissionError::Overloaded)
            }
            other => other,
        }
    }

    /// [`BatchServer::try_submit`] under a [`RetryPolicy`]: transient
    /// admission failures back off and retry; `ShuttingDown` and the
    /// final failure propagate. Each retry counts on `serve.retries`.
    pub fn submit_with_retry(
        &self,
        model: &str,
        req: LinearRequest,
        policy: RetryPolicy,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        self.with_retry(model, policy, req.deadline, |req| self.try_submit(model, req), req)
    }

    /// Blocking admission of a whole-model forward request (PR 7): the
    /// coalescer's continuous-batching scheduler steps it through the
    /// registered [`crate::infer::CompressedForward`] layer by layer,
    /// re-forming the in-flight batch at every layer boundary — bitwise
    /// identical to solo execution at any scheduling.
    pub fn submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        self.queue.submit_forward(model, req)
    }

    /// Non-blocking [`BatchServer::submit_forward`]: a full admission
    /// queue is an explicit [`AdmissionError::Overloaded`].
    pub fn try_submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        match self.queue.try_submit_forward(model, req) {
            Err(AdmissionError::Overloaded) => {
                self.metrics.incr("serve.rejected_overloaded", 1);
                Err(AdmissionError::Overloaded)
            }
            other => other,
        }
    }

    /// [`BatchServer::try_submit_forward`] under a [`RetryPolicy`] — see
    /// [`BatchServer::submit_with_retry`].
    pub fn submit_forward_with_retry(
        &self,
        model: &str,
        req: ForwardRequest,
        policy: RetryPolicy,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        self.with_retry(model, policy, req.deadline, |req| self.try_submit_forward(model, req), req)
    }

    /// The shared retry loop. `deadline` short-circuits the backoff: an
    /// expired request skips the sleep, and the next attempt is answered
    /// immediately with [`ServeError::DeadlineExceeded`] by admission
    /// (expired requests never occupy a queue slot).
    fn with_retry<R, T>(
        &self,
        model: &str,
        policy: RetryPolicy,
        deadline: Option<std::time::Instant>,
        mut attempt_fn: impl FnMut(R) -> Result<T, AdmissionError>,
        req: R,
    ) -> Result<T, AdmissionError>
    where
        R: Clone,
    {
        let attempts = policy.attempts.max(1);
        let mut retry = 0u32;
        loop {
            match attempt_fn(req.clone()) {
                Err(e) if RetryPolicy::retryable(e) && retry + 1 < attempts => {
                    self.metrics.incr("serve.retries", 1);
                    // No admitted-request id exists here (each failed
                    // attempt's id died with the rejection), so retries
                    // trace on the reserved NO_REQUEST_ID track — never
                    // the server-scope batch-pick track (trace id 0).
                    if let Some(t) = &self.trace {
                        t.event(
                            EventKind::Retry,
                            NO_REQUEST_ID,
                            model,
                            &format!("attempt {}", retry + 1),
                        );
                    }
                    if !super::deadline_expired(deadline) {
                        std::thread::sleep(policy.delay(retry));
                    }
                    retry += 1;
                }
                other => return other,
            }
        }
    }

    /// Submit a forward request and wait for its logits.
    pub fn submit_forward_blocking(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> anyhow::Result<ForwardResponse> {
        let rx = self.submit_forward(model, req).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv().context("server dropped response")?.map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Submit and wait — convenience mirroring
    /// `EvalService::linear_blocking`.
    pub fn submit_blocking(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> anyhow::Result<LinearResponse> {
        let rx = self.submit(model, req).map_err(|e| anyhow::anyhow!("{e}"))?;
        rx.recv().context("server dropped response")?.map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Reject new admissions and wake the coalescer; does not join.
    /// Everything admitted before this call is still served; anything
    /// racing in behind the marker gets an explicit shutdown error.
    pub fn begin_shutdown(&self) {
        self.queue.begin_shutdown();
    }

    /// Graceful shutdown: stop admitting, serve what was admitted, answer
    /// the rest with explicit errors, join the coalescer.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.begin_shutdown();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.stop();
    }
}
