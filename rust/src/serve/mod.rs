//! Batched serving: micro-batch coalescing, a multi-model registry, and
//! admission-controlled backpressure over the compressed-domain engine.
//!
//! [`crate::infer`] (PR 4) made a *single* compressed product cheap. This
//! module makes *concurrent traffic* cheap: the shared-weight
//! factorization `W ≈ R[labels] + A·B` only compounds at serving time
//! when many activations amortize one set of packed GEMM panels and one
//! label-gather pass — the same deployment observation the DeltaLLM and
//! head-wise weight-sharing lines make (PAPERS.md). Before this layer,
//! `coordinator::EvalService` answered every linear request inline, one
//! at a time; every request paid its own dispatch, packing, and
//! microkernel ramp-up alone.
//!
//! Three pieces, composable on their own or assembled by [`BatchServer`]:
//!
//! - [`Coalescer`] — drains the request queue into micro-batches
//!   (bounded by [`BatchConfig::max_batch_rows`] stacked activation rows,
//!   flushed after [`BatchConfig::max_wait`] when arrivals run dry),
//!   stacks each (model, weight) group's row-major activations **in
//!   arrival order** into one batch matrix, runs a single
//!   [`crate::infer::CompressedModel::apply`] per group on the exec pool,
//!   and scatters rows back to per-request responders.
//! - [`ModelRegistry`] — multiple named `.swsc` models behind `Arc`s, so
//!   one service serves many models and every in-flight request shares
//!   each model's lazily packed GEMM panels.
//! - [`AdmissionQueue`] — bounded depth with **explicit**
//!   [`AdmissionError::Overloaded`] rejection (backpressure, not OOM) and
//!   drain-on-shutdown: whatever sits behind the shutdown marker is
//!   answered with an explicit error, never a silently dropped sender.
//!
//! ## The bitwise contract
//!
//! Batching is *invisible* in the results: every `apply` path (compressed
//! gather or dense passthrough GEMM) computes each output row as
//! single-register increasing-k dots over that row's own activations —
//! row-independent by the crate-wide kernel accumulation policy
//! (`tests/fixtures/README.md`). Stacking rows changes *which call*
//! computes a row, never its bits, so batched responses are bitwise
//! equal to solo responses at any `SWSC_THREADS` — pinned by the
//! row-independence property test in `tests/serve_batched.rs` and by the
//! `ServiceConfig::batching` oracle flag ([`Batching::Disabled`] mirrors
//! `ExecBackend::SpawnPerCall` / `GemmKernel::Blocked` /
//! `InferMode::Reconstructed`: the old inline path, kept as the bitwise
//! baseline).
//!
//! `benches/hotpath.rs` drives the `bench::loadgen` open-loop generator
//! through both configurations and emits `batched_vs_solo_*` rows;
//! `examples/serve_batched.rs` is the artifact-free demo and CI smoke
//! test.
//!
//! ## Whole-model serving and continuous batching (PR 7)
//!
//! [`ForwardRequest`] serves an entire transformer forward pass from a
//! registered [`crate::infer::CompressedForward`] — not one linear op.
//! Because the forward is a start/step/finish state machine at layer
//! granularity, the coalescer runs it with **continuous batching**: the
//! in-flight request set is re-formed at every layer boundary, so
//! arrivals join mid-flight (at their layer 0) and short requests finish
//! and respond without convoying behind long ones. The flush-the-batch
//! model survives as [`coalescer::ForwardScheduling::Flush`], the
//! scheduling oracle — both modes, and solo execution, are **bitwise
//! identical** because every cross-request op is a row-independent
//! `apply` (see [`crate::infer::CompressedForward`]'s module docs; the
//! end-to-end pins live in `tests/serve_forward.rs`, and
//! `forward_batched_vs_flush_*` bench rows quantify the latency win).

//!
//! ## Fault tolerance (PR 8)
//!
//! Every failure mode is an explicit, typed [`ServeError`] — never a hang
//! or a dead coalescer thread:
//!
//! - **Panic containment.** Per-(model, weight)-group applies and
//!   per-forward-step execution run under `catch_unwind`; a poisoned
//!   request answers its responder with [`ServeError::Panicked`] (carrying
//!   the original panic message when downcastable) while the rest of the
//!   micro-batch completes and the coalescer thread survives.
//! - **Deadlines.** Requests may carry an absolute deadline
//!   ([`LinearRequest::with_timeout`] etc.), checked at admission and at
//!   every layer boundary of the continuous forward scheduler. Eviction is
//!   pure scheduling — survivors stay bitwise equal to solo.
//! - **Seeded fault injection.** [`fault::FaultInjector`] (env- or
//!   config-gated, zero-cost when off) deterministically injects panics,
//!   latency, and admission failures by (seed, request-id).
//! - **Graceful degradation.** Bounded retry-with-backoff
//!   ([`server::RetryPolicy`]), per-model admission quotas
//!   ([`queue::QuotaConfig`]), and atomic model hot-swap
//!   ([`ModelRegistry::replace_forward_file`]: build outside the lock,
//!   flip the `Arc`, drain the old one).

pub mod coalescer;
pub mod fault;
pub mod queue;
pub mod registry;
pub mod server;

pub use coalescer::{BatchConfig, Coalescer, ForwardScheduling};
pub use fault::{FaultConfig, FaultInjector};
pub use queue::{AdmissionError, AdmissionQueue, JobReceiver, QueueOptions, QuotaConfig};
pub use registry::ModelRegistry;
pub use server::{BatchServer, RetryPolicy, ServerOptions, DEFAULT_MODEL};

use crate::tensor::Tensor;
use std::fmt;
use std::time::{Duration, Instant};

/// Why a served request failed. Every serving failure mode is one of
/// these typed variants — an explicit `Err`, never a hang, a dropped
/// sender, or a dead worker thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Execution panicked. Containment is per request where possible
    /// (injected faults, per-request start/finish); a panic inside a
    /// grouped op (stacked `apply`, `step_group`) poisons that group —
    /// every member gets this error, other groups and the coalescer
    /// thread survive. `message` carries the panic payload when it was a
    /// `&str`/`String`.
    Panicked { message: String },
    /// The request's deadline expired before it could be (fully) served.
    DeadlineExceeded,
    /// The server is shutting down; the request was drained, not served.
    ShuttingDown,
    /// No model registered under this name.
    UnknownModel(String),
    /// Execution failed with an ordinary (non-panic) error.
    Failed(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Panicked { message } => write!(f, "request panicked: {message}"),
            ServeError::DeadlineExceeded => write!(f, "deadline exceeded before the request was served"),
            ServeError::ShuttingDown => {
                write!(f, "server shutting down — request drained before it was served")
            }
            ServeError::UnknownModel(name) => write!(f, "no model named `{name}` in the registry"),
            ServeError::Failed(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One linear-layer request: apply the named weight of a model to a
/// row-major activation batch (`x` is `[b, in_features]`).
#[derive(Debug, Clone)]
pub struct LinearRequest {
    pub name: String,
    pub x: Tensor,
    /// Optional absolute deadline. Checked at admission and when the
    /// request is picked into a batch; expired requests answer
    /// [`ServeError::DeadlineExceeded`].
    pub deadline: Option<Instant>,
}

impl LinearRequest {
    pub fn new(name: impl Into<String>, x: Tensor) -> LinearRequest {
        LinearRequest { name: name.into(), x, deadline: None }
    }

    /// Set an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> LinearRequest {
        self.deadline = Some(deadline);
        self
    }

    /// Set a deadline `timeout` from now.
    pub fn with_timeout(self, timeout: Duration) -> LinearRequest {
        self.with_deadline(Instant::now() + timeout)
    }

    /// Whether the deadline (if any) has passed.
    pub fn expired(&self) -> bool {
        deadline_expired(self.deadline)
    }
}

/// Response to a [`LinearRequest`]: `y = x · W[name]`, `[b, out_features]`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinearResponse {
    pub y: Tensor,
}

/// One whole-model request: run the registered compressed forward pass
/// over a token window (`tokens.len() ≤ seq`, values `< vocab`).
#[derive(Debug, Clone)]
pub struct ForwardRequest {
    pub tokens: Vec<u32>,
    /// Optional absolute deadline. Checked at admission and at **every
    /// layer boundary** of the continuous scheduler; an expired request
    /// leaves the in-flight set with [`ServeError::DeadlineExceeded`].
    /// Eviction is pure scheduling — survivors' bits never move.
    pub deadline: Option<Instant>,
}

impl ForwardRequest {
    pub fn new(tokens: Vec<u32>) -> ForwardRequest {
        ForwardRequest { tokens, deadline: None }
    }

    /// Set an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> ForwardRequest {
        self.deadline = Some(deadline);
        self
    }

    /// Set a deadline `timeout` from now.
    pub fn with_timeout(self, timeout: Duration) -> ForwardRequest {
        self.with_deadline(Instant::now() + timeout)
    }

    /// Whether the deadline (if any) has passed.
    pub fn expired(&self) -> bool {
        deadline_expired(self.deadline)
    }
}

pub(crate) fn deadline_expired(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// Response to a [`ForwardRequest`]: `[tokens, vocab]` logits.
#[derive(Debug, Clone, PartialEq)]
pub struct ForwardResponse {
    pub logits: Tensor,
}

/// How a serving front end routes linear requests.
///
/// The two settings are bitwise identical (row-independent `apply`), so
/// this is purely a throughput/latency knob — `Disabled` survives as the
/// solo oracle and bench baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Batching {
    /// Micro-batch coalescing through a [`BatchServer`] (the default).
    Enabled(BatchConfig),
    /// Inline per-request serving — the pre-batching path, kept as the
    /// bitwise oracle.
    Disabled,
}

impl Default for Batching {
    fn default() -> Self {
        Batching::Enabled(BatchConfig::default())
    }
}
