//! Batched serving: micro-batch coalescing, a multi-model registry, and
//! admission-controlled backpressure over the compressed-domain engine.
//!
//! [`crate::infer`] (PR 4) made a *single* compressed product cheap. This
//! module makes *concurrent traffic* cheap: the shared-weight
//! factorization `W ≈ R[labels] + A·B` only compounds at serving time
//! when many activations amortize one set of packed GEMM panels and one
//! label-gather pass — the same deployment observation the DeltaLLM and
//! head-wise weight-sharing lines make (PAPERS.md). Before this layer,
//! `coordinator::EvalService` answered every linear request inline, one
//! at a time; every request paid its own dispatch, packing, and
//! microkernel ramp-up alone.
//!
//! Three pieces, composable on their own or assembled by [`BatchServer`]:
//!
//! - [`Coalescer`] — drains the request queue into micro-batches
//!   (bounded by [`BatchConfig::max_batch_rows`] stacked activation rows,
//!   flushed after [`BatchConfig::max_wait`] when arrivals run dry),
//!   stacks each (model, weight) group's row-major activations **in
//!   arrival order** into one batch matrix, runs a single
//!   [`crate::infer::CompressedModel::apply`] per group on the exec pool,
//!   and scatters rows back to per-request responders.
//! - [`ModelRegistry`] — multiple named `.swsc` models behind `Arc`s, so
//!   one service serves many models and every in-flight request shares
//!   each model's lazily packed GEMM panels.
//! - [`AdmissionQueue`] — bounded depth with **explicit**
//!   [`AdmissionError::Overloaded`] rejection (backpressure, not OOM) and
//!   drain-on-shutdown: whatever sits behind the shutdown marker is
//!   answered with an explicit error, never a silently dropped sender.
//!
//! ## The bitwise contract
//!
//! Batching is *invisible* in the results: every `apply` path (compressed
//! gather or dense passthrough GEMM) computes each output row as
//! single-register increasing-k dots over that row's own activations —
//! row-independent by the crate-wide kernel accumulation policy
//! (`tests/fixtures/README.md`). Stacking rows changes *which call*
//! computes a row, never its bits, so batched responses are bitwise
//! equal to solo responses at any `SWSC_THREADS` — pinned by the
//! row-independence property test in `tests/serve_batched.rs` and by the
//! `ServiceConfig::batching` oracle flag ([`Batching::Disabled`] mirrors
//! `ExecBackend::SpawnPerCall` / `GemmKernel::Blocked` /
//! `InferMode::Reconstructed`: the old inline path, kept as the bitwise
//! baseline).
//!
//! `benches/hotpath.rs` drives the `bench::loadgen` open-loop generator
//! through both configurations and emits `batched_vs_solo_*` rows;
//! `examples/serve_batched.rs` is the artifact-free demo and CI smoke
//! test.
//!
//! ## Whole-model serving and continuous batching (PR 7)
//!
//! [`ForwardRequest`] serves an entire transformer forward pass from a
//! registered [`crate::infer::CompressedForward`] — not one linear op.
//! Because the forward is a start/step/finish state machine at layer
//! granularity, the coalescer runs it with **continuous batching**: the
//! in-flight request set is re-formed at every layer boundary, so
//! arrivals join mid-flight (at their layer 0) and short requests finish
//! and respond without convoying behind long ones. The flush-the-batch
//! model survives as [`coalescer::ForwardScheduling::Flush`], the
//! scheduling oracle — both modes, and solo execution, are **bitwise
//! identical** because every cross-request op is a row-independent
//! `apply` (see [`crate::infer::CompressedForward`]'s module docs; the
//! end-to-end pins live in `tests/serve_forward.rs`, and
//! `forward_batched_vs_flush_*` bench rows quantify the latency win).

pub mod coalescer;
pub mod queue;
pub mod registry;
pub mod server;

pub use coalescer::{BatchConfig, Coalescer, ForwardScheduling};
pub use queue::{AdmissionError, AdmissionQueue, JobReceiver};
pub use registry::ModelRegistry;
pub use server::{BatchServer, DEFAULT_MODEL};

use crate::tensor::Tensor;

/// One linear-layer request: apply the named weight of a model to a
/// row-major activation batch (`x` is `[b, in_features]`).
#[derive(Debug, Clone)]
pub struct LinearRequest {
    pub name: String,
    pub x: Tensor,
}

/// Response to a [`LinearRequest`]: `y = x · W[name]`, `[b, out_features]`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinearResponse {
    pub y: Tensor,
}

/// One whole-model request: run the registered compressed forward pass
/// over a token window (`tokens.len() ≤ seq`, values `< vocab`).
#[derive(Debug, Clone)]
pub struct ForwardRequest {
    pub tokens: Vec<u32>,
}

/// Response to a [`ForwardRequest`]: `[tokens, vocab]` logits.
#[derive(Debug, Clone, PartialEq)]
pub struct ForwardResponse {
    pub logits: Tensor,
}

/// How a serving front end routes linear requests.
///
/// The two settings are bitwise identical (row-independent `apply`), so
/// this is purely a throughput/latency knob — `Disabled` survives as the
/// solo oracle and bench baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Batching {
    /// Micro-batch coalescing through a [`BatchServer`] (the default).
    Enabled(BatchConfig),
    /// Inline per-request serving — the pre-batching path, kept as the
    /// bitwise oracle.
    Disabled,
}

impl Default for Batching {
    fn default() -> Self {
        Batching::Enabled(BatchConfig::default())
    }
}
