//! Multiple named `.swsc` models behind one serving surface.

use crate::infer::{CompressedForward, CompressedModel, InferMode, Precision};
use crate::io::SwscFile;
use crate::model::ModelConfig;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// Named [`CompressedModel`]s, `Arc`-shared so every in-flight request —
/// and every coalesced batch — reuses one set of lazily packed GEMM
/// panels per model. A model's panels pack on the first request that
/// needs an orientation and are shared by all later requests, across
/// models' names (two registry names may alias one `Arc`'d model and the
/// coalescer will still batch them together).
///
/// ## Hot-swap (PR 8)
///
/// The name→`Arc` maps live behind an `RwLock`, so the registry mutates
/// through `&self` while the server holds it in an `Arc`:
///
/// - **Lookups are atomic.** `get`/`forward` clone the `Arc` under a read
///   lock; a concurrent [`ModelRegistry::replace_forward_file`] flips the
///   entry under the write lock, so a request observes the old model or
///   the new one — never a partially-swapped state.
/// - **Builds happen outside the lock.** The replace/insert paths parse
///   and validate the new `.swsc` *before* taking the write lock; a
///   corrupt reload returns `Err` with the registry untouched and
///   in-flight traffic never stalls behind the build.
/// - **Old models drain naturally.** Requests that already resolved the
///   old `Arc` (and the coalescer's in-flight forwards, which pin it at
///   admission) keep computing against it; the panels free when the last
///   holder drops.
#[derive(Default)]
struct Inner {
    models: BTreeMap<String, Arc<CompressedModel>>,
    forwards: BTreeMap<String, Arc<CompressedForward>>,
    /// name → canonical name, rebuilt on every registration change.
    /// [`ModelRegistry::canonical`] sits on the per-request metrics path
    /// (several lookups per served request), so it must be a map hit
    /// under the read lock — not a scan over all registered models.
    canonicals: BTreeMap<String, String>,
}

impl Inner {
    /// Recompute the canonical-name cache: for every registered name, the
    /// lexicographically first name sharing the same model `Arc`.
    /// O(n log n) on the cold registration path, so the hot-path
    /// [`ModelRegistry::canonical`] lookup stays O(log n).
    fn rebuild_canonicals(&mut self) {
        let mut first: BTreeMap<*const CompressedModel, String> = BTreeMap::new();
        for (name, m) in &self.models {
            first.entry(Arc::as_ptr(m)).or_insert_with(|| name.clone());
        }
        self.canonicals = self
            .models
            .iter()
            .map(|(name, m)| (name.clone(), first[&Arc::as_ptr(m)].clone()))
            .collect();
    }
}

#[derive(Default)]
pub struct ModelRegistry {
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    fn read(&self) -> RwLockReadGuard<'_, Inner> {
        // The lock only guards BTreeMap ops — a poisoning panic cannot
        // leave the maps mid-update, so recover instead of cascading.
        self.inner.read().unwrap_or_else(|e| e.into_inner())
    }

    fn write(&self) -> RwLockWriteGuard<'_, Inner> {
        self.inner.write().unwrap_or_else(|e| e.into_inner())
    }

    /// Load `file` in `mode` and register it under `name` (replacing any
    /// previous entry of that name). Returns the shared handle.
    pub fn insert_file(
        &self,
        name: &str,
        file: &SwscFile,
        mode: InferMode,
    ) -> Arc<CompressedModel> {
        self.insert_file_with(name, file, mode, Precision::default())
    }

    /// [`ModelRegistry::insert_file`] with an explicit serving
    /// [`Precision`]. At [`Precision::Int8`] the `Arc`-shared panels are
    /// the *quantized* panels — every alias and in-flight request reuses
    /// the ≈4×-smaller panel cache, not an f32 expansion.
    pub fn insert_file_with(
        &self,
        name: &str,
        file: &SwscFile,
        mode: InferMode,
        precision: Precision,
    ) -> Arc<CompressedModel> {
        // Build outside the lock; the flip below is the only locked work.
        let model = Arc::new(CompressedModel::from_file_with(file, mode, precision));
        let mut inner = self.write();
        inner.models.insert(name.to_string(), model.clone());
        // A stale forward under this name would reference the replaced
        // model — linear-only inserts clear it.
        inner.forwards.remove(name);
        inner.rebuild_canonicals();
        model
    }

    /// Register an already-built model under `name`.
    pub fn insert(&self, name: &str, model: Arc<CompressedModel>) {
        let mut inner = self.write();
        inner.models.insert(name.to_string(), model);
        inner.forwards.remove(name);
        inner.rebuild_canonicals();
    }

    /// Register a whole-model forward pass under `name` (PR 7). The
    /// forward's underlying [`CompressedModel`] is registered under the
    /// same name, so one name answers both [`super::LinearRequest`]s
    /// (individual weights) and [`super::ForwardRequest`]s (the full
    /// stack) from one set of shared packed panels.
    pub fn insert_forward(&self, name: &str, fwd: Arc<CompressedForward>) {
        let mut inner = self.write();
        inner.models.insert(name.to_string(), fwd.model().clone());
        inner.forwards.insert(name.to_string(), fwd);
        inner.rebuild_canonicals();
    }

    /// Build a [`CompressedForward`] from `file` (validating that every
    /// parameter `cfg` requires is present) and register it under `name`.
    pub fn insert_forward_file(
        &self,
        name: &str,
        file: &SwscFile,
        cfg: ModelConfig,
        mode: InferMode,
    ) -> Result<Arc<CompressedForward>> {
        let model = Arc::new(CompressedModel::from_file(file, mode));
        let fwd = Arc::new(CompressedForward::new(model, cfg)?);
        self.insert_forward(name, fwd.clone());
        Ok(fwd)
    }

    /// Atomic hot-swap of a whole-model forward: build and **validate**
    /// the replacement entirely outside the lock, then flip both map
    /// entries under one write lock. On `Err` the registry is untouched —
    /// a corrupt reload never interrupts in-flight traffic, and requests
    /// holding the old `Arc` drain against it naturally.
    ///
    /// Returns the new forward handle. (This is `insert_forward_file`
    /// with replacement semantics made explicit; use an alias name to
    /// stage a load-then-flip without disturbing the live name.)
    pub fn replace_forward_file(
        &self,
        name: &str,
        file: &SwscFile,
        cfg: ModelConfig,
        mode: InferMode,
    ) -> Result<Arc<CompressedForward>> {
        let model = Arc::new(CompressedModel::from_file(file, mode));
        let fwd = Arc::new(CompressedForward::new(model, cfg)?);
        self.insert_forward(name, fwd.clone());
        Ok(fwd)
    }

    /// Unregister `name` (both the linear model and any forward). Returns
    /// the removed model handle; in-flight requests holding it keep
    /// computing — the panels free when the last holder drops.
    pub fn remove(&self, name: &str) -> Option<Arc<CompressedModel>> {
        let mut inner = self.write();
        inner.forwards.remove(name);
        let removed = inner.models.remove(name);
        inner.rebuild_canonicals();
        removed
    }

    /// The model registered under `name`, if any.
    pub fn get(&self, name: &str) -> Option<Arc<CompressedModel>> {
        self.read().models.get(name).cloned()
    }

    /// The whole-model forward registered under `name`, if any.
    pub fn forward(&self, name: &str) -> Option<Arc<CompressedForward>> {
        self.read().forwards.get(name).cloned()
    }

    /// Registered names, in sorted order.
    pub fn names(&self) -> Vec<String> {
        self.read().models.keys().cloned().collect()
    }

    /// Resolve `name` to its **canonical** name: the lexicographically
    /// first registered name sharing the same model `Arc` (PR 9). Aliases
    /// inserted via [`ModelRegistry::insert`] with a cloned handle all
    /// report one canonical name, so per-model metric labels aggregate
    /// alias traffic instead of splintering it. Returns `None` when
    /// `name` is unregistered. A cache hit under the read lock — the
    /// name→canonical map is maintained on registration changes, so the
    /// per-request metrics path never scans the registry.
    pub fn canonical(&self, name: &str) -> Option<String> {
        self.read().canonicals.get(name).cloned()
    }

    pub fn len(&self) -> usize {
        self.read().models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn insert_get_and_share() {
        let mut rng = Rng::new(50);
        let mut file = SwscFile::new();
        file.compressed
            .insert("w".into(), compress_matrix(&Tensor::randn(&[8, 8], &mut rng), &SwscConfig::new(2, 1)));
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let a = reg.insert_file("a", &file, InferMode::Compressed);
        reg.insert("alias", a.clone());
        reg.insert_file("b", &file, InferMode::Reconstructed);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.names(), vec!["a", "alias", "b"]);
        // `alias` shares `a`'s model (same Arc — shared packed panels).
        assert!(Arc::ptr_eq(&reg.get("a").unwrap(), &reg.get("alias").unwrap()));
        assert!(!Arc::ptr_eq(&reg.get("a").unwrap(), &reg.get("b").unwrap()));
        assert!(reg.get("missing").is_none());
        assert_eq!(reg.get("a").unwrap().num_compressed(), 1);
        assert_eq!(reg.get("b").unwrap().num_compressed(), 0);
        // Canonical resolution: alias → lexicographically-first sharer.
        assert_eq!(reg.canonical("alias").as_deref(), Some("a"));
        assert_eq!(reg.canonical("a").as_deref(), Some("a"));
        assert_eq!(reg.canonical("b").as_deref(), Some("b"));
        assert!(reg.canonical("missing").is_none());
    }

    /// The canonical cache follows registration changes: a new alias
    /// that sorts first re-canonicalizes every sharer, and removing the
    /// canonical name falls back to the next-first survivor.
    #[test]
    fn canonical_cache_follows_mutations() {
        let mut rng = Rng::new(52);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[8, 8], &mut rng), &SwscConfig::new(2, 1)),
        );
        let reg = ModelRegistry::new();
        let m = reg.insert_file("mid", &file, InferMode::Compressed);
        reg.insert("zz", m.clone());
        assert_eq!(reg.canonical("zz").as_deref(), Some("mid"));
        reg.insert("aa", m.clone());
        for n in ["aa", "mid", "zz"] {
            assert_eq!(reg.canonical(n).as_deref(), Some("aa"), "alias {n} must follow");
        }
        reg.remove("aa");
        assert_eq!(reg.canonical("mid").as_deref(), Some("mid"));
        assert_eq!(reg.canonical("zz").as_deref(), Some("mid"));
        assert!(reg.canonical("aa").is_none(), "removed names must resolve to None");
    }

    #[test]
    fn insert_file_with_precision_serves_quantized() {
        let mut rng = Rng::new(51);
        let mut file = SwscFile::new();
        let w = Tensor::randn(&[16, 16], &mut rng);
        file.compressed.insert("w".into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
        let reg = ModelRegistry::new();
        let q = reg.insert_file_with("q", &file, InferMode::Compressed, Precision::Int8);
        assert_eq!(q.precision(), Precision::Int8);
        assert_eq!(q.num_quantized(), 1);
        // The default-precision path stays f32 — the oracle is untouched.
        let f = reg.insert_file("f", &file, InferMode::Compressed);
        assert_eq!(f.precision(), Precision::F32);
        assert_eq!(f.num_quantized(), 0);
        let x = Tensor::randn(&[2, 16], &mut rng);
        let (a, b) = (q.apply("w", &x).unwrap(), f.apply("w", &x).unwrap());
        let worst = a
            .data()
            .iter()
            .zip(b.data())
            .fold(0.0f32, |m, (p, q)| m.max((p - q).abs()));
        assert!(worst < 0.5, "int8 vs f32 diverged: {worst}");
    }

    /// Re-inserting under a live name leaves old `Arc` holders serving
    /// the old model; removal likewise only unlinks the name.
    #[test]
    fn reinsert_and_remove_preserve_held_arcs() {
        let mut rng = Rng::new(52);
        let mut file = SwscFile::new();
        let w = Tensor::randn(&[8, 8], &mut rng);
        file.compressed.insert("w".into(), compress_matrix(&w, &SwscConfig::new(2, 1)));
        let reg = ModelRegistry::new();
        let old = reg.insert_file("m", &file, InferMode::Compressed);
        let x = Tensor::randn(&[1, 8], &mut rng);
        let y_old = old.apply("w", &x).unwrap();
        // Re-insert under the same name: lookups flip, the held Arc lives.
        let new = reg.insert_file("m", &file, InferMode::Reconstructed);
        assert!(!Arc::ptr_eq(&old, &new));
        assert!(Arc::ptr_eq(&reg.get("m").unwrap(), &new));
        assert_eq!(old.apply("w", &x).unwrap(), y_old, "held Arc must keep serving");
        // Remove: the name is gone, both Arcs still compute.
        let removed = reg.remove("m").unwrap();
        assert!(Arc::ptr_eq(&removed, &new));
        assert!(reg.get("m").is_none());
        assert!(reg.is_empty());
        assert_eq!(old.apply("w", &x).unwrap(), y_old);
    }
}
