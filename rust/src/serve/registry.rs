//! Multiple named `.swsc` models behind one serving surface.

use crate::infer::{CompressedForward, CompressedModel, InferMode, Precision};
use crate::io::SwscFile;
use crate::model::ModelConfig;
use anyhow::Result;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Named [`CompressedModel`]s, `Arc`-shared so every in-flight request —
/// and every coalesced batch — reuses one set of lazily packed GEMM
/// panels per model. The registry is assembled up front and then moved
/// behind an `Arc` into the server; a model's panels pack on the first
/// request that needs an orientation and are shared by all later
/// requests, across models' names (two registry names may alias one
/// `Arc`'d model and the coalescer will still batch them together).
#[derive(Default)]
pub struct ModelRegistry {
    models: BTreeMap<String, Arc<CompressedModel>>,
    forwards: BTreeMap<String, Arc<CompressedForward>>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    /// Load `file` in `mode` and register it under `name` (replacing any
    /// previous entry of that name). Returns the shared handle.
    pub fn insert_file(
        &mut self,
        name: &str,
        file: &SwscFile,
        mode: InferMode,
    ) -> Arc<CompressedModel> {
        self.insert_file_with(name, file, mode, Precision::default())
    }

    /// [`ModelRegistry::insert_file`] with an explicit serving
    /// [`Precision`]. At [`Precision::Int8`] the `Arc`-shared panels are
    /// the *quantized* panels — every alias and in-flight request reuses
    /// the ≈4×-smaller panel cache, not an f32 expansion.
    pub fn insert_file_with(
        &mut self,
        name: &str,
        file: &SwscFile,
        mode: InferMode,
        precision: Precision,
    ) -> Arc<CompressedModel> {
        let model = Arc::new(CompressedModel::from_file_with(file, mode, precision));
        self.models.insert(name.to_string(), model.clone());
        model
    }

    /// Register an already-built model under `name`.
    pub fn insert(&mut self, name: &str, model: Arc<CompressedModel>) {
        self.models.insert(name.to_string(), model);
    }

    /// Register a whole-model forward pass under `name` (PR 7). The
    /// forward's underlying [`CompressedModel`] is registered under the
    /// same name, so one name answers both [`super::LinearRequest`]s
    /// (individual weights) and [`super::ForwardRequest`]s (the full
    /// stack) from one set of shared packed panels.
    pub fn insert_forward(&mut self, name: &str, fwd: Arc<CompressedForward>) {
        self.models.insert(name.to_string(), fwd.model().clone());
        self.forwards.insert(name.to_string(), fwd);
    }

    /// Build a [`CompressedForward`] from `file` (validating that every
    /// parameter `cfg` requires is present) and register it under `name`.
    pub fn insert_forward_file(
        &mut self,
        name: &str,
        file: &SwscFile,
        cfg: ModelConfig,
        mode: InferMode,
    ) -> Result<Arc<CompressedForward>> {
        let model = Arc::new(CompressedModel::from_file(file, mode));
        let fwd = Arc::new(CompressedForward::new(model, cfg)?);
        self.insert_forward(name, fwd.clone());
        Ok(fwd)
    }

    /// The model registered under `name`, if any.
    pub fn get(&self, name: &str) -> Option<Arc<CompressedModel>> {
        self.models.get(name).cloned()
    }

    /// The whole-model forward registered under `name`, if any.
    pub fn forward(&self, name: &str) -> Option<Arc<CompressedForward>> {
        self.forwards.get(name).cloned()
    }

    /// Registered names, in sorted order.
    pub fn names(&self) -> Vec<&str> {
        self.models.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.models.len()
    }

    pub fn is_empty(&self) -> bool {
        self.models.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn insert_get_and_share() {
        let mut rng = Rng::new(50);
        let mut file = SwscFile::new();
        file.compressed
            .insert("w".into(), compress_matrix(&Tensor::randn(&[8, 8], &mut rng), &SwscConfig::new(2, 1)));
        let mut reg = ModelRegistry::new();
        assert!(reg.is_empty());
        let a = reg.insert_file("a", &file, InferMode::Compressed);
        reg.insert("alias", a.clone());
        reg.insert_file("b", &file, InferMode::Reconstructed);
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.names(), vec!["a", "alias", "b"]);
        // `alias` shares `a`'s model (same Arc — shared packed panels).
        assert!(Arc::ptr_eq(&reg.get("a").unwrap(), &reg.get("alias").unwrap()));
        assert!(!Arc::ptr_eq(&reg.get("a").unwrap(), &reg.get("b").unwrap()));
        assert!(reg.get("missing").is_none());
        assert_eq!(reg.get("a").unwrap().num_compressed(), 1);
        assert_eq!(reg.get("b").unwrap().num_compressed(), 0);
    }

    #[test]
    fn insert_file_with_precision_serves_quantized() {
        let mut rng = Rng::new(51);
        let mut file = SwscFile::new();
        let w = Tensor::randn(&[16, 16], &mut rng);
        file.compressed.insert("w".into(), compress_matrix(&w, &SwscConfig::new(4, 2)));
        let mut reg = ModelRegistry::new();
        let q = reg.insert_file_with("q", &file, InferMode::Compressed, Precision::Int8);
        assert_eq!(q.precision(), Precision::Int8);
        assert_eq!(q.num_quantized(), 1);
        // The default-precision path stays f32 — the oracle is untouched.
        let f = reg.insert_file("f", &file, InferMode::Compressed);
        assert_eq!(f.precision(), Precision::F32);
        assert_eq!(f.num_quantized(), 0);
        let x = Tensor::randn(&[2, 16], &mut rng);
        let (a, b) = (q.apply("w", &x).unwrap(), f.apply("w", &x).unwrap());
        let worst = a
            .data()
            .iter()
            .zip(b.data())
            .fold(0.0f32, |m, (p, q)| m.max((p - q).abs()));
        assert!(worst < 0.5, "int8 vs f32 diverged: {worst}");
    }
}
