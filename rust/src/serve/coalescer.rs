//! Micro-batch coalescing: drain the admission queue, stack compatible
//! requests into one activation matrix, run a single `apply` per
//! (model, weight) group, scatter rows back to the responders.
//!
//! ## Scheduling
//!
//! The coalescer blocks on the queue while idle (no polling). The first
//! arrival opens a micro-batch and starts the fill clock: further
//! arrivals are folded in until the stacked row count reaches
//! [`BatchConfig::max_batch_rows`] or [`BatchConfig::max_wait`] elapses.
//! Requests already queued coalesce without waiting — the wait bound only
//! adds latency when the queue runs dry mid-fill, so under saturation the
//! batch size is governed by the row bound and under trickle traffic by
//! the wait bound.
//!
//! ## Continuous batching for whole-model forwards (PR 7)
//!
//! Forward requests are a different shape of work: a request is not one
//! `apply` but a *sequence* of layer steps through a
//! [`crate::infer::CompressedForward`] state machine. Flushing them like
//! linear batches would convoy short requests behind long ones and make
//! arrivals wait out the entire in-flight cohort. Instead the scheduler
//! keeps an **in-flight set** and re-forms it at every layer boundary:
//! arrivals are admitted (at their layer 0) whenever the stacked token
//! rows fit [`BatchConfig::max_batch_rows`], requests that clear the last
//! layer `finish` and respond immediately, and each scheduler iteration
//! steps every `(forward, layer)` cohort one layer as a single grouped
//! call. [`ForwardScheduling::Flush`] keeps the old flush-the-batch model
//! as the in-tree scheduling oracle. Both are bitwise identical to solo
//! execution — group composition is pure scheduling, because every
//! cross-request op inside a layer step is a row-independent `apply`
//! (the fill clock never runs while forwards are in flight; it would
//! stall the layer clock for no batching gain).
//!
//! ## Why batching never changes results
//!
//! Every serving path computes each output row from that row's own
//! activations with single-register increasing-k accumulation (the
//! crate-wide kernel policy, `tests/fixtures/README.md`) — `apply` is
//! row-independent. Stacking requests `[x1; x2]` and splitting the result
//! is therefore bitwise identical to applying `x1` and `x2` alone, at any
//! `SWSC_THREADS`. Arrival order is preserved purely so the stack/scatter
//! bookkeeping is trivially auditable — correctness never depends on it.

use super::fault::FaultInjector;
use super::queue::{ForwardJob, Job, JobReceiver, ServeJob};
use super::registry::ModelRegistry;
use super::{ForwardResponse, LinearResponse, ServeError};
use crate::coordinator::metrics::Metrics;
use crate::exec;
use crate::infer::{CompressedForward, CompressedModel, ForwardState};
use crate::obs::{EventKind, SpanKind, TraceSink};
use crate::tensor::Tensor;
use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How whole-model forward requests are scheduled across layer steps.
/// Purely a latency/throughput knob: both modes are bitwise identical to
/// solo execution (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ForwardScheduling {
    /// Re-form the in-flight set at every layer boundary: arrivals join
    /// mid-flight, finished requests leave immediately (the default).
    #[default]
    Continuous,
    /// Flush-the-batch: admit a cohort only when the previous one has run
    /// to completion. The scheduling oracle the
    /// `forward_batched_vs_flush_*` bench rows compare against.
    Flush,
}

/// Coalescing knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Flush a micro-batch once its stacked activation rows reach this
    /// bound (a single larger request still forms its own batch). Also
    /// bounds the stacked token rows of the in-flight forward set.
    pub max_batch_rows: usize,
    /// Longest the coalescer waits for further arrivals before flushing a
    /// partial batch. Only bounds *added* latency: queued requests
    /// coalesce immediately.
    pub max_wait: Duration,
    /// Layer-step scheduling for whole-model forward requests.
    pub forward_scheduling: ForwardScheduling,
}

impl BatchConfig {
    /// Construct with `max_wait` in microseconds — the serving-latency
    /// scale the knob is usually quoted in.
    pub fn with_wait_us(max_batch_rows: usize, max_wait_us: u64) -> BatchConfig {
        BatchConfig {
            max_batch_rows,
            max_wait: Duration::from_micros(max_wait_us),
            forward_scheduling: ForwardScheduling::default(),
        }
    }

    /// Serve every request alone: batch bound 1, no fill wait. The solo
    /// baseline configuration the `batched_vs_solo_*` bench rows compare
    /// against (one `apply` per request through the same machinery).
    pub fn solo() -> BatchConfig {
        BatchConfig::with_wait_us(1, 0)
    }

    /// This configuration with the given forward scheduling.
    pub fn with_forward_scheduling(self, forward_scheduling: ForwardScheduling) -> BatchConfig {
        BatchConfig { forward_scheduling, ..self }
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig::with_wait_us(256, 200)
    }
}

/// The batching engine: owns nothing but shared handles, driven by
/// [`Coalescer::run`] on a dedicated thread (see
/// [`super::BatchServer`]).
///
/// ## Panic containment (PR 8)
///
/// Every execution site — the grouped linear `apply`, per-forward
/// `start`/`finish`, and each `step_group` — runs under `catch_unwind`.
/// A panic answers the affected request(s) with
/// [`ServeError::Panicked`] (carrying the payload's message when it was a
/// `&str`/`String`) and the loop keeps serving: the containment boundary
/// is the *grouped op*, so a panic inside a stacked `apply` or a cohort
/// step poisons that group's members only, and a per-request site
/// (injected faults, `start`, `finish`) poisons exactly one request.
///
/// ## Deadlines
///
/// Expired linears are evicted when picked into a batch; expired forwards
/// are evicted at every layer boundary, before cohorts form. Eviction is
/// pure scheduling (cohort composition never affects arithmetic — module
/// docs above), so surviving requests stay bitwise equal to solo.
pub struct Coalescer {
    registry: Arc<ModelRegistry>,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
    faults: Option<Arc<FaultInjector>>,
    /// Request-scoped trace sink (PR 9). Strictly observation-only: every
    /// emission happens *around* the compute sites, never inside them,
    /// and `None` (the default) keeps the hot path free of clock reads
    /// and allocations attributable to tracing.
    trace: Option<Arc<TraceSink>>,
}

/// Convert a caught panic payload into the typed error, preserving the
/// original message when the payload allows it.
fn panicked(payload: Box<dyn Any + Send>) -> ServeError {
    ServeError::Panicked {
        message: exec::panic_message(payload.as_ref())
            .unwrap_or("opaque panic payload")
            .to_string(),
    }
}

/// Run `f` with panic containment: a panic becomes
/// [`ServeError::Panicked`], an ordinary error becomes
/// [`ServeError::Failed`] prefixed with `what`.
fn contain<T>(what: &str, f: impl FnOnce() -> anyhow::Result<T>) -> Result<T, ServeError> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(ServeError::Failed(format!("{what} failed: {e:#}"))),
        Err(payload) => Err(panicked(payload)),
    }
}

/// Requests for one (model, weight) pair within a micro-batch, in
/// arrival order.
struct Group {
    model: Arc<CompressedModel>,
    name: String,
    in_features: usize,
    jobs: Vec<ServeJob>,
}

/// One admitted forward request mid-stack: its per-request activation
/// state, re-formed into `(forward, layer)` cohorts at every boundary.
struct InflightForward {
    job: ForwardJob,
    fwd: Arc<CompressedForward>,
    state: ForwardState,
    /// Set when the request fails mid-stack (grouped step error or panic,
    /// expired deadline, injected fault) — the request is answered with
    /// this error at the next finish pass instead of stepping further.
    error: Option<ServeError>,
}

impl Coalescer {
    pub fn new(registry: Arc<ModelRegistry>, cfg: BatchConfig, metrics: Arc<Metrics>) -> Coalescer {
        Coalescer::with_faults(registry, cfg, metrics, None)
    }

    /// [`Coalescer::new`] with a fault injector (chaos testing; `None` is
    /// the zero-cost production default).
    pub fn with_faults(
        registry: Arc<ModelRegistry>,
        cfg: BatchConfig,
        metrics: Arc<Metrics>,
        faults: Option<Arc<FaultInjector>>,
    ) -> Coalescer {
        Coalescer::with_observers(registry, cfg, metrics, faults, None)
    }

    /// [`Coalescer::with_faults`] plus a request-scoped trace sink
    /// (PR 9). Both extras default off; tracing is pure observation —
    /// traced and untraced serving are bitwise identical.
    pub fn with_observers(
        registry: Arc<ModelRegistry>,
        cfg: BatchConfig,
        metrics: Arc<Metrics>,
        faults: Option<Arc<FaultInjector>>,
        trace: Option<Arc<TraceSink>>,
    ) -> Coalescer {
        let cfg = BatchConfig { max_batch_rows: cfg.max_batch_rows.max(1), ..cfg };
        Coalescer { registry, cfg, metrics, faults, trace }
    }

    /// The per-model metric label for a registry key: the canonical name
    /// when registered (aliases collapse onto one label), the requested
    /// name otherwise (so unknown-model errors still get labeled).
    fn model_label(&self, name: &str) -> String {
        self.registry.canonical(name).unwrap_or_else(|| name.to_string())
    }

    /// Fire an injected panic for request `id` as a *real* unwind, caught
    /// right here — per request, so cohort-mates are untouched — and
    /// returned as the typed error.
    fn fire_injected_panic(&self, id: u64, site: &str) -> ServeError {
        if let Some(f) = &self.faults {
            f.record_panic();
        }
        self.metrics.incr("serve.faults_injected", 1);
        if let Some(t) = &self.trace {
            t.event(EventKind::FaultInjected, id, "", &format!("panic at {site}"));
        }
        let payload = catch_unwind(|| {
            panic!("injected fault: request {id} poisoned at {site}");
        })
        .unwrap_err();
        panicked(payload)
    }

    /// Injected artificial latency for request `id`, applied in place.
    fn inject_delay(&self, id: u64) {
        if let Some(f) = &self.faults {
            if let Some(d) = f.injects_delay(id) {
                f.record_delay();
                self.metrics.incr("serve.faults_injected", 1);
                if let Some(t) = &self.trace {
                    t.event(EventKind::FaultInjected, id, "", "delay");
                }
                std::thread::sleep(d);
            }
        }
    }

    /// Injected pre-execution faults for a linear request: delay fires in
    /// place; a fated panic fires immediately.
    fn inject_before_execute(&self, id: u64) -> Option<ServeError> {
        self.inject_delay(id);
        let f = self.faults.as_ref()?;
        if f.injects_panic(id) {
            return Some(self.fire_injected_panic(id, "linear execute"));
        }
        None
    }

    /// Whether a forward request's fated panic fires at its current layer
    /// boundary.
    fn forward_panic_due(&self, id: u64, layer: usize, n_layers: usize) -> bool {
        self.faults
            .as_ref()
            .is_some_and(|f| f.injects_panic(id) && f.panic_layer(id, n_layers) == layer)
    }

    /// Drive the queue until a shutdown marker arrives (or every producer
    /// is gone). Blocks while idle; never drops a responder — jobs behind
    /// the shutdown marker get an explicit error, and forwards admitted
    /// *before* the marker are still served to completion.
    pub fn run(&self, rx: JobReceiver) {
        let mut shutting_down = false;
        let mut pending: VecDeque<ForwardJob> = VecDeque::new();
        let mut inflight: Vec<InflightForward> = Vec::new();
        loop {
            let mut batch: Vec<ServeJob> = Vec::new();
            let mut rows = 0usize;
            // Tracing only: batch-formation span start. Gated so the
            // untraced loop performs no extra clock reads.
            let mut pick_t0 = self.trace.as_ref().map(|_| Instant::now());
            // Fully idle: block for the first arrival (no polling).
            if !shutting_down && pending.is_empty() && inflight.is_empty() {
                match rx.recv() {
                    Ok(job) => {
                        // The blocking wait above was idle time, not
                        // batch formation — restart the span clock at
                        // the first arrival so a lightly loaded server's
                        // BatchPick spans measure fill/drain work, not
                        // however long the queue sat empty.
                        pick_t0 = self.trace.as_ref().map(|_| Instant::now());
                        self.intake(job, &mut batch, &mut rows, &mut pending, &mut shutting_down)
                    }
                    Err(_) => shutting_down = true,
                }
            }
            if !shutting_down {
                if !batch.is_empty() && pending.is_empty() && inflight.is_empty() {
                    // A pure-linear micro-batch is forming: run the fill
                    // clock exactly as before PR 7.
                    let deadline = Instant::now() + self.cfg.max_wait;
                    while rows < self.cfg.max_batch_rows && !shutting_down {
                        let timeout = deadline.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(timeout) {
                            Ok(job) => self.intake(
                                job,
                                &mut batch,
                                &mut rows,
                                &mut pending,
                                &mut shutting_down,
                            ),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => shutting_down = true,
                        }
                    }
                } else {
                    // Forward work is outstanding: fold in whatever is
                    // already queued without stalling the layer clock
                    // behind a fill window.
                    while rows < self.cfg.max_batch_rows && !shutting_down {
                        match rx.try_recv() {
                            Ok(job) => self.intake(
                                job,
                                &mut batch,
                                &mut rows,
                                &mut pending,
                                &mut shutting_down,
                            ),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => shutting_down = true,
                        }
                    }
                }
            }
            if !batch.is_empty() {
                self.note_batch_pick(&rx, batch.len(), pick_t0);
                self.execute_batch(batch);
            }
            self.admit(&mut pending, &mut inflight);
            self.step_inflight(&mut inflight);
            if shutting_down && pending.is_empty() && inflight.is_empty() {
                self.drain(&rx);
                return;
            }
        }
    }

    /// Batch-pick observation point (PR 9): sample the admission queue
    /// depth and the shared exec pool's gauges, and close the
    /// batch-formation span. Strictly after every scheduling decision —
    /// nothing read here feeds one.
    fn note_batch_pick(&self, rx: &JobReceiver, batch_len: usize, t0: Option<Instant>) {
        self.metrics.record("exec.queue_depth", rx.depth() as f64);
        let pool = exec::global();
        self.metrics.set("exec.pool_workers", pool.workers_spawned() as u64);
        self.metrics.set("exec.pool_busy_workers", pool.workers_busy() as u64);
        self.metrics.set("exec.pool_busy_nanos", pool.busy_nanos());
        if let (Some(t), Some(t0)) = (&self.trace, t0) {
            t.span(SpanKind::BatchPick, 0, "", format!("{batch_len} requests"), t0);
        }
    }

    /// Queue-pick bookkeeping shared by both job kinds (PR 9): stamp the
    /// pick time (for the queue-wait/service-time latency split), record
    /// the wait, and close the request's queue-wait span.
    fn note_picked(&self, id: u64, model: &str, enqueued: Instant) -> Instant {
        let picked = Instant::now();
        let wait = picked.saturating_duration_since(enqueued).as_secs_f64();
        self.metrics.record("serve.queue_wait_seconds", wait);
        self.metrics.record_with("serve.queue_wait_seconds", &self.model_label(model), wait);
        if let Some(t) = &self.trace {
            t.span(SpanKind::QueueWait, id, model, "", enqueued);
        }
        picked
    }

    fn intake(
        &self,
        job: Job,
        batch: &mut Vec<ServeJob>,
        rows: &mut usize,
        pending: &mut VecDeque<ForwardJob>,
        shutting_down: &mut bool,
    ) {
        match job {
            Job::Linear(mut job) => {
                job.picked = Some(self.note_picked(job.id, &job.model, job.enqueued));
                // Expired while queued: evict at intake, before the fill
                // clock spends any time on it.
                if job.req.expired() {
                    self.respond(job, Err(ServeError::DeadlineExceeded));
                    return;
                }
                *rows += request_rows(&job);
                batch.push(job);
            }
            Job::Forward(mut job) => {
                self.metrics.incr("serve.forward_requests", 1);
                self.metrics.incr_with("serve.forward_requests", &self.model_label(&job.model), 1);
                job.picked = Some(self.note_picked(job.id, &job.model, job.enqueued));
                if job.req.expired() {
                    self.respond_forward(job, Err(ServeError::DeadlineExceeded));
                    return;
                }
                pending.push_back(job);
            }
            Job::Shutdown => *shutting_down = true,
        }
    }

    /// Admit pending forwards into the in-flight set at their layer 0.
    /// [`ForwardScheduling::Continuous`] admits at every layer boundary
    /// while the stacked token rows fit `max_batch_rows` (the first
    /// admission always goes through, like a single oversized linear
    /// request); [`ForwardScheduling::Flush`] admits only into an empty
    /// set, so each cohort runs to completion before the next forms.
    fn admit(&self, pending: &mut VecDeque<ForwardJob>, inflight: &mut Vec<InflightForward>) {
        // Flush only forms a new cohort once the previous one is gone —
        // but within one formation it still fills up to the row bound.
        if self.cfg.forward_scheduling == ForwardScheduling::Flush && !inflight.is_empty() {
            return;
        }
        while let Some(next) = pending.front() {
            if !inflight.is_empty() {
                let rows: usize = inflight.iter().map(|f| f.state.tokens()).sum();
                if rows + next.req.tokens.len().max(1) > self.cfg.max_batch_rows {
                    break;
                }
            }
            let job = pending.pop_front().expect("front() was Some");
            // Expired while waiting for an in-flight slot: evict here —
            // admission order is pure scheduling, survivors' bits never
            // depend on who else was admitted.
            if job.req.expired() {
                self.respond_forward(job, Err(ServeError::DeadlineExceeded));
                continue;
            }
            self.inject_delay(job.id);
            let Some(fwd) = self.registry.forward(&job.model) else {
                self.respond_forward(job, Err(ServeError::UnknownModel(job.model.clone())));
                continue;
            };
            // `start` is per-request: a panic (or error) poisons exactly
            // this request.
            match contain("forward start", || fwd.start(&job.req.tokens)) {
                Ok(state) => inflight.push(InflightForward { job, fwd, state, error: None }),
                Err(e) => self.respond_forward(job, Err(e)),
            }
        }
    }

    /// Step every `(forward, layer)` cohort one layer as a single grouped
    /// call, then finish and respond to requests that cleared the stack.
    fn step_inflight(&self, inflight: &mut Vec<InflightForward>) {
        if inflight.is_empty() {
            return;
        }
        // Layer-boundary sweep, before cohorts form: evict expired
        // requests and fire fated injected panics. Both are per-request
        // and purely subtractive — the survivors' cohort is re-formed
        // without them, which is ordinary scheduling and cannot move
        // their bits.
        for f in inflight.iter_mut() {
            if f.error.is_some() {
                continue;
            }
            if f.job.req.expired() {
                f.error = Some(ServeError::DeadlineExceeded);
                continue;
            }
            if self.forward_panic_due(f.job.id, f.state.layer(), f.fwd.n_layers()) {
                let layer = f.state.layer();
                f.error = Some(
                    self.fire_injected_panic(f.job.id, &format!("forward layer {layer}")),
                );
            }
        }
        // Cohort keys are collected up front so arrivals admitted this
        // iteration (layer 0) step alongside older requests deeper in the
        // stack — one step per cohort per iteration keeps progress fair.
        let mut keys: Vec<(*const CompressedForward, usize)> = Vec::new();
        for f in inflight.iter() {
            let key = (Arc::as_ptr(&f.fwd), f.state.layer());
            if !keys.contains(&key) {
                keys.push(key);
            }
        }
        for (ptr, layer) in keys {
            let mut members: Vec<&mut InflightForward> = inflight
                .iter_mut()
                .filter(|f| {
                    Arc::as_ptr(&f.fwd) == ptr && f.state.layer() == layer && f.error.is_none()
                })
                .collect();
            if members.is_empty() {
                continue;
            }
            let fwd = members[0].fwd.clone();
            let step_rows: usize = members.iter().map(|m| m.state.tokens()).sum();
            self.metrics.incr("serve.forward_steps", 1);
            self.metrics.record("serve.forward_step_rows", step_rows as f64);
            let t0 = Instant::now();
            let mut states: Vec<&mut ForwardState> =
                members.iter_mut().map(|m| &mut m.state).collect();
            // Containment boundary: the grouped step. A panic (or error)
            // inside poisons this cohort's members — every one is
            // answered, other cohorts and the scheduler loop survive.
            let result = catch_unwind(AssertUnwindSafe(|| fwd.step_group(&mut states, exec::global())));
            self.metrics.record("serve.apply_seconds", t0.elapsed().as_secs_f64());
            if let Some(t) = &self.trace {
                for m in members.iter() {
                    t.span(SpanKind::LayerStep, m.job.id, &m.job.model, format!("layer {layer}"), t0);
                }
            }
            let err = match result {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(ServeError::Failed(format!("forward step failed: {e:#}"))),
                Err(payload) => Some(panicked(payload)),
            };
            if let Some(err) = err {
                for m in members {
                    m.error = Some(err.clone());
                }
            }
        }
        let mut i = 0;
        while i < inflight.len() {
            let done = inflight[i].error.is_some()
                || inflight[i].state.layer() == inflight[i].fwd.n_layers();
            if !done {
                i += 1;
                continue;
            }
            let f = inflight.remove(i);
            match f.error {
                Some(err) => self.respond_forward(f.job, Err(err)),
                None => {
                    // `finish` is per-request: containment poisons
                    // exactly this request.
                    let res = contain("forward finish", || f.fwd.finish(&f.state, exec::global()));
                    self.respond_forward(f.job, res);
                }
            }
        }
    }

    /// One micro-batch: group by (model, weight), one `apply` per group
    /// over the stacked activations, scatter rows back in arrival order.
    fn execute_batch(&self, batch: Vec<ServeJob>) {
        self.metrics.incr("serve.batches", 1);
        self.metrics.incr("serve.requests", batch.len() as u64);
        for job in &batch {
            self.metrics.incr_with("serve.requests", &self.model_label(&job.model), 1);
        }
        self.metrics.record("serve.batch_requests", batch.len() as f64);
        let total_rows: usize = batch.iter().map(request_rows).sum();
        self.metrics.record("serve.batch_rows", total_rows as f64);

        let mut groups: Vec<Group> = Vec::new();
        for job in batch {
            // Pre-execution fault hooks fire per request, before the job
            // can join a group — a poisoned request never touches its
            // batch-mates.
            if let Some(err) = self.inject_before_execute(job.id) {
                self.respond(job, Err(err));
                continue;
            }
            // Deadline re-check at pick time: the fill window may have
            // outlived the request's budget.
            if job.req.expired() {
                self.respond(job, Err(ServeError::DeadlineExceeded));
                continue;
            }
            let Some(model) = self.registry.get(&job.model) else {
                self.respond(job, Err(ServeError::UnknownModel(job.model.clone())));
                continue;
            };
            // A well-formed zero-row request has nothing to compute:
            // answer the empty `[0, out]` immediately instead of routing
            // it into the stack.
            if job.req.x.ndim() == 2 && job.req.x.rows() == 0 {
                if let Some((m, n)) = model.shape(&job.req.name) {
                    if job.req.x.cols() == m {
                        self.respond(job, Ok(Tensor::zeros(&[0, n])));
                        continue;
                    }
                }
            }
            // Only well-formed requests are stacked; anything else goes
            // through the model's own `apply` so the error (unknown
            // weight, shape mismatch, non-matrix) is exactly the solo
            // path's.
            let stackable = job.req.x.ndim() == 2
                && model.shape(&job.req.name).is_some_and(|(m, _)| job.req.x.cols() == m);
            if !stackable {
                let what = format!("linear `{}`", job.req.name);
                let t0 = self.trace.as_ref().map(|_| Instant::now());
                let res = contain(&what, || model.apply(&job.req.name, &job.req.x));
                if let (Some(t), Some(t0)) = (&self.trace, t0) {
                    t.span(SpanKind::GroupApply, job.id, &job.model, job.req.name.clone(), t0);
                }
                self.respond(job, res);
                continue;
            }
            let found = groups
                .iter()
                .position(|g| g.name == job.req.name && Arc::ptr_eq(&g.model, &model));
            match found {
                Some(i) => groups[i].jobs.push(job),
                None => {
                    let in_features = job.req.x.cols();
                    let name = job.req.name.clone();
                    groups.push(Group { model, name, in_features, jobs: vec![job] });
                }
            }
        }
        for group in groups {
            self.execute_group(group);
        }
    }

    fn execute_group(&self, g: Group) {
        let rows: usize = g.jobs.iter().map(|j| j.req.x.rows()).sum();
        let what = format!("linear `{}`", g.name);
        let t0 = Instant::now();
        // Containment boundary: the grouped apply. A panic inside poisons
        // this group's members only — other groups in the batch, and the
        // coalescer thread, survive.
        let result = if let [job] = &g.jobs[..] {
            // Single request — skip the stack/scatter copies.
            contain(&what, || g.model.apply(&g.name, &job.req.x))
        } else {
            let mut data = Vec::with_capacity(rows * g.in_features);
            for job in &g.jobs {
                data.extend_from_slice(job.req.x.data());
            }
            let stacked = Tensor::from_vec(&[rows, g.in_features], data);
            contain(&what, || g.model.apply(&g.name, &stacked))
        };
        self.metrics.record("serve.apply_seconds", t0.elapsed().as_secs_f64());
        if let Some(t) = &self.trace {
            // One span per member on its own track: the group apply is
            // the unit of compute, but a stall should be visible on the
            // timeline of every request it delayed.
            for job in &g.jobs {
                t.span(SpanKind::GroupApply, job.id, &job.model, g.name.clone(), t0);
            }
        }
        match result {
            Err(e) => {
                for job in g.jobs {
                    self.respond(job, Err(e.clone()));
                }
            }
            Ok(y) if g.jobs.len() == 1 => {
                let job = g.jobs.into_iter().next().unwrap();
                self.respond(job, Ok(y));
            }
            Ok(y) => {
                let out_features = y.cols();
                let mut row0 = 0usize;
                for job in g.jobs {
                    let r = job.req.x.rows();
                    let slab = y.data()[row0 * out_features..(row0 + r) * out_features].to_vec();
                    row0 += r;
                    self.respond(job, Ok(Tensor::from_vec(&[r, out_features], slab)));
                }
            }
        }
    }

    /// Centralized error accounting: every `Err` counts toward
    /// `serve.errors` (globally and per model label), with typed
    /// breakdowns for panics and deadline misses, plus the matching
    /// trace events.
    fn note_error(&self, err: &ServeError, id: u64, label: &str) {
        self.metrics.incr("serve.errors", 1);
        self.metrics.incr_with("serve.errors", label, 1);
        match err {
            ServeError::Panicked { .. } => {
                self.metrics.incr("serve.panics", 1);
                self.metrics.incr_with("serve.panics", label, 1);
            }
            ServeError::DeadlineExceeded => {
                self.metrics.incr("serve.deadline_miss", 1);
                self.metrics.incr_with("serve.deadline_miss", label, 1);
            }
            _ => {}
        }
        if let Some(t) = &self.trace {
            match err {
                ServeError::Panicked { .. } => t.event(EventKind::Panic, id, label, ""),
                ServeError::DeadlineExceeded => {
                    t.event(EventKind::DeadlineEvicted, id, label, "respond")
                }
                ServeError::ShuttingDown => t.event(EventKind::Drained, id, label, ""),
                _ => {}
            }
        }
    }

    /// Response-time latency accounting shared by both job kinds: the
    /// end-to-end latency (from admission) and, when the job was picked,
    /// the service time (from pick) — the two halves the loadgen report
    /// splits percentiles over.
    fn note_latency(&self, name: &str, label: &str, enqueued: Instant, picked: Option<Instant>) {
        let latency = enqueued.elapsed().as_secs_f64();
        self.metrics.record(name, latency);
        self.metrics.record_with(name, label, latency);
        if let Some(picked) = picked {
            let service = picked.elapsed().as_secs_f64();
            self.metrics.record("serve.service_seconds", service);
            self.metrics.record_with("serve.service_seconds", label, service);
        }
    }

    fn respond(&self, job: ServeJob, result: Result<Tensor, ServeError>) {
        let label = self.model_label(&job.model);
        self.note_latency("serve.latency_seconds", &label, job.enqueued, job.picked);
        if let Err(e) = &result {
            self.note_error(e, job.id, &label);
        }
        let _ = job.tx.send(result.map(|y| LinearResponse { y }));
    }

    fn respond_forward(&self, job: ForwardJob, result: Result<Tensor, ServeError>) {
        let label = self.model_label(&job.model);
        self.note_latency("serve.forward_latency_seconds", &label, job.enqueued, job.picked);
        if let Err(e) = &result {
            self.note_error(e, job.id, &label);
        }
        let _ = job.tx.send(result.map(|logits| ForwardResponse { logits }));
    }

    /// Everything behind a shutdown marker gets an explicit error — never
    /// a silently dropped sender.
    fn drain(&self, rx: &JobReceiver) {
        while let Ok(job) = rx.try_recv() {
            match job {
                Job::Linear(job) => {
                    self.metrics.incr("serve.drained_on_shutdown", 1);
                    self.respond(job, Err(ServeError::ShuttingDown));
                }
                Job::Forward(job) => {
                    self.metrics.incr("serve.drained_on_shutdown", 1);
                    self.respond_forward(job, Err(ServeError::ShuttingDown));
                }
                Job::Shutdown => {}
            }
        }
    }
}

/// Row contribution of a request toward the batch bound. Every request
/// occupies at least one slot: malformed (non-2-D) requests count one on
/// their way to an error response, and well-formed zero-row (`[0, m]`)
/// requests count one too. Before PR 7 zero-row requests counted zero —
/// a stream of them never advanced the row bound, so each paid the full
/// `max_wait` fill window despite being answerable immediately, while
/// malformed requests (which do even less work) counted one.
fn request_rows(job: &ServeJob) -> usize {
    if job.req.x.ndim() == 2 {
        job.req.x.rows().max(1)
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::infer::InferMode;
    use crate::io::SwscFile;
    use crate::model::{init_params, param_specs, ModelConfig};
    use crate::serve::queue::AdmissionQueue;
    use crate::serve::{ForwardRequest, LinearRequest};
    use crate::util::rng::Rng;

    /// Registry with a tiny whole-model forward under "m": 2-D params
    /// with ≥ 16 columns compressed, the rest dense.
    fn forward_registry(seed: u64) -> (Arc<ModelRegistry>, Arc<CompressedForward>) {
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, seed);
        let mut file = SwscFile::new();
        for spec in param_specs(&cfg) {
            let t = ck.get(&spec.name).unwrap().clone();
            if spec.shape.len() == 2 && spec.shape[1] >= 16 {
                file.compressed
                    .insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
            } else {
                file.dense.insert(spec.name.clone(), t);
            }
        }
        let reg = ModelRegistry::new();
        let fwd = reg.insert_forward_file("m", &file, cfg, InferMode::Compressed).unwrap();
        (Arc::new(reg), fwd)
    }

    fn registry() -> Arc<ModelRegistry> {
        let mut rng = Rng::new(70);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[16, 16], &mut rng), &SwscConfig::new(2, 1)),
        );
        file.dense.insert("d".into(), Tensor::randn(&[16, 16], &mut rng));
        let reg = ModelRegistry::new();
        reg.insert_file("m", &file, InferMode::Compressed);
        Arc::new(reg)
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Deterministic drain-on-shutdown: the job ahead of the marker is
    /// served, the job behind it gets the explicit shutdown error.
    #[test]
    fn drains_jobs_behind_shutdown_marker() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::solo(), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let r1 = q.try_submit("m", LinearRequest::new("w", Tensor::zeros(&[1, 16]))).unwrap();
        q.begin_shutdown();
        let r2 = q.submit_behind_shutdown("m", LinearRequest::new("w", Tensor::zeros(&[1, 16])));
        drop(q);
        coal.run(rx); // runs to completion on this thread — no races
        assert!(r1.recv().unwrap().is_ok(), "job ahead of the marker must be served");
        let err = r2.recv().unwrap().unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown, "unexpected drain error: {err}");
        assert_eq!(metrics.counter("serve.drained_on_shutdown"), 1);
        assert_eq!(metrics.counter("serve.batches"), 1);
    }

    /// A single batch holding good requests, an unknown weight, a shape
    /// mismatch, an unknown model, and a dense-entry request: groups are
    /// stacked and scattered bitwise-correctly and the error cases are
    /// isolated per request — they never poison the batch.
    #[test]
    fn mixed_batch_groups_scatter_and_isolate_errors() {
        let reg = registry();
        let model = reg.get("m").unwrap();
        let metrics = Arc::new(Metrics::new());
        // Everything is queued before `run`, so with a generous row bound
        // the whole stream coalesces into exactly one batch.
        let coal = Coalescer::new(reg.clone(), BatchConfig::with_wait_us(1024, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(16);
        let mut rng = Rng::new(71);
        let xs: Vec<Tensor> =
            (0..4).map(|i| Tensor::randn(&[1 + (i % 3), 16], &mut rng)).collect();
        let good: Vec<_> = xs
            .iter()
            .map(|x| q.try_submit("m", LinearRequest::new("w", x.clone())).unwrap())
            .collect();
        let xd = Tensor::randn(&[3, 16], &mut rng);
        let dense = q.try_submit("m", LinearRequest::new("d", xd.clone())).unwrap();
        let bad_weight =
            q.try_submit("m", LinearRequest::new("nope", Tensor::zeros(&[2, 16]))).unwrap();
        let bad_shape =
            q.try_submit("m", LinearRequest::new("w", Tensor::zeros(&[2, 15]))).unwrap();
        let bad_model =
            q.try_submit("ghost", LinearRequest::new("w", Tensor::zeros(&[1, 16]))).unwrap();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);

        for (x, r) in xs.iter().zip(good) {
            let got = r.recv().unwrap().unwrap();
            let want = model.apply("w", x).unwrap();
            assert_eq!(bits(&got.y), bits(&want), "batched response differs from solo apply");
        }
        let got_dense = dense.recv().unwrap().unwrap();
        assert_eq!(bits(&got_dense.y), bits(&model.apply("d", &xd).unwrap()));
        assert!(bad_weight.recv().unwrap().unwrap_err().to_string().contains("nope"));
        assert!(bad_shape.recv().unwrap().unwrap_err().to_string().contains("failed"));
        assert_eq!(
            bad_model.recv().unwrap().unwrap_err(),
            ServeError::UnknownModel("ghost".into())
        );
        assert_eq!(metrics.counter("serve.batches"), 1, "stream must coalesce into one batch");
        assert_eq!(metrics.counter("serve.requests"), 8);
        assert_eq!(metrics.counter("serve.errors"), 3);
    }

    /// Satellite 2 (PR 7): well-formed zero-row `[0, m]` requests advance
    /// the row bound like any other request and are answered with an
    /// empty `[0, out]` tensor without entering the stack. Before the
    /// fix they counted zero rows — a stream of them never flushed on the
    /// bound, so each paid the full `max_wait` fill window.
    #[test]
    fn zero_row_requests_count_and_answer_empty() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(2, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| q.try_submit("m", LinearRequest::new("w", Tensor::zeros(&[0, 16]))).unwrap())
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            let y = r.recv().unwrap().unwrap().y;
            assert_eq!(y.shape(), &[0, 16]);
        }
        // One row each against a bound of 2: the stream splits 2 + 1. The
        // old zero-count behavior coalesced all three into one batch.
        assert_eq!(metrics.counter("serve.batches"), 2);
        assert_eq!(metrics.counter("serve.errors"), 0);
    }

    /// The other half of satellite 2: malformed (non-2-D) requests keep
    /// counting one row toward the bound on their way to an error.
    #[test]
    fn malformed_requests_count_one_row() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(2, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| q.try_submit("m", LinearRequest::new("w", Tensor::zeros(&[16]))).unwrap())
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            assert!(r.recv().unwrap().is_err(), "non-2-D request must error");
        }
        assert_eq!(metrics.counter("serve.batches"), 2);
    }

    /// The row bound flushes mid-stream: 3 × 2-row requests against a
    /// 4-row bound split into two batches at a deterministic boundary.
    #[test]
    fn row_bound_flushes_batches() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(4, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| q.try_submit("m", LinearRequest::new("w", Tensor::zeros(&[2, 16]))).unwrap())
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            assert!(r.recv().unwrap().is_ok());
        }
        assert_eq!(metrics.counter("serve.batches"), 2);
        assert_eq!(metrics.timing_count("serve.batch_rows"), 2);
    }

    /// PR 8: requests whose deadline expired while queued are evicted at
    /// the coalescer's intake — linear and forward alike — while live
    /// requests in the same stream are still served. The `*_behind_shutdown`
    /// hooks bypass admission preflight, so it is the coalescer's own
    /// check that answers here.
    #[test]
    fn expired_deadlines_are_evicted_at_intake() {
        let (reg, _fwd) = forward_registry(80);
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(64, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let lin = q.submit_behind_shutdown(
            "m",
            LinearRequest::new("w_q.0", Tensor::zeros(&[1, 16])).with_timeout(Duration::ZERO),
        );
        let f = q.submit_forward_behind_shutdown(
            "m",
            ForwardRequest::new(vec![1, 2, 3]).with_timeout(Duration::ZERO),
        );
        let live = q.try_submit_forward("m", ForwardRequest::new(vec![1, 2, 3])).unwrap();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        assert_eq!(lin.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        assert_eq!(f.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        assert!(live.recv().unwrap().is_ok(), "unexpired request must still be served");
        assert_eq!(metrics.counter("serve.deadline_miss"), 2);
        assert_eq!(metrics.counter("serve.errors"), 2);
    }

    /// PR 8: injected panics poison exactly the fated requests; their
    /// batch-mates' responses stay bitwise equal to a solo `apply`, and
    /// the coalescer keeps running.
    #[test]
    fn injected_panics_poison_only_fated_requests() {
        use crate::serve::fault::FaultConfig;
        let n = 6u64;
        // Scan for a seed whose first `n` request ids mix fated and clean
        // — the decision function is deterministic by (seed, id), so the
        // scan is cheap and the chosen pattern is stable.
        let mut cfg = FaultConfig { panic_rate: 0.5, ..FaultConfig::default() };
        cfg.seed = (0..1000)
            .find(|&s| {
                let probe = FaultInjector::new(FaultConfig { seed: s, ..cfg.clone() });
                let fated = (0..n).filter(|&id| probe.injects_panic(id)).count();
                fated > 0 && fated < n as usize
            })
            .expect("some seed under 1000 must mix fated and clean ids");
        let oracle = FaultInjector::new(cfg.clone());
        let reg = registry();
        let model = reg.get("m").unwrap();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::with_faults(
            reg,
            BatchConfig::with_wait_us(1024, 0),
            metrics.clone(),
            Some(Arc::new(FaultInjector::new(cfg))),
        );
        let (q, rx) = AdmissionQueue::bounded(16);
        let mut rng = Rng::new(72);
        let reqs: Vec<_> = (0..n)
            .map(|_| {
                let x = Tensor::randn(&[1, 16], &mut rng);
                let r = q.try_submit("m", LinearRequest::new("w", x.clone())).unwrap();
                (x, r)
            })
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        let mut fated = 0u64;
        for (id, (x, r)) in reqs.into_iter().enumerate() {
            let res = r.recv().unwrap();
            if oracle.injects_panic(id as u64) {
                fated += 1;
                match res.unwrap_err() {
                    ServeError::Panicked { message } => {
                        assert!(message.contains("injected fault"), "payload lost: {message}")
                    }
                    other => panic!("want injected panic, got {other}"),
                }
            } else {
                let got = res.unwrap();
                let want = model.apply("w", &x).unwrap();
                assert_eq!(bits(&got.y), bits(&want), "clean request's bits moved");
            }
        }
        assert!(fated > 0, "seed scan guaranteed at least one fated request");
        assert_eq!(metrics.counter("serve.panics"), fated);
        assert_eq!(metrics.counter("serve.errors"), fated);
    }
}
