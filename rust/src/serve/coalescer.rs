//! Micro-batch coalescing: drain the admission queue, stack compatible
//! requests into one activation matrix, run a single `apply` per
//! (model, weight) group, scatter rows back to the responders.
//!
//! ## Scheduling
//!
//! The coalescer blocks on the queue while idle (no polling). The first
//! arrival opens a micro-batch and starts the fill clock: further
//! arrivals are folded in until the stacked row count reaches
//! [`BatchConfig::max_batch_rows`] or [`BatchConfig::max_wait`] elapses.
//! Requests already queued coalesce without waiting — the wait bound only
//! adds latency when the queue runs dry mid-fill, so under saturation the
//! batch size is governed by the row bound and under trickle traffic by
//! the wait bound.
//!
//! ## Continuous batching for whole-model forwards (PR 7)
//!
//! Forward requests are a different shape of work: a request is not one
//! `apply` but a *sequence* of layer steps through a
//! [`crate::infer::CompressedForward`] state machine. Flushing them like
//! linear batches would convoy short requests behind long ones and make
//! arrivals wait out the entire in-flight cohort. Instead the scheduler
//! keeps an **in-flight set** and re-forms it at every layer boundary:
//! arrivals are admitted (at their layer 0) whenever the stacked token
//! rows fit [`BatchConfig::max_batch_rows`], requests that clear the last
//! layer `finish` and respond immediately, and each scheduler iteration
//! steps every `(forward, layer)` cohort one layer as a single grouped
//! call. [`ForwardScheduling::Flush`] keeps the old flush-the-batch model
//! as the in-tree scheduling oracle. Both are bitwise identical to solo
//! execution — group composition is pure scheduling, because every
//! cross-request op inside a layer step is a row-independent `apply`
//! (the fill clock never runs while forwards are in flight; it would
//! stall the layer clock for no batching gain).
//!
//! ## Why batching never changes results
//!
//! Every serving path computes each output row from that row's own
//! activations with single-register increasing-k accumulation (the
//! crate-wide kernel policy, `tests/fixtures/README.md`) — `apply` is
//! row-independent. Stacking requests `[x1; x2]` and splitting the result
//! is therefore bitwise identical to applying `x1` and `x2` alone, at any
//! `SWSC_THREADS`. Arrival order is preserved purely so the stack/scatter
//! bookkeeping is trivially auditable — correctness never depends on it.

use super::queue::{ForwardJob, Job, JobReceiver, ServeJob};
use super::registry::ModelRegistry;
use super::{ForwardResponse, LinearResponse};
use crate::coordinator::metrics::Metrics;
use crate::exec;
use crate::infer::{CompressedForward, CompressedModel, ForwardState};
use crate::tensor::Tensor;
use std::collections::VecDeque;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How whole-model forward requests are scheduled across layer steps.
/// Purely a latency/throughput knob: both modes are bitwise identical to
/// solo execution (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ForwardScheduling {
    /// Re-form the in-flight set at every layer boundary: arrivals join
    /// mid-flight, finished requests leave immediately (the default).
    #[default]
    Continuous,
    /// Flush-the-batch: admit a cohort only when the previous one has run
    /// to completion. The scheduling oracle the
    /// `forward_batched_vs_flush_*` bench rows compare against.
    Flush,
}

/// Coalescing knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Flush a micro-batch once its stacked activation rows reach this
    /// bound (a single larger request still forms its own batch). Also
    /// bounds the stacked token rows of the in-flight forward set.
    pub max_batch_rows: usize,
    /// Longest the coalescer waits for further arrivals before flushing a
    /// partial batch. Only bounds *added* latency: queued requests
    /// coalesce immediately.
    pub max_wait: Duration,
    /// Layer-step scheduling for whole-model forward requests.
    pub forward_scheduling: ForwardScheduling,
}

impl BatchConfig {
    /// Construct with `max_wait` in microseconds — the serving-latency
    /// scale the knob is usually quoted in.
    pub fn with_wait_us(max_batch_rows: usize, max_wait_us: u64) -> BatchConfig {
        BatchConfig {
            max_batch_rows,
            max_wait: Duration::from_micros(max_wait_us),
            forward_scheduling: ForwardScheduling::default(),
        }
    }

    /// Serve every request alone: batch bound 1, no fill wait. The solo
    /// baseline configuration the `batched_vs_solo_*` bench rows compare
    /// against (one `apply` per request through the same machinery).
    pub fn solo() -> BatchConfig {
        BatchConfig::with_wait_us(1, 0)
    }

    /// This configuration with the given forward scheduling.
    pub fn with_forward_scheduling(self, forward_scheduling: ForwardScheduling) -> BatchConfig {
        BatchConfig { forward_scheduling, ..self }
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig::with_wait_us(256, 200)
    }
}

const SHUTDOWN_MSG: &str = "server shutting down — request drained before it was served";

/// The batching engine: owns nothing but shared handles, driven by
/// [`Coalescer::run`] on a dedicated thread (see
/// [`super::BatchServer`]).
pub struct Coalescer {
    registry: Arc<ModelRegistry>,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
}

/// Requests for one (model, weight) pair within a micro-batch, in
/// arrival order.
struct Group {
    model: Arc<CompressedModel>,
    name: String,
    in_features: usize,
    jobs: Vec<ServeJob>,
}

/// One admitted forward request mid-stack: its per-request activation
/// state, re-formed into `(forward, layer)` cohorts at every boundary.
struct InflightForward {
    job: ForwardJob,
    fwd: Arc<CompressedForward>,
    state: ForwardState,
    /// Set when a grouped layer step fails — the request is answered with
    /// this error at the next finish pass instead of stepping further.
    error: Option<String>,
}

impl Coalescer {
    pub fn new(registry: Arc<ModelRegistry>, cfg: BatchConfig, metrics: Arc<Metrics>) -> Coalescer {
        let cfg = BatchConfig { max_batch_rows: cfg.max_batch_rows.max(1), ..cfg };
        Coalescer { registry, cfg, metrics }
    }

    /// Drive the queue until a shutdown marker arrives (or every producer
    /// is gone). Blocks while idle; never drops a responder — jobs behind
    /// the shutdown marker get an explicit error, and forwards admitted
    /// *before* the marker are still served to completion.
    pub fn run(&self, rx: JobReceiver) {
        let mut shutting_down = false;
        let mut pending: VecDeque<ForwardJob> = VecDeque::new();
        let mut inflight: Vec<InflightForward> = Vec::new();
        loop {
            let mut batch: Vec<ServeJob> = Vec::new();
            let mut rows = 0usize;
            // Fully idle: block for the first arrival (no polling).
            if !shutting_down && pending.is_empty() && inflight.is_empty() {
                match rx.recv() {
                    Ok(job) => {
                        self.intake(job, &mut batch, &mut rows, &mut pending, &mut shutting_down)
                    }
                    Err(_) => shutting_down = true,
                }
            }
            if !shutting_down {
                if !batch.is_empty() && pending.is_empty() && inflight.is_empty() {
                    // A pure-linear micro-batch is forming: run the fill
                    // clock exactly as before PR 7.
                    let deadline = Instant::now() + self.cfg.max_wait;
                    while rows < self.cfg.max_batch_rows && !shutting_down {
                        let timeout = deadline.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(timeout) {
                            Ok(job) => self.intake(
                                job,
                                &mut batch,
                                &mut rows,
                                &mut pending,
                                &mut shutting_down,
                            ),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => shutting_down = true,
                        }
                    }
                } else {
                    // Forward work is outstanding: fold in whatever is
                    // already queued without stalling the layer clock
                    // behind a fill window.
                    while rows < self.cfg.max_batch_rows && !shutting_down {
                        match rx.try_recv() {
                            Ok(job) => self.intake(
                                job,
                                &mut batch,
                                &mut rows,
                                &mut pending,
                                &mut shutting_down,
                            ),
                            Err(mpsc::TryRecvError::Empty) => break,
                            Err(mpsc::TryRecvError::Disconnected) => shutting_down = true,
                        }
                    }
                }
            }
            if !batch.is_empty() {
                self.execute_batch(batch);
            }
            self.admit(&mut pending, &mut inflight);
            self.step_inflight(&mut inflight);
            if shutting_down && pending.is_empty() && inflight.is_empty() {
                self.drain(&rx);
                return;
            }
        }
    }

    fn intake(
        &self,
        job: Job,
        batch: &mut Vec<ServeJob>,
        rows: &mut usize,
        pending: &mut VecDeque<ForwardJob>,
        shutting_down: &mut bool,
    ) {
        match job {
            Job::Linear(job) => {
                *rows += request_rows(&job);
                batch.push(job);
            }
            Job::Forward(job) => {
                self.metrics.incr("serve.forward_requests", 1);
                pending.push_back(job);
            }
            Job::Shutdown => *shutting_down = true,
        }
    }

    /// Admit pending forwards into the in-flight set at their layer 0.
    /// [`ForwardScheduling::Continuous`] admits at every layer boundary
    /// while the stacked token rows fit `max_batch_rows` (the first
    /// admission always goes through, like a single oversized linear
    /// request); [`ForwardScheduling::Flush`] admits only into an empty
    /// set, so each cohort runs to completion before the next forms.
    fn admit(&self, pending: &mut VecDeque<ForwardJob>, inflight: &mut Vec<InflightForward>) {
        // Flush only forms a new cohort once the previous one is gone —
        // but within one formation it still fills up to the row bound.
        if self.cfg.forward_scheduling == ForwardScheduling::Flush && !inflight.is_empty() {
            return;
        }
        while let Some(next) = pending.front() {
            if !inflight.is_empty() {
                let rows: usize = inflight.iter().map(|f| f.state.tokens()).sum();
                if rows + next.req.tokens.len().max(1) > self.cfg.max_batch_rows {
                    break;
                }
            }
            let job = pending.pop_front().expect("front() was Some");
            let Some(fwd) = self.registry.forward(&job.model) else {
                let msg = format!("no forward named `{}` in the registry", job.model);
                self.respond_forward(job, Err(msg));
                continue;
            };
            match fwd.start(&job.req.tokens) {
                Ok(state) => inflight.push(InflightForward { job, fwd, state, error: None }),
                Err(e) => {
                    let msg = format!("forward start failed: {e:#}");
                    self.respond_forward(job, Err(msg));
                }
            }
        }
    }

    /// Step every `(forward, layer)` cohort one layer as a single grouped
    /// call, then finish and respond to requests that cleared the stack.
    fn step_inflight(&self, inflight: &mut Vec<InflightForward>) {
        if inflight.is_empty() {
            return;
        }
        // Cohort keys are collected up front so arrivals admitted this
        // iteration (layer 0) step alongside older requests deeper in the
        // stack — one step per cohort per iteration keeps progress fair.
        let mut keys: Vec<(*const CompressedForward, usize)> = Vec::new();
        for f in inflight.iter() {
            let key = (Arc::as_ptr(&f.fwd), f.state.layer());
            if !keys.contains(&key) {
                keys.push(key);
            }
        }
        for (ptr, layer) in keys {
            let mut members: Vec<&mut InflightForward> = inflight
                .iter_mut()
                .filter(|f| {
                    Arc::as_ptr(&f.fwd) == ptr && f.state.layer() == layer && f.error.is_none()
                })
                .collect();
            if members.is_empty() {
                continue;
            }
            let fwd = members[0].fwd.clone();
            let step_rows: usize = members.iter().map(|m| m.state.tokens()).sum();
            self.metrics.incr("serve.forward_steps", 1);
            self.metrics.record("serve.forward_step_rows", step_rows as f64);
            let t0 = Instant::now();
            let mut states: Vec<&mut ForwardState> =
                members.iter_mut().map(|m| &mut m.state).collect();
            let result = fwd.step_group(&mut states, exec::global());
            self.metrics.record("serve.apply_seconds", t0.elapsed().as_secs_f64());
            if let Err(e) = result {
                let msg = format!("forward step failed: {e:#}");
                for m in members {
                    m.error = Some(msg.clone());
                }
            }
        }
        let mut i = 0;
        while i < inflight.len() {
            let done = inflight[i].error.is_some()
                || inflight[i].state.layer() == inflight[i].fwd.n_layers();
            if !done {
                i += 1;
                continue;
            }
            let f = inflight.remove(i);
            match f.error {
                Some(msg) => self.respond_forward(f.job, Err(msg)),
                None => {
                    let res = f
                        .fwd
                        .finish(&f.state, exec::global())
                        .map_err(|e| format!("forward finish failed: {e:#}"));
                    self.respond_forward(f.job, res);
                }
            }
        }
    }

    /// One micro-batch: group by (model, weight), one `apply` per group
    /// over the stacked activations, scatter rows back in arrival order.
    fn execute_batch(&self, batch: Vec<ServeJob>) {
        self.metrics.incr("serve.batches", 1);
        self.metrics.incr("serve.requests", batch.len() as u64);
        self.metrics.record("serve.batch_requests", batch.len() as f64);
        let total_rows: usize = batch.iter().map(request_rows).sum();
        self.metrics.record("serve.batch_rows", total_rows as f64);

        let mut groups: Vec<Group> = Vec::new();
        for job in batch {
            let Some(model) = self.registry.get(&job.model) else {
                let msg = format!("no model named `{}` in the registry", job.model);
                self.respond(job, Err(msg));
                continue;
            };
            // A well-formed zero-row request has nothing to compute:
            // answer the empty `[0, out]` immediately instead of routing
            // it into the stack.
            if job.req.x.ndim() == 2 && job.req.x.rows() == 0 {
                if let Some((m, n)) = model.shape(&job.req.name) {
                    if job.req.x.cols() == m {
                        self.respond(job, Ok(Tensor::zeros(&[0, n])));
                        continue;
                    }
                }
            }
            // Only well-formed requests are stacked; anything else goes
            // through the model's own `apply` so the error (unknown
            // weight, shape mismatch, non-matrix) is exactly the solo
            // path's.
            let stackable = job.req.x.ndim() == 2
                && model.shape(&job.req.name).is_some_and(|(m, _)| job.req.x.cols() == m);
            if !stackable {
                let res = model
                    .apply(&job.req.name, &job.req.x)
                    .map_err(|e| format!("linear `{}` failed: {e:#}", job.req.name));
                self.respond(job, res);
                continue;
            }
            let found = groups
                .iter()
                .position(|g| g.name == job.req.name && Arc::ptr_eq(&g.model, &model));
            match found {
                Some(i) => groups[i].jobs.push(job),
                None => {
                    let in_features = job.req.x.cols();
                    let name = job.req.name.clone();
                    groups.push(Group { model, name, in_features, jobs: vec![job] });
                }
            }
        }
        for group in groups {
            self.execute_group(group);
        }
    }

    fn execute_group(&self, g: Group) {
        let rows: usize = g.jobs.iter().map(|j| j.req.x.rows()).sum();
        let t0 = Instant::now();
        let result = if let [job] = &g.jobs[..] {
            // Single request — skip the stack/scatter copies.
            g.model.apply(&g.name, &job.req.x)
        } else {
            let mut data = Vec::with_capacity(rows * g.in_features);
            for job in &g.jobs {
                data.extend_from_slice(job.req.x.data());
            }
            g.model.apply(&g.name, &Tensor::from_vec(&[rows, g.in_features], data))
        };
        self.metrics.record("serve.apply_seconds", t0.elapsed().as_secs_f64());
        match result {
            Err(e) => {
                let msg = format!("linear `{}` failed: {e:#}", g.name);
                for job in g.jobs {
                    self.respond(job, Err(msg.clone()));
                }
            }
            Ok(y) if g.jobs.len() == 1 => {
                let job = g.jobs.into_iter().next().unwrap();
                self.respond(job, Ok(y));
            }
            Ok(y) => {
                let out_features = y.cols();
                let mut row0 = 0usize;
                for job in g.jobs {
                    let r = job.req.x.rows();
                    let slab = y.data()[row0 * out_features..(row0 + r) * out_features].to_vec();
                    row0 += r;
                    self.respond(job, Ok(Tensor::from_vec(&[r, out_features], slab)));
                }
            }
        }
    }

    fn respond(&self, job: ServeJob, result: Result<Tensor, String>) {
        self.metrics.record("serve.latency_seconds", job.enqueued.elapsed().as_secs_f64());
        if result.is_err() {
            self.metrics.incr("serve.errors", 1);
        }
        let _ = job.tx.send(result.map(|y| LinearResponse { y }));
    }

    fn respond_forward(&self, job: ForwardJob, result: Result<Tensor, String>) {
        self.metrics
            .record("serve.forward_latency_seconds", job.enqueued.elapsed().as_secs_f64());
        if result.is_err() {
            self.metrics.incr("serve.errors", 1);
        }
        let _ = job.tx.send(result.map(|logits| ForwardResponse { logits }));
    }

    /// Everything behind a shutdown marker gets an explicit error — never
    /// a silently dropped sender.
    fn drain(&self, rx: &JobReceiver) {
        while let Ok(job) = rx.try_recv() {
            match job {
                Job::Linear(job) => {
                    self.metrics.incr("serve.drained_on_shutdown", 1);
                    self.respond(job, Err(SHUTDOWN_MSG.to_string()));
                }
                Job::Forward(job) => {
                    self.metrics.incr("serve.drained_on_shutdown", 1);
                    self.respond_forward(job, Err(SHUTDOWN_MSG.to_string()));
                }
                Job::Shutdown => {}
            }
        }
    }
}

/// Row contribution of a request toward the batch bound. Every request
/// occupies at least one slot: malformed (non-2-D) requests count one on
/// their way to an error response, and well-formed zero-row (`[0, m]`)
/// requests count one too. Before PR 7 zero-row requests counted zero —
/// a stream of them never advanced the row bound, so each paid the full
/// `max_wait` fill window despite being answerable immediately, while
/// malformed requests (which do even less work) counted one.
fn request_rows(job: &ServeJob) -> usize {
    if job.req.x.ndim() == 2 {
        job.req.x.rows().max(1)
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::infer::InferMode;
    use crate::io::SwscFile;
    use crate::model::{init_params, param_specs, ModelConfig};
    use crate::serve::queue::AdmissionQueue;
    use crate::serve::{ForwardRequest, LinearRequest};
    use crate::util::rng::Rng;

    /// Registry with a tiny whole-model forward under "m": 2-D params
    /// with ≥ 16 columns compressed, the rest dense.
    fn forward_registry(seed: u64) -> (Arc<ModelRegistry>, Arc<CompressedForward>) {
        let cfg = ModelConfig::tiny();
        let ck = init_params(&cfg, seed);
        let mut file = SwscFile::new();
        for spec in param_specs(&cfg) {
            let t = ck.get(&spec.name).unwrap().clone();
            if spec.shape.len() == 2 && spec.shape[1] >= 16 {
                file.compressed
                    .insert(spec.name.clone(), compress_matrix(&t, &SwscConfig::new(8, 2)));
            } else {
                file.dense.insert(spec.name.clone(), t);
            }
        }
        let mut reg = ModelRegistry::new();
        let fwd = reg.insert_forward_file("m", &file, cfg, InferMode::Compressed).unwrap();
        (Arc::new(reg), fwd)
    }

    fn registry() -> Arc<ModelRegistry> {
        let mut rng = Rng::new(70);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[16, 16], &mut rng), &SwscConfig::new(2, 1)),
        );
        file.dense.insert("d".into(), Tensor::randn(&[16, 16], &mut rng));
        let mut reg = ModelRegistry::new();
        reg.insert_file("m", &file, InferMode::Compressed);
        Arc::new(reg)
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Deterministic drain-on-shutdown: the job ahead of the marker is
    /// served, the job behind it gets the explicit shutdown error.
    #[test]
    fn drains_jobs_behind_shutdown_marker() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::solo(), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let r1 = q
            .try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 16]) })
            .unwrap();
        q.begin_shutdown();
        let r2 = q.submit_behind_shutdown(
            "m",
            LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 16]) },
        );
        drop(q);
        coal.run(rx); // runs to completion on this thread — no races
        assert!(r1.recv().unwrap().is_ok(), "job ahead of the marker must be served");
        let err = r2.recv().unwrap().unwrap_err();
        assert!(err.contains("shutting down"), "unexpected drain error: {err}");
        assert_eq!(metrics.counter("serve.drained_on_shutdown"), 1);
        assert_eq!(metrics.counter("serve.batches"), 1);
    }

    /// A single batch holding good requests, an unknown weight, a shape
    /// mismatch, an unknown model, and a dense-entry request: groups are
    /// stacked and scattered bitwise-correctly and the error cases are
    /// isolated per request — they never poison the batch.
    #[test]
    fn mixed_batch_groups_scatter_and_isolate_errors() {
        let reg = registry();
        let model = reg.get("m").unwrap();
        let metrics = Arc::new(Metrics::new());
        // Everything is queued before `run`, so with a generous row bound
        // the whole stream coalesces into exactly one batch.
        let coal = Coalescer::new(reg.clone(), BatchConfig::with_wait_us(1024, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(16);
        let mut rng = Rng::new(71);
        let xs: Vec<Tensor> =
            (0..4).map(|i| Tensor::randn(&[1 + (i % 3), 16], &mut rng)).collect();
        let good: Vec<_> = xs
            .iter()
            .map(|x| {
                q.try_submit("m", LinearRequest { name: "w".into(), x: x.clone() }).unwrap()
            })
            .collect();
        let xd = Tensor::randn(&[3, 16], &mut rng);
        let dense = q.try_submit("m", LinearRequest { name: "d".into(), x: xd.clone() }).unwrap();
        let bad_weight = q
            .try_submit("m", LinearRequest { name: "nope".into(), x: Tensor::zeros(&[2, 16]) })
            .unwrap();
        let bad_shape = q
            .try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[2, 15]) })
            .unwrap();
        let bad_model = q
            .try_submit("ghost", LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 16]) })
            .unwrap();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);

        for (x, r) in xs.iter().zip(good) {
            let got = r.recv().unwrap().unwrap();
            let want = model.apply("w", x).unwrap();
            assert_eq!(bits(&got.y), bits(&want), "batched response differs from solo apply");
        }
        let got_dense = dense.recv().unwrap().unwrap();
        assert_eq!(bits(&got_dense.y), bits(&model.apply("d", &xd).unwrap()));
        assert!(bad_weight.recv().unwrap().unwrap_err().contains("nope"));
        assert!(bad_shape.recv().unwrap().unwrap_err().contains("failed"));
        assert!(bad_model.recv().unwrap().unwrap_err().contains("ghost"));
        assert_eq!(metrics.counter("serve.batches"), 1, "stream must coalesce into one batch");
        assert_eq!(metrics.counter("serve.requests"), 8);
        assert_eq!(metrics.counter("serve.errors"), 3);
    }

    /// Satellite 2 (PR 7): well-formed zero-row `[0, m]` requests advance
    /// the row bound like any other request and are answered with an
    /// empty `[0, out]` tensor without entering the stack. Before the
    /// fix they counted zero rows — a stream of them never flushed on the
    /// bound, so each paid the full `max_wait` fill window.
    #[test]
    fn zero_row_requests_count_and_answer_empty() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(2, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                q.try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[0, 16]) })
                    .unwrap()
            })
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            let y = r.recv().unwrap().unwrap().y;
            assert_eq!(y.shape(), &[0, 16]);
        }
        // One row each against a bound of 2: the stream splits 2 + 1. The
        // old zero-count behavior coalesced all three into one batch.
        assert_eq!(metrics.counter("serve.batches"), 2);
        assert_eq!(metrics.counter("serve.errors"), 0);
    }

    /// The other half of satellite 2: malformed (non-2-D) requests keep
    /// counting one row toward the bound on their way to an error.
    #[test]
    fn malformed_requests_count_one_row() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(2, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                q.try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[16]) })
                    .unwrap()
            })
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            assert!(r.recv().unwrap().is_err(), "non-2-D request must error");
        }
        assert_eq!(metrics.counter("serve.batches"), 2);
    }

    /// The row bound flushes mid-stream: 3 × 2-row requests against a
    /// 4-row bound split into two batches at a deterministic boundary.
    #[test]
    fn row_bound_flushes_batches() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(4, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                q.try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[2, 16]) })
                    .unwrap()
            })
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            assert!(r.recv().unwrap().is_ok());
        }
        assert_eq!(metrics.counter("serve.batches"), 2);
        assert_eq!(metrics.timing_count("serve.batch_rows"), 2);
    }
}
