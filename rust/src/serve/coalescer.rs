//! Micro-batch coalescing: drain the admission queue, stack compatible
//! requests into one activation matrix, run a single `apply` per
//! (model, weight) group, scatter rows back to the responders.
//!
//! ## Scheduling
//!
//! The coalescer blocks on the queue while idle (no polling). The first
//! arrival opens a micro-batch and starts the fill clock: further
//! arrivals are folded in until the stacked row count reaches
//! [`BatchConfig::max_batch_rows`] or [`BatchConfig::max_wait`] elapses.
//! Requests already queued coalesce without waiting — the wait bound only
//! adds latency when the queue runs dry mid-fill, so under saturation the
//! batch size is governed by the row bound and under trickle traffic by
//! the wait bound.
//!
//! ## Why batching never changes results
//!
//! Every serving path computes each output row from that row's own
//! activations with single-register increasing-k accumulation (the
//! crate-wide kernel policy, `tests/fixtures/README.md`) — `apply` is
//! row-independent. Stacking requests `[x1; x2]` and splitting the result
//! is therefore bitwise identical to applying `x1` and `x2` alone, at any
//! `SWSC_THREADS`. Arrival order is preserved purely so the stack/scatter
//! bookkeeping is trivially auditable — correctness never depends on it.

use super::queue::{Job, JobReceiver, ServeJob};
use super::registry::ModelRegistry;
use super::LinearResponse;
use crate::coordinator::metrics::Metrics;
use crate::infer::CompressedModel;
use crate::tensor::Tensor;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Coalescing knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Flush a micro-batch once its stacked activation rows reach this
    /// bound (a single larger request still forms its own batch).
    pub max_batch_rows: usize,
    /// Longest the coalescer waits for further arrivals before flushing a
    /// partial batch. Only bounds *added* latency: queued requests
    /// coalesce immediately.
    pub max_wait: Duration,
}

impl BatchConfig {
    /// Construct with `max_wait` in microseconds — the serving-latency
    /// scale the knob is usually quoted in.
    pub fn with_wait_us(max_batch_rows: usize, max_wait_us: u64) -> BatchConfig {
        BatchConfig { max_batch_rows, max_wait: Duration::from_micros(max_wait_us) }
    }

    /// Serve every request alone: batch bound 1, no fill wait. The solo
    /// baseline configuration the `batched_vs_solo_*` bench rows compare
    /// against (one `apply` per request through the same machinery).
    pub fn solo() -> BatchConfig {
        BatchConfig { max_batch_rows: 1, max_wait: Duration::ZERO }
    }
}

impl Default for BatchConfig {
    fn default() -> Self {
        BatchConfig { max_batch_rows: 256, max_wait: Duration::from_micros(200) }
    }
}

const SHUTDOWN_MSG: &str = "server shutting down — request drained before it was served";

/// The batching engine: owns nothing but shared handles, driven by
/// [`Coalescer::run`] on a dedicated thread (see
/// [`super::BatchServer`]).
pub struct Coalescer {
    registry: Arc<ModelRegistry>,
    cfg: BatchConfig,
    metrics: Arc<Metrics>,
}

/// Requests for one (model, weight) pair within a micro-batch, in
/// arrival order.
struct Group {
    model: Arc<CompressedModel>,
    name: String,
    in_features: usize,
    jobs: Vec<ServeJob>,
}

impl Coalescer {
    pub fn new(registry: Arc<ModelRegistry>, cfg: BatchConfig, metrics: Arc<Metrics>) -> Coalescer {
        let cfg = BatchConfig { max_batch_rows: cfg.max_batch_rows.max(1), ..cfg };
        Coalescer { registry, cfg, metrics }
    }

    /// Drive the queue until a shutdown marker arrives (or every producer
    /// is gone). Blocks while idle; never drops a responder — jobs behind
    /// the shutdown marker get an explicit error.
    pub fn run(&self, rx: JobReceiver) {
        loop {
            let first = match rx.recv() {
                Ok(Job::Linear(job)) => job,
                Ok(Job::Shutdown) => {
                    self.drain(&rx);
                    return;
                }
                Err(_) => return,
            };
            let mut shutting_down = false;
            let mut rows = request_rows(&first);
            let mut batch = vec![first];
            let deadline = Instant::now() + self.cfg.max_wait;
            while rows < self.cfg.max_batch_rows && !shutting_down {
                let timeout = deadline.saturating_duration_since(Instant::now());
                match rx.recv_timeout(timeout) {
                    Ok(Job::Linear(job)) => {
                        rows += request_rows(&job);
                        batch.push(job);
                    }
                    Ok(Job::Shutdown) => shutting_down = true,
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => shutting_down = true,
                }
            }
            self.execute_batch(batch);
            if shutting_down {
                self.drain(&rx);
                return;
            }
        }
    }

    /// One micro-batch: group by (model, weight), one `apply` per group
    /// over the stacked activations, scatter rows back in arrival order.
    fn execute_batch(&self, batch: Vec<ServeJob>) {
        self.metrics.incr("serve.batches", 1);
        self.metrics.incr("serve.requests", batch.len() as u64);
        self.metrics.record("serve.batch_requests", batch.len() as f64);
        let total_rows: usize = batch.iter().map(request_rows).sum();
        self.metrics.record("serve.batch_rows", total_rows as f64);

        let mut groups: Vec<Group> = Vec::new();
        for job in batch {
            let Some(model) = self.registry.get(&job.model) else {
                let msg = format!("no model named `{}` in the registry", job.model);
                self.respond(job, Err(msg));
                continue;
            };
            // Only well-formed requests are stacked; anything else goes
            // through the model's own `apply` so the error (unknown
            // weight, shape mismatch, non-matrix) is exactly the solo
            // path's.
            let stackable = job.req.x.ndim() == 2
                && model.shape(&job.req.name).is_some_and(|(m, _)| job.req.x.cols() == m);
            if !stackable {
                let res = model
                    .apply(&job.req.name, &job.req.x)
                    .map_err(|e| format!("linear `{}` failed: {e:#}", job.req.name));
                self.respond(job, res);
                continue;
            }
            let found = groups
                .iter()
                .position(|g| g.name == job.req.name && Arc::ptr_eq(&g.model, &model));
            match found {
                Some(i) => groups[i].jobs.push(job),
                None => {
                    let in_features = job.req.x.cols();
                    let name = job.req.name.clone();
                    groups.push(Group { model, name, in_features, jobs: vec![job] });
                }
            }
        }
        for group in groups {
            self.execute_group(group);
        }
    }

    fn execute_group(&self, g: Group) {
        let rows: usize = g.jobs.iter().map(|j| j.req.x.rows()).sum();
        let t0 = Instant::now();
        let result = if let [job] = &g.jobs[..] {
            // Single request — skip the stack/scatter copies.
            g.model.apply(&g.name, &job.req.x)
        } else {
            let mut data = Vec::with_capacity(rows * g.in_features);
            for job in &g.jobs {
                data.extend_from_slice(job.req.x.data());
            }
            g.model.apply(&g.name, &Tensor::from_vec(&[rows, g.in_features], data))
        };
        self.metrics.record("serve.apply_seconds", t0.elapsed().as_secs_f64());
        match result {
            Err(e) => {
                let msg = format!("linear `{}` failed: {e:#}", g.name);
                for job in g.jobs {
                    self.respond(job, Err(msg.clone()));
                }
            }
            Ok(y) if g.jobs.len() == 1 => {
                let job = g.jobs.into_iter().next().unwrap();
                self.respond(job, Ok(y));
            }
            Ok(y) => {
                let out_features = y.cols();
                let mut row0 = 0usize;
                for job in g.jobs {
                    let r = job.req.x.rows();
                    let slab = y.data()[row0 * out_features..(row0 + r) * out_features].to_vec();
                    row0 += r;
                    self.respond(job, Ok(Tensor::from_vec(&[r, out_features], slab)));
                }
            }
        }
    }

    fn respond(&self, job: ServeJob, result: Result<Tensor, String>) {
        self.metrics.record("serve.latency_seconds", job.enqueued.elapsed().as_secs_f64());
        if result.is_err() {
            self.metrics.incr("serve.errors", 1);
        }
        let _ = job.tx.send(result.map(|y| LinearResponse { y }));
    }

    /// Everything behind a shutdown marker gets an explicit error — never
    /// a silently dropped sender.
    fn drain(&self, rx: &JobReceiver) {
        while let Ok(job) = rx.try_recv() {
            if let Job::Linear(job) = job {
                self.metrics.incr("serve.drained_on_shutdown", 1);
                self.respond(job, Err(SHUTDOWN_MSG.to_string()));
            }
        }
    }
}

/// Row contribution of a request toward the batch bound. Malformed
/// requests (non-2-D activations) count as one row — they still occupy a
/// batch slot on their way to an error response.
fn request_rows(job: &ServeJob) -> usize {
    if job.req.x.ndim() == 2 {
        job.req.x.rows()
    } else {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_matrix, SwscConfig};
    use crate::infer::InferMode;
    use crate::io::SwscFile;
    use crate::serve::queue::AdmissionQueue;
    use crate::serve::LinearRequest;
    use crate::util::rng::Rng;

    fn registry() -> Arc<ModelRegistry> {
        let mut rng = Rng::new(70);
        let mut file = SwscFile::new();
        file.compressed.insert(
            "w".into(),
            compress_matrix(&Tensor::randn(&[16, 16], &mut rng), &SwscConfig::new(2, 1)),
        );
        file.dense.insert("d".into(), Tensor::randn(&[16, 16], &mut rng));
        let mut reg = ModelRegistry::new();
        reg.insert_file("m", &file, InferMode::Compressed);
        Arc::new(reg)
    }

    fn bits(t: &Tensor) -> Vec<u32> {
        t.data().iter().map(|v| v.to_bits()).collect()
    }

    /// Deterministic drain-on-shutdown: the job ahead of the marker is
    /// served, the job behind it gets the explicit shutdown error.
    #[test]
    fn drains_jobs_behind_shutdown_marker() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::solo(), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let r1 = q
            .try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 16]) })
            .unwrap();
        q.begin_shutdown();
        let r2 = q.submit_behind_shutdown(
            "m",
            LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 16]) },
        );
        drop(q);
        coal.run(rx); // runs to completion on this thread — no races
        assert!(r1.recv().unwrap().is_ok(), "job ahead of the marker must be served");
        let err = r2.recv().unwrap().unwrap_err();
        assert!(err.contains("shutting down"), "unexpected drain error: {err}");
        assert_eq!(metrics.counter("serve.drained_on_shutdown"), 1);
        assert_eq!(metrics.counter("serve.batches"), 1);
    }

    /// A single batch holding good requests, an unknown weight, a shape
    /// mismatch, an unknown model, and a dense-entry request: groups are
    /// stacked and scattered bitwise-correctly and the error cases are
    /// isolated per request — they never poison the batch.
    #[test]
    fn mixed_batch_groups_scatter_and_isolate_errors() {
        let reg = registry();
        let model = reg.get("m").unwrap();
        let metrics = Arc::new(Metrics::new());
        // Everything is queued before `run`, so with a generous row bound
        // the whole stream coalesces into exactly one batch.
        let coal = Coalescer::new(reg.clone(), BatchConfig::with_wait_us(1024, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(16);
        let mut rng = Rng::new(71);
        let xs: Vec<Tensor> =
            (0..4).map(|i| Tensor::randn(&[1 + (i % 3), 16], &mut rng)).collect();
        let good: Vec<_> = xs
            .iter()
            .map(|x| {
                q.try_submit("m", LinearRequest { name: "w".into(), x: x.clone() }).unwrap()
            })
            .collect();
        let xd = Tensor::randn(&[3, 16], &mut rng);
        let dense = q.try_submit("m", LinearRequest { name: "d".into(), x: xd.clone() }).unwrap();
        let bad_weight = q
            .try_submit("m", LinearRequest { name: "nope".into(), x: Tensor::zeros(&[2, 16]) })
            .unwrap();
        let bad_shape = q
            .try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[2, 15]) })
            .unwrap();
        let bad_model = q
            .try_submit("ghost", LinearRequest { name: "w".into(), x: Tensor::zeros(&[1, 16]) })
            .unwrap();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);

        for (x, r) in xs.iter().zip(good) {
            let got = r.recv().unwrap().unwrap();
            let want = model.apply("w", x).unwrap();
            assert_eq!(bits(&got.y), bits(&want), "batched response differs from solo apply");
        }
        let got_dense = dense.recv().unwrap().unwrap();
        assert_eq!(bits(&got_dense.y), bits(&model.apply("d", &xd).unwrap()));
        assert!(bad_weight.recv().unwrap().unwrap_err().contains("nope"));
        assert!(bad_shape.recv().unwrap().unwrap_err().contains("failed"));
        assert!(bad_model.recv().unwrap().unwrap_err().contains("ghost"));
        assert_eq!(metrics.counter("serve.batches"), 1, "stream must coalesce into one batch");
        assert_eq!(metrics.counter("serve.requests"), 8);
        assert_eq!(metrics.counter("serve.errors"), 3);
    }

    /// The row bound flushes mid-stream: 3 × 2-row requests against a
    /// 4-row bound split into two batches at a deterministic boundary.
    #[test]
    fn row_bound_flushes_batches() {
        let reg = registry();
        let metrics = Arc::new(Metrics::new());
        let coal = Coalescer::new(reg, BatchConfig::with_wait_us(4, 0), metrics.clone());
        let (q, rx) = AdmissionQueue::bounded(8);
        let rxs: Vec<_> = (0..3)
            .map(|_| {
                q.try_submit("m", LinearRequest { name: "w".into(), x: Tensor::zeros(&[2, 16]) })
                    .unwrap()
            })
            .collect();
        q.begin_shutdown();
        drop(q);
        coal.run(rx);
        for r in rxs {
            assert!(r.recv().unwrap().is_ok());
        }
        assert_eq!(metrics.counter("serve.batches"), 2);
        assert_eq!(metrics.timing_count("serve.batch_rows"), 2);
    }
}
