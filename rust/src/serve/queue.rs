//! Admission control: a bounded queue in front of the coalescer.
//!
//! The queue is the backpressure boundary of the serving layer. Depth is
//! bounded at construction, so a traffic spike turns into explicit
//! [`AdmissionError::Overloaded`] rejections (or a stalled submitter, if
//! the caller prefers [`AdmissionQueue::submit`]'s blocking semantics) —
//! never into unbounded buffering. Shutdown is a marker in the queue:
//! everything admitted ahead of it is still served, anything behind it
//! is answered with an explicit shutdown error by the coalescer's drain
//! pass, so no responder is ever dropped silently.
//!
//! ## Fault-tolerance surface (PR 8)
//!
//! The channel is a hand-rolled `Mutex<VecDeque>` + two-condvar bounded
//! queue rather than `mpsc::sync_channel`, for three reasons the std
//! channel cannot express:
//!
//! - **Prompt shutdown.** A submitter blocked on a full queue wakes with
//!   [`AdmissionError::ShuttingDown`] the moment
//!   [`AdmissionQueue::begin_shutdown`] fires, instead of stalling until a
//!   drain slot frees — and the shutdown marker itself bypasses the
//!   capacity bound, so `begin_shutdown` never blocks either.
//! - **Per-model quotas.** [`QuotaConfig`] caps how many *queued* jobs one
//!   model may hold, so a hot model sheds ([`AdmissionError::QuotaExceeded`],
//!   immediately — never blocking) while cold models keep admitting. The
//!   check and the push are atomic under one lock.
//! - **Admission-time fault hooks.** Request ids are assigned here, and a
//!   configured [`super::FaultInjector`] can deterministically reject by
//!   (seed, id); expired deadlines are answered with
//!   [`ServeError::DeadlineExceeded`] without ever occupying a slot.
//!
//! The receiver API intentionally keeps `std::sync::mpsc`'s error types
//! (`RecvError` / `RecvTimeoutError` / `TryRecvError`) so the coalescer's
//! event loop is indifferent to the swap.

use super::fault::FaultInjector;
use super::{ForwardRequest, ForwardResponse, LinearRequest, LinearResponse, ServeError};
use crate::coordinator::metrics::Metrics;
use crate::obs::{EventKind, TraceSink};
use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionError {
    /// The queue is at capacity. Explicit backpressure: the caller decides
    /// whether to retry, shed, or fall back — the server never buffers
    /// unboundedly.
    Overloaded,
    /// The server is shutting down (or already gone); no new work is
    /// admitted.
    ShuttingDown,
    /// This model's per-model admission quota is exhausted. Unlike
    /// `Overloaded` this is never a blocking condition: quota shed is
    /// immediate even on the blocking submit paths, so one hot model
    /// cannot park submitters while starving the rest of the registry.
    QuotaExceeded,
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Overloaded => write!(f, "server overloaded (admission queue full)"),
            AdmissionError::ShuttingDown => write!(f, "server shutting down"),
            AdmissionError::QuotaExceeded => {
                write!(f, "per-model admission quota exhausted")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Per-model caps on *queued* jobs. A model at its cap sheds new
/// admissions with [`AdmissionError::QuotaExceeded`] until the coalescer
/// drains some of its queued work; other models are unaffected.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct QuotaConfig {
    limits: BTreeMap<String, usize>,
    default_limit: Option<usize>,
}

impl QuotaConfig {
    pub fn new() -> QuotaConfig {
        QuotaConfig::default()
    }

    /// Cap the named model at `limit` queued jobs.
    pub fn with_limit(mut self, model: &str, limit: usize) -> QuotaConfig {
        self.limits.insert(model.to_string(), limit);
        self
    }

    /// Cap every model without an explicit limit at `limit` queued jobs.
    pub fn with_default_limit(mut self, limit: usize) -> QuotaConfig {
        self.default_limit = Some(limit);
        self
    }

    /// The effective limit for `model`, if any.
    pub fn limit(&self, model: &str) -> Option<usize> {
        self.limits.get(model).copied().or(self.default_limit)
    }

    /// Whether no quota is configured at all (the zero-cost default).
    pub fn is_empty(&self) -> bool {
        self.limits.is_empty() && self.default_limit.is_none()
    }
}

/// Optional admission-side wiring for [`AdmissionQueue::bounded_with`].
#[derive(Default)]
pub struct QueueOptions {
    pub quotas: QuotaConfig,
    pub faults: Option<Arc<FaultInjector>>,
    pub metrics: Option<Arc<Metrics>>,
    /// Admission-side trace sink (PR 9). `None` keeps every admission
    /// path byte-for-byte the pre-tracing code: no clock reads, no
    /// allocation, no lock traffic.
    pub trace: Option<Arc<TraceSink>>,
}

/// Channel a response is delivered on.
pub(crate) type Responder = mpsc::Sender<Result<LinearResponse, ServeError>>;

/// One admitted request, on its way to the coalescer.
pub(crate) struct ServeJob {
    /// Admission-order request id — the fault injector's decision key.
    pub id: u64,
    /// Registry key of the target model.
    pub model: String,
    pub req: LinearRequest,
    /// Admission time — the coalescer records queue-to-response latency
    /// from this.
    pub enqueued: Instant,
    /// When the coalescer picked this job out of the queue (PR 9) —
    /// splits end-to-end latency into queue-wait vs service-time.
    pub picked: Option<Instant>,
    pub tx: Responder,
}

/// Channel a forward response is delivered on.
pub(crate) type ForwardResponder = mpsc::Sender<Result<ForwardResponse, ServeError>>;

/// One admitted whole-model request (PR 7), on its way to the
/// coalescer's continuous-batching scheduler.
pub(crate) struct ForwardJob {
    pub id: u64,
    /// Registry key of the target forward.
    pub model: String,
    pub req: ForwardRequest,
    pub enqueued: Instant,
    /// When the coalescer picked this job out of the queue (PR 9).
    pub picked: Option<Instant>,
    pub tx: ForwardResponder,
}

pub(crate) enum Job {
    Linear(ServeJob),
    Forward(ForwardJob),
    Shutdown,
}

impl Job {
    fn model_key(&self) -> Option<&str> {
        match self {
            Job::Linear(j) => Some(&j.model),
            Job::Forward(j) => Some(&j.model),
            Job::Shutdown => None,
        }
    }
}

struct ChanState {
    queue: VecDeque<Job>,
    /// Count of Linear/Forward entries (the shutdown marker is exempt
    /// from the capacity bound).
    jobs: usize,
    /// Queued jobs per model, for quota enforcement.
    per_model: BTreeMap<String, usize>,
    shutting_down: bool,
    receiver_gone: bool,
    producer_gone: bool,
}

impl ChanState {
    fn model_count(&self, model: &str) -> usize {
        self.per_model.get(model).copied().unwrap_or(0)
    }

    fn enqueue(&mut self, job: Job) {
        if let Some(model) = job.model_key() {
            self.jobs += 1;
            *self.per_model.entry(model.to_string()).or_insert(0) += 1;
        }
        self.queue.push_back(job);
    }

    fn dequeue(&mut self) -> Option<Job> {
        let job = self.queue.pop_front()?;
        if let Some(model) = job.model_key() {
            self.jobs -= 1;
            if let Some(count) = self.per_model.get_mut(model) {
                *count -= 1;
                if *count == 0 {
                    self.per_model.remove(model);
                }
            }
        }
        Some(job)
    }
}

struct Chan {
    state: Mutex<ChanState>,
    /// Submitters blocked on a full queue wait here; woken on dequeue,
    /// shutdown, and receiver drop.
    space: Condvar,
    /// The receiver waits here; woken on enqueue and producer drop.
    ready: Condvar,
    capacity: usize,
}

impl Chan {
    fn lock(&self) -> MutexGuard<'_, ChanState> {
        // A panic can only poison this lock between plain collection ops;
        // the state is never left mid-update, so recover rather than
        // cascade the poison into every submitter.
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue under the lock; `block` waits for a free slot. The
    /// shutdown/quota/capacity decisions and the push are one atomic
    /// critical section.
    fn push(&self, job: Job, quota: Option<usize>, block: bool) -> Result<(), AdmissionError> {
        let mut job = Some(job);
        let mut st = self.lock();
        loop {
            if st.shutting_down || st.receiver_gone {
                return Err(AdmissionError::ShuttingDown);
            }
            if let (Some(limit), Some(model)) =
                (quota, job.as_ref().and_then(|j| j.model_key()))
            {
                if st.model_count(model) >= limit {
                    return Err(AdmissionError::QuotaExceeded);
                }
            }
            if st.jobs < self.capacity {
                st.enqueue(job.take().expect("job consumed twice"));
                drop(st);
                self.ready.notify_one();
                return Ok(());
            }
            if !block {
                return Err(AdmissionError::Overloaded);
            }
            st = self.space.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Enqueue unconditionally — no capacity, quota, or shutdown check.
    /// Used for the shutdown marker and the behind-shutdown test hooks.
    fn push_unchecked(&self, job: Job) {
        let mut st = self.lock();
        st.enqueue(job);
        drop(st);
        self.ready.notify_one();
    }

    fn dequeue_and_wake(&self, st: &mut ChanState) -> Option<Job> {
        let job = st.dequeue()?;
        // notify_all, not notify_one: a woken submitter may bail on quota
        // or shutdown without consuming the freed slot, which would strand
        // a second waiter under notify_one.
        self.space.notify_all();
        Some(job)
    }
}

/// Producer side of the bounded admission queue.
pub struct AdmissionQueue {
    chan: Arc<Chan>,
    quotas: QuotaConfig,
    faults: Option<Arc<FaultInjector>>,
    metrics: Option<Arc<Metrics>>,
    trace: Option<Arc<TraceSink>>,
    next_id: AtomicU64,
}

/// Consumer side, handed to [`super::Coalescer::run`].
pub struct JobReceiver {
    chan: Arc<Chan>,
}

impl AdmissionQueue {
    /// Build a queue admitting at most `capacity` waiting requests
    /// (clamped to ≥ 1). Returns the producer handle and the receiver the
    /// coalescer drives.
    pub fn bounded(capacity: usize) -> (AdmissionQueue, JobReceiver) {
        Self::bounded_with(capacity, QueueOptions::default())
    }

    /// [`AdmissionQueue::bounded`] plus per-model quotas, fault
    /// injection, and admission-side metrics.
    pub fn bounded_with(capacity: usize, opts: QueueOptions) -> (AdmissionQueue, JobReceiver) {
        let chan = Arc::new(Chan {
            state: Mutex::new(ChanState {
                queue: VecDeque::new(),
                jobs: 0,
                per_model: BTreeMap::new(),
                shutting_down: false,
                receiver_gone: false,
                producer_gone: false,
            }),
            space: Condvar::new(),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        });
        let queue = AdmissionQueue {
            chan: chan.clone(),
            quotas: opts.quotas,
            faults: opts.faults,
            metrics: opts.metrics,
            trace: opts.trace,
            next_id: AtomicU64::new(0),
        };
        (queue, JobReceiver { chan })
    }

    /// The depth bound this queue was built with.
    pub fn capacity(&self) -> usize {
        self.chan.capacity
    }

    /// Requests admitted but not yet picked up by the coalescer.
    pub fn depth(&self) -> usize {
        self.chan.lock().jobs
    }

    /// Whether [`AdmissionQueue::begin_shutdown`] has been called (or the
    /// receiver is gone).
    pub fn is_shutting_down(&self) -> bool {
        let st = self.chan.lock();
        st.shutting_down || st.receiver_gone
    }

    /// The fault injector wired at construction, if any.
    pub fn faults(&self) -> Option<&Arc<FaultInjector>> {
        self.faults.as_ref()
    }

    fn incr(&self, name: &str) {
        if let Some(m) = &self.metrics {
            m.incr(name, 1);
        }
    }

    /// Record an admission-side trace event. `None` sink: no-op — no
    /// clock read, no allocation.
    fn emit(&self, kind: EventKind, id: u64, model: &str, detail: &str) {
        if let Some(t) = &self.trace {
            t.event(kind, id, model, detail);
        }
    }

    /// Shared admission prologue: id assignment, injected rejections, and
    /// expired-deadline answering. `Err(Some(_))` is a rejection,
    /// `Err(None)` means "answered already" is impossible here — the
    /// deadline short-circuit is handled by the callers because the
    /// responder types differ.
    fn preflight(&self, model: &str, deadline_expired: bool) -> Result<u64, AdmissionError> {
        // Ids are assigned before any rejection so every admission
        // attempt — including a shutdown rejection — traces under its own
        // id instead of landing on the reserved server-scope track
        // (trace id 0, the coalescer's batch-pick spans). Burning ids on
        // shutdown rejections cannot perturb the fault schedule: nothing
        // is admitted after shutdown begins, so no served request's id
        // shifts.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        if self.is_shutting_down() {
            self.emit(EventKind::Rejected, id, model, "shutting down");
            return Err(AdmissionError::ShuttingDown);
        }
        if let Some(f) = &self.faults {
            if f.injects_rejection(id) {
                f.record_rejection();
                self.incr("serve.faults_injected");
                self.emit(EventKind::FaultInjected, id, model, "reject");
                self.emit(EventKind::Rejected, id, model, "injected");
                return Err(AdmissionError::Overloaded);
            }
        }
        if deadline_expired {
            self.incr("serve.deadline_miss");
            self.emit(EventKind::DeadlineEvicted, id, model, "admission");
        }
        Ok(id)
    }

    /// Shared admission epilogue: labeled quota accounting and the
    /// admitted/rejected trace events.
    fn note_outcome(&self, outcome: &Result<(), AdmissionError>, id: u64, model: &str) {
        match outcome {
            Ok(()) => self.emit(EventKind::Admitted, id, model, ""),
            Err(AdmissionError::QuotaExceeded) => {
                self.incr("serve.quota_rejected");
                // Quotas are keyed by the *requested* name (an alias can
                // carry its own cap), so the label is the requested name.
                if let Some(m) = &self.metrics {
                    m.incr_with("serve.quota_rejected", model, 1);
                }
                self.emit(EventKind::Rejected, id, model, "quota");
            }
            Err(AdmissionError::Overloaded) => {
                self.emit(EventKind::Rejected, id, model, "overloaded")
            }
            Err(AdmissionError::ShuttingDown) => {
                self.emit(EventKind::Rejected, id, model, "shutting down")
            }
        }
    }

    fn admit_linear(
        &self,
        model: &str,
        req: LinearRequest,
        block: bool,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        let expired = req.expired();
        let id = self.preflight(model, expired)?;
        if expired {
            // Answer without ever occupying a queue slot.
            let (rtx, rrx) = mpsc::channel();
            let _ = rtx.send(Err(ServeError::DeadlineExceeded));
            return Ok(rrx);
        }
        let (job, rrx) = self.make_job(id, model, req);
        let outcome = self.chan.push(Job::Linear(job), self.quotas.limit(model), block);
        self.note_outcome(&outcome, id, model);
        outcome.map(|()| rrx)
    }

    fn admit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
        block: bool,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        let expired = req.expired();
        let id = self.preflight(model, expired)?;
        if expired {
            let (rtx, rrx) = mpsc::channel();
            let _ = rtx.send(Err(ServeError::DeadlineExceeded));
            return Ok(rrx);
        }
        let (job, rrx) = self.make_forward_job(id, model, req);
        let outcome = self.chan.push(Job::Forward(job), self.quotas.limit(model), block);
        self.note_outcome(&outcome, id, model);
        outcome.map(|()| rrx)
    }

    /// Non-blocking admission: [`AdmissionError::Overloaded`] when the
    /// queue is full. On success returns the receiver the response
    /// arrives on.
    pub fn try_submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        self.admit_linear(model, req, false)
    }

    /// Blocking admission: waits for queue space instead of rejecting —
    /// backpressure becomes "the submitter stalls", matching
    /// `EvalService::submit_linear`'s historical contract. A submitter
    /// blocked here when [`AdmissionQueue::begin_shutdown`] fires wakes
    /// promptly with [`AdmissionError::ShuttingDown`].
    pub fn submit(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> Result<mpsc::Receiver<Result<LinearResponse, ServeError>>, AdmissionError> {
        self.admit_linear(model, req, true)
    }

    /// Non-blocking admission of a whole-model forward request. Same
    /// backpressure contract as [`AdmissionQueue::try_submit`]: a forward
    /// occupies one queue slot regardless of its token count — token-level
    /// bounds are the scheduler's job ([`super::BatchConfig`]).
    pub fn try_submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        self.admit_forward(model, req, false)
    }

    /// Blocking admission of a whole-model forward request.
    pub fn submit_forward(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> Result<mpsc::Receiver<Result<ForwardResponse, ServeError>>, AdmissionError> {
        self.admit_forward(model, req, true)
    }

    /// Stop admitting and wake the coalescer with a shutdown marker. The
    /// coalescer serves everything admitted before the marker, then
    /// answers anything behind it with an explicit shutdown error.
    ///
    /// Never blocks: the marker bypasses the capacity bound, and every
    /// submitter blocked on a full queue wakes with
    /// [`AdmissionError::ShuttingDown`].
    pub fn begin_shutdown(&self) {
        let mut st = self.chan.lock();
        if st.shutting_down {
            return; // idempotent — exactly one marker
        }
        st.shutting_down = true;
        if !st.receiver_gone {
            st.queue.push_back(Job::Shutdown);
        }
        drop(st);
        self.chan.ready.notify_all();
        self.chan.space.notify_all();
    }

    fn make_job(
        &self,
        id: u64,
        model: &str,
        req: LinearRequest,
    ) -> (ServeJob, mpsc::Receiver<Result<LinearResponse, ServeError>>) {
        let (rtx, rrx) = mpsc::channel();
        let job = ServeJob {
            id,
            model: model.to_string(),
            req,
            enqueued: Instant::now(),
            picked: None,
            tx: rtx,
        };
        (job, rrx)
    }

    fn make_forward_job(
        &self,
        id: u64,
        model: &str,
        req: ForwardRequest,
    ) -> (ForwardJob, mpsc::Receiver<Result<ForwardResponse, ServeError>>) {
        let (rtx, rrx) = mpsc::channel();
        let job = ForwardJob {
            id,
            model: model.to_string(),
            req,
            enqueued: Instant::now(),
            picked: None,
            tx: rtx,
        };
        (job, rrx)
    }

    /// Test hook: enqueue past the shutdown flag, to exercise the drain
    /// path deterministically (a job *behind* the marker).
    #[cfg(test)]
    pub(crate) fn submit_behind_shutdown(
        &self,
        model: &str,
        req: LinearRequest,
    ) -> mpsc::Receiver<Result<LinearResponse, ServeError>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (job, rrx) = self.make_job(id, model, req);
        self.chan.push_unchecked(Job::Linear(job));
        rrx
    }

    /// Test hook: enqueue a forward past the shutdown flag (the drain
    /// path must answer it, never drop its responder).
    #[cfg(test)]
    pub(crate) fn submit_forward_behind_shutdown(
        &self,
        model: &str,
        req: ForwardRequest,
    ) -> mpsc::Receiver<Result<ForwardResponse, ServeError>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (job, rrx) = self.make_forward_job(id, model, req);
        self.chan.push_unchecked(Job::Forward(job));
        rrx
    }
}

impl Drop for AdmissionQueue {
    fn drop(&mut self) {
        let mut st = self.chan.lock();
        st.producer_gone = true;
        drop(st);
        self.chan.ready.notify_all();
    }
}

impl JobReceiver {
    /// Jobs admitted but not yet dequeued — the coalescer samples this at
    /// batch pick for the `exec.queue_depth` gauge (PR 9).
    pub(crate) fn depth(&self) -> usize {
        self.chan.lock().jobs
    }

    pub(crate) fn recv(&self) -> Result<Job, mpsc::RecvError> {
        let mut st = self.chan.lock();
        loop {
            if let Some(job) = self.chan.dequeue_and_wake(&mut st) {
                return Ok(job);
            }
            if st.producer_gone {
                return Err(mpsc::RecvError);
            }
            st = self.chan.ready.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub(crate) fn recv_timeout(&self, timeout: Duration) -> Result<Job, mpsc::RecvTimeoutError> {
        let deadline = Instant::now() + timeout;
        let mut st = self.chan.lock();
        loop {
            if let Some(job) = self.chan.dequeue_and_wake(&mut st) {
                return Ok(job);
            }
            if st.producer_gone {
                return Err(mpsc::RecvTimeoutError::Disconnected);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(mpsc::RecvTimeoutError::Timeout);
            }
            let (guard, _timed_out) = self
                .chan
                .ready
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    pub(crate) fn try_recv(&self) -> Result<Job, mpsc::TryRecvError> {
        let mut st = self.chan.lock();
        if let Some(job) = self.chan.dequeue_and_wake(&mut st) {
            return Ok(job);
        }
        if st.producer_gone {
            return Err(mpsc::TryRecvError::Disconnected);
        }
        Err(mpsc::TryRecvError::Empty)
    }
}

impl Drop for JobReceiver {
    fn drop(&mut self) {
        let mut st = self.chan.lock();
        st.receiver_gone = true;
        drop(st);
        // Blocked submitters must observe the dead receiver promptly.
        self.chan.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn req() -> LinearRequest {
        LinearRequest::new("w", Tensor::zeros(&[1, 4]))
    }

    /// With no consumer attached, admission beyond capacity is an
    /// explicit `Overloaded` — fully deterministic backpressure.
    #[test]
    fn overload_is_explicit_at_capacity() {
        let (q, _rx) = AdmissionQueue::bounded(2);
        assert_eq!(q.capacity(), 2);
        let _r1 = q.try_submit("m", req()).unwrap();
        let _r2 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::Overloaded);
        // Still overloaded, still explicit — nothing was buffered.
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::Overloaded);
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn shutdown_rejects_new_admissions() {
        let (q, rx) = AdmissionQueue::bounded(4);
        let _r = q.try_submit("m", req()).unwrap();
        q.begin_shutdown();
        assert!(q.is_shutting_down());
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::ShuttingDown);
        assert_eq!(q.submit("m", req()).unwrap_err(), AdmissionError::ShuttingDown);
        // The marker is queued exactly once, behind the admitted job.
        assert!(matches!(rx.recv().unwrap(), Job::Linear(_)));
        assert!(matches!(rx.recv().unwrap(), Job::Shutdown));
        q.begin_shutdown(); // idempotent — no second marker
        assert!(matches!(rx.try_recv(), Err(mpsc::TryRecvError::Empty)));
    }

    #[test]
    fn depth_tracks_consumption() {
        let (q, rx) = AdmissionQueue::bounded(3);
        let _r1 = q.try_submit("m", req()).unwrap();
        let _r2 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 2);
        let _ = rx.recv().unwrap();
        assert_eq!(q.depth(), 1);
        let _ = rx.try_recv().unwrap();
        assert_eq!(q.depth(), 0);
        // Capacity freed: admission works again.
        let _r3 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 1);
    }

    /// Forward jobs ride the same bounded channel: they count toward the
    /// depth bound and decrement it on consumption, exactly like linears.
    #[test]
    fn forward_jobs_share_the_depth_bound() {
        let (q, rx) = AdmissionQueue::bounded(2);
        let _r1 = q.try_submit_forward("m", ForwardRequest::new(vec![1, 2])).unwrap();
        let _r2 = q.try_submit("m", req()).unwrap();
        assert_eq!(q.depth(), 2);
        assert_eq!(
            q.try_submit_forward("m", ForwardRequest::new(vec![3])).unwrap_err(),
            AdmissionError::Overloaded
        );
        assert!(matches!(rx.recv().unwrap(), Job::Forward(_)));
        assert_eq!(q.depth(), 1);
        q.begin_shutdown();
        assert_eq!(
            q.submit_forward("m", ForwardRequest::new(vec![0])).unwrap_err(),
            AdmissionError::ShuttingDown
        );
    }

    #[test]
    fn dropped_receiver_reads_as_shutting_down() {
        let (q, rx) = AdmissionQueue::bounded(2);
        drop(rx);
        assert_eq!(q.try_submit("m", req()).unwrap_err(), AdmissionError::ShuttingDown);
    }

    /// PR 8 satellite regression: a submitter blocked on a *saturated*
    /// queue must wake with `ShuttingDown` the moment `begin_shutdown`
    /// fires — not stall until a drain slot frees.
    #[test]
    fn blocked_submitter_unblocks_promptly_on_shutdown() {
        let (q, _rx) = AdmissionQueue::bounded(1);
        let q = std::sync::Arc::new(q);
        let _held = q.try_submit("m", req()).unwrap(); // saturate
        let (done_tx, done_rx) = mpsc::channel();
        let q2 = q.clone();
        let blocked = std::thread::spawn(move || {
            let outcome = q2.submit("m", req()); // blocks: queue full
            done_tx.send(outcome.map(|_| ())).unwrap();
        });
        // Give the thread time to actually block on the full queue.
        std::thread::sleep(Duration::from_millis(30));
        assert!(matches!(done_rx.try_recv(), Err(mpsc::TryRecvError::Empty)));
        q.begin_shutdown();
        // Nothing was ever dequeued, yet the submitter must return.
        let outcome = done_rx
            .recv_timeout(Duration::from_secs(5))
            .expect("blocked submitter did not wake on shutdown");
        assert_eq!(outcome.unwrap_err(), AdmissionError::ShuttingDown);
        blocked.join().unwrap();
    }

    /// Per-model quotas shed the hot model only; cold models keep
    /// admitting until global capacity.
    #[test]
    fn quota_sheds_hot_model_only() {
        let opts = QueueOptions {
            quotas: QuotaConfig::new().with_limit("hot", 2),
            ..Default::default()
        };
        let (q, rx) = AdmissionQueue::bounded_with(8, opts);
        let _h1 = q.try_submit("hot", req()).unwrap();
        let _h2 = q.try_submit("hot", req()).unwrap();
        assert_eq!(q.try_submit("hot", req()).unwrap_err(), AdmissionError::QuotaExceeded);
        // Quota shed is immediate even on the blocking path.
        assert_eq!(q.submit("hot", req()).unwrap_err(), AdmissionError::QuotaExceeded);
        // Cold model admits freely.
        let _c1 = q.try_submit("cold", req()).unwrap();
        let _c2 = q.try_submit("cold", req()).unwrap();
        assert_eq!(q.depth(), 4);
        // Draining a hot job frees its quota slot.
        assert!(matches!(rx.recv().unwrap(), Job::Linear(_)));
        let _h3 = q.try_submit("hot", req()).unwrap();
        assert_eq!(q.try_submit("hot", req()).unwrap_err(), AdmissionError::QuotaExceeded);
    }

    /// An already-expired deadline is answered `DeadlineExceeded` at
    /// admission without occupying a queue slot.
    #[test]
    fn expired_deadline_answers_at_admission() {
        let (q, _rx) = AdmissionQueue::bounded(2);
        let stale = req().with_timeout(Duration::ZERO);
        let rrx = q.submit("m", stale).unwrap();
        assert_eq!(rrx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        assert_eq!(q.depth(), 0);
        let stale = ForwardRequest::new(vec![1]).with_timeout(Duration::ZERO);
        let rrx = q.try_submit_forward("m", stale).unwrap();
        assert_eq!(rrx.recv().unwrap().unwrap_err(), ServeError::DeadlineExceeded);
        assert_eq!(q.depth(), 0);
    }

    /// Injected admission rejections are deterministic by (seed, id) and
    /// read as `Overloaded`.
    #[test]
    fn injected_rejections_are_deterministic() {
        use crate::serve::fault::{FaultConfig, FaultInjector};
        let cfg = FaultConfig { seed: 11, reject_rate: 0.5, ..Default::default() };
        let oracle = FaultInjector::new(cfg.clone());
        let opts = QueueOptions {
            faults: Some(Arc::new(FaultInjector::new(cfg))),
            ..Default::default()
        };
        let (q, _rx) = AdmissionQueue::bounded_with(64, opts);
        let mut rejected = 0;
        for id in 0..32u64 {
            let got = q.try_submit("m", req());
            if oracle.injects_rejection(id) {
                assert_eq!(got.unwrap_err(), AdmissionError::Overloaded);
                rejected += 1;
            } else {
                assert!(got.is_ok());
            }
        }
        assert!(rejected > 0, "seed 11 should reject at least one of 32 ids");
        assert_eq!(q.faults().unwrap().counts().rejections, rejected);
    }
}
